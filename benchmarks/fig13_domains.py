"""Fig. 13 (ours): correlated failure survival — failure domains,
partition-safe repair, and brownout degradation.

fig11's heterogeneous cluster and steady load, but the chaos is
*correlated*: a whole failure domain (zone ``fast-d1`` — half the fast
tier) dies at once, or a network partition cuts the same nodes off
while they stay up.  Two axes, each run over the SAME arrival schedule:

  * **replication topology** — ``same`` (domain-blind replica placement:
    rendezvous order, so some groups put both copies in the doomed
    zone) vs ``spread`` (the tier declares ``domains=2`` and
    ``ReplicatedPlacement`` spreads replicas anti-affinity: every group
    keeps one copy per zone).  Under a zone kill or a cut, ``spread``
    always has a reachable replica to read from and dispatch to;
    ``same`` stalls on the groups it co-located.
  * **degradation policy** — ``shed`` (survivors run every stage at
    full cost; the lost capacity becomes deadline misses) vs
    ``brownout`` (stages declared a cheap degraded variant; sustained
    fault pressure drops low-priority stages to it, restoring full
    quality on recovery).  Capacity loss costs quality first,
    completions last.

A serving-engine slice runs fig12's row-chaos drive against the
split-brain epoch fence: every group re-route advances the group's
epoch, so a stale commit can never double-apply (``dup_effects`` and
``order_violations`` stay zero with the fence active).

Recorded acceptance (all deterministic):

  1. ZERO lost instances in every configuration — zone kills and
     partitions cost latency, never completions — and the serving slice
     holds ZERO dup effects / order violations with fence epochs live;
  2. ``spread`` p99 strictly below ``same`` p99 under BOTH the zone
     outage and the cut, and under the cut only the domain-blind run
     parks dispatches at the partition boundary
     (``partition_parked_dispatches`` > 0) — ``spread`` always has a
     majority-side replica lane and parks none;
  3. ``brownout`` completes strictly more on-deadline instances than
     ``shed`` at equal surviving capacity, degraded firings engage
     during the outage, and the level returns to 0 on recovery;
  4. fault-free behavior is byte-identical with brownout armed (the
     degradation hooks cost nothing until a fault arrives), and domain
     striping — which intentionally re-spreads second replicas — leaves
     the fault-free p50 identical and p99 within 0.5% (the anti-affinity
     premium is sync-traffic placement, not service time).
"""
import time

from .common import emit, write_chrome_trace

BASE_SLOTS = 4               # fast tier (H100), striped over 2 zones
SPARE_SLOTS = 2              # standby tier (exists; unused without autoscale)
SLO = 0.120                  # end-to-end deadline, seconds
RATE = 300.0                 # steady arrivals/s — inside 4 slots, over 2
DURATION = 2.0               # submission horizon, seconds
ZONE = "fast-d1"             # the doomed zone: fast1 + fast3
ZONE_NODES = ("fast1", "fast3")
KILL = (0.5, 0.6)            # zone outage: (t_down, duration)
CUT = (0.5, 0.6)             # partition: same window, nodes stay up
BROWNOUT = 0.25              # down-fraction per degradation level
INFER_COST = 0.016           # full-quality gpu service time
DEGRADED_COST = 0.004        # brownout variant (distilled/low-res path)


def build_graph(domains=1):
    """fig11's prep (cpu) -> infer (gpu) shape; ``domains=2`` stripes the
    fast tier over two zones (everything else byte-identical)."""
    from repro.runtime import GPU_A100, GPU_H100
    from repro.workflows import Emit, WorkflowGraph
    g = WorkflowGraph("domains")
    g.add_tier("fast", BASE_SLOTS, {"gpu": 1, "cpu": 2, "nic": 2},
               profile=GPU_H100, domains=domains)
    g.add_tier("slow", 0, {"gpu": 1, "cpu": 2, "nic": 2},
               profile=GPU_A100, spares=SPARE_SLOTS)
    pool_kw = dict(tier=("fast", "slow"), shards=BASE_SLOTS)
    g.add_pool("/req", **pool_kw)
    g.add_pool("/feat", **pool_kw)
    g.add_pool("/out", **pool_kw)
    g.add_stage("prep", pool="/req", resource="cpu", cost=0.002,
                emits=[Emit("/feat", fanout=1, size=256 * 1024)])
    g.add_stage("infer", pool="/feat", resource="gpu", cost=INFER_COST,
                degraded_cost=DEGRADED_COST, priority=0,
                emits=[Emit("/out", fanout=1, size=16 * 1024)], sink=True)
    return g.validate()


def submit_stream(wrt):
    n = int(DURATION * RATE)
    for i in range(n):
        wrt.submit(f"r{i}", at=0.05 + i / RATE, deadline=SLO)
    return n


def run_wf(fault, domains, mode="affinity", brownout=None, seed=0,
           tracing=False):
    """One configuration over the shared schedule.

    ``fault`` is ``None`` (healthy), ``"zone"`` (kill every node of
    ``ZONE`` at once), or ``"cut"`` (partition the same nodes off while
    they stay up).  ``domains=1`` is the domain-blind baseline: replicas
    placed by rendezvous order, chaos injected node-by-node on the same
    member set so both topologies face the identical outage.
    """
    from repro.workflows import WorkflowRuntime, mode_kwargs
    wrt = WorkflowRuntime(build_graph(domains), seed=seed,
                          read_replicas=2, brownout=brownout,
                          tracing=tracing, **mode_kwargs(mode))
    inj = wrt.enable_faults()
    if fault == "zone":
        at, dur = KILL
        if domains > 1:
            inj.fail_domain(ZONE, at=at, duration=dur)
        else:
            for node in ZONE_NODES:
                inj.fail_node(node, at=at, duration=dur)
    elif fault == "cut":
        at, dur = CUT
        inj.partition(((), ZONE_NODES), at=at, duration=dur)
    n = submit_stream(wrt)
    wrt.run()
    return wrt, inj, n


def _row(tag, wrt, inj, n_submitted, t0):
    s = wrt.summary()
    completed = s["n"]
    misses = s.get("slo_misses", 0)
    d = {
        "p50_ms": round(s["median"] * 1e3, 2),
        "p99_ms": round(s["p99"] * 1e3, 2),
        "on_deadline": completed - misses,
        "late_completions": misses,
        "completed": completed,
        "submitted": n_submitted,
        "lost_instances": n_submitted - completed,
        "failovers": s.get("fault_failovers", 0),
        "stalled": s.get("fault_stalled", 0),
        "repins": wrt.fault_repins,
        "fence_rejected": s.get("fence_rejected", 0),
        "wall_s": round(time.perf_counter() - t0, 3),
    }
    if "fault_domain_downtime_s" in s:
        d["zone_downtime_s"] = s["fault_domain_downtime_s"].get(ZONE, 0.0)
    if "fault_partition_s" in s:
        d["partition_s"] = s["fault_partition_s"]
        d["partition_blocked_gets"] = s["partition_blocked_gets"]
        d["partition_parked_dispatches"] = s["partition_parked_dispatches"]
    if wrt.brownout is not None:
        d["brownout_engagements"] = s["brownout_engagements"]
        d["degraded_firings"] = s["degraded_firings"]
        d["brownout_level_end"] = s["brownout_level"]
    return (f"fig13/{tag}", s["median"] * 1e6, d)


def run_serving_fence():
    """fig12's row-chaos drive with the split-brain fence live: every
    group re-route advances the group epoch; commits are token-checked."""
    from repro.runtime import FaultInjector, RetryPolicy
    from repro.serving import ServingEngine
    from .fig12_serving_chaos import DT, SVC, _model
    model, params = _model()
    eng = ServingEngine(model, params, n_rows=3, max_slots=8, max_seq=128,
                        policy="affinity", checkpoint_every=2)
    eng._svc = dict(SVC)
    eng.retry = RetryPolicy(max_attempts=4, backoff=2 * DT)
    inj = FaultInjector(serving=eng)
    inj.fail_row(0, at=40 * DT, duration=30 * DT)
    inj.fail_row(1, at=55 * DT, duration=30 * DT)
    n_sessions, turns = 6, 4
    for i in range(n_sessions):
        eng.open_session(f"s{i}")
    t = 0.0
    for _ in range(turns):
        for i in range(n_sessions):
            eng.turn(f"s{i}", [1 + i, 2, 3], gen_tokens=4, now=t)
            t += 2 * DT
    lost = sum(1 for s in eng.sessions.values() if s.turns != turns)
    return eng, inj, lost


def run(quick=True):
    rows = []
    p99 = {}
    on_time = {}
    lost = {}
    blocked = {}
    sig = {}            # fault-free identity signatures

    # -- fault-free: striping and brownout arming must cost nothing ------
    for tag, kw in (("healthy", dict(domains=2, brownout=BROWNOUT)),
                    ("healthy/unarmed", dict(domains=2)),
                    ("healthy/flat", dict(domains=1))):
        t0 = time.perf_counter()
        wrt, inj, n = run_wf(None, **kw)
        rows.append(_row(tag, wrt, inj, n, t0))
        s = wrt.summary()
        sig[tag] = (s["n"], s["median"], s["p99"])
        lost[tag] = n - s["n"]

    # -- replication topology under correlated chaos ---------------------
    for fault in ("zone", "cut"):
        for tag, domains in (("same", 1), ("spread", 2)):
            t0 = time.perf_counter()
            wrt, inj, n = run_wf(fault, domains)
            name = f"{tag}-{fault}"
            rows.append(_row(name, wrt, inj, n, t0))
            s = wrt.summary()
            p99[name] = s["p99"]
            lost[name] = n - s["n"]
            blocked[name] = s.get("partition_parked_dispatches", 0)

    # -- degradation policy at equal surviving capacity ------------------
    brown = {}
    for tag, kw in (("shed-zone", dict(brownout=None)),
                    ("brownout-zone", dict(brownout=BROWNOUT))):
        t0 = time.perf_counter()
        wrt, inj, n = run_wf("zone", 2, mode="atomic", **kw)
        rows.append(_row(tag, wrt, inj, n, t0))
        s = wrt.summary()
        on_time[tag] = s["n"] - s.get("slo_misses", 0)
        lost[tag] = n - s["n"]
        brown[tag] = s
    repair_engaged = all(brown[t]["fault_repins"] > 0 for t in brown)
    degraded = brown["brownout-zone"]["degraded_firings"]
    restored = brown["brownout-zone"]["brownout_level"] == 0
    engaged = brown["brownout-zone"]["brownout_engagements"] >= 1

    # -- serving slice: split-brain fence under row chaos ----------------
    t0 = time.perf_counter()
    eng, sinj, lost_sessions = run_serving_fence()
    rerouted = sum(ev.groups_rerouted for ev in sinj.events)
    rows.append(("fig13/serving-fence", 0.0, {
        "dup_effects": eng.dup_effects,
        "order_violations": eng.order_violations,
        "shed_turns": eng.shed_turns,
        "lost_sessions": lost_sessions,
        "groups_rerouted": rerouted,
        "fence_epochs": eng.fence.n_labels(),
        "fence_rejected": eng.fence.rejected,
        "wall_s": round(time.perf_counter() - t0, 3),
    }))
    fence_clean = (eng.dup_effects == 0 and eng.order_violations == 0
                   and lost_sessions == 0)
    fence_live = rerouted > 0 and eng.fence.n_labels() > 0

    # -- one traced cut run: where did the partition's latency go? -------
    t0 = time.perf_counter()
    wrt, inj, n = run_wf("cut", 1, tracing=True)
    s = wrt.summary()
    path, payload = write_chrome_trace(wrt.tracer, "fig13")
    rows.append(("fig13/trace/same-cut", s["median"] * 1e6, {
        "p99_ms": round(s["p99"] * 1e3, 2),
        "spans": s["spans"],
        "trace_events": len(payload["traceEvents"]),
        "blame_top": s["blame_top"],
        "blame_partition_stall_ms": s["blame_partition_stall_ms"],
        "artifact": path.name,
        "wall_s": round(time.perf_counter() - t0, 3)}))
    traced_matches = abs(s["p99"] - p99["same-cut"]) < 1e-12
    stall_blamed = s["blame_partition_stall_ms"] > 0.0

    # -- acceptance ------------------------------------------------------
    zero_lost = all(v == 0 for v in lost.values())
    spread_beats_same = (p99["spread-zone"] < p99["same-zone"]
                         and p99["spread-cut"] < p99["same-cut"])
    cut_parks_blind_only = blocked["same-cut"] > 0 \
        and blocked["spread-cut"] == 0
    brownout_beats_shed = on_time["brownout-zone"] > on_time["shed-zone"]
    armed_identical = sig["healthy"] == sig["healthy/unarmed"]
    striping_negligible = (
        sig["healthy"][0] == sig["healthy/flat"][0]
        and sig["healthy"][1] == sig["healthy/flat"][1]
        and abs(sig["healthy"][2] - sig["healthy/flat"][2])
        <= 0.005 * sig["healthy/flat"][2])
    rows.append(("fig13/acceptance", 0.0, {
        "zero_lost_instances": zero_lost,
        "fence_zero_dup_effects": fence_clean,
        "fence_epochs_advanced": fence_live,
        "spread_p99_beats_same_under_chaos": spread_beats_same,
        "cut_parks_domain_blind_only": cut_parks_blind_only,
        "brownout_on_deadline_beats_shed": brownout_beats_shed,
        "degraded_firings_engaged": degraded > 0 and engaged,
        "brownout_restored_on_recovery": restored,
        "repair_engaged": repair_engaged,
        "brownout_armed_byte_identical": armed_identical,
        "striping_fault_free_cost_negligible": striping_negligible,
        "traced_run_latency_identical": traced_matches,
        "partition_stall_blamed": stall_blamed,
    }))
    assert zero_lost and fence_clean and fence_live \
        and spread_beats_same and cut_parks_blind_only \
        and brownout_beats_shed and degraded > 0 and engaged \
        and restored and repair_engaged and armed_identical \
        and striping_negligible and traced_matches and stall_blamed, \
        rows[-1][2]
    return rows


if __name__ == "__main__":
    emit(run())
