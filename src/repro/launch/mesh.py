"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data x model).
Multi-pod: 2x16x16 = 512 chips (pod x data x model); the 'pod' axis crosses
DCN, 'data'/'model' stay on ICI.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# v5e-like hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s per link
HBM_BYTES = 16 * 2 ** 30          # 16 GiB
