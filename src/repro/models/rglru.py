"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Residual block layout follows Griffin: norm -> temporal-mixer -> residual,
where the mixer is the gated recurrent branch (linear -> causal conv ->
RG-LRU) multiplied by a GeLU branch, followed by an output projection.
Gates use block-diagonal linears (nb blocks) as in the reference Flax impl.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .common import ModelConfig, ParamFactory, scaled_init, zeros_init, ones_init
from . import layers

Params = Dict[str, Any]

GATE_BLOCKS = 16
LRU_C = 8.0


def init_rglru_block(pf: ParamFactory, cfg: ModelConfig):
    d, W, cw = cfg.d_model, cfg.lru_width, cfg.conv_width
    nb = GATE_BLOCKS
    layers.init_rmsnorm(pf, "ln", d)
    pf.param("w_x", (d, W), ("embed", "lru"), fan_in=d)
    pf.param("w_gate", (d, W), ("embed", "lru"), fan_in=d)
    pf.param("conv_w", (cw, W), ("conv", "lru"), fan_in=cw)
    pf.param("conv_b", (W,), ("lru",), init=zeros_init)
    pf.param("gate_a_w", (nb, W // nb, W // nb),
             ("lru_blocks", "lru_in", "lru_out"), fan_in=W // nb)
    pf.param("gate_a_b", (W,), ("lru",), init=zeros_init)
    pf.param("gate_x_w", (nb, W // nb, W // nb),
             ("lru_blocks", "lru_in", "lru_out"), fan_in=W // nb)
    pf.param("gate_x_b", (W,), ("lru",), init=zeros_init)
    pf.param("lam", (W,), ("lru",), init=ones_init)
    pf.param("w_out", (W, d), ("lru", "embed"), fan_in=W)


def _blockdiag(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """u: (..., W) -> (..., W) via block-diagonal linear (nb blocks)."""
    nb, bin_, bout = w.shape
    shp = u.shape
    ub = u.reshape(shp[:-1] + (nb, bin_))
    out = jnp.einsum("...ni,nio->...no", ub, w.astype(u.dtype))
    return out.reshape(shp[:-1] + (nb * bout,)) + b.astype(u.dtype)


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv; u (B,S,W), w (cw,W)."""
    cw = w.shape[0]
    out = u * w[-1].astype(u.dtype)
    for i in range(1, cw):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, :u.shape[1]]
        out = out + shifted * w[cw - 1 - i].astype(u.dtype)
    return out + b.astype(u.dtype)


def _gates(p: Params, cfg: ModelConfig, u: jax.Array):
    """Compute per-step decay a and input term b of the linear recurrence."""
    r = jax.nn.sigmoid(_blockdiag(u, p["gate_a_w"], p["gate_a_b"]))
    i = jax.nn.sigmoid(_blockdiag(u, p["gate_x_w"], p["gate_x_b"]))
    log_a = (-LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32))
             * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i.astype(jnp.float32) * u.astype(jnp.float32))
    return a.astype(u.dtype), b.astype(u.dtype)


def rglru_train(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = layers.rmsnorm(p["ln"], x, cfg.norm_eps)
    cd = cfg.compute_dtype
    u = h @ p["w_x"].astype(cd)
    g = jax.nn.gelu(h @ p["w_gate"].astype(cd))
    u = _causal_conv(u, p["conv_w"], p["conv_b"])
    a, b = _gates(p, cfg, u)
    hseq, _ = ops.rglru(a, b)
    out = (hseq * g) @ p["w_out"].astype(cd)
    return x + out


def rglru_prefill(p: Params, cfg: ModelConfig, x: jax.Array
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    h = layers.rmsnorm(p["ln"], x, cfg.norm_eps)
    cd = cfg.compute_dtype
    u_in = h @ p["w_x"].astype(cd)
    g = jax.nn.gelu(h @ p["w_gate"].astype(cd))
    u = _causal_conv(u_in, p["conv_w"], p["conv_b"])
    a, b = _gates(p, cfg, u)
    hseq, hfin = ops.rglru(a, b)
    out = (hseq * g) @ p["w_out"].astype(cd)
    cw = cfg.conv_width
    conv_state = u_in[:, -(cw - 1):, :]                       # last cw-1 inputs
    return x + out, {"h": hfin.astype(cd), "conv": conv_state}


def rglru_decode(p: Params, cfg: ModelConfig, x: jax.Array,
                 cache: Dict[str, jax.Array], lengths: jax.Array
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, d). cache: h (B,W), conv (B,cw-1,W)."""
    del lengths
    h = layers.rmsnorm(p["ln"], x[:, None, :], cfg.norm_eps)[:, 0]
    cd = cfg.compute_dtype
    u_in = h @ p["w_x"].astype(cd)                            # (B,W)
    g = jax.nn.gelu(h @ p["w_gate"].astype(cd))
    w = p["conv_w"].astype(cd)
    hist = jnp.concatenate([cache["conv"], u_in[:, None, :]], axis=1)
    u = jnp.einsum("bcw,cw->bw", hist, w) + p["conv_b"].astype(cd)
    a, b = _gates(p, cfg, u[:, None, :])
    hnew, _ = ops.rglru_decode(a[:, 0], b[:, 0], cache["h"])
    out = (hnew * g) @ p["w_out"].astype(cd)
    return x + out, {"h": hnew.astype(cd), "conv": hist[:, 1:]}


def rglru_cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    del max_seq
    W, cw = cfg.lru_width, cfg.conv_width
    return {"h": jax.ShapeDtypeStruct((batch, W), cfg.compute_dtype),
            "conv": jax.ShapeDtypeStruct((batch, cw - 1, W),
                                         cfg.compute_dtype)}
