"""Per-affinity-group ordering and atomic group updates (paper §3.4).

Objects/tasks sharing an affinity key may need to be handled sequentially
and in order (e.g. frames of one video stream); groups with different keys
are independent and run in parallel.  Because a group lives entirely in one
shard, group-atomic multi-object updates need no cross-shard coordination —
the paper notes this fell out of the design for free.

These primitives are the correctness backbone of recovery: the workflow
runtime's ``exactly_once`` mode parks replayed firings in a
:class:`GroupSequencer` so failover/retry/hedge duplicates cannot reorder
a group's deliveries, and gang repair moves a stranded group's objects
through :meth:`AtomicGroupUpdate.move_group` so a mid-repair fault cannot
leave the group half-migrated.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

from .object_store import CascadeStore


class GroupSequencer:
    """FIFO execution order within each affinity group.

    ``admit(label, item)`` enqueues; ``ready(label)`` yields the next item
    only when the previous one for that group was ``complete``d.  Different
    labels never block each other.

    Memory is bounded by the number of labels with work *currently* in
    flight: a label's queue entry is pruned the moment it drains, and the
    busy marker is a set, so a sequencer that has seen a million distinct
    groups over a run's lifetime holds state only for the active ones.
    ``ready``/``complete``/``pending`` on an unknown (or pruned) label are
    cheap no-ops — callers retire labels without unregistering them.
    """

    def __init__(self):
        self._queues: Dict[str, Deque[Any]] = {}
        self._busy: set = set()
        self.max_queue_len: int = 0

    def admit(self, label: str, item: Any) -> None:
        q = self._queues.get(label)
        if q is None:
            q = self._queues[label] = deque()
        q.append(item)
        self.max_queue_len = max(self.max_queue_len, len(q))

    def ready(self, label: str) -> Optional[Any]:
        if label in self._busy:
            return None
        q = self._queues.get(label)
        if not q:
            return None
        item = q.popleft()
        if not q:
            del self._queues[label]     # prune: bounded by in-flight labels
        self._busy.add(label)
        return item

    def complete(self, label: str) -> None:
        self._busy.discard(label)

    def pending(self, label: str) -> int:
        return (len(self._queues.get(label, ()))
                + (1 if label in self._busy else 0))

    def n_labels(self) -> int:
        """Labels currently holding any state (the memory bound)."""
        return len(self._queues.keys() | self._busy)

    def drain_ready(self) -> List[Tuple[str, Any]]:
        out = []
        for label in list(self._queues):
            item = self.ready(label)
            if item is not None:
                out.append((label, item))
        return out


class EpochFence:
    """Per-label monotonic epochs: the split-brain guard for repair and
    commit paths (Vortex-style lease fencing, localized per affinity
    group).

    Every authoritative action on a label — re-pinning its gang, claiming
    the right to drive its commits — first ``advance``s the label's epoch
    and carries the token it got back.  Any actor still holding an older
    token (a partitioned minority that observed the same failure, a
    repair scheduled before a later one superseded it) fails ``check``
    and must drop its action: a double-pin or double-commit becomes a
    counted rejection instead of divergent state.  Fault-free runs never
    advance past epoch 1 per label, and an unknown label always passes
    ``check`` at token 0, so the healthy path costs one dict lookup.
    """

    def __init__(self):
        self._epochs: Dict[str, int] = {}
        self.rejected = 0          # stale-token actions fenced off

    def current(self, label: str) -> int:
        return self._epochs.get(label, 0)

    def advance(self, label: str) -> int:
        e = self._epochs.get(label, 0) + 1
        self._epochs[label] = e
        return e

    def check(self, label: str, epoch: int) -> bool:
        """True iff ``epoch`` is still the label's newest token.  A stale
        token is counted in ``rejected`` — the caller must abandon the
        fenced action, not retry it with the same token."""
        if epoch == self._epochs.get(label, 0):
            return True
        self.rejected += 1
        return False

    def n_labels(self) -> int:
        return len(self._epochs)


class AtomicGroupUpdate:
    """All-or-nothing multi-put of objects sharing one affinity key.

    Single-shard residency makes this a local transaction: we verify every
    key homes to the same shard, then apply the batch under one version.
    A put that fails mid-batch rolls the already-applied prefix back to
    the pre-batch records, so readers never observe a partial group write.
    """

    def __init__(self, store: CascadeStore):
        self.store = store

    def apply(self, puts: List[Tuple[str, Any]]) -> str:
        if not puts:
            raise ValueError("empty atomic update")
        shards = {self.store.shard_of(k).name for k, _ in puts}
        labels = {self.store.affinity_of(k) for k, _ in puts}
        if len(labels) != 1:
            raise ValueError(f"atomic update spans affinity groups: {labels}")
        if len(shards) != 1:
            raise ValueError(f"group split across shards: {shards}")
        # stage: snapshot every record this batch may touch (replicas
        # included) before mutating anything
        prior = []
        for k, _ in puts:
            for pool in self.store.pools.values():
                if not k.startswith(pool.prefix):
                    continue
                for shard in pool.shards.values():
                    prior.append((shard, k, shard.objects.get(k)))
        try:
            for k, v in puts:
                self.store.put(k, v, fire=False)
        except Exception:
            # commit failed: restore the staged snapshot so the group is
            # either fully updated or untouched
            for shard, k, rec in prior:
                if rec is None:
                    shard.objects.pop(k, None)
                else:
                    shard.objects[k] = rec
            raise
        return labels.pop()

    # -- gang-repair commit --------------------------------------------------

    def move_group(self, pool, label: str,
                   moves: List[Tuple[Any, str, Any]],
                   keep_source: bool = False) -> int:
        """All-or-nothing relocation of one group's records within ``pool``.

        ``moves`` is ``[(src_shard, key, record), ...]``; every record must
        carry affinity ``label`` and every key must home to one destination
        shard (single-shard residency is what makes the commit local).
        Validation happens before any mutation; the commit itself is plain
        dict surgery that cannot fail midway, so repair never leaves a
        group with some objects moved and some stranded.  Returns the
        number of records moved.
        """
        if not moves:
            raise ValueError("empty atomic move")
        homes = {pool.home(k).name for _, k, _ in moves}
        if len(homes) != 1:
            raise ValueError(f"group move split across shards: {homes}")
        labels = {rec.affinity for _, _, rec in moves}
        if labels != {label}:
            raise ValueError(
                f"atomic move spans affinity groups: {labels} != {label!r}")
        home = pool.shards[homes.pop()]
        for src, key, rec in moves:            # staged: commit cannot fail
            home.objects[key] = rec
            if not keep_source and src.name != home.name:
                del src.objects[key]
        return len(moves)
