"""Latency blame attribution over :mod:`repro.runtime.tracing` spans.

``decompose`` turns one completed :class:`~repro.runtime.tracing.
InstanceTrace` into an **exclusive** split of its end-to-end latency
across :data:`~repro.runtime.tracing.CATEGORIES`.  The algorithm is an
interval sweep, not per-span summing: all spans are clipped to the
instance's ``[t_submit, t_complete]`` window, the window is cut at every
span boundary, and each elementary interval is charged to the highest-
priority category active over it (compute beats network beats stalls
beats passive waits — except ``partition_stall``, which beats the
coarse network spans that cover the same held interval); intervals
covered by nothing are charged to ``other``.  Because the elementary intervals partition the window
exactly, the per-category durations sum to the e2e latency **by
construction** — concurrency (fan-out stages running in parallel),
overlap (a hedge racing a stall) and double-recording cannot break the
invariant, only shift time between categories.

``critical_path`` returns that winning-segment timeline itself: the
contiguous chain of (category, span-name, t0, t1) segments from submit
to completion — "what was this instance waiting on at every instant",
which is the causal path a per-stage profile (InferLine) or an
interference diagnosis (ODIN) starts from.

``BlameTable`` aggregates decompositions across instances: exact float
totals per category (for shares) plus bounded
:class:`repro.runtime.StageStats` sketches per category (for tails),
serializable into BENCH records via ``StageStats.to_dict``.
"""
from __future__ import annotations

import heapq
from typing import Any, Dict, List, Tuple

from repro.runtime.stats import StageStats
from repro.runtime.tracing import CATEGORIES, InstanceTrace, priority

Segment = Tuple[str, str, float, float]     # (category, name, t0, t1)


def timeline(trace: InstanceTrace) -> List[Segment]:
    """The winning-segment partition of ``[t_submit, t_complete]``.

    Every instant of the window appears in exactly one segment; a
    segment's category is the highest-priority span active there
    (``other`` where no span covers).  Adjacent segments with the same
    category and name are coalesced.
    """
    t0w, t1w = trace.t_submit, trace.t_complete
    assert t1w is not None, "timeline() needs a completed trace"
    if t1w <= t0w:
        return []
    clipped = []
    for sp in trace.spans:
        a, b = max(sp.t0, t0w), min(sp.t1, t1w)
        if b > a:
            clipped.append((a, b, priority(sp.cat), sp))
    if not clipped:
        return [("other", "uncovered", t0w, t1w)]
    cuts = {t0w, t1w}
    for a, b, _, _ in clipped:
        cuts.add(a)
        cuts.add(b)
    points = sorted(cuts)
    # sort spans once; walk them with a moving lower bound so the sweep
    # is O((n + k) log n) over n spans and k cut points
    clipped.sort(key=lambda e: e[0])
    out: List[Segment] = []
    idx = 0
    heap: List[Tuple[int, float, int, Any]] = []
    seq = 0
    for i in range(len(points) - 1):
        a, b = points[i], points[i + 1]
        while idx < len(clipped) and clipped[idx][0] <= a:
            ca, cb, prio, sp = clipped[idx]
            heapq.heappush(heap, (prio, -cb, seq, sp))
            seq += 1
            idx += 1
        # drop spans that ended at or before this interval's start
        while heap and -heap[0][1] <= a:
            heapq.heappop(heap)
        if heap:
            prio, negend, _, sp = heap[0]
            cat, name = sp.cat, sp.name
        else:
            cat, name = "other", "uncovered"
        if out and out[-1][0] == cat and out[-1][1] == name and \
                out[-1][3] == a:
            out[-1] = (cat, name, out[-1][2], b)
        else:
            out.append((cat, name, a, b))
    return out


def decompose(trace: InstanceTrace) -> Dict[str, float]:
    """Exclusive per-category seconds summing exactly to e2e latency."""
    out = {c: 0.0 for c in CATEGORIES}
    for cat, _, a, b in timeline(trace):
        out[cat] += b - a
    return out


def critical_path(trace: InstanceTrace) -> List[Segment]:
    """The causal wait chain from submit to completion (see module doc).

    Identical partition to :func:`timeline`; exposed under the name the
    analysis reads as.  Segments are contiguous: ``seg[i][3] ==
    seg[i+1][2]``, the first starts at ``t_submit``, the last ends at
    ``t_complete``.
    """
    return timeline(trace)


class BlameTable:
    """Aggregate blame decompositions across completed instances.

    Registered as a ``TraceRecorder.on_complete`` hook, so every sampled
    completed instance lands here regardless of trace retention — the
    aggregate covers the full sampled population while raw spans stay
    bounded by the recorder's reservoir.
    """

    def __init__(self):
        self.n = 0
        self.totals: Dict[str, float] = {c: 0.0 for c in CATEGORIES}
        self.stats: Dict[str, StageStats] = {c: StageStats()
                                             for c in CATEGORIES}
        self.e2e_total = 0.0

    def add(self, trace: InstanceTrace) -> Dict[str, float]:
        parts = decompose(trace)
        self.n += 1
        self.e2e_total += trace.e2e or 0.0
        for cat, dt in parts.items():
            self.totals[cat] += dt
            self.stats[cat].observe(dt)
        return parts

    def merge(self, other: "BlameTable") -> "BlameTable":
        """Fold another table in (e.g. per-slot tables combined)."""
        self.n += other.n
        self.e2e_total += other.e2e_total
        for cat in CATEGORIES:
            self.totals[cat] += other.totals[cat]
            self.stats[cat].merge(other.stats[cat])
        return self

    def shares(self) -> Dict[str, float]:
        tot = sum(self.totals.values())
        if tot <= 0.0:
            return {c: 0.0 for c in CATEGORIES}
        return {c: v / tot for c, v in self.totals.items()}

    def dominant(self) -> str:
        """The category holding the most total time."""
        return max(CATEGORIES, key=lambda c: self.totals[c])

    def flat(self, prefix: str = "blame_") -> Dict[str, float]:
        """Flat per-instance-mean milliseconds per category (+ top), the
        shape benchmark rows and ``bench_explain`` diff."""
        out: Dict[str, Any] = {}
        n = max(self.n, 1)
        for cat in CATEGORIES:
            out[f"{prefix}{cat}_ms"] = round(
                self.totals[cat] / n * 1e3, 4)
        out[f"{prefix}top"] = self.dominant()
        out[f"{prefix}n"] = self.n
        return out

    def to_dict(self) -> Dict[str, Any]:
        """Full serialization (exact totals + per-category sketches)."""
        return {
            "n": self.n,
            "e2e_total_s": self.e2e_total,
            "totals_s": dict(self.totals),
            "shares": self.shares(),
            "dominant": self.dominant(),
            "stats": {c: st.to_dict() for c, st in self.stats.items()
                      if st.count},
        }
