"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import sys
import time
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"
ARTIFACTS.mkdir(exist_ok=True)


def run_rcp(grouped, layout, scenes, n_frames, caching=True, net=None,
            scheduler=None, replication=1, seed=0, placement="hash",
            read_replicas=1, migrate_every=None, straggler=None):
    from repro.pipelines.rcp.app import Layout, RCPApp
    from repro.pipelines.rcp.data import make_scene
    from repro.runtime import RandomScheduler, set_straggler
    lay = Layout(*layout, replication=replication)
    kw = {"net": net} if net is not None else {}
    app = RCPApp([make_scene(s, n_frames) for s in scenes], lay,
                 grouped=grouped,
                 scheduler=scheduler if scheduler is not None
                 else (None if grouped else RandomScheduler(seed)),
                 caching=caching, seed=seed, placement=placement,
                 read_replicas=read_replicas, migrate_every=migrate_every,
                 **kw)
    if straggler is not None:                  # (node, speed), e.g. ("pred0", 0.3)
        set_straggler(app.rt, *straggler)
    app.stream()
    t0 = time.perf_counter()
    app.run()
    wall = time.perf_counter() - t0
    s = app.summary(warmup=min(100, n_frames // 3))
    s["sim_wall_s"] = wall
    return s


def emit(rows):
    """Print ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        d = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{us:.1f},{d}")


def write_bench_json(suite: str, rows, wall_s: float) -> Path:
    """Write ``BENCH_<suite>.json`` — the machine-readable benchmark record.

    One file per suite under ``benchmarks/artifacts/`` (uploaded as a CI
    artifact) so the perf trajectory — p50/p99/SLO-hit/wall-clock per
    config — is diffable across PRs instead of living in CI logs.

    ``BENCH_fig7.json`` / ``BENCH_fig8.json`` are golden-file style: the
    committed copies are the current PR's reference numbers and each perf
    PR refreshes them (that IS the trajectory record); a local run
    rewriting them is expected — commit the refresh or discard it, like
    any golden file.  Every other suite's record is gitignored.
    """
    import json
    payload = {
        "suite": suite,
        "wall_s": round(wall_s, 3),
        "rows": [{"name": name, "us_per_call": round(us, 1), **derived}
                 for name, us, derived in rows],
    }
    path = ARTIFACTS / f"BENCH_{suite}.json"
    path.write_text(json.dumps(payload, indent=1, sort_keys=True))
    return path
