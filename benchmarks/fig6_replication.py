"""Paper Fig. 6: replication (nodes per shard) vs affinity+many-shards."""
from .common import emit, run_rcp

SCENES = ("little3", "hyang5", "gates3")


def run(quick=True):
    frames = 150 if quick else 700
    cases = [
        ("3/5/5_r1_affinity", True, (3, 5, 5), 1),
        ("3/5/5_r1_random", False, (3, 5, 5), 1),
        ("1/1/1_r3", True, (1, 1, 1), 3),
        ("1/3/3_r2_affinity", True, (1, 3, 3), 2),
        ("1/3/3_r2_random", False, (1, 3, 3), 2),
    ]
    rows = []
    for name, grouped, layout, repl in cases:
        s = run_rcp(grouped, layout, SCENES, frames, replication=repl)
        rows.append((f"fig6/{name}", s["median"] * 1e6,
                     {"p95_ms": round(s["p95"] * 1e3, 1),
                      "remote_gets": s["remote_gets"]}))
    return rows


if __name__ == "__main__":
    emit(run())
