from .engine import Row, ServingEngine, TurnMetrics
from .sessions import Session, SessionRouter
from .adapters import AdapterStore, LoRAAdapter, apply_adapter, make_adapter
from . import kv_cache

__all__ = ["Row", "ServingEngine", "TurnMetrics", "Session", "SessionRouter",
           "AdapterStore", "LoRAAdapter", "apply_adapter", "make_adapter",
           "kv_cache"]
