"""Training substrate: loop, checkpoint/restart determinism, compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.shapes import ShapeConfig
from repro.training import (AdamWConfig, TokenPipeline, TrainConfig, Trainer,
                            checkpointing, compression, lr_at)
from repro.training.data import DataConfig

SHAPE = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")


def make_trainer(tmp_path=None, steps=6, arch="granite-3-2b", seed=0):
    tc = TrainConfig(n_steps=steps, ckpt_every=3, log_every=100,
                     ckpt_dir=str(tmp_path) if tmp_path else None, seed=seed)
    return Trainer(configs.get_smoke(arch), SHAPE, tc)


def test_loss_decreases():
    tr = make_trainer(steps=20)
    hist = tr.run()
    first = np.mean([h["loss"] for h in hist[:4]])
    last = np.mean([h["loss"] for h in hist[-4:]])
    assert np.isfinite(last) and last < first


def test_checkpoint_restart_bit_identical(tmp_path):
    """Run 6 straight vs 3 + crash + restore + 3: identical loss traces."""
    straight = make_trainer(steps=6).run()

    tr = make_trainer(tmp_path, steps=6)
    with pytest.raises(RuntimeError):
        tr.run(crash_at=3)
    tr.ckpt.wait()
    tr2 = make_trainer(tmp_path, steps=6)     # restores from step 3
    assert tr2.step == 3
    resumed = tr2.run(n_steps=3)
    a = [round(h["loss"], 5) for h in straight[3:]]
    b = [round(h["loss"], 5) for h in resumed]
    assert a == b


def test_checkpoint_rotation(tmp_path):
    state = {"w": jnp.arange(4.0)}
    for step in (1, 2, 3, 4, 5):
        checkpointing.save_checkpoint(str(tmp_path), step, state, keep=2)
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [4, 5]


def test_checkpoint_checksum_detects_corruption(tmp_path):
    state = {"w": jnp.arange(4.0)}
    path = checkpointing.save_checkpoint(str(tmp_path), 1, state)
    blob = (path / "arrays.npz").read_bytes()
    (path / "arrays.npz").write_bytes(blob[:-2] + b"xx")
    with pytest.raises(AssertionError):
        checkpointing.restore_checkpoint(str(tmp_path), state)


def test_data_pipeline_deterministic_and_resumable():
    c = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    p1 = TokenPipeline(c)
    batches = [p1.next_batch() for _ in range(5)]
    p2 = TokenPipeline(c)
    p2.restore({"step": 3})
    np.testing.assert_array_equal(p2.next_batch()["tokens"],
                                  batches[3]["tokens"])


def test_data_pipeline_dp_shards_differ():
    mk = lambda r: TokenPipeline(DataConfig(
        vocab_size=100, seq_len=16, global_batch=8, seed=7, dp_rank=r,
        dp_size=2))
    b0, b1 = mk(0).next_batch(), mk(1).next_batch()
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_at(cfg, jnp.array(0))) == 0.0
    assert float(lr_at(cfg, jnp.array(10))) == pytest.approx(1e-3)
    assert float(lr_at(cfg, jnp.array(100))) == pytest.approx(
        1e-3 * cfg.min_lr_ratio, rel=1e-3)


def test_int8_quantization_roundtrip(rng):
    x = jnp.asarray(rng.normal(0, 3, (64, 64)), jnp.float32)
    q, s = compression.quantize_int8(x)
    err = np.abs(np.asarray(compression.dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_compression_saves_bytes():
    grads = {"a": jnp.zeros((1000,)), "b": jnp.zeros((50, 50))}
    full, comp = compression.dcn_bytes_saved(grads)
    assert comp < full / 3.5
