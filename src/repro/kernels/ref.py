"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *semantics* — each Pallas kernel in ``flash_attention.py`` /
``decode_attention.py`` / ``ssd_scan.py`` / ``rglru_scan.py`` must match the
corresponding function here (asserted in ``tests/test_kernels.py``).  The
model zoo calls them through ``repro.kernels.ops`` which dispatches between
this reference path (CPU / dry-run) and the Pallas path (TPU target).

Shape conventions:
  B batch, S query seq, T key seq, H query heads, K kv heads, D head dim,
  P ssd head dim, G ssd groups, N ssd state dim, W lru width.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _softcap(logits, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(logits / cap) * cap
    return logits


# ---------------------------------------------------------------------------
# Multi-head attention (train / prefill): causal, local-window, bidirectional
# ---------------------------------------------------------------------------

def mha(
    q: jax.Array,              # (B, S, H, D)
    k: jax.Array,              # (B, T, K, D)
    v: jax.Array,              # (B, T, K, Dv)
    *,
    causal: bool = True,
    window: int = 0,           # >0: local attention (last `window` keys)
    softcap: float = 0.0,
    scale: Optional[float] = None,
    q_offset: int = 0,         # absolute position of q[0] (chunked prefill)
    q_chunk: int = 0,          # >0: process queries in blocks of this size
    unroll: bool = False,      # unroll the q-block loop (exact HLO cost)
) -> jax.Array:
    B, S, H, D = q.shape
    if q_chunk and 0 < q_chunk < S and S % q_chunk == 0:
        nq = S // q_chunk
        qb = q.reshape(B, nq, q_chunk, H, D)

        if unroll:
            outs = [_mha_dense(qb[:, i], k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale,
                               q_offset=q_offset + i * q_chunk)
                    for i in range(nq)]
            return jnp.concatenate(outs, axis=1)

        def body2(_, xs):
            i, qi = xs
            o = _mha_dense_dyn(qi, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale,
                               q_offset_dyn=q_offset + i * q_chunk)
            return None, o
        idx = jnp.arange(nq)
        _, outs = jax.lax.scan(body2, None, (idx, jnp.moveaxis(qb, 1, 0)))
        return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, v.shape[-1])
    return _mha_dense(q, k, v, causal=causal, window=window, softcap=softcap,
                      scale=scale, q_offset=q_offset)


def _mha_dense(q, k, v, *, causal, window, softcap, scale, q_offset):
    B, S, H, D = q.shape
    qpos = jnp.arange(S)[:, None] + q_offset                # (S,1)
    return _mha_core(q, k, v, qpos, causal=causal, window=window,
                     softcap=softcap, scale=scale)


def _mha_dense_dyn(q, k, v, *, causal, window, softcap, scale, q_offset_dyn):
    S = q.shape[1]
    qpos = jnp.arange(S)[:, None] + q_offset_dyn
    return _mha_core(q, k, v, qpos, causal=causal, window=window,
                     softcap=softcap, scale=scale)


def _mha_core(q, k, v, qpos, *, causal, window, softcap, scale):
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    g = H // K
    scale = scale if scale is not None else D ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # GQA: group query heads over kv heads.
    qf = qf.reshape(B, S, K, g, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qf, kf)        # (B,K,g,S,T)
    logits = _softcap(logits, softcap)

    kpos = jnp.arange(T)[None, :]                           # (1,T)
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window and window > 0:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, vf)
    return out.reshape(B, S, H, vf.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention: one query token against a (possibly partial) KV cache
# ---------------------------------------------------------------------------

def decode_attention(
    q: jax.Array,              # (B, H, D)
    k_cache: jax.Array,        # (B, Smax, K, D)
    v_cache: jax.Array,        # (B, Smax, K, D)
    lengths: jax.Array,        # (B,) int32 — valid cache entries per row
    *,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    window: int = 0,
) -> jax.Array:
    B, H, D = q.shape
    Smax, K = k_cache.shape[1], k_cache.shape[2]
    g = H // K
    scale = scale if scale is not None else D ** -0.5

    qf = (q.astype(jnp.float32) * scale).reshape(B, K, g, D)
    logits = jnp.einsum("bkgd,btkd->bkgt", qf, k_cache.astype(jnp.float32))
    logits = _softcap(logits, softcap)
    pos = jnp.arange(Smax)[None]                            # (1,Smax)
    mask = pos < lengths[:, None]
    if window and window > 0:
        mask &= pos >= (lengths[:, None] - window)
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(B, H, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality) — chunked algorithm
# ---------------------------------------------------------------------------

def ssd(
    x: jax.Array,              # (B, S, H, P)
    dt: jax.Array,             # (B, S, H)  — already softplus'd, > 0
    A: jax.Array,              # (H,)       — negative
    Bm: jax.Array,             # (B, S, G, N)
    Cm: jax.Array,             # (B, S, G, N)
    D: Optional[jax.Array] = None,   # (H,) skip connection
    *,
    chunk: int = 256,
    init_state: Optional[jax.Array] = None,  # (B, H, P, N)
    unroll: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S_in, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert H % G == 0
    hpg = H // G
    L = min(chunk, S_in)
    if S_in % L:
        # pad with dt=0 steps: decay exp(0)=1, zero input — exact no-ops
        pad = L - S_in % L
        z = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +
                              [(0, 0)] * (a.ndim - 2))
        x, dt, Bm, Cm = z(x), z(dt), z(Bm), z(Cm)
    S = x.shape[1]
    nc = S // L

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    # expand groups to heads once
    Bh = Bm.astype(jnp.float32)
    Ch = Cm.astype(jnp.float32)
    if G != H:
        Bh = jnp.repeat(Bh, hpg, axis=2)
        Ch = jnp.repeat(Ch, hpg, axis=2)

    # chunked views (chunk axis first for the scan)
    xc = jnp.moveaxis(xf.reshape(Bsz, nc, L, H, P), 1, 0)
    dtc = jnp.moveaxis(dtf.reshape(Bsz, nc, L, H), 1, 0)
    Bc = jnp.moveaxis(Bh.reshape(Bsz, nc, L, H, N), 1, 0)
    Cc = jnp.moveaxis(Ch.reshape(Bsz, nc, L, H, N), 1, 0)

    tri = jnp.tril(jnp.ones((L, L), dtype=bool))
    h0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def one_chunk(h, inp):
        xi, dti, Bi, Ci = inp            # (B,L,H,P),(B,L,H),(B,L,H,N)x2
        dA = dti * Af[None, None, :]                        # (B,L,H) <= 0
        cum = jnp.cumsum(dA, axis=1)                        # inclusive
        # intra-chunk: decay(i,j) = exp(cum_i - cum_j), j <= i
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])
        decay = jnp.where(tri[None, :, :, None], decay, 0.0)
        cb = jnp.einsum("bihn,bjhn->bijh", Ci, Bi)          # (B,i,j,H)
        w = cb * decay * dti[:, None, :, :]
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xi)
        # inter-chunk contribution: C_i . exp(cum_i) h_prev
        y_inter = jnp.einsum("bihn,bih,bhpn->bihp", Ci, jnp.exp(cum), h)
        # chunk-final state update
        last = cum[:, -1:, :]                               # (B,1,H)
        sdecay = jnp.exp(last - cum) * dti                  # (B,L,H)
        states = jnp.einsum("blh,blhn,blhp->bhpn", sdecay, Bi, xi)
        h_new = h * jnp.exp(last[:, 0])[:, :, None, None] + states
        return h_new, y_intra + y_inter

    if unroll:
        h = h0
        ys = []
        for c in range(nc):
            h, y = one_chunk(h, (xc[c], dtc[c], Bc[c], Cc[c]))
            ys.append(y)
        final = h
        yall = jnp.stack(ys, axis=0)
    else:
        final, yall = jax.lax.scan(one_chunk, h0, (xc, dtc, Bc, Cc))

    y = jnp.moveaxis(yall, 0, 1).reshape(Bsz, S, H, P)
    if D is not None:
        y = y + xf * D.astype(jnp.float32)[None, None, :, None]
    return y[:, :S_in].astype(x.dtype), final


def ssd_decode(
    x: jax.Array,              # (B, H, P)
    dt: jax.Array,             # (B, H)
    A: jax.Array,              # (H,)
    Bm: jax.Array,             # (B, G, N)
    Cm: jax.Array,             # (B, G, N)
    D: Optional[jax.Array],
    state: jax.Array,          # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """One recurrent SSD step. Returns (y (B,H,P), new_state)."""
    B, H, P = x.shape
    G, N = Bm.shape[1], Bm.shape[2]
    hpg = H // G
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bh = jnp.repeat(Bm, hpg, axis=1).astype(jnp.float32)    # (B,H,N)
    Ch = jnp.repeat(Cm, hpg, axis=1).astype(jnp.float32)
    dA = jnp.exp(dtf * A.astype(jnp.float32)[None])         # (B,H)
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dtf, Bh, xf)
    new_state = state.astype(jnp.float32) * dA[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new_state)
    if D is not None:
        y = y + xf * D.astype(jnp.float32)[None, :, None]
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# RG-LRU linear recurrence (Griffin / recurrentgemma)
# ---------------------------------------------------------------------------

def rglru(
    a: jax.Array,              # (B, S, W) — per-step decay in (0,1)
    b: jax.Array,              # (B, S, W) — per-step input term
    h0: Optional[jax.Array] = None,   # (B, W)
) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + b_t via associative scan.

    Returns (h (B,S,W), h_final (B,W)).
    """
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    if h0 is not None:
        # fold h0 into the first input term
        bf = bf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    ascan, bscan = jax.lax.associative_scan(combine, (af, bf), axis=1)
    return bscan.astype(a.dtype), bscan[:, -1]


def rglru_decode(a, b, h):
    """One step: a,b,h all (B, W)."""
    hf = (a.astype(jnp.float32) * h.astype(jnp.float32)
          + b.astype(jnp.float32))
    return hf.astype(a.dtype), hf
