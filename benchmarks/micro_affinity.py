"""Paper §4.3 microbenchmark: affinity-function matching overhead.

Cascade+Hyperscan reported <300 us mean; Python `re` over Table-1 patterns
is single-digit us — far inside the budget the paper establishes."""
import time

from .common import emit


def run(quick=True):
    from repro.core import Descriptor, InstrumentedAffinity, RegexAffinity
    n = 5000 if quick else 100000
    rows = []
    patterns = {
        "frame": (r"/[a-zA-Z0-9]+_", "/little3_42"),
        "actor": (r"/[a-zA-Z0-9]+_[0-9]+_", "/little3_7_42"),
    }
    for name, (pat, key) in patterns.items():
        fn = InstrumentedAffinity(RegexAffinity(pat))
        d = Descriptor.of(key)
        for _ in range(n):
            fn(d)
        rows.append((f"micro/regex_{name}", fn.stats.mean_us,
                     {"calls": fn.stats.calls,
                      "paper_budget_us": 300}))
    # placement decision end to end (regex + hash)
    from repro.core import CascadeStore
    store = CascadeStore([f"n{i}" for i in range(16)])
    store.create_object_pool("/positions", store.nodes, 16,
                             affinity_set_regex=r"/[a-z0-9]+_[0-9]+_")
    t0 = time.perf_counter()
    for i in range(n):
        store.pool_for("/positions/little3_7_42").home(
            f"/positions/little3_{i % 50}_42")
    us = (time.perf_counter() - t0) / n * 1e6
    rows.append(("micro/placement_decision", us, {"calls": n}))
    return rows


if __name__ == "__main__":
    emit(run())
