"""Trainer: jitted train step + data + async checkpointing + restart.

Runs on whatever mesh it is given (the CPU tests use a 1x1 local mesh; the
production launcher passes the pod mesh).  Fault tolerance: on start it
resumes from the newest checkpoint if one exists; `simulate_crash` in tests
kills the loop between steps and a fresh Trainer picks up byte-identically
(data pipeline state is checkpointed with the model).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import ShapeConfig
from repro.launch import steps as steplib
from repro.models.common import ModelConfig
from . import checkpointing as ckpt
from .data import DataConfig, TokenPipeline
from .optimizer import AdamWConfig, init_opt_state


@dataclasses.dataclass
class TrainConfig:
    n_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, model_cfg: ModelConfig, shape: ShapeConfig,
                 train_cfg: TrainConfig,
                 mesh: Optional[Any] = None,
                 ocfg: Optional[AdamWConfig] = None):
        self.model_cfg = model_cfg
        self.shape = shape
        self.tc = train_cfg
        self.mesh = mesh or jax.make_mesh((1, 1), ("data", "model"))
        self.bundle = steplib.make_train_step(model_cfg, shape, self.mesh,
                                              ocfg=ocfg)
        model = self.bundle.meta["model"]
        with self.mesh:
            self.step_fn = jax.jit(
                self.bundle.fn,
                in_shardings=steplib.to_shardings(
                    self.mesh, self.bundle.in_shardings),
                out_shardings=steplib.to_shardings(
                    self.mesh, self.bundle.out_shardings),
                donate_argnums=self.bundle.donate_argnums)
        params = model.init(jax.random.PRNGKey(train_cfg.seed))
        opt = init_opt_state(params, model_cfg.opt_state_dtype,
                             factored=model_cfg.opt_factored)
        self.state = {"params": params, "opt": opt}
        self.data = TokenPipeline(DataConfig(
            vocab_size=model_cfg.vocab_size, seq_len=shape.seq_len,
            global_batch=shape.global_batch, seed=train_cfg.seed,
            kind="audio" if model_cfg.frontend == "audio" else "lm",
            frontend_dim=model_cfg.frontend_dim))
        self.step = 0
        self.history: List[Dict[str, float]] = []
        self.ckpt = (ckpt.AsyncCheckpointer(train_cfg.ckpt_dir,
                                            keep=train_cfg.keep_ckpts)
                     if train_cfg.ckpt_dir else None)
        self._maybe_restore()

    # -- checkpoint/restore ---------------------------------------------------

    def _maybe_restore(self) -> None:
        if not self.tc.ckpt_dir:
            return
        last = ckpt.latest_step(self.tc.ckpt_dir)
        if last is None:
            return
        tree, manifest = ckpt.restore_checkpoint(self.tc.ckpt_dir,
                                                 self.state, step=last)
        self.state = tree
        self.step = int(manifest["step"])
        self.data.restore(manifest["meta"]["data"])

    def save(self) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save(self.step, self.state,
                       meta={"data": self.data.state(),
                             "arch": self.model_cfg.name})

    # -- loop -------------------------------------------------------------------

    def _device_batch(self, batch: Dict[str, np.ndarray]):
        full = {}
        for k, v in batch.items():
            if self.model_cfg.frontend == "vision" and k == "tokens":
                pass
            full[k] = jnp.asarray(v)
        if self.model_cfg.frontend == "vision":
            B = self.shape.global_batch
            full["patches"] = jnp.zeros(
                (B, self.model_cfg.n_patches, self.model_cfg.frontend_dim),
                jnp.bfloat16)
        return full

    def run(self, n_steps: Optional[int] = None,
            crash_at: Optional[int] = None) -> List[Dict[str, float]]:
        n = n_steps if n_steps is not None else self.tc.n_steps
        target = self.step + n
        while self.step < target:
            batch = self._device_batch(self.data.next_batch())
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step += 1
            rec = {"step": self.step, "loss": loss, "sec": dt,
                   "grad_norm": float(metrics.get("grad_norm", 0.0))}
            self.history.append(rec)
            if self.step % self.tc.log_every == 0:
                print(f"step {self.step:5d} loss {loss:8.4f} "
                      f"gnorm {rec['grad_norm']:8.3f} {dt*1e3:7.1f} ms")
            if self.tc.ckpt_dir and self.step % self.tc.ckpt_every == 0:
                self.save()
            if crash_at is not None and self.step >= crash_at:
                raise RuntimeError("simulated crash")   # fault drill
        if self.ckpt is not None:
            self.save()
            self.ckpt.wait()
        return self.history
