"""Continuous-batching serving engine with affinity-grouped session state.

Real JAX execution (any local device count) + a virtual clock for the
network/queue components we cannot measure on CPU:

  * each *row* models one data-parallel replica group: it owns params, a
    slotted decode cache, and a virtual busy-until time;
  * requests route through ``SessionRouter`` (affinity vs baselines);
  * a routed turn whose session state lives on another row pays a
    migration: real `read_slot`/`write_slot` tensor movement + virtual
    transfer time = state_bytes / interconnect_bw (the cost affinity
    routing exists to avoid);
  * decode is genuinely batched: one ``decode_step`` advances every active
    slot of the row by one token, and the *virtual* cost of a step is
    priced by the shared ``repro.runtime.batching.BatchCostModel`` — the
    same curve the workflow layer's StageBatcher uses — amortized over the
    row's active slots, so co-residency (what affinity routing maximizes)
    directly buys decode throughput.

Service times (prefill/decode-step) are measured on the real model once and
reused by the virtual clock, so relative policy effects are grounded.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.runtime.batching import BatchCostModel
from repro.runtime.simulation import (CLUSTER_NET, UNIFORM, HardwareProfile,
                                      NetProfile)
from . import kv_cache as kvc
from .adapters import AdapterStore, apply_adapter
from .sessions import Session, SessionRouter


@dataclasses.dataclass
class TurnMetrics:
    sid: str
    row: int
    migrated: bool
    migration_bytes: int
    ttft: float              # virtual seconds to first token
    decode_time: float       # virtual seconds for the remaining tokens
    tokens: int


class Row:
    def __init__(self, model: Model, params: Any, max_slots: int,
                 max_seq: int, profile: HardwareProfile = UNIFORM):
        self.model = model
        self.params = params
        self.cache = model.init_cache(max_slots, max_seq)
        self.lengths = jnp.zeros((max_slots,), jnp.int32)
        self.active = np.zeros((max_slots,), bool)
        self.slot_sid: List[Optional[str]] = [None] * max_slots
        self.busy_until = 0.0
        self.decoded_tokens = 0
        # backend tier: virtual decode time divides by the gpu speed, and
        # the tier's own batch curve (if declared) prices amortization
        self.profile = profile
        self.speed = profile.speed_of("gpu")
        self.cost_model = profile.cost_model()   # None -> engine-shared

    def free_slot(self) -> Optional[int]:
        for i, a in enumerate(self.active):
            if not a:
                return i
        return None

    def load(self) -> int:
        return int(self.active.sum())

    def backlog(self, now: float) -> float:
        """Virtual seconds of queued decode work still ahead of ``now`` —
        the row-scheduler analogue of a node's resource queue depth."""
        return max(0.0, self.busy_until - now)


class ServingEngine:
    def __init__(self, model: Model, params: Any, n_rows: int = 4,
                 max_slots: int = 8, max_seq: int = 256,
                 policy: str = "affinity",
                 net: NetProfile = CLUSTER_NET, seed: int = 0,
                 cost_model: Optional[BatchCostModel] = None,
                 row_profiles: Optional[Sequence[HardwareProfile]] = None,
                 tracer: Optional[Any] = None):
        self.model = model
        # optional repro.runtime.tracing.TraceRecorder: every turn becomes
        # one completed trace (queueing/migration/prefill/decode spans
        # telescoping exactly over the turn's virtual window)
        self.tracer = tracer
        profs = list(row_profiles or [])
        profs += [UNIFORM] * (n_rows - len(profs))
        self.rows = [Row(model, params, max_slots, max_seq,
                         profile=profs[i]) for i in range(n_rows)]
        self.router = SessionRouter(n_rows, policy=policy, seed=seed)
        self.adapters = AdapterStore(n_rows)
        self.net = net
        self.cost_model = cost_model or BatchCostModel(max_batch=max_slots)
        self.max_seq = max_seq
        self.sessions: Dict[str, Session] = {}
        self.metrics: List[TurnMetrics] = []
        self.state_bytes = kvc.session_cache_bytes(model, max_seq)
        self._decode = jax.jit(model.decode_step)
        self._decode_h = jax.jit(
            lambda p, c, t, l: model.decode_step(p, c, t, l,
                                                 return_hidden=True))
        self._prefill = jax.jit(model.prefill)
        self._svc = self._calibrate(params)

    # -- calibration -----------------------------------------------------------

    def _calibrate(self, params) -> Dict[str, float]:
        B = len(self.rows[0].active)
        tok = jnp.zeros((B,), jnp.int32)
        lens = jnp.zeros((B,), jnp.int32)
        cache = self.rows[0].cache
        out = self._decode(params, cache, tok, lens)
        jax.block_until_ready(out[0])
        t0 = time.perf_counter()
        for _ in range(3):
            out = self._decode(params, cache, tok, lens)
            jax.block_until_ready(out[0])
        step = (time.perf_counter() - t0) / 3
        return {"decode_step": step, "prefill_per_tok": step / 8}

    # -- public API ---------------------------------------------------------------

    def open_session(self, sid: str, adapter: Optional[str] = None) -> Session:
        s = Session(sid=sid, adapter=adapter)
        self.sessions[sid] = s
        return s

    def turn(self, sid: str, prompt: List[int], gen_tokens: int = 16,
             now: float = 0.0) -> Tuple[List[int], TurnMetrics]:
        """One chat turn: route, (maybe migrate), prefill, decode."""
        s = self.sessions[sid]
        req_id = f"{sid}:{s.turns}"
        # the row scheduler's load signal mirrors the DES schedulers'
        # pick_batch ranking (repro.runtime.scheduler.node_load): prefer
        # rows with a free lane first, then the shallowest virtual queue,
        # then the fewest co-resident sessions
        signals = [(0 if r.free_slot() is not None else 1,
                    r.backlog(now), r.load()) for r in self.rows]
        row_idx = self.router.route(s, req_id, row_loads=signals)
        # capacity overflow: spill to the best-signal row with a free slot
        if (s.row != row_idx
                and self.rows[row_idx].free_slot() is None):
            cands = [i for i, r in enumerate(self.rows)
                     if i == s.row or r.free_slot() is not None]
            row_idx = s.row if s.row in cands else \
                min(cands, key=lambda i: signals[i])
        row = self.rows[row_idx]

        t = max(now, row.busy_until)
        t_q = t                     # queue wait ends here
        mig_bytes = 0
        migrated = False
        # adapter residency (baselines fetch per row; affinity pins)
        mig_bytes += self.adapters.ensure_resident(row_idx, s.adapter)

        if s.row is not None and s.row != row_idx:
            # migrate session state between rows: real tensor movement
            src = self.rows[s.row]
            payload = kvc.read_slot(src.cache, s.slot)
            src.cache = kvc.clear_slot(src.cache, s.slot)
            src.active[s.slot] = False
            src.slot_sid[s.slot] = None
            slot = row.free_slot()
            assert slot is not None, "row full"
            row.cache = kvc.write_slot(row.cache, payload, slot)
            row.lengths = row.lengths.at[slot].set(s.length)
            mig_bytes += self.state_bytes
            migrated = True
            s.migrations += 1
            s.migrated_bytes += self.state_bytes
            s.row, s.slot = row_idx, slot
        elif s.row is None:
            slot = row.free_slot()
            assert slot is not None, "row full"
            s.row, s.slot = row_idx, slot
        slot = s.slot
        row.active[slot] = True
        row.slot_sid[slot] = sid

        t += self.net.transfer_time(mig_bytes) if mig_bytes else 0.0

        # prefill the prompt token-by-token through decode_step (keeps the
        # slotted cache layout; fine at test scale); like decode, virtual
        # prefill time divides by the row's tier speed
        toks = list(prompt)
        t_prefill = self._svc["prefill_per_tok"] * len(toks) / row.speed
        for tok in toks:
            row.cache, row.lengths = self._advance(row, slot, tok)
        # virtual step cost: the row's tier batch curve (engine-shared on
        # uniform rows) amortized over co-resident sessions — one real
        # decode_step advances every active slot, so a fuller row prices
        # each token cheaper — divided by the tier's gpu speed
        cm = row.cost_model or self.cost_model
        t_step = cm.step_seconds(self._svc["decode_step"],
                                 row.load()) / row.speed
        ttft = (t + t_prefill + t_step) - now

        out: List[int] = []
        adapter = (self.adapters.get(s.adapter) if s.adapter else None)
        tok = toks[-1] if toks else 0
        t_dec = 0.0
        for _ in range(gen_tokens):
            nxt, row.cache, row.lengths = self._decode_one(row, slot, tok,
                                                           adapter)
            out.append(int(nxt))
            tok = int(nxt)
            t_dec += t_step
            row.decoded_tokens += row.load()

        row.busy_until = t + t_prefill + t_dec
        s.length = int(row.lengths[slot])
        s.turns += 1
        m = TurnMetrics(sid=sid, row=row_idx, migrated=migrated,
                        migration_bytes=mig_bytes, ttft=ttft,
                        decode_time=t_dec, tokens=len(out))
        self.metrics.append(m)
        if self.tracer is not None:
            tr = self.tracer.begin(req_id, now)
            if tr is not None:
                rname = f"row{row_idx}"
                tracer = self.tracer
                tracer.span(tr, "queueing", "row_queue", now, t_q,
                            node=rname)
                tracer.span(tr, "migration", "session_migrate", t_q, t,
                            node=rname, args={"bytes": mig_bytes})
                tracer.span(tr, "compute", "prefill", t, t + t_prefill,
                            node=rname)
                tracer.span(tr, "compute", "decode", t + t_prefill,
                            row.busy_until, node=rname,
                            args={"tokens": len(out), "slots": row.load()})
                tracer.complete(tr, row.busy_until)
        return out, m

    # -- internals ---------------------------------------------------------------
    # Cache updates are committed per-slot through a mask so recurrent-state
    # families (SSM/LRU) never advance state for slots that didn't consume a
    # token this step.

    @staticmethod
    def _commit(old_cache, new_cache, mask):
        def sel(o, n):
            m = mask.reshape((1, -1) + (1,) * (o.ndim - 2))
            return jnp.where(m, n.astype(o.dtype), o)
        return jax.tree_util.tree_map(sel, old_cache, new_cache)

    def _advance(self, row: Row, slot: int, tok: int):
        """Feed one known token into the slot's cache (prefill path)."""
        B = len(row.active)
        toks = jnp.zeros((B,), jnp.int32).at[slot].set(tok)
        mask = jnp.zeros((B,), bool).at[slot].set(True)
        _, cache = self._decode(row.params, row.cache, toks, row.lengths)
        cache = self._commit(row.cache, cache, mask)
        lengths = row.lengths.at[slot].add(1)
        return cache, lengths

    def _decode_one(self, row: Row, slot: int, tok: int, adapter):
        B = len(row.active)
        toks = jnp.zeros((B,), jnp.int32).at[slot].set(tok)
        mask = jnp.zeros((B,), bool).at[slot].set(True)
        if adapter is not None:
            logits, cache, hidden = self._decode_h(
                row.params, row.cache, toks, row.lengths)
            logits = apply_adapter(logits, hidden, adapter)
        else:
            logits, cache = self._decode(row.params, row.cache, toks,
                                         row.lengths)
        cache = self._commit(row.cache, cache, mask)
        nxt = jnp.argmax(logits[slot], -1)
        lengths = row.lengths.at[slot].add(1)
        return nxt, cache, lengths

    # -- load-aware group rebalancing ---------------------------------------------

    def rebalance(self, imbalance: int = 2, max_moves: int = 1
                  ) -> List[Tuple[str, int]]:
        """Move whole session groups off overloaded rows.

        Mirrors the store-side ``GroupMigrator`` at the serving layer: when
        the hottest row holds `imbalance` more active sessions than the
        coldest, the smallest group on the hot row is pinned to the cold
        row.  Sessions follow their group lazily — each member's next turn
        routes to the new row and pays its state migration there (the
        engine's existing migration path), so no decode work is interrupted.
        Returns the (label, destination_row) moves made.
        """
        moves: List[Tuple[str, int]] = []
        # only affinity policies route through the placement engine, so only
        # they can honor a pin — anything else would report moves that
        # never take effect
        if self.router.policy not in ("affinity", "adapter_affinity"):
            return moves
        # migration is lazy (groups move on their next turn), so work on
        # *projected* loads — else the same group gets re-picked each pass
        loads = [r.load() for r in self.rows]
        moved_labels = set()
        for _ in range(max_moves):
            hot = max(range(len(loads)), key=lambda i: loads[i])
            cold = min(range(len(loads)), key=lambda i: loads[i])
            if loads[hot] - loads[cold] < imbalance:
                break
            groups: Dict[str, List[Session]] = {}
            for s in self.sessions.values():
                if s.row == hot:
                    lbl = self.router.label_of(s)
                    if lbl not in moved_labels:
                        groups.setdefault(lbl, []).append(s)
            if not groups:
                break
            # smallest group that still fits the cold row's free slots
            free = len(self.rows[cold].active) - loads[cold]
            cands = sorted(groups.items(), key=lambda kv: len(kv[1]))
            pick = next(((lbl, ss) for lbl, ss in cands if len(ss) <= free),
                        None)
            if pick is None:
                break
            label, members = pick
            self.router.pin_group(label, cold)
            moved_labels.add(label)
            loads[hot] -= len(members)
            loads[cold] += len(members)
            moves.append((label, cold))
        return moves

    # -- reporting ----------------------------------------------------------------

    def summary(self) -> Dict[str, float]:
        if not self.metrics:
            return {}
        ttfts = np.array([m.ttft for m in self.metrics])
        migs = sum(m.migrated for m in self.metrics)
        return {
            "turns": len(self.metrics),
            "ttft_mean": float(ttfts.mean()),
            "ttft_p95": float(np.percentile(ttfts, 95)),
            "migrations": migs,
            "migration_bytes": sum(m.migration_bytes for m in self.metrics),
            "adapter_fetch_bytes": self.adapters.bytes_fetched,
        }
