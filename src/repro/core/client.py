"""Developer-facing client API mirroring the paper's Listing 1.

    capi = ServiceClientAPI(store)
    capi.create_object_pool("/grouping", subgroup_type, 0,
                            affinity_set_regex="_[0-9]+")
    capi.put("/grouping/example_1", None)   # affinity key '_1'
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

from .object_store import CascadeStore, ObjectPool

VOLATILE = "VolatileCascadeStoreWithStringKey"
PERSISTENT = "PersistentCascadeStoreWithStringKey"


class ServiceClientAPI:
    def __init__(self, store: CascadeStore,
                 default_nodes: Optional[Sequence[str]] = None):
        self._store = store
        self._default_nodes = list(default_nodes or store.nodes)

    def create_object_pool(self, prefix: str,
                           subgroup_type: str = VOLATILE,
                           subgroup_index: int = 0,
                           affinity_set_regex: Optional[str] = None,
                           n_shards: Optional[int] = None,
                           nodes: Optional[Sequence[str]] = None,
                           replication: int = 1) -> ObjectPool:
        del subgroup_type, subgroup_index   # accepted for API fidelity
        nodes = list(nodes or self._default_nodes)
        n_shards = n_shards or max(len(nodes) // replication, 1)
        return self._store.create_object_pool(
            prefix, nodes, n_shards, replication=replication,
            affinity_set_regex=affinity_set_regex)

    def put(self, key: str, value: Any = None, **meta):
        return self._store.put(key, value, **meta)

    def get(self, key: str, node: Optional[str] = None):
        rec, _local = self._store.get(key, node=node)
        return None if rec is None else rec.value

    def trigger(self, key: str, value: Any = None, **meta):
        return self._store.trigger(key, value, **meta)

    def get_affinity_key(self, key: str) -> str:
        return self._store.affinity_of(key)
