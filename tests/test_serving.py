"""Serving engine: session/KV affinity (paper §7.2 applied)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import build_model
from repro.serving import ServingEngine, make_adapter


@pytest.fixture(scope="module")
def model_and_params():
    cfg = configs.get_smoke("granite-3-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def drive(engine, n_sessions=6, turns=3, gen=4):
    for i in range(n_sessions):
        engine.open_session(f"s{i}")
    t = 0.0
    outs = {}
    for turn in range(turns):
        for i in range(n_sessions):
            out, _ = engine.turn(f"s{i}", [1 + i, 2, 3], gen_tokens=gen,
                                 now=t)
            outs.setdefault(f"s{i}", []).extend(out)
            t += 0.001
    return outs


def test_affinity_policy_never_migrates(model_and_params):
    cfg, model, params = model_and_params
    eng = ServingEngine(model, params, n_rows=3, max_slots=4, max_seq=64,
                        policy="affinity")
    drive(eng)
    s = eng.summary()
    assert s["migrations"] == 0
    assert s["migration_bytes"] == 0


def test_random_policy_migrates_and_costs(model_and_params):
    cfg, model, params = model_and_params
    eng = ServingEngine(model, params, n_rows=3, max_slots=6, max_seq=64,
                        policy="random")
    drive(eng)
    s = eng.summary()
    assert s["migrations"] > 0
    assert s["migration_bytes"] > 0


def test_affinity_ttft_wins_when_state_is_expensive(model_and_params):
    """Production regime: a session's KV state is large relative to a
    decode step (GBs on real models), so any migration dominates TTFT.
    Modeled here by a slow interconnect; the smoke model's state is tiny,
    production caches are ~10^5x bigger."""
    from repro.runtime.simulation import NetProfile
    slow = NetProfile(bandwidth=1e6, rtt=0.25)
    cfg, model, params = model_and_params
    ea = ServingEngine(model, params, n_rows=3, max_slots=6, max_seq=64,
                       policy="affinity", net=slow)
    er = ServingEngine(model, params, n_rows=3, max_slots=6, max_seq=64,
                       policy="random", seed=1, net=slow)
    drive(ea)
    drive(er)
    assert ea.summary()["ttft_mean"] <= er.summary()["ttft_mean"]


def test_migration_preserves_generation(model_and_params):
    """Greedy decode must produce identical tokens regardless of routing —
    migrations move state, they must not change it."""
    cfg, model, params = model_and_params
    ea = ServingEngine(model, params, n_rows=3, max_slots=6, max_seq=64,
                       policy="affinity")
    er = ServingEngine(model, params, n_rows=3, max_slots=6, max_seq=64,
                       policy="random", seed=3)
    oa = drive(ea, n_sessions=4, turns=2)
    orr = drive(er, n_sessions=4, turns=2)
    assert oa == orr


def test_adapter_changes_logits(model_and_params):
    cfg, model, params = model_and_params
    eng = ServingEngine(model, params, n_rows=2, max_slots=4, max_seq=64,
                        policy="affinity")
    ad = make_adapter(jax.random.PRNGKey(1), "a1", cfg.d_model,
                      cfg.vocab_size)
    # standard LoRA init has B=0 (no-op); randomize B to make it active
    ad.B = jax.random.normal(jax.random.PRNGKey(2), ad.B.shape) * 2.0
    eng.adapters.register(ad)
    eng.open_session("plain")
    eng.open_session("tuned", adapter="a1")
    out_plain, _ = eng.turn("plain", [1, 2, 3], gen_tokens=6)
    out_tuned, _ = eng.turn("tuned", [1, 2, 3], gen_tokens=6)
    assert out_plain != out_tuned


def test_adapter_affinity_fetches_once(model_and_params):
    cfg, model, params = model_and_params
    eng = ServingEngine(model, params, n_rows=4, max_slots=8, max_seq=64,
                        policy="adapter_affinity")
    ad = make_adapter(jax.random.PRNGKey(1), "a1", cfg.d_model,
                      cfg.vocab_size)
    eng.adapters.register(ad)
    for i in range(6):
        eng.open_session(f"s{i}", adapter="a1")
    drive_sessions = [f"s{i}" for i in range(6)]
    for sid in drive_sessions:
        eng.turn(sid, [1, 2], gen_tokens=2)
    # all sessions share the adapter's affinity key -> one row, one fetch
    assert eng.adapters.fetches == 1


def _same_row_sids(router, k):
    """First ``k`` session ids the affinity policy homes on one row."""
    from repro.serving.sessions import Session
    buckets = {}
    for i in range(200):
        sid = f"sess{i}"
        r = router.route(Session(sid=sid), f"{sid}:0")
        buckets.setdefault(r, []).append(sid)
        if len(buckets[r]) == k:
            return r, buckets[r]
    raise AssertionError("no row collected k sessions")


def test_row_overflow_spills_to_best_free_row(model_and_params):
    """The row scheduler's overflow-spill path: a session whose affinity
    row is full must land on the best-signal row WITH a free slot instead
    of asserting on the full one."""
    cfg, model, params = model_and_params
    eng = ServingEngine(model, params, n_rows=2, max_slots=2, max_seq=64,
                        policy="affinity")
    home, sids = _same_row_sids(eng.router, 3)
    # occupy both slots of the affinity row
    for sid in sids[:2]:
        eng.open_session(sid)
        _, m = eng.turn(sid, [1], gen_tokens=1)
        assert m.row == home
    eng.open_session(sids[2])                   # same home row, now full
    _, m2 = eng.turn(sids[2], [1], gen_tokens=1)
    assert m2.row != home                       # spilled, not crashed
    assert eng.rows[m2.row].load() == 1


def test_row_overflow_spill_prefers_emptier_row(model_and_params):
    """With several spill candidates, the row scheduler's (free-lane,
    backlog, load) signal picks the least-loaded one."""
    cfg, model, params = model_and_params
    eng = ServingEngine(model, params, n_rows=3, max_slots=2, max_seq=64,
                        policy="affinity")
    home, sids = _same_row_sids(eng.router, 3)
    for sid in sids[:2]:
        eng.open_session(sid)
        eng.turn(sid, [1], gen_tokens=1)
    # make one non-home row busier than the other
    others = [i for i in range(3) if i != home]
    eng.rows[others[0]].busy_until = 10.0
    eng.open_session(sids[2])
    _, m = eng.turn(sids[2], [1], gen_tokens=1, now=0.5)
    assert m.row == others[1]


def test_heterogeneous_rows_price_decode_by_tier(model_and_params):
    """A faster row profile yields cheaper virtual decode time; the
    uniform default stays byte-identical to the pre-tier engine."""
    from repro.runtime import GPU_H100, UNIFORM
    cfg, model, params = model_and_params
    base = ServingEngine(model, params, n_rows=2, max_slots=2, max_seq=64,
                         policy="affinity")
    fast = ServingEngine(model, params, n_rows=2, max_slots=2, max_seq=64,
                         policy="affinity",
                         row_profiles=[GPU_H100, GPU_H100])
    # calibration is per-engine; pin identical service times for fairness
    fast._svc = dict(base._svc)
    uni = ServingEngine(model, params, n_rows=2, max_slots=2, max_seq=64,
                        policy="affinity", row_profiles=[UNIFORM])
    uni._svc = dict(base._svc)
    for eng in (base, fast, uni):
        eng.open_session("s0")
    _, mb = base.turn("s0", [1, 2], gen_tokens=4)
    _, mf = fast.turn("s0", [1, 2], gen_tokens=4)
    _, mu = uni.turn("s0", [1, 2], gen_tokens=4)
    assert mf.decode_time < mb.decode_time      # 2x gpu speed
    assert mu.decode_time == mb.decode_time     # uniform == identity


def test_turn_traces_decompose_to_e2e(model_and_params):
    """Every traced turn's spans telescope exactly over its virtual
    window and the blame decomposition sums to the turn's e2e; random
    routing must surface migration spans carrying the moved bytes."""
    from repro.runtime import TraceRecorder
    from repro.workflows import decompose

    cfg, model, params = model_and_params
    rec = TraceRecorder()
    eng = ServingEngine(model, params, n_rows=3, max_slots=6, max_seq=64,
                        policy="random", tracer=rec)
    drive(eng)
    traces = rec.traces()
    assert rec.n_completed == len(eng.metrics) == len(traces) == 18
    totals = {}
    for tr in traces:
        sid, turn = tr.instance.split(":")
        assert sid in eng.sessions and turn.isdigit()
        parts = decompose(tr)
        assert abs(sum(parts.values()) - tr.e2e) < 1e-9
        spans = sorted(tr.spans, key=lambda sp: sp.t0)
        assert spans and {sp.cat for sp in spans} >= {"compute"}
        # telescoping: first span opens at submit, last closes at
        # complete, no span starts before its predecessor ends
        assert spans[0].t0 >= tr.t_submit - 1e-12
        assert spans[-1].t1 == pytest.approx(tr.t_complete, abs=1e-12)
        for a, b in zip(spans, spans[1:]):
            assert b.t0 >= a.t1 - 1e-12
        for c, v in parts.items():
            totals[c] = totals.get(c, 0.0) + v
    assert totals["compute"] > 0.0
    migrated = [tr for tr in traces
                if any(sp.cat == "migration" for sp in tr.spans)]
    assert migrated, "random routing should migrate at least one turn"
    for tr in migrated:
        sp = next(s for s in tr.spans if s.cat == "migration")
        assert sp.name == "session_migrate" and sp.args["bytes"] > 0
    assert totals["migration"] > 0.0
