from .common import ModelConfig, count_params
from .model import Model, build_model

__all__ = ["ModelConfig", "Model", "build_model", "count_params"]
