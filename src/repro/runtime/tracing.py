"""Causal per-instance tracing: span records for every layer of the stack.

``TraceRecorder`` is the mechanism half of the observability layer (the
policy half — blame decomposition and critical-path extraction — lives in
``repro.workflows.blame``).  A recorder is attached to a simulator from
the *outside* (``sim.tracer = recorder``); the simulator never imports
this module and, with no recorder attached, pays exactly one attribute
check on the paths that could emit — tracing off is the byte-identical
hot path every benchmark already measures.

Design constraints, in order:

  * **The DES result must not change.**  Tracing only observes: sampling
    is a deterministic hash of the instance id (never ``sim.rng`` — a
    random draw would perturb every downstream seed), and no recorder
    call schedules events or touches node state.  Enabling tracing on a
    run reproduces every latency byte-for-byte.
  * **O(1) per event, bounded memory.**  A span append is a list append
    on a sampled instance's trace; unsampled instances cost one dict
    miss.  Completed traces are retained in a fixed-size reservoir plus
    a tail-biased top-K-by-latency heap (the p99 cohort is exactly what
    blame queries want), so memory is bounded by ``TraceConfig`` knobs,
    not horizon.
  * **Category spans, not log lines.**  Every span carries one of
    :data:`CATEGORIES` so the blame sweep can decompose an instance's
    end-to-end latency into exclusive buckets.  For raw simulator ops
    the work is split across the instance lifecycle: the step loop
    appends flat records of atomic values (``record_op`` — invisible to
    the GC), and ``materialize`` categorizes them (compute service vs
    lane wait vs down-node stall, local vs remote data ops, barrier
    waits) lazily — at completion when completion hooks consume spans,
    else when a retained trace is first read; traces that retention
    evicts unread are never categorized at all.

``export_chrome_trace`` emits Chrome trace-event JSON (``ph``/``ts``/
``dur``/``pid``/``tid``) loadable in Perfetto / ``chrome://tracing``:
one process per node, one thread per instance, global instants (node
death, scale decisions) on a synthetic cluster track.
"""
from __future__ import annotations

import dataclasses
import heapq
import json
import random
import zlib
from typing import (Any, Callable, Dict, Iterable, List, Optional, Tuple)

from .simulation import (BatchCompute, Compute, Get, Put, Sleep, Trigger,
                         WaitFor)

#: Exclusive blame categories, highest attribution priority first: time
#: where real service happens (compute/network/migration) outranks time
#: explained by a stall, which outranks the passive waits.  The blame
#: sweep (``repro.workflows.blame``) charges every instant of an
#: instance's e2e window to exactly one of these.
#: blame priority is tuple order (lower index wins the sweep).
#: ``partition_stall`` sits ABOVE ``network``: a dispatch or read held
#: at a partition boundary is also covered by the coarse ingress/
#: transfer span, and the specific cause must win the overlap — every
#: other relative order is unchanged, so partition-free decompositions
#: are byte-identical.  ``prefetch`` sits below compute and above
#: ``network``: time a read spent joined to an in-flight warm-up
#: transfer is still data movement, but it is the *overlapped* kind —
#: attributing it separately is what lets ``bench_explain`` show which
#: network milliseconds the overlap removed.
CATEGORIES = ("compute", "partition_stall", "prefetch", "network",
              "migration", "recovery", "fault_stall", "retry", "queueing",
              "batch_wait", "barrier", "admission_defer", "other")

_PRIORITY = {c: i for i, c in enumerate(CATEGORIES)}

# record_op's exact-type dispatch table (isinstance only on a miss)
_COMPUTE, _GET, _PUT, _WAIT, _OTHER = range(5)
_OP_KIND = {Compute: _COMPUTE, BatchCompute: _COMPUTE, Get: _GET,
            Put: _PUT, Trigger: _PUT, WaitFor: _WAIT, Sleep: _OTHER}
#: slots per raw op record in ``InstanceTrace.raw``
_RAW_W = 7


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Sampling / retention knobs.

    ``sample_rate`` selects instances by a deterministic hash of their id
    (same instances traced on every run — reproducible cohorts, zero RNG
    perturbation).  ``max_traces`` bounds the uniform reservoir of
    completed traces; ``top_k`` bounds the tail-biased retention (the
    slowest completed instances, kept regardless of the reservoir —
    blame queries about p99 cohorts read these).
    """
    sample_rate: float = 1.0
    max_traces: int = 512
    top_k: int = 64


class Span:
    """One closed interval of an instance's timeline."""
    __slots__ = ("name", "cat", "t0", "t1", "node", "args")

    def __init__(self, name: str, cat: str, t0: float, t1: float,
                 node: str = "", args: Optional[Dict[str, Any]] = None):
        self.name = name
        self.cat = cat
        self.t0 = t0
        self.t1 = t1
        self.node = node
        self.args = args

    def __repr__(self):
        return (f"Span({self.cat}:{self.name} "
                f"[{self.t0:.6f},{self.t1:.6f}] @{self.node})")


class InstanceTrace:
    """The causal record of one workflow instance (or serving turn)."""
    __slots__ = ("instance", "t_submit", "t_complete", "spans", "events",
                 "marks", "raw")

    def __init__(self, instance: str, t_submit: float):
        self.instance = instance
        self.t_submit = t_submit
        self.t_complete: Optional[float] = None
        self.spans: List[Span] = []
        self.events: List[Tuple[str, float, Optional[Dict]]] = []
        # scratch timestamps the instrumentation layers stitch spans
        # from (ingress put time, first join arrival, ...)
        self.marks: Dict[Any, float] = {}
        # deferred op records from the DES step loop: a FLAT list of
        # atomic values, _RAW_W slots per record (kind, t0, t1,
        # node_name, a, b, c), appended on the hot path and categorized
        # into spans once, at completion (see ``TraceRecorder.record_op``
        # / ``complete``).  Flat atoms instead of per-record tuples so
        # tracing retains zero GC-tracked objects per op — the traced
        # run's generational-collection workload stays that of the
        # untraced run.
        self.raw: List[Any] = []

    @property
    def e2e(self) -> Optional[float]:
        if self.t_complete is None:
            return None
        return self.t_complete - self.t_submit


class TraceRecorder:
    """Span sink shared by the simulator, workflow, and serving layers.

    Attach with ``sim.tracer = recorder`` (the workflow runtime's
    ``tracing=`` knob does this); layers emit through the methods below
    and gate every call site on ``sim.tracer is not None`` so the
    disabled path stays free.
    """

    def __init__(self, config: Optional[TraceConfig] = None):
        self.config = config or TraceConfig()
        self._threshold = int(min(max(self.config.sample_rate, 0.0), 1.0)
                              * 2.0 ** 32)
        self.live: Dict[str, InstanceTrace] = {}
        self.reservoir: List[InstanceTrace] = []
        self._top: List[Tuple[float, int, InstanceTrace]] = []  # min-heap
        self._seq = 0
        self.global_events: List[Tuple[str, float, Optional[Dict]]] = []
        self.n_begun = 0
        self.n_completed = 0
        self.n_spans = 0
        # own deterministic stream for reservoir replacement — NEVER the
        # simulator's rng (tracing must not perturb the DES)
        self._rng = random.Random(0xC0FFEE)
        # node -> down intervals [(t_down, t_up|inf)] fed by the fault
        # injector; op_span splits lane waits against these
        self._downs: Dict[str, List[List[float]]] = {}
        self.on_complete: List[Callable[[InstanceTrace], None]] = []
        # elapsed-time threshold separating local store ops from remote
        # transfers: local ops cost ``Simulator.local_get_cost`` (2 µs
        # default) while the cheapest remote hop pays at least the RTT
        # (10 µs cluster, ms cloud), so 4x the local cost separates the
        # two.  ``attach`` re-derives it from the simulator's setting.
        self.local_cut = 8.2e-6
        # resource -> span-name caches (hot-path f-string avoidance)
        self._cnames: Dict[str, str] = {}
        self._lnames: Dict[str, str] = {}
        # the attached simulator's node table — raw op records carry
        # node *names*, so materialization looks rates up here
        self._nodes: Optional[Dict[str, Any]] = None

    def attach(self, sim) -> "TraceRecorder":
        """Install this recorder on a simulator (``sim.tracer = self``)
        and calibrate the local/remote op threshold to its settings."""
        sim.tracer = self
        self.local_cut = sim.local_get_cost * 4 + 2e-7
        self._nodes = sim.nodes
        return self

    # -- sampling / lifecycle ----------------------------------------------

    def sampled(self, instance: str) -> bool:
        """Deterministic per-instance coin: hash, not RNG."""
        return (zlib.crc32(instance.encode()) & 0xFFFFFFFF) \
            < self._threshold

    def begin(self, instance: str, t_submit: float
              ) -> Optional[InstanceTrace]:
        if not self.sampled(instance):
            return None
        tr = InstanceTrace(instance, t_submit)
        self.live[instance] = tr
        self.n_begun += 1
        return tr

    def drop(self, instance: str) -> None:
        """Forget a live trace (rejected admission, abandoned turn)."""
        self.live.pop(instance, None)

    def complete(self, trace: InstanceTrace, t: float) -> None:
        """Finalize a trace (idempotent) and move it into retention.

        The step loop only appends raw records via ``record_op`` (one
        flat-list extend per op — the event-loop overhead budget), and
        retention needs nothing but the latency, so the categorization
        work (service/wait split, local/remote cut, span objects) runs
        lazily: here only when completion hooks need spans, otherwise
        when a retained trace is first read (``traces`` / ``tail`` /
        ``export_chrome_trace``).  A trace that retention evicts unread
        is dropped without ever being categorized."""
        if trace.t_complete is not None:
            return
        trace.t_complete = t
        self.live.pop(trace.instance, None)
        self.n_completed += 1
        if self.on_complete:
            self.materialize(trace)
            for fn in self.on_complete:
                fn(trace)
        self._retain(trace)

    def materialize(self, trace: InstanceTrace) -> None:
        """Categorize a trace's deferred raw op records into spans
        (idempotent — the raw buffer is consumed)."""
        raw = trace.raw
        if raw:
            emit = self._emit
            for i in range(0, len(raw), _RAW_W):
                emit(trace, raw, i)
            del raw[:]

    def _retain(self, trace: InstanceTrace) -> None:
        cfg = self.config
        self._seq += 1
        lat = trace.e2e or 0.0
        if len(self._top) < cfg.top_k:
            heapq.heappush(self._top, (lat, self._seq, trace))
        elif self._top and lat > self._top[0][0]:
            heapq.heapreplace(self._top, (lat, self._seq, trace))
        if len(self.reservoir) < cfg.max_traces:
            self.reservoir.append(trace)
        else:
            j = self._rng.randrange(self.n_completed)
            if j < cfg.max_traces:
                self.reservoir[j] = trace

    def traces(self) -> List[InstanceTrace]:
        """Every retained completed trace (reservoir ∪ tail cohort)."""
        seen = set()
        out = []
        for tr in self.reservoir:
            if id(tr) not in seen:
                seen.add(id(tr))
                out.append(tr)
        for _, _, tr in sorted(self._top):
            if id(tr) not in seen:
                seen.add(id(tr))
                out.append(tr)
        for tr in out:
            self.materialize(tr)
        return out

    def tail(self, k: Optional[int] = None) -> List[InstanceTrace]:
        """The slowest retained traces, slowest first."""
        out = [tr for _, _, tr in sorted(self._top, reverse=True)]
        out = out if k is None else out[:k]
        for tr in out:
            self.materialize(tr)
        return out

    # -- span emission ------------------------------------------------------

    def span(self, trace: InstanceTrace, cat: str, name: str, t0: float,
             t1: float, node: str = "",
             args: Optional[Dict[str, Any]] = None) -> None:
        if t1 <= t0:
            return
        trace.spans.append(Span(name, cat, t0, t1, node, args))
        self.n_spans += 1

    def instant(self, trace: Optional[InstanceTrace], name: str, t: float,
                args: Optional[Dict[str, Any]] = None) -> None:
        """A zero-duration marker: per-instance, or global (trace=None)."""
        if trace is None:
            self.global_events.append((name, t, args))
        else:
            trace.events.append((name, t, args))

    def wait_span(self, trace: InstanceTrace, node: str, t0: float,
                  t1: float, name: str) -> None:
        """Record a lane/queue wait, splitting out any overlap with the
        node's recorded down intervals as ``fault_stall``."""
        if t1 <= t0:
            return
        downs = self._downs.get(node)
        if downs:
            cur = t0
            for d0, d1 in downs:
                a, b = max(cur, d0), min(t1, d1)
                if b > a:
                    if a > cur:
                        self.span(trace, "queueing", name, cur, a, node)
                    self.span(trace, "fault_stall", name, a, b, node)
                    cur = b
                if cur >= t1:
                    break
            if cur < t1:
                self.span(trace, "queueing", name, cur, t1, node)
        else:
            self.span(trace, "queueing", name, t0, t1, node)

    def record_op(self, trace: InstanceTrace, op: Any, t0: float,
                  t1: float, node: Any) -> None:
        """Append one raw op record to ``trace.raw`` — the traced DES
        step loop's whole per-op cost.

        A record is ``_RAW_W`` flat slots of atomic values (kind tag,
        timestamps, names, the op's cost parameters) extended onto one
        list — never the op or node objects, and no per-record
        container — so tracing retains zero GC-tracked objects per op
        and extends no object lifetimes.  The categorization into spans
        happens once, in ``complete``.
        """
        tp = type(op)
        # exact-type dispatch first (op types are never subclassed in
        # practice), isinstance chain only as fallback
        kind = _OP_KIND.get(tp)
        if kind is None:
            kind = (_COMPUTE if isinstance(op, (Compute, BatchCompute))
                    else _GET if isinstance(op, Get)
                    else _PUT if isinstance(op, (Put, Trigger))
                    else _WAIT if isinstance(op, WaitFor) else _OTHER)
        if kind == _COMPUTE:
            trace.raw.extend((_COMPUTE, t0, t1, node.name, op.resource,
                              op.seconds,
                              op.n if tp is BatchCompute else 0))
        elif kind == _WAIT:
            if not getattr(op.future, "blame", False):
                trace.raw.extend((_WAIT, t0, t1, node.name, "wait",
                                  0.0, 0))
            # else: batch future — the batcher decomposes it itself
        elif kind == _OTHER:            # Sleep and anything exotic
            trace.raw.extend((_OTHER, t0, t1, node.name,
                              tp.__name__.lower(), 0.0, 0))
        elif kind == _GET and op.wait:  # blocking get = a barrier
            trace.raw.extend((_WAIT, t0, t1, node.name,
                              f"get_wait:{op.key}", 0.0, 0))
        else:                           # plain data op: Get/Put/Trigger
            # slot 5 carries the partition-heal stamp for reads a cut
            # parked (Simulator.heal_partition); slot 6 the prefetch-join
            # resume stamp (Simulator._op_get); 0 everywhere else
            if kind == _GET:
                ps = getattr(op, "_pstall", 0.0)
                pw = getattr(op, "_pwait", 0.0)
            else:
                ps = pw = 0.0
            trace.raw.extend((kind, t0, t1, node.name, op.key, ps, pw))

    def _emit(self, trace: InstanceTrace, raw: List[Any], i: int) -> None:
        """Categorize the raw op record at ``raw[i:i+_RAW_W]`` into
        spans (completion time; indexed reads — no record slicing).

        ``[t0, t1]`` is everything the op cost the instance.  Compute
        ops are split into service (re-derived from the op's cost and
        the node's rate — completion-time rates, identical unless a
        straggler dial moved mid-instance, and the clamp keeps every
        span inside ``[t0, t1]`` so the exactness invariant never
        depends on it) vs lane wait (queueing / fault_stall); remote
        data ops are ``network`` while sub-cut local ones record
        nothing (the blame sweep charges uncovered time to ``other``,
        so skipping the micro-span changes no decomposition); barrier
        waits (``Get(wait=True)``, bare ``WaitFor``) are ``barrier``.
        """
        kind, t0, t1, nn = raw[i], raw[i + 1], raw[i + 2], raw[i + 3]
        if t1 <= t0:
            return
        if kind == _COMPUTE:
            res = raw[i + 4]
            nodes = self._nodes
            node = nodes.get(nn) if nodes is not None else None
            rate = node.rate(res) if node is not None else 1.0
            dur = raw[i + 5] / max(rate, 1e-9)
            start = max(t0, t1 - dur)       # failover may re-price; clamp
            if start > t0:
                names = self._lnames
                lname = names.get(res) or \
                    names.setdefault(res, f"lane:{res}")
                if self._downs.get(nn):
                    self.wait_span(trace, nn, t0, start, lname)
                else:               # common case: plain queueing
                    trace.spans.append(Span(lname, "queueing", t0,
                                            start, nn))
                    self.n_spans += 1
            if start >= t1:                 # zero-cost op: nothing to show
                return
            names = self._cnames
            name = names.get(res) or \
                names.setdefault(res, f"compute:{res}")
            bn = raw[i + 6]
            trace.spans.append(Span(name, "compute", start, t1, nn,
                                    {"n": bn} if bn else None))
        elif kind == _WAIT:
            trace.spans.append(Span(raw[i + 4], "barrier", t0, t1, nn))
        elif kind == _GET:
            ps = raw[i + 5]
            if ps > t0:
                # the read parked behind a partition until the heal
                # stamp: that share is the cut's fault, the remainder is
                # the ordinary transfer — together they telescope over
                # [t0, t1] so decomposition exactness is unaffected
                cut = min(ps, t1)
                trace.spans.append(Span("get", "partition_stall", t0,
                                        cut, nn, {"key": raw[i + 4]}))
                self.n_spans += 1
                t0 = cut
            pw = raw[i + 6]
            if pw > t0:
                # the read joined an in-flight warm-up transfer until the
                # resume stamp: that share is `prefetch` (overlapped data
                # movement), the remainder the residual get — telescoping
                # over [t0, t1] keeps decomposition exactness
                cut = min(pw, t1)
                trace.spans.append(Span("get", "prefetch", t0, cut, nn,
                                        {"key": raw[i + 4]}))
                self.n_spans += 1
                t0 = cut
            if t1 - t0 <= self.local_cut:
                return      # local op: the sweep charges it to "other"
            trace.spans.append(Span("get", "network", t0, t1, nn,
                                    {"key": raw[i + 4]}))
        elif kind == _PUT:
            if t1 - t0 <= self.local_cut:
                return      # local op: the sweep charges it to "other"
            trace.spans.append(Span("put", "network", t0, t1, nn,
                                    {"key": raw[i + 4]}))
        else:
            trace.spans.append(Span(raw[i + 4], "other", t0, t1, nn))
        self.n_spans += 1

    def op_span(self, trace: InstanceTrace, op: Any, t0: float, t1: float,
                node: Any) -> None:
        """Categorize one simulator op's elapsed interval immediately —
        the single-op equivalent of ``record_op`` + ``complete``'s
        deferred materialization, for callers outside the step loop."""
        if t1 <= t0:
            return
        raw = trace.raw
        mark = len(raw)
        self.record_op(trace, op, t0, t1, node)
        if len(raw) > mark:
            self._emit(trace, raw, mark)
            del raw[mark:]

    # -- fault bookkeeping --------------------------------------------------

    def note_down(self, node: str, t: float) -> None:
        self._downs.setdefault(node, []).append([t, float("inf")])
        self.instant(None, "node_down", t, {"node": node})

    def note_up(self, node: str, t: float) -> None:
        downs = self._downs.get(node)
        if downs and downs[-1][1] == float("inf"):
            downs[-1][1] = t
        self.instant(None, "node_up", t, {"node": node})

    # -- export -------------------------------------------------------------

    def export_chrome_trace(self, path: Optional[str] = None,
                            traces: Optional[Iterable[InstanceTrace]]
                            = None) -> Dict[str, Any]:
        """Chrome trace-event JSON (Perfetto / chrome://tracing loadable).

        One *process* per node (named via ``M`` metadata), one *thread*
        per instance; spans are ``ph="X"`` complete events with
        microsecond ``ts``/``dur``; per-instance and global instants are
        ``ph="i"`` events (scope thread / global).  Returns the payload;
        writes it to ``path`` when given.
        """
        pids: Dict[str, int] = {}
        tids: Dict[str, int] = {}
        events: List[Dict[str, Any]] = []

        def pid_of(node: str) -> int:
            pid = pids.get(node)
            if pid is None:
                pid = pids[node] = len(pids) + 1
                events.append({"ph": "M", "name": "process_name",
                               "pid": pid, "tid": 0,
                               "args": {"name": node or "cluster"}})
            return pid

        def tid_of(instance: str) -> int:
            tid = tids.get(instance)
            if tid is None:
                tid = tids[instance] = len(tids) + 1
            return tid

        cluster = pid_of("cluster")
        for tr in (self.traces() if traces is None else traces):
            self.materialize(tr)        # no-op unless deferred raw remains
            tid = tid_of(tr.instance)
            for sp in tr.spans:
                ev = {"name": sp.name, "cat": sp.cat, "ph": "X",
                      "ts": sp.t0 * 1e6,
                      "dur": (sp.t1 - sp.t0) * 1e6,
                      "pid": pid_of(sp.node or "cluster"), "tid": tid,
                      "args": {"instance": tr.instance,
                               **(sp.args or {})}}
                events.append(ev)
            for name, t, args in tr.events:
                events.append({"name": name, "cat": "event", "ph": "i",
                               "ts": t * 1e6, "s": "t",
                               "pid": pid_of("cluster"), "tid": tid,
                               "args": args or {}})
        for name, t, args in self.global_events:
            events.append({"name": name, "cat": "cluster", "ph": "i",
                           "ts": t * 1e6, "s": "g", "pid": cluster,
                           "tid": 0, "args": args or {}})
        payload = {"traceEvents": events, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(payload, f)
        return payload

    def summary(self) -> Dict[str, Any]:
        return {"traces_begun": self.n_begun,
                "traces_completed": self.n_completed,
                "spans": self.n_spans,
                "retained": len(self.traces()),
                "live": len(self.live)}


def priority(cat: str) -> int:
    """Attribution priority of a category (lower wins the blame sweep)."""
    return _PRIORITY[cat]
