"""Documentation is executable: every fenced python snippet in
docs/affinity_api.md and docs/workflows.md runs, and every
fully-qualified `repro.*` name mentioned in the docs resolves to a real
symbol."""
import importlib
import re
from pathlib import Path

import pytest

DOCS = Path(__file__).resolve().parents[1] / "docs"
README = Path(__file__).resolve().parents[1] / "README.md"

API_DOC = DOCS / "affinity_api.md"
ARCH_DOC = DOCS / "architecture.md"
WORKFLOWS_DOC = DOCS / "workflows.md"
BATCHING_DOC = DOCS / "batching.md"
ELASTICITY_DOC = DOCS / "elasticity.md"
FAULTS_DOC = DOCS / "faults.md"
OBSERVABILITY_DOC = DOCS / "observability.md"
PREFETCH_DOC = DOCS / "prefetch.md"


def fenced_python_blocks(text: str):
    return re.findall(r"```python\n(.*?)```", text, re.DOTALL)


def qualified_names(text: str):
    """`repro.x.y.Z`-style names in backticks (strip call suffixes)."""
    names = set()
    for m in re.finditer(r"`(repro(?:\.\w+)+)[^`]*`", text):
        names.add(m.group(1))
    return sorted(names)


def resolve(qualname: str):
    parts = qualname.split(".")
    for split in range(len(parts), 0, -1):
        modname = ".".join(parts[:split])
        try:
            obj = importlib.import_module(modname)
        except ImportError:
            continue
        for attr in parts[split:]:
            obj = getattr(obj, attr)
        return obj
    raise ImportError(qualname)


def test_docs_exist():
    assert README.exists()
    assert API_DOC.exists()
    assert ARCH_DOC.exists()
    assert WORKFLOWS_DOC.exists()
    assert BATCHING_DOC.exists()
    assert ELASTICITY_DOC.exists()
    assert FAULTS_DOC.exists()
    assert OBSERVABILITY_DOC.exists()
    assert PREFETCH_DOC.exists()


@pytest.mark.parametrize("doc", [API_DOC, ARCH_DOC, WORKFLOWS_DOC,
                                 BATCHING_DOC, ELASTICITY_DOC,
                                 FAULTS_DOC, OBSERVABILITY_DOC,
                                 PREFETCH_DOC])
def test_all_qualified_names_resolve(doc):
    names = qualified_names(doc.read_text())
    assert names, f"{doc.name} should document qualified repro.* symbols"
    missing = []
    for qn in names:
        try:
            resolve(qn)
        except (ImportError, AttributeError) as e:
            missing.append((qn, repr(e)))
    assert not missing, f"doc names that don't resolve: {missing}"


@pytest.mark.parametrize(
    "doc_idx_snippet",
    [(doc, i, snip) for doc in (API_DOC, WORKFLOWS_DOC, BATCHING_DOC,
                                ELASTICITY_DOC, FAULTS_DOC,
                                OBSERVABILITY_DOC, PREFETCH_DOC)
     for i, snip in enumerate(fenced_python_blocks(doc.read_text()))],
    ids=lambda p: f"{p[0].stem}-snippet{p[1]}")
def test_doc_snippets_run(doc_idx_snippet):
    doc, _, snippet = doc_idx_snippet
    exec(compile(snippet, str(doc), "exec"), {"__name__": "__docs__"})


def test_readme_names_tier1_command():
    assert "python -m pytest" in README.read_text()
