"""Activation sharding constraints (GSPMD hints) for model internals.

Model code is mesh-agnostic; steps/dryrun set the ambient mesh here before
tracing, and blocks call ``constrain(x, "dp", "tp", None)`` with logical
roles per dimension:

  "dp"  -> the data-parallel axes present in the mesh (("pod","data"))
  "tp"  -> the tensor-parallel axis ("model")
  None  -> replicated / unconstrained

Without an ambient mesh (smoke tests, serving on 1 device) it's a no-op.
GSPMD occasionally picks pathological partitionings for MoE dispatch
einsums (observed: ~8x effective parallelism on a 256-chip mesh); these
constraints pin the intended sharding and are part of the *baseline*
config, matching how production MoE frameworks annotate dispatch.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def constrain(x: jax.Array, *roles) -> jax.Array:
    mesh = _MESH
    if mesh is None:
        return x
    assert len(roles) == x.ndim, (roles, x.shape)
    spec = []
    for dim, role in zip(x.shape, roles):
        if role == "dp":
            axes = [a for a in _dp_axes(mesh)]
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if axes and dim % size == 0:
                spec.append(tuple(axes) if len(axes) > 1 else axes[0])
            else:
                spec.append(None)
        elif role == "tp":
            if "model" in mesh.axis_names and dim % mesh.shape["model"] == 0:
                spec.append("model")
            else:
                spec.append(None)
        else:
            spec.append(None)
    # NamedSharding (not bare PartitionSpec) so tracing works outside a
    # `with mesh:` context (e.g. Trainer steps traced at first call).
    from jax.sharding import NamedSharding
    try:
        sh = NamedSharding(mesh, P(*spec))
    except TypeError:        # AbstractMesh in unit tests
        return jax.lax.with_sharding_constraint(x, P(*spec))
    return jax.lax.with_sharding_constraint(x, sh)
