"""Group migration + replication subsystem: whole-group moves, cache
invalidation, replica-read placement, load-aware binding, runtime charge."""
import pytest

from repro.core import (CascadeStore, GroupMigrator, HashPlacement,
                        LoadAwarePlacement, ReplicatedPlacement)


def make_store(policy=None, n_nodes=8, n_shards=8):
    store = CascadeStore([f"n{i}" for i in range(n_nodes)])
    store.create_object_pool("/p", store.nodes, n_shards,
                             affinity_set_regex=r"/[a-z0-9]+_[0-9]+_",
                             policy=policy)
    return store


# -- migration ----------------------------------------------------------------


def test_migration_moves_whole_group():
    store = make_store()
    for a in (1, 2):
        for f in range(6):
            store.put(f"/p/vid_{a}_{f}", b"x" * 100)
    pool = store.pools["/p"]
    before = store.shard_of("/p/vid_1_0").name
    target = next(n for n in pool.shards if n != before)
    rec = GroupMigrator(store).migrate("/p", "/vid_1_", to_shard=target)
    assert rec is not None
    assert rec.n_objects == 6 and rec.bytes_moved == 600
    # every member homes to the target; collocation invariant intact
    homes = {store.shard_of(f"/p/vid_1_{f}").name for f in range(6)}
    assert homes == {target}
    # the untouched group did not move
    assert store.shard_of("/p/vid_2_0").name != target or \
        store.shard_of("/p/vid_2_0").name == \
        pool.engine.policy.place("/vid_2_", list(pool.shards))
    # new puts into the group follow the pin (data AND tasks)
    shard, _ = store.put("/p/vid_1_99", b"x")
    assert shard.name == target
    task_shard, _ = store.trigger("/p/vid_1_100")
    assert task_shard.name == target


def test_migration_invalidates_caches_and_charges_stats():
    store = make_store()
    for f in range(4):
        store.put(f"/p/vid_1_{f}", b"x" * 50)
    home = store.shard_of("/p/vid_1_0")
    reader = next(n for n in store.nodes if n not in home.nodes)
    for f in range(4):
        store.get(f"/p/vid_1_{f}", node=reader)       # warm reader's cache
    assert store.caches[reader]
    target = next(n for n in store.pools["/p"].shards if n != home.name)
    rec = GroupMigrator(store).migrate("/p", "/vid_1_", to_shard=target)
    assert rec.cache_invalidations == 4
    assert all(k not in store.caches[reader]
               for k in store.group_members("/p", "/vid_1_"))
    assert store.stats.migrations == 1
    assert store.stats.bytes_migrated == 200
    # post-migration read returns the *moved* (re-versioned) record
    r, _ = store.get("/p/vid_1_0", node=reader)
    assert r.version > 4


def test_migrate_noop_when_already_home():
    store = make_store()
    store.put("/p/vid_1_0", b"x")
    home = store.shard_of("/p/vid_1_0").name
    assert GroupMigrator(store).migrate("/p", "/vid_1_", to_shard=home) is None


def test_migrate_noop_for_empty_group():
    store = make_store()
    store.put("/p/vid_1_0", b"x")
    target = next(iter(store.pools["/p"].shards))
    assert GroupMigrator(store).migrate("/p", "/typo_",
                                        to_shard=target) is None
    assert store.stats.migrations == 0
    assert "/typo_" not in store.pools["/p"].engine.pins


def test_hot_group_detection_and_rebalance():
    store = make_store(n_nodes=4, n_shards=4)
    for a in range(8):
        store.put(f"/p/vid_{a}_0", b"x" * 100)
    # hammer one group remotely -> it becomes the hottest
    hot_home = store.shard_of("/p/vid_3_0")
    reader = next(n for n in store.nodes if n not in hot_home.nodes)
    store.cache_enabled = False
    for _ in range(50):
        store.get("/p/vid_3_0", node=reader)
    mig = GroupMigrator(store, min_heat=1.0)
    hot = mig.hot_groups("/p")
    assert hot and hot[0].label == "/vid_3_"
    heat = mig.shard_heat("/p")
    assert max(heat.values()) == heat[store.shard_of("/p/vid_3_0").name]


# -- replica-read placement ---------------------------------------------------


def test_replicated_put_fans_out_and_reads_hit_nearest():
    store = make_store(policy=ReplicatedPlacement(HashPlacement(),
                                                  n_replicas=3))
    store.put("/p/vid_1_0", b"y" * 100)
    homes = store.pools["/p"].replica_homes("/p/vid_1_0")
    assert len({h.name for h in homes}) == 3
    assert store.stats.replica_syncs == 2
    assert store.stats.bytes_replica_sync == 200
    store.cache_enabled = False
    # a member of ANY replica shard reads locally
    for h in homes:
        _, local = store.get("/p/vid_1_0", node=h.nodes[0])
        assert local, h.name
    # a non-member still pays a remote get
    outside = next(n for n in store.nodes
                   if all(n not in h.nodes for h in homes))
    _, local = store.get("/p/vid_1_0", node=outside)
    assert not local


def test_replicated_group_collocates_per_replica():
    store = make_store(policy=ReplicatedPlacement(HashPlacement(),
                                                  n_replicas=2))
    for f in range(10):
        store.put(f"/p/vid_7_{f}", b"z" * 10)
    homesets = [frozenset(h.name for h in
                          store.pools["/p"].replica_homes(f"/p/vid_7_{f}"))
                for f in range(10)]
    assert len(set(homesets)) == 1, "replica set must be group-stable"


def test_migration_of_replicated_group():
    store = make_store(policy=ReplicatedPlacement(HashPlacement(),
                                                  n_replicas=2))
    for f in range(5):
        store.put(f"/p/vid_1_{f}", b"x" * 40)
    pool = store.pools["/p"]
    old = {h.name for h in pool.replica_homes("/p/vid_1_0")}
    target = next(n for n in pool.shards if n not in old)
    rec = GroupMigrator(store).migrate("/p", "/vid_1_", to_shard=target)
    assert rec.n_objects == 5
    new = {h.name for h in pool.replica_homes("/p/vid_1_0")}
    assert target in new and store.shard_of("/p/vid_1_0").name == target
    # no replica shard outside the new set still holds group members
    for name, shard in pool.shards.items():
        if name not in new:
            assert not any(k.startswith("/p/vid_1_") for k in shard.objects)


# -- load-aware placement -----------------------------------------------------


def test_load_aware_spreads_bytes_better_than_worst_case():
    store = make_store(policy=LoadAwarePlacement(), n_nodes=4, n_shards=4)
    # skewed group sizes: group a gets (a+1)*5 objects
    for a in range(8):
        for f in range((a + 1) * 5):
            store.put(f"/p/vid_{a}_{f}", b"x" * 100)
    resident = [sum(r.size for r in s.objects.values())
                for s in store.pools["/p"].shards.values()]
    assert min(resident) > 0, "no shard may be left empty under load-aware"
    assert max(resident) < 3 * min(resident)


def test_load_aware_binding_is_sticky():
    store = make_store(policy=LoadAwarePlacement())
    store.put("/p/vid_1_0", b"x" * 10)
    first = store.shard_of("/p/vid_1_0").name
    # heavy later traffic elsewhere must not move the existing binding
    for a in range(2, 10):
        store.put(f"/p/vid_{a}_0", b"x" * 1000)
    assert store.shard_of("/p/vid_1_1").name == first


# -- runtime integration ------------------------------------------------------


def test_runtime_migration_terminates_and_charges():
    from repro.pipelines.rcp.app import Layout, RCPApp
    from repro.pipelines.rcp.data import make_scene
    app = RCPApp([make_scene("little3", 40)], Layout(2, 3, 3),
                 grouped=True, placement="load_aware", migrate_every=0.25)
    app.stream()
    app.run()            # must terminate despite the recurring tick
    s = app.summary(warmup=5)
    assert s["n"] > 0
    if s["migrations"]:
        assert s["bytes_migrated"] > 0
        assert app.rt.migration_log
        assert app.rt.sim.metrics["background_xfer_s"], \
            "migration bytes must be charged as background transfers"


def test_queue_pressure_rebalance_unit():
    """shard_load mode: the busiest group moves off the loaded shard even
    with zero remote traffic (counter heat would never fire)."""
    store = make_store(n_nodes=4, n_shards=4)
    for a in range(8):
        for f in range(4):
            store.put(f"/p/vid_{a}_{f}", b"x" * 50)
    hot = store.shard_of("/p/vid_0_0").name
    mig = GroupMigrator(store)
    # no load signal + no remote traffic -> provably no movement
    assert mig.rebalance("/p") == []
    load = {name: (20.0 if name == hot else 0.0)
            for name in store.pools["/p"].shards}
    moves = mig.rebalance("/p", shard_load=load)
    assert moves and moves[0].src_shards == [hot]
    assert store.shard_of("/p" + moves[0].label + "0").name != hot
    # below the absolute depth floor: transient blips never trigger
    calm = {name: (mig.min_depth - 1 if name == hot else 0.0)
            for name in store.pools["/p"].shards}
    assert mig.rebalance("/p", shard_load=calm) == []


def test_queue_pressure_migration_drains_straggler():
    """A severe straggler creates queue pressure but zero remote traffic;
    the runtime's shard_load rebalance path must still drain it."""
    from repro.pipelines.rcp.app import Layout, RCPApp
    from repro.pipelines.rcp.data import make_scene
    from repro.runtime.faults import set_straggler

    def build(migrate):
        app = RCPApp([make_scene("little3", 80)], Layout(2, 3, 3),
                     grouped=True, placement="load_aware",
                     migrate_every=0.25 if migrate else None)
        set_straggler(app.rt, "pred0", 0.05)
        app.stream()
        app.run()
        return app.summary(warmup=10)

    slow = build(migrate=False)
    fixed = build(migrate=True)
    assert fixed["migrations"] > 0, \
        "queue pressure must trigger migration despite zero remote heat"
    assert fixed["p95"] < slow["p95"], (fixed["p95"], slow["p95"])


def test_runtime_replica_sync_charged():
    from repro.pipelines.rcp.app import Layout, RCPApp
    from repro.pipelines.rcp.data import make_scene
    app = RCPApp([make_scene("little3", 40)], Layout(2, 3, 3),
                 grouped=True, read_replicas=2)
    app.stream()
    app.run()
    s = app.summary(warmup=5)
    assert s["bytes_replica_sync"] > 0
    assert app.rt.sim.metrics["background_xfer_s"], \
        "replica fan-out must occupy NIC time"
