"""Serving engine: session/KV affinity (paper §7.2 applied)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import build_model
from repro.serving import ServingEngine, make_adapter


@pytest.fixture(scope="module")
def model_and_params():
    cfg = configs.get_smoke("granite-3-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def drive(engine, n_sessions=6, turns=3, gen=4):
    for i in range(n_sessions):
        engine.open_session(f"s{i}")
    t = 0.0
    outs = {}
    for turn in range(turns):
        for i in range(n_sessions):
            out, _ = engine.turn(f"s{i}", [1 + i, 2, 3], gen_tokens=gen,
                                 now=t)
            outs.setdefault(f"s{i}", []).extend(out)
            t += 0.001
    return outs


def test_affinity_policy_never_migrates(model_and_params):
    cfg, model, params = model_and_params
    eng = ServingEngine(model, params, n_rows=3, max_slots=4, max_seq=64,
                        policy="affinity")
    drive(eng)
    s = eng.summary()
    assert s["migrations"] == 0
    assert s["migration_bytes"] == 0


def test_random_policy_migrates_and_costs(model_and_params):
    cfg, model, params = model_and_params
    eng = ServingEngine(model, params, n_rows=3, max_slots=6, max_seq=64,
                        policy="random")
    drive(eng)
    s = eng.summary()
    assert s["migrations"] > 0
    assert s["migration_bytes"] > 0


def test_affinity_ttft_wins_when_state_is_expensive(model_and_params):
    """Production regime: a session's KV state is large relative to a
    decode step (GBs on real models), so any migration dominates TTFT.
    Modeled here by a slow interconnect; the smoke model's state is tiny,
    production caches are ~10^5x bigger."""
    from repro.runtime.simulation import NetProfile
    slow = NetProfile(bandwidth=1e6, rtt=0.25)
    cfg, model, params = model_and_params
    ea = ServingEngine(model, params, n_rows=3, max_slots=6, max_seq=64,
                       policy="affinity", net=slow)
    er = ServingEngine(model, params, n_rows=3, max_slots=6, max_seq=64,
                       policy="random", seed=1, net=slow)
    drive(ea)
    drive(er)
    assert ea.summary()["ttft_mean"] <= er.summary()["ttft_mean"]


def test_migration_preserves_generation(model_and_params):
    """Greedy decode must produce identical tokens regardless of routing —
    migrations move state, they must not change it."""
    cfg, model, params = model_and_params
    ea = ServingEngine(model, params, n_rows=3, max_slots=6, max_seq=64,
                       policy="affinity")
    er = ServingEngine(model, params, n_rows=3, max_slots=6, max_seq=64,
                       policy="random", seed=3)
    oa = drive(ea, n_sessions=4, turns=2)
    orr = drive(er, n_sessions=4, turns=2)
    assert oa == orr


def test_adapter_changes_logits(model_and_params):
    cfg, model, params = model_and_params
    eng = ServingEngine(model, params, n_rows=2, max_slots=4, max_seq=64,
                        policy="affinity")
    ad = make_adapter(jax.random.PRNGKey(1), "a1", cfg.d_model,
                      cfg.vocab_size)
    # standard LoRA init has B=0 (no-op); randomize B to make it active
    ad.B = jax.random.normal(jax.random.PRNGKey(2), ad.B.shape) * 2.0
    eng.adapters.register(ad)
    eng.open_session("plain")
    eng.open_session("tuned", adapter="a1")
    out_plain, _ = eng.turn("plain", [1, 2, 3], gen_tokens=6)
    out_tuned, _ = eng.turn("tuned", [1, 2, 3], gen_tokens=6)
    assert out_plain != out_tuned


def test_adapter_affinity_fetches_once(model_and_params):
    cfg, model, params = model_and_params
    eng = ServingEngine(model, params, n_rows=4, max_slots=8, max_seq=64,
                        policy="adapter_affinity")
    ad = make_adapter(jax.random.PRNGKey(1), "a1", cfg.d_model,
                      cfg.vocab_size)
    eng.adapters.register(ad)
    for i in range(6):
        eng.open_session(f"s{i}", adapter="a1")
    drive_sessions = [f"s{i}" for i in range(6)]
    for sid in drive_sessions:
        eng.turn(sid, [1, 2], gen_tokens=2)
    # all sessions share the adapter's affinity key -> one row, one fetch
    assert eng.adapters.fetches == 1
