"""Insert the generated roofline table into EXPERIMENTS.md and refresh the
per-cell §Perf iteration numbers from the artifacts."""
import io
import json
import re
import sys
from contextlib import redirect_stdout
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

DRY = ROOT / "benchmarks" / "artifacts" / "dryrun"


def table_md():
    from scripts.gen_tables import roofline_table
    buf = io.StringIO()
    with redirect_stdout(buf):
        roofline_table("baseline")
    return buf.getvalue()


def cell(arch, shape, rules):
    f = DRY / f"{arch}__{shape}__single__{rules}.json"
    if not f.exists():
        return None
    from benchmarks.roofline import recompute
    d = json.loads(f.read_text())
    return d, recompute(d)


def main():
    exp = (ROOT / "EXPERIMENTS.md").read_text()
    exp = exp.replace("<!-- ROOFLINE_TABLE -->", table_md())

    # llama4 decode it2
    got = cell("llama4-maverick-400b-a17b", "decode_32k", "opt_moedec")
    if got:
        d, r = got
        verdict = (f"**confirmed**: coll bytes/dev "
                   f"{d['collective_bytes_per_device']:.2e} -> coll_s "
                   f"{r['collective_s']:.3f}; dominant: {r['dominant']}"
                   if r["collective_s"] < 0.9 else
                   f"**refuted**: coll_s {r['collective_s']:.3f} "
                   f"(GSPMD still gathers; shard_map dispatch is the next "
                   f"step)")
        exp = exp.replace(
            "| 2 | pin the dispatched tensors' CONTRACTED dims over `data` "
            "to match the weights' FSDP layout — then the cheap thing "
            "(moving (E,C,f) activations, ~5 MB/layer) is the only legal "
            "plan | `opt_moedec` v2 (contracted-dim constraints in "
            "`models/moe.py`) | — | — | <!-- LLAMA4_IT2 -->",
            "| 2 | pin the dispatched tensors' CONTRACTED dims over `data` "
            "to match the weights' FSDP layout — then the cheap thing "
            "(moving (E,C,f) activations, ~5 MB/layer) is the only legal "
            "plan | `opt_moedec` v2 (contracted-dim constraints in "
            f"`models/moe.py`) | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.3f} | {verdict}")

    # dsv2 it4 / it5
    for rules, tag in (("opt_dsv2", "<!-- DSV2_IT4 -->"),
                       ("opt_moetrain", "<!-- DSV2_IT5 -->")):
        got = cell("deepseek-v2-236b", "train_4k", rules)
        if got:
            d, r = got
            exp = exp.replace(
                f"| — | — | {tag}",
                f"| {r['compute_s']:.1f} | {r['collective_s']:.1f} | "
                f"flops/dev {d['flops_per_device']:.2e}, coll "
                f"{d['collective_bytes_per_device']:.2e} |")

    (ROOT / "EXPERIMENTS.md").write_text(exp)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
