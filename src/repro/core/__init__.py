"""The paper's primary contribution: the affinity grouping mechanism."""
from .affinity import (AffinityFunction, AffinityKey, CallableAffinity,
                       Descriptor, InstanceAffinity, InstrumentedAffinity,
                       NoAffinity, RegexAffinity, affinity_key_for,
                       instance_label, instance_of, workflow_key)
from .placement import (HashPlacement, LoadAwarePlacement, PlacementEngine,
                        PlacementPolicy, RendezvousPlacement,
                        ReplicatedPlacement, stable_hash)
from .object_store import (CascadeStore, GroupCounters, ObjectPool,
                           ObjectRecord, Shard, UDL)
from .client import ServiceClientAPI, VOLATILE, PERSISTENT
from .prefetch import PrefetchEngine, PrefetchPlan
from .consistency import AtomicGroupUpdate, EpochFence, GroupSequencer
from .groups import GroupRegistry, MigrationPlan
from .migration import GroupMigrator, MigrationRecord

__all__ = [
    "AffinityFunction", "AffinityKey", "CallableAffinity", "Descriptor",
    "InstanceAffinity", "InstrumentedAffinity", "NoAffinity", "RegexAffinity",
    "affinity_key_for", "instance_label", "instance_of", "workflow_key",
    "HashPlacement", "LoadAwarePlacement", "PlacementEngine",
    "PlacementPolicy", "RendezvousPlacement", "ReplicatedPlacement",
    "stable_hash",
    "CascadeStore", "GroupCounters", "ObjectPool", "ObjectRecord", "Shard",
    "UDL",
    "ServiceClientAPI", "VOLATILE", "PERSISTENT",
    "PrefetchEngine", "PrefetchPlan",
    "AtomicGroupUpdate", "EpochFence", "GroupSequencer",
    "GroupRegistry", "MigrationPlan",
    "GroupMigrator", "MigrationRecord",
]
