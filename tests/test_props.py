"""Property-based tests (hypothesis) for the system's invariants.

``hypothesis`` is an optional test dependency (see pyproject.toml
[project.optional-dependencies].test); the module skips cleanly when it
is not installed so the tier-1 suite always collects.
"""
import re

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Descriptor, HashPlacement, RegexAffinity,
                        RendezvousPlacement, GroupSequencer, stable_hash,
                        instance_label, instance_of)
from repro.training import compression
from repro.training.data import DataConfig, TokenPipeline
from repro.workflows import Emit, WorkflowGraph, WorkflowRuntime

import jax.numpy as jnp

KEYS = st.from_regex(r"/[a-z][a-z0-9]{0,6}_[0-9]{1,3}_[0-9]{1,3}",
                     fullmatch=True)
SHARDS = st.integers(min_value=1, max_value=32)


@given(KEYS, SHARDS)
@settings(max_examples=100, deadline=None)
def test_collocation_invariant(key, n_shards):
    """Objects sharing an affinity key ALWAYS share a shard — any layout."""
    fn = RegexAffinity(r"/[a-z0-9]+_[0-9]+_")
    shards = [f"s{i}" for i in range(n_shards)]
    pol = HashPlacement()
    label = fn(Descriptor.of(key))
    assert label is not None
    # any other key with the same matched prefix maps to the same shard
    suffix_variant = key.rsplit("_", 1)[0] + "_999"
    label2 = fn(Descriptor.of(suffix_variant))
    assert label == label2
    assert pol.place(label, shards) == pol.place(label2, shards)


@given(st.lists(st.text(alphabet="abcdef0123456789", min_size=1,
                        max_size=12), min_size=1, max_size=50, unique=True),
       st.integers(min_value=2, max_value=16))
@settings(max_examples=50, deadline=None)
def test_rendezvous_only_moves_to_new_shard(labels, n):
    """Elasticity invariant: adding a shard never moves a group laterally."""
    pol = RendezvousPlacement()
    old = [f"s{i}" for i in range(n)]
    new = old + ["s_new"]
    for lbl in labels:
        before, after = pol.place(lbl, old), pol.place(lbl, new)
        assert after == before or after == "s_new"


@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=50, deadline=None)
def test_stable_hash_deterministic(x):
    s = f"key_{x}"
    assert stable_hash(s) == stable_hash(s)
    assert 0 <= stable_hash(s) < 2 ** 64


# -- workflow affinity propagation (random graph shapes) ---------------------

CHAINS = st.lists(
    st.tuples(st.integers(min_value=1, max_value=3),    # edge fanout
              st.booleans()),                           # join barrier?
    min_size=1, max_size=4)


def _chain_workflow(chain, n_shards):
    """A linear workflow with random per-edge fan-out and join barriers."""
    g = WorkflowGraph("prop")
    g.add_tier("t", n_shards, {"gpu": 1, "cpu": 2, "nic": 2})
    for i in range(len(chain) + 1):
        g.add_pool(f"/p{i}", tier="t", shards=n_shards)
    for i, (fanout, join) in enumerate(chain):
        g.add_stage(f"s{i}", pool=f"/p{i}", resource="gpu", cost=0.0,
                    emits=[Emit(f"/p{i + 1}", fanout=fanout, size=64)],
                    join=join and i > 0, sink=(i == len(chain) - 1))
    return g.validate()


@given(CHAINS, st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=4), st.booleans())
@settings(max_examples=30, deadline=None)
def test_workflow_instance_forms_one_affinity_group(chain, n_shards,
                                                    n_instances, gang):
    """Every object every stage of an instance writes — across random
    graph shapes, shard counts, and gang pinning — carries the same
    affinity label, and (gang-pinned) lives on the pinned shard slot."""
    g = _chain_workflow(chain, n_shards)
    wrt = WorkflowRuntime(g, gang_pin=gang,
                          placement="load_aware" if gang else "hash")
    for i in range(n_instances):
        wrt.submit(f"i{i}", at=0.001 + i * 0.001)
    wrt.run()
    assert wrt.summary()["n"] == n_instances
    for i in range(n_instances):
        inst, label = f"i{i}", instance_label(f"i{i}")
        slot = wrt.pinned_slot(inst) if gang else None
        n_objects = 0
        for pool in wrt.store.pools.values():
            shard_names = list(pool.shards)
            for si, shard in enumerate(pool.shards.values()):
                for key, rec in shard.objects.items():
                    if instance_of(key) != inst:
                        continue
                    n_objects += 1
                    assert rec.affinity == label, key
                    home = pool.engine.home_of(label)
                    assert shard_names.index(home) == si, key
                    if gang:
                        assert si == slot % len(shard_names), key
        assert n_objects >= len(chain)      # every stage's event landed


@given(st.lists(st.tuples(st.sampled_from("abc"), st.integers(0, 100)),
                min_size=1, max_size=40))
@settings(max_examples=50, deadline=None)
def test_sequencer_per_group_fifo(items):
    """Completion order within each group == admission order."""
    seq = GroupSequencer()
    for g, v in items:
        seq.admit(g, v)
    seen = {g: [] for g, _ in items}
    progress = True
    while progress:
        progress = False
        for g in seen:
            item = seq.ready(g)
            if item is not None:
                seen[g].append(item)
                seq.complete(g)
                progress = True
    for g in seen:
        want = [v for gg, v in items if gg == g]
        assert seen[g] == want


@given(st.lists(st.floats(min_value=-100, max_value=100,
                          allow_nan=False), min_size=1, max_size=256))
@settings(max_examples=50, deadline=None)
def test_int8_quantization_error_bound(xs):
    x = jnp.asarray(np.array(xs, np.float32))
    q, s = compression.quantize_int8(x)
    err = np.abs(np.asarray(compression.dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-5


@given(st.integers(min_value=0, max_value=500),
       st.integers(min_value=1, max_value=4))
@settings(max_examples=25, deadline=None)
def test_data_pipeline_restart_property(step, dp_rank):
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=8, seed=1,
                     dp_rank=dp_rank % 2, dp_size=2)
    p = TokenPipeline(cfg)
    p.restore({"step": step})
    b1 = p.next_batch()
    p2 = TokenPipeline(cfg)
    p2.restore({"step": step})
    np.testing.assert_array_equal(b1["tokens"], p2.next_batch()["tokens"])
