"""AdamW in pure JAX, with optional low-precision optimizer states.

For the >=100B-param MoE archs the second/first moments are stored bf16
(``cfg.opt_state_dtype``) so params+moments fit the 16 GB/chip HBM budget of
the single-pod mesh — a distributed-optimization trick recorded in
EXPERIMENTS.md §Dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    factored: bool = False    # Adafactor-style factored 2nd moment for
    #                           >=2D tensors: v ~ outer(row, col) / mean.
    #                           O(n) -> O(rows+cols) state; lets the 770B
    #                           llama4 fit 16 GB/chip on the single pod.


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def _factorable(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def init_opt_state(params: Any, state_dtype=jnp.float32,
                   factored: bool = False) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)

    def v_like(p):
        if factored and _factorable(p):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return zeros(p)

    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(v_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, grads: Any, params: Any,
                 opt_state: Dict[str, Any]
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    stepf = step.astype(jnp.float32)
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    sdt = jax.tree_util.tree_leaves(opt_state["m"])[0].dtype

    def upd(g, p, m, v):
        gf = g.astype(jnp.float32) * scale
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        mhat = mf / (1 - cfg.b1 ** stepf)
        g2 = gf * gf
        if isinstance(v, dict):           # factored second moment
            vr = cfg.b2 * v["vr"] + (1 - cfg.b2) * jnp.mean(g2, axis=-1)
            vc = cfg.b2 * v["vc"] + (1 - cfg.b2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            vf = (vr[..., None] * vc[..., None, :]) / denom[..., None]
            new_v = {"vr": vr, "vc": vc}
        else:
            vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g2
            new_v = vf.astype(sdt)
        vhat = vf / (1 - cfg.b2 ** stepf)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mf.astype(sdt), new_v

    is_v_leaf = lambda x: isinstance(x, dict) and set(x) == {"vr", "vc"}
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_p = treedef.flatten_up_to(params)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = jax.tree_util.tree_flatten(
        opt_state["v"], is_leaf=is_v_leaf)[0]
    out = [upd(g, p, m, v)
           for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
