"""Example workflow graphs exercised by tests and ``benchmarks/fig7``.

Two shapes beyond the paper's RCP pipeline, picked to stress the two graph
features RCP does not use:

  * :func:`rag_workflow` — retrieve -> rerank -> generate.  A linear
    pipeline with a *fan-out/fan-in bulge* in the middle (retrieve emits
    ``n_docs`` candidate passages, rerank joins them) and a **shared-index
    hot group**: every retrieve reads the same corpus slabs, which form a
    single affinity group pinned to one shard — the canonical "popular
    object" the paper's replication extension targets (``read_replicas``
    spreads it).

  * :func:`speech_workflow` — asr -> {intent, diarize} -> action.  One
    event fans out to two parallel stages on different resources (GPU
    intent model, CPU diarizer) whose outputs a join barrier merges.

Costs are paper-scale service times (milliseconds), object sizes chosen so
placement matters: scattering a workflow instance across shards pays
multi-MB transfers on every edge, exactly like RCP's frames.
"""
from __future__ import annotations

from repro.core import workflow_key
from .graph import INSTANCE, Emit, Read, WorkflowGraph

# shared retrieval index: one slab per part, all in one affinity group
INDEX_PARTS = 4
INDEX_SLAB_BYTES = 4 * 1024 * 1024


def index_keys(n_parts: int = INDEX_PARTS):
    """Keys of the shared corpus slabs (instance token: ``corpus``)."""
    return [workflow_key("/index", "corpus", "slab", j)
            for j in range(n_parts)]


def rag_workflow(shards: int = 4, replication: int = 1,
                 n_docs: int = 6) -> WorkflowGraph:
    """retrieve -> rerank (join n_docs) -> generate, with a shared index."""
    g = WorkflowGraph("rag")
    g.add_tier("rag", shards * replication,
               {"gpu": 1, "cpu": 2, "nic": 2})
    g.add_pool("/queries", tier="rag", shards=shards,
               replication=replication, affinity=INSTANCE)
    g.add_pool("/index", tier="rag", shards=shards,
               replication=replication, affinity=INSTANCE)
    g.add_pool("/cands", tier="rag", shards=shards,
               replication=replication, affinity=INSTANCE, migratable=True)
    g.add_pool("/ranked", tier="rag", shards=shards,
               replication=replication, affinity=INSTANCE)
    g.add_pool("/answers", tier="rag", shards=shards,
               replication=replication, affinity=INSTANCE)
    g.add_stage("retrieve", pool="/queries", resource="cpu", cost=0.004,
                reads=[Read("/index", keys=lambda inst: index_keys())],
                emits=[Emit("/cands", fanout=n_docs,
                            size=2 * 1024 * 1024)])
    g.add_stage("rerank", pool="/cands", resource="gpu", cost=0.008,
                join=True,
                emits=[Emit("/ranked", fanout=1, size=1024 * 1024)])
    g.add_stage("generate", pool="/ranked", resource="gpu", cost=0.030,
                emits=[Emit("/answers", fanout=1, size=16 * 1024)],
                sink=True)
    return g.validate()


def preload_index(wrt, n_parts: int = INDEX_PARTS,
                  slab_bytes: int = INDEX_SLAB_BYTES) -> None:
    """Seed the shared corpus slabs before streaming queries."""
    for k in index_keys(n_parts):
        wrt.preload(k, ("slab", k), size=slab_bytes)


# agent workflow: per-instance tool adapters (LoRA-style deltas) the act
# stage must have resident before it can run a tool call
ADAPTER_PARTS = 2
ADAPTER_BYTES = 4 * 1024 * 1024


def adapter_keys(inst: str, n_parts: int = ADAPTER_PARTS):
    """Keys of one instance's tool-adapter slabs (per-instance state)."""
    return [workflow_key("/adapters", inst, "adapter", j)
            for j in range(n_parts)]


def agent_workflow(shards: int = 4, replication: int = 1,
                   n_tools: int = 4,
                   n_adapters: int = ADAPTER_PARTS) -> WorkflowGraph:
    """plan -> act (x n_tools, reads per-instance adapters) -> reduce.

    The shape ``benchmarks/fig14`` cold-starts: every act firing needs
    the instance's adapter slabs resident (required reads), and the
    reduce stage is an ``n_tools``-way fan-in over multi-MB observations
    — so scatter placement pays adapter bytes on every tool call and
    barrier-input bytes at the join, while admission-time prefetch can
    overlap the former with ``plan``'s compute and speculative staging
    the latter with the stragglers' compute.
    """
    g = WorkflowGraph("agent")
    g.add_tier("agent", shards * replication,
               {"gpu": 1, "cpu": 2, "nic": 2})
    for prefix in ("/tasks", "/calls", "/adapters", "/obs", "/final"):
        g.add_pool(prefix, tier="agent", shards=shards,
                   replication=replication, affinity=INSTANCE)
    g.add_stage("plan", pool="/tasks", resource="cpu", cost=0.003,
                emits=[Emit("/calls", fanout=n_tools, size=512 * 1024)])
    g.add_stage("act", pool="/calls", resource="gpu", cost=0.005,
                reads=[Read("/adapters",
                            keys=lambda inst: adapter_keys(inst, n_adapters),
                            required=True)],
                emits=[Emit("/obs", fanout=1, size=2 * 1024 * 1024)])
    g.add_stage("reduce", pool="/obs", resource="gpu", cost=0.004,
                join=True, emits=[Emit("/final", fanout=1, size=8192)],
                sink=True)
    return g.validate()


def preload_adapters(wrt, instance: str, at: float = 0.0,
                     n_parts: int = ADAPTER_PARTS,
                     slab_bytes: int = ADAPTER_BYTES) -> None:
    """Store one instance's adapter slabs (same virtual time as its
    submit: the puts land after the admission pins, so under gang
    placement they live on the pinned slot)."""
    for k in adapter_keys(instance, n_parts):
        wrt.preload(k, ("adapter", k), size=slab_bytes, at=at)


def speech_workflow(shards: int = 4, replication: int = 1) -> WorkflowGraph:
    """asr -> {intent (gpu), diarize (cpu)} -> action (join 2)."""
    g = WorkflowGraph("speech")
    g.add_tier("speech", shards * replication,
               {"gpu": 1, "cpu": 2, "nic": 2})
    g.add_pool("/audio", tier="speech", shards=shards,
               replication=replication, affinity=INSTANCE)
    g.add_pool("/text", tier="speech", shards=shards,
               replication=replication, affinity=INSTANCE, migratable=True)
    g.add_pool("/acts", tier="speech", shards=shards,
               replication=replication, affinity=INSTANCE)
    g.add_pool("/out", tier="speech", shards=shards,
               replication=replication, affinity=INSTANCE)
    g.add_stage("asr", pool="/audio", resource="gpu", cost=0.020,
                emits=[Emit("/text", fanout=1, size=4 * 1024 * 1024)])
    g.add_stage("intent", pool="/text", resource="gpu", cost=0.006,
                emits=[Emit("/acts", fanout=1, size=256 * 1024)])
    g.add_stage("diarize", pool="/text", resource="cpu", cost=0.010,
                emits=[Emit("/acts", fanout=1, size=256 * 1024)])
    g.add_stage("action", pool="/acts", resource="cpu", cost=0.003,
                join=True, emits=[Emit("/out", fanout=1, size=2048)],
                sink=True)
    return g.validate()


WORKFLOW_SHAPES = {
    "rag": rag_workflow,
    "speech": speech_workflow,
    "agent": agent_workflow,
}


def mode_kwargs(mode: str) -> dict:
    """WorkflowRuntime kwargs for the canonical placement-mode names.

    ``keyhash`` (ungrouped raw key-hash baseline), ``affinity`` (instance
    groups, hash-of-label), ``atomic`` (instance groups + load-aware gang
    pinning); suffixes compose: ``+mig`` adds the migration driver on
    migratable pools, ``+batch`` turns on cross-instance stage batching
    with the static window (the fig8 sweep axis), ``+abatch`` turns on
    batching driven by the adaptive planner (the fig9 headline — no
    window knob at all), ``+prefetch`` arms admission-time affinity
    prefetch (fig14), and ``+spec`` additionally stages fan-in inputs
    speculatively from the first barrier arrival.  One definition so
    benchmarks, examples, and tests sweep the exact same configurations.
    """
    base, *suffixes = mode.split("+")
    if base not in ("keyhash", "affinity", "atomic") or \
            any(s not in ("mig", "batch", "abatch", "prefetch", "spec")
                for s in suffixes):
        raise ValueError(f"unknown workflow placement mode {mode!r}")
    return dict(grouped=base != "keyhash",
                placement="load_aware" if base == "atomic" else "hash",
                gang_pin=base == "atomic",
                migrate_every=0.2 if "mig" in suffixes else None,
                batching="batch" in suffixes,
                adaptive_batching="abatch" in suffixes,
                prefetch="prefetch" in suffixes or "spec" in suffixes,
                speculative="spec" in suffixes)
