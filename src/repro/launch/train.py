"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

On this CPU container use --smoke (reduced config).  On a real pod, drop
--smoke and pass --mesh single|multi to train the full config on the
production mesh (same code path the dry-run proves out).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import configs
from repro.configs.shapes import ShapeConfig
from repro.training import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="local", choices=["local", "single",
                                                        "multi"])
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    mesh = None
    if args.mesh != "local":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    tc = TrainConfig(n_steps=args.steps, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir)
    trainer = Trainer(cfg, shape, tc, mesh=mesh)
    hist = trainer.run()
    first = sum(h["loss"] for h in hist[:5]) / max(len(hist[:5]), 1)
    last = sum(h["loss"] for h in hist[-5:]) / max(len(hist[-5:]), 1)
    print(f"done: steps={trainer.step} loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
