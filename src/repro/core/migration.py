"""Hot-group detection and group-granularity migration.

Static hash placement (the paper's §4.5 default) balances *groups* across
shards, but real workloads skew: a few affinity groups (one viral video,
one busy actor, one chatty session) can dominate a shard.  The paper's
collocation invariant makes the fix cheap — the affinity group is already
the unit of residency, so it is also the natural unit of *migration*: move
every member of the group to a new home shard, pin the label there, and
all future placements (data AND tasks, §3.3 unified placement) follow.

``GroupMigrator`` consumes the store's per-group ``GroupCounters`` (updated
on every put/get), ranks groups by a bytes-weighted heat score, and
relocates the hottest group off the hottest shard when the shard-level
imbalance exceeds a threshold.  A migration:

  1. collects every member object of the group (all replicas);
  2. re-homes the label via ``PlacementEngine.pin`` (works for any policy);
  3. reinstalls the members at the new replica homes under bumped
     versions, removing the old copies;
  4. drops stale node-cache entries for the moved keys;
  5. charges ``StoreStats.migrations`` / ``bytes_migrated`` so the
     discrete-event runtime can bill transfer time for the move.

The runtime driver (``repro.runtime.executor.Runtime.enable_migration``)
calls ``rebalance`` on a virtual-time interval and charges the returned
byte volume as NIC transfers on the destination shard.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

from .object_store import CascadeStore, GroupCounters, ObjectPool


@dataclasses.dataclass
class MigrationRecord:
    pool: str
    label: str
    src_shards: List[str]
    dst_shard: str
    n_objects: int
    bytes_moved: int
    cache_invalidations: int


class GroupMigrator:
    """Detects hot affinity groups and relocates them atomically."""

    def __init__(self, store: CascadeStore,
                 imbalance_ratio: float = 2.0,
                 min_heat: float = 1.0,
                 min_depth: float = 8.0):
        self.store = store
        self.imbalance_ratio = imbalance_ratio
        self.min_heat = min_heat
        self.min_depth = min_depth   # queue-pressure floor (shard_load mode)
        self.log: List[MigrationRecord] = []

    # -- detection ----------------------------------------------------------

    def resident_bytes(self, pool_prefix: str) -> Dict[str, int]:
        pool = self.store.pools[pool_prefix]
        out = {name: 0 for name in pool.shards}
        for name, shard in pool.shards.items():
            out[name] = sum(r.size for r in shard.objects.values())
        return out

    def shard_heat(self, pool_prefix: str) -> Dict[str, float]:
        """Access heat per shard = sum of its resident groups' heat."""
        pool = self.store.pools[pool_prefix]
        heat = {name: 0.0 for name in pool.shards}
        for (pfx, label), g in self.store.group_counters.items():
            if pfx != pool_prefix:
                continue
            home = pool.engine.home_of(label)
            if home in heat:
                heat[home] += g.heat
        return heat

    def hot_groups(self, pool_prefix: str, shard: Optional[str] = None,
                   top_k: int = 5) -> List[GroupCounters]:
        """Hottest groups in the pool (optionally restricted to a shard)."""
        pool = self.store.pools[pool_prefix]
        out = []
        for (pfx, label), g in self.store.group_counters.items():
            if pfx != pool_prefix or g.heat < self.min_heat:
                continue
            if shard is not None and pool.engine.home_of(label) != shard:
                continue
            out.append(g)
        out.sort(key=lambda g: g.heat, reverse=True)
        return out[:top_k]

    # -- relocation ---------------------------------------------------------

    def migrate(self, pool_prefix: str, label: str,
                to_shard: Optional[str] = None) -> Optional[MigrationRecord]:
        """Atomically move every member of `label` to `to_shard`.

        Returns None when there is nothing to move or the group already
        lives on the target.  All members move together — the collocation
        invariant holds before and after.
        """
        pool = self.store.pools[pool_prefix]
        keys = self.store.group_members(pool_prefix, label)
        if not keys:
            return None
        if to_shard is None:
            to_shard = self._coldest(pool, exclude=pool.engine.home_of(label))
        if to_shard is None or pool.engine.home_of(label) == to_shard:
            return None
        assert to_shard in pool.shards, (to_shard, list(pool.shards))

        # 1. collect members (dedupe across replicas) and drop old copies
        recs = {}
        src = set()
        for name, shard in pool.shards.items():
            for k in keys:
                r = shard.objects.pop(k, None)
                if r is not None:
                    recs.setdefault(k, r)
                    src.add(name)
        total = sum(r.size for r in recs.values())

        # 2. re-home the label; every later put/get/trigger follows
        pool.engine.pin(label, to_shard, nbytes=total)

        # 3. reinstall under bumped versions at the new replica homes
        for k, r in recs.items():
            self.store._version += 1
            moved = dataclasses.replace(r, version=self.store._version)
            for home in pool.replica_homes(k):
                home.objects[k] = moved

        # 4. stale node caches must not serve the old versions
        invalidated = self.store.invalidate_cached(list(recs))

        # 5. charge the move
        self.store.stats.migrations += 1
        self.store.stats.bytes_migrated += total

        rec = MigrationRecord(pool=pool_prefix, label=label,
                              src_shards=sorted(src), dst_shard=to_shard,
                              n_objects=len(recs), bytes_moved=total,
                              cache_invalidations=invalidated)
        self.log.append(rec)
        return rec

    def rebalance(self, pool_prefix: str, max_moves: int = 1,
                  shard_load: Optional[Dict[str, float]] = None
                  ) -> List[MigrationRecord]:
        """Move hottest groups off the hottest shard while imbalanced.

        Two load signals, depending on the deployment:

        * default (``shard_load=None``): counter-based remote-traffic heat
          — only fires where placement causes real network cost, so a
          perfectly collocated pool is never touched;
        * ``shard_load`` given (e.g. queue depths from the runtime):
          compute pressure — catches stragglers/overload that never show
          up as remote bytes because compute follows data.  The busiest
          resident group is moved off the most-loaded shard.
        """
        if shard_load is not None:
            return self._rebalance_by_load(pool_prefix, shard_load,
                                           max_moves)
        moves: List[MigrationRecord] = []
        for _ in range(max_moves):
            heat = self.shard_heat(pool_prefix)
            if len(heat) < 2:
                break
            hottest = max(heat, key=heat.get)
            coldest = min(heat, key=heat.get)
            if heat[hottest] < self.min_heat or \
                    heat[hottest] < self.imbalance_ratio * \
                    max(heat[coldest], self.min_heat):
                break
            cands = self.hot_groups(pool_prefix, shard=hottest, top_k=5)
            moved = None
            for g in cands:
                # don't move a group so hot it would just flip the imbalance
                if g.heat > (heat[hottest] - heat[coldest]):
                    continue
                moved = self.migrate(pool_prefix, g.label, to_shard=coldest)
                if moved is not None:
                    break
            if moved is None:
                break
            moves.append(moved)
        return moves

    def _rebalance_by_load(self, pool_prefix: str,
                           shard_load: Dict[str, float],
                           max_moves: int) -> List[MigrationRecord]:
        pool = self.store.pools[pool_prefix]
        load = {name: shard_load.get(name, 0.0) for name in pool.shards}
        moves: List[MigrationRecord] = []
        if len(load) < 2:
            return moves
        hottest = max(load, key=load.get)
        coldest = min(load, key=load.get)
        # the absolute floor keeps transient 1-2 deep queue blips from
        # triggering moves on a healthy cluster
        if load[hottest] < self.min_depth or \
                load[hottest] < self.imbalance_ratio * \
                max(load[coldest], 1.0):
            return moves
        # rank resident groups by recent activity (local ops included —
        # activity is what queues the shard, not remoteness)
        cands = []
        for (pfx, label), g in self.store.group_counters.items():
            if pfx == pool_prefix and pool.engine.home_of(label) == hottest:
                cands.append((g.gets + g.puts, label))
        cands.sort(reverse=True)
        for _, label in cands[:max_moves]:
            moved = self.migrate(pool_prefix, label, to_shard=coldest)
            if moved is not None:
                moves.append(moved)
        return moves

    def decay(self, alpha: float = 0.5,
              pool_prefix: Optional[str] = None) -> None:
        """Age the heat counters so old traffic stops driving decisions.

        Pass ``pool_prefix`` to age only that pool's counters — a driver
        ticking several pools must not compound-decay the whole store.
        """
        for (pfx, _), g in self.store.group_counters.items():
            if pool_prefix is not None and pfx != pool_prefix:
                continue
            g.puts = int(g.puts * alpha)
            g.gets = int(g.gets * alpha)
            g.remote_gets = int(g.remote_gets * alpha)
            g.bytes_put = int(g.bytes_put * alpha)
            g.bytes_remote = int(g.bytes_remote * alpha)

    # -- internals ----------------------------------------------------------

    @staticmethod
    def _coldest(pool: ObjectPool, exclude: str) -> Optional[str]:
        cands = [name for name in pool.shards if name != exclude]
        if not cands:
            return None
        resident = {name: sum(r.size for r in pool.shards[name]
                              .objects.values()) for name in cands}
        return min(cands, key=lambda n: resident[n])
