"""Mixture-of-Experts MLP with capacity-based einsum dispatch.

GSPMD-friendly (MaxText-style "dropping" dispatch): tokens are processed in
fixed-size chunks via ``lax.scan`` so the (chunk, E, C) dispatch tensor stays
bounded regardless of global batch; experts shard over the ``model`` mesh
axis (EP), tokens over ``data`` — the dispatch einsums lower to all-to-alls.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.constraints import constrain
from .common import ModelConfig, ParamFactory, scaled_init, zeros_init
from . import layers

Params = Dict[str, Any]


def init_moe_mlp(pf: ParamFactory, cfg: ModelConfig):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    layers.init_rmsnorm(pf, "ln", d)
    pf.param("router", (d, E), ("embed", "experts"), init=scaled_init, fan_in=d)
    pf.param("e_gate", (E, d, f), ("experts", "embed", "mlp"), fan_in=d)
    pf.param("e_up", (E, d, f), ("experts", "embed", "mlp"), fan_in=d)
    pf.param("e_down", (E, f, d), ("experts", "mlp", "embed"), fan_in=f)
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        pf.param("s_gate", (d, fs), ("embed", "mlp"), fan_in=d)
        pf.param("s_up", (d, fs), ("embed", "mlp"), fan_in=d)
        pf.param("s_down", (fs, d), ("mlp", "embed"), fan_in=fs)


def _capacity(chunk: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(chunk * cfg.moe_top_k * cfg.moe_capacity_factor
                      / cfg.n_experts))
    # multiple of 16 so the capacity dim shards over the 'data' axis
    return max(16, -(-c // 16) * 16)


def _dispatch_combine(gates: jax.Array, idx: jax.Array, E: int, C: int):
    """gates/idx: (T, k). Returns combine (T, E, C) fp32 (0 where dropped)."""
    T, k = idx.shape
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)          # (T,k,E)
    # token-major priority: position of each (t, slot) within its expert
    flat = onehot.transpose(1, 0, 2).reshape(k * T, E)          # slot-major
    pos_flat = jnp.cumsum(flat, axis=0) - flat
    pos = pos_flat.reshape(k, T, E).transpose(1, 0, 2)          # (T,k,E)
    pos = jnp.sum(pos * onehot, axis=-1)                        # (T,k)
    keep = pos < C
    combine = jnp.zeros((T, E, C), jnp.float32)
    for s in range(k):                                          # k is small
        sel = jax.nn.one_hot(pos[:, s], C, dtype=jnp.float32)   # (T,C)
        contrib = (onehot[:, s, :, None] * sel[:, None, :]
                   * (gates[:, s] * keep[:, s])[:, None, None])
        combine = combine + contrib
    return combine


def moe_mlp_core(p: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    """h: (B, S, d) normalized hidden. Returns MoE output (no residual)."""
    B, S, d = h.shape
    T = B * S
    cd = cfg.compute_dtype
    ht = h.reshape(T, d)
    chunk = min(cfg.moe_chunk, T)
    assert T % chunk == 0, (T, chunk)
    nchunks = T // chunk
    C = _capacity(chunk, cfg)
    E, k = cfg.n_experts, cfg.moe_top_k

    # Hoist the FSDP weight all-gather out of the token-chunk loop: pin the
    # gathered experts to (E over 'model', replicated elsewhere) ONCE here;
    # without this GSPMD re-gathers ~0.5 GB/expert-tensor per chunk body.
    # For tiny token counts (decode) gathering 100s of GB of experts to
    # process a handful of tokens is the wrong trade — keep them sharded
    # and let the einsum partial-sum over the FSDP axis instead.
    if cfg.moe_hoist_gather:
        eg = constrain(p["e_gate"].astype(cd), "tp", None, None)
        eu = constrain(p["e_up"].astype(cd), "tp", None, None)
        ed = constrain(p["e_down"].astype(cd), "tp", None, None)
    else:
        # keep expert weights FSDP-sharded; the expert einsums below pin
        # their contracted dim over 'data' so GSPMD partial-sums in place
        # (an (E,C,f)-sized all-reduce) instead of gathering weights.
        eg = p["e_gate"].astype(cd)
        eu = p["e_up"].astype(cd)
        ed = p["e_down"].astype(cd)
    router = p["router"]

    def one_chunk(_, xc):                                       # xc: (chunk, d)
        xc = constrain(xc, "dp", None)
        logits = (xc.astype(jnp.float32) @ router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)                 # (chunk, E)
        gates, idx = jax.lax.top_k(probs, k)
        gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
        combine = _dispatch_combine(gates, idx, E, C)           # (chunk,E,C)
        combine = constrain(combine, "dp", "tp", None)
        dispatch = (combine > 0).astype(cd)
        xin = jnp.einsum("tec,td->ecd", dispatch, xc)           # (E,C,d)
        if cfg.moe_hoist_gather:
            # experts over 'model' (EP), capacity over 'data': compute
            # shards over the full mesh; resharding is an all-to-all.
            xin = constrain(xin, "tp", "dp", None)
            act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, eg))
            act = act * jnp.einsum("ecd,edf->ecf", xin, eu)
            act = constrain(act, "tp", "dp", None)
            yout = jnp.einsum("ecf,efd->ecd", act, ed)          # (E,C,d)
            yout = constrain(yout, "tp", "dp", None)
        else:
            # decode regime: shard the CONTRACTED dims over 'data' to
            # match the weights' FSDP layout — activations move, weights
            # don't (128 tokens should not gather 100s of GB of experts).
            xin = constrain(xin, "tp", None, "dp")
            act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, eg))
            act = act * jnp.einsum("ecd,edf->ecf", xin, eu)
            act = constrain(act, "tp", None, "dp")
            yout = jnp.einsum("ecf,efd->ecd", act, ed)          # (E,C,d)
        out = jnp.einsum("tec,ecd->td", combine.astype(cd), yout)
        out = constrain(out, "dp", None)
        return None, out

    if nchunks == 1:
        _, out = one_chunk(None, ht)
    elif cfg.unroll_inner:
        outs = [one_chunk(None, ht[i * chunk:(i + 1) * chunk])[1]
                for i in range(nchunks)]
        out = jnp.concatenate(outs, axis=0)
    else:
        _, out = jax.lax.scan(one_chunk, None,
                              ht.reshape(nchunks, chunk, d))
        out = out.reshape(T, d)
    out = out.reshape(B, S, d)

    if cfg.n_shared_experts:
        sg = jax.nn.silu(h @ p["s_gate"].astype(cd)) * (h @ p["s_up"].astype(cd))
        out = out + sg @ p["s_down"].astype(cd)
    return out


def moe_block(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = layers.rmsnorm(p["ln"], x, cfg.norm_eps)
    return x + moe_mlp_core(p, cfg, h)


def aux_load_balance_loss(p: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    """Switch-style auxiliary loss (fraction-dispatched × mean router prob)."""
    T = h.shape[0] * h.shape[1]
    logits = (h.reshape(T, -1).astype(jnp.float32)
              @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32), 0)
    return cfg.n_experts * jnp.sum(frac * jnp.mean(probs, axis=0))
