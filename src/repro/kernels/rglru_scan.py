"""RG-LRU linear recurrence h_t = a_t * h_{t-1} + b_t for TPU.

Grid (batch, width_blocks, seq_blocks): the width dimension tiles across
VMEM lanes (block_w multiples of 128), the sequence dimension is innermost
and sequential with the (1, block_w) hidden state carried in VMEM scratch.
Inside a sequence block the recurrence steps with a ``fori_loop`` over
time — elementwise VPU work, which is what this op is on TPU (no MXU
contraction exists in a diagonal RNN).

Oracle: ``repro.kernels.ref.rglru``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, o_ref, hf_ref, carry_ref, *, bs, ns,
            use_h0):
    js = pl.program_id(2)

    @pl.when(js == 0)
    def _init():
        if use_h0:
            carry_ref[...] = h0_ref[...].astype(jnp.float32)
        else:
            carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0].astype(jnp.float32)       # (bs, bw)
    b = b_ref[0].astype(jnp.float32)

    def body(t, h):
        h = a[t] * h + b[t]                # (bw,)
        pl.store(o_ref, (0, pl.dslice(t, 1), pl.dslice(None)),
                 h[None].astype(o_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, bs, body, carry_ref[0])
    carry_ref[...] = h[None]

    @pl.when(js == ns - 1)
    def _fin():
        hf_ref[...] = carry_ref[...].astype(hf_ref.dtype)


def rglru_scan(a, b, h0=None, *, block_s: int = 256, block_w: int = 512,
               interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """a/b (B,S,W), h0 (B,W) or None. Returns (h (B,S,W), h_final (B,W))."""
    B, S, W = a.shape
    bs = min(block_s, S)
    bw = min(block_w, W)
    assert S % bs == 0 and W % bw == 0, (S, W, bs, bw)
    ns, nw = S // bs, W // bw
    use_h0 = h0 is not None
    h0_in = h0 if use_h0 else jnp.zeros((B, W), a.dtype)
    kernel = functools.partial(_kernel, bs=bs, ns=ns, use_h0=use_h0)

    h, hf = pl.pallas_call(
        kernel,
        grid=(B, nw, ns),
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda b_, w, s: (b_, s, w)),
            pl.BlockSpec((1, bs, bw), lambda b_, w, s: (b_, s, w)),
            pl.BlockSpec((1, bw), lambda b_, w, s: (b_, w)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bw), lambda b_, w, s: (b_, s, w)),
            pl.BlockSpec((1, bw), lambda b_, w, s: (b_, w)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), a.dtype),
            jax.ShapeDtypeStruct((B, W), a.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(a, b, h0_in)
    return h, hf
