"""End-to-end driver: the paper's RCP application (MOT->PRED->CD) on the
affinity runtime, affinity vs random placement across layouts.

Run:  PYTHONPATH=src python examples/rcp_pipeline.py [--frames 200]
"""
import argparse
import sys
from pathlib import Path
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.pipelines.rcp.app import Layout, RCPApp
from repro.pipelines.rcp.data import make_scene
from repro.runtime import RandomScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=200)
    ap.add_argument("--scenes", default="gates3")
    args = ap.parse_args()
    scenes = args.scenes.split(",")

    print(f"{'layout':8s} {'policy':9s} {'median_ms':>9s} {'p95_ms':>8s} "
          f"{'remote_gets':>11s} {'remote_MB':>9s}")
    for layout in [(1, 1, 1), (1, 3, 3), (3, 5, 5)]:
        for grouped in (True, False):
            app = RCPApp([make_scene(s, args.frames) for s in scenes],
                         Layout(*layout), grouped=grouped,
                         scheduler=None if grouped else RandomScheduler(0))
            app.stream()
            app.run()
            s = app.summary(warmup=args.frames // 4)
            name = "/".join(map(str, layout))
            pol = "affinity" if grouped else "random"
            print(f"{name:8s} {pol:9s} {s['median']*1e3:9.1f} "
                  f"{s['p95']*1e3:8.1f} {s['remote_gets']:11d} "
                  f"{s['bytes_remote']/1e6:9.1f}")


if __name__ == "__main__":
    main()
