"""Placement engine: affinity key -> shard/location.

The paper's modified Cascade policy is ``hash(affinity_key) % n_shards``
(pseudo-random across *groups*, deterministic within a group -> load balance
+ collocation, §4.5 "best of both worlds").  Baseline is the same hash over
the raw object key ("random placement").

For elastic scaling we also provide rendezvous (HRW) hashing: when a shard
is added/removed only ~1/n of affinity groups move, and the mapping needs no
synchronized state — any node computes it locally (the paper's 'lightweight'
requirement under autoscaling).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Optional, Sequence

from .affinity import AffinityFunction, AffinityKey, Descriptor, affinity_key_for


def stable_hash(s: str) -> int:
    """Deterministic across processes (unlike python's hash())."""
    return int.from_bytes(hashlib.blake2b(s.encode(), digest_size=8).digest(),
                          "big")


class PlacementPolicy:
    def place(self, label: str, shards: Sequence[str]) -> str:
        raise NotImplementedError

    def name(self) -> str:
        return type(self).__name__


class HashPlacement(PlacementPolicy):
    """hash(label) % n — Cascade's default mapping."""

    def place(self, label: str, shards: Sequence[str]) -> str:
        return shards[stable_hash(label) % len(shards)]

    def name(self) -> str:
        return "hash"


class RendezvousPlacement(PlacementPolicy):
    """Highest-random-weight hashing: minimal movement under resharding."""

    def place(self, label: str, shards: Sequence[str]) -> str:
        return max(shards, key=lambda s: stable_hash(f"{label}::{s}"))

    def name(self) -> str:
        return "rendezvous"


@dataclasses.dataclass
class PlacementDecision:
    shard: str
    label: str
    grouped: bool           # True if an affinity key drove the decision


class PlacementEngine:
    """Unified placement for data objects AND compute tasks (paper §3.3).

    ``affinity_fn=None`` (or a fn returning None) degrades to the baseline
    random (key-hash) placement the paper compares against.
    """

    def __init__(self, shards: Sequence[str],
                 affinity_fn: Optional[AffinityFunction] = None,
                 policy: Optional[PlacementPolicy] = None):
        self.shards: List[str] = list(shards)
        self.affinity_fn = affinity_fn
        self.policy = policy or HashPlacement()

    def place(self, desc: Descriptor) -> PlacementDecision:
        label = affinity_key_for(self.affinity_fn, desc)
        shard = self.policy.place(label, self.shards)
        return PlacementDecision(shard=shard, label=label,
                                 grouped=(label != desc.key))

    # -- elasticity ---------------------------------------------------------

    def add_shard(self, shard: str) -> None:
        if shard not in self.shards:
            self.shards.append(shard)

    def remove_shard(self, shard: str) -> None:
        self.shards.remove(shard)

    def moved_labels(self, labels: Sequence[str],
                     new_shards: Sequence[str]) -> Dict[str, str]:
        """Labels whose home changes under a new shard set (migration plan)."""
        out = {}
        for lbl in labels:
            old = self.policy.place(lbl, self.shards)
            new = self.policy.place(lbl, list(new_shards))
            if old != new:
                out[lbl] = new
        return out
