"""Adaptive batch planner + streaming metrics hot path.

Covers the StageStats / P2Quantile sketches (accuracy vs exact
np.percentile, bounded memory, order-invariance), the InstanceTracker's
evict-completed long-horizon mode, the BatchPlanner's decisions, the
window-timer coalescing in StageBatcher, and the end-to-end guarantee the
fig9 benchmark records: one adaptive policy, no per-rate knobs, never
worse than the hand-tuned static window.
"""
import json

import numpy as np
import pytest

from repro.runtime import Node, P2Quantile, StageStats, node_load
from repro.runtime.batching import BatchCostModel
from repro.workflows import (AdaptiveBatchPolicy, BatchPlanner, BatchPolicy,
                             Emit, WorkflowGraph, WorkflowRuntime,
                             mode_kwargs, preload_index, rag_workflow)

RES = {"gpu": 1, "cpu": 2, "nic": 2}


# -- StageStats: the bounded quantile sketch ----------------------------------

def test_stage_stats_exact_inside_warmup_buffer():
    rng = np.random.default_rng(1)
    xs = rng.lognormal(-3.0, 1.0, 400)       # < exact_cap=512
    st = StageStats()
    for x in xs:
        st.observe(float(x))
    assert st.exact
    for q in (0.5, 0.75, 0.95, 0.99):
        assert st.quantile(q) == pytest.approx(
            float(np.percentile(xs, q * 100)), rel=1e-9)
    assert st.mean == pytest.approx(float(xs.mean()))
    assert st.min == float(xs.min()) and st.max == float(xs.max())


def test_stage_stats_property_within_5pct_of_numpy():
    """Acceptance property: sketch p50/p95/p99 within 5% of exact
    np.percentile on the same samples, across distribution families,
    sizes spanning the exact->sketch graduation, and stream orders."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st_

    @given(st_.sampled_from(["uniform", "exponential", "lognormal"]),
           st_.integers(min_value=10, max_value=4000),
           st_.sampled_from(["natural", "sorted", "reversed"]),
           st_.integers(min_value=0, max_value=2 ** 31 - 1))
    @settings(max_examples=40, deadline=None)
    def prop(family, n, order, seed):
        rng = np.random.default_rng(seed)
        xs = {"uniform": lambda: rng.uniform(1e-4, 1.0, n),
              "exponential": lambda: rng.exponential(0.05, n),
              "lognormal": lambda: rng.lognormal(-2.0, 1.0, n)}[family]()
        if order == "sorted":
            xs = np.sort(xs)
        elif order == "reversed":
            xs = np.sort(xs)[::-1]
        st = StageStats()
        for x in xs:
            st.observe(float(x))
        for q in (0.5, 0.95, 0.99):
            est = st.quantile(q)
            if st.exact:        # inside the warm-up buffer: numpy-equal
                exact = float(np.percentile(xs, q * 100))
                assert est == pytest.approx(exact, rel=1e-9), \
                    (family, n, order, q)
            else:
                # sketch regime: within 5% of the exact percentile,
                # bracketed by the adjacent order statistics (numpy's
                # linear interpolation picks a point between them; the
                # sketch returns the rank-correct sample's bin)
                lo = float(np.percentile(xs, q * 100, method="lower"))
                hi = float(np.percentile(xs, q * 100, method="higher"))
                assert 0.95 * lo - 1e-12 <= est <= 1.05 * hi + 1e-12, \
                    (family, n, order, q, lo, est, hi)

    prop()


def test_stage_stats_order_invariant_beyond_buffer():
    """The log-binned estimator sees a multiset, not a sequence."""
    rng = np.random.default_rng(3)
    xs = rng.exponential(0.02, 20_000)
    vals = {}
    for order, stream in (("shuffled", xs),
                          ("sorted", np.sort(xs)),
                          ("reversed", np.sort(xs)[::-1])):
        st = StageStats()
        for x in stream:
            st.observe(float(x))
        vals[order] = [st.quantile(q) for q in (0.5, 0.95, 0.99)]
    assert vals["shuffled"] == vals["sorted"] == vals["reversed"]


def test_stage_stats_gap_median_is_rank_correct():
    """Across a density gap np.percentile interpolates a value that is
    near NO sample; the sketch returns the rank-correct order statistic
    instead — pin it to the adjacent exact order statistics."""
    rng = np.random.default_rng(4)
    xs = np.concatenate([rng.normal(0.01, 0.002, 10_000),
                         rng.normal(0.1, 0.01, 10_000)])
    st = StageStats()
    for x in xs:
        st.observe(float(x))
    est = st.quantile(0.5)
    lo = float(np.percentile(xs, 50, method="lower"))
    hi = float(np.percentile(xs, 50, method="higher"))
    assert min(lo, est) / max(lo, est) > 0.95 or \
        min(hi, est) / max(hi, est) > 0.95


def test_stage_stats_memory_bounded_at_100k():
    st = StageStats()
    rng = np.random.default_rng(5)
    for x in rng.exponential(0.01, 100_000):
        st.observe(float(x))
    n_buf, n_bins = st.footprint()
    assert n_buf == 0                  # warm-up buffer freed on graduation
    assert n_bins < 1000               # fixed bucket array, horizon-free
    assert not st.exact and st.count == 100_000
    assert st.quantile(0.99) > st.quantile(0.5) > 0.0
    assert st.quantile(0.0) == st.min       # empty zero-bucket edge


def test_stage_stats_zero_and_negative_observations():
    st = StageStats()
    for x in (0.0, -1e-9, 0.0, 2.0):
        st.observe(x)
    assert st.min == 0.0 and st.max == 2.0
    assert st.quantile(0.25) == 0.0
    assert st.quantile(1.0) == 2.0


def test_p2_quantile_on_stationary_stream():
    rng = np.random.default_rng(6)
    xs = rng.lognormal(-3.0, 0.8, 50_000)
    for q in (0.5, 0.95, 0.99):
        sk = P2Quantile(q)
        for x in xs:
            sk.observe(float(x))
        exact = float(np.percentile(xs, q * 100))
        assert abs(sk.value() - exact) <= 0.05 * exact
    assert len(sk._h) == 5             # five markers, nothing retained


def test_p2_quantile_tiny_streams_are_numpy_exact():
    """Below five observations P² has no markers yet and must fall back
    to the exact interpolated order statistic — including n == 0."""
    assert P2Quantile(0.5).value() == 0.0
    for n in range(1, 6):
        xs = [3.0, 1.0, 4.0, 1.5, 9.0][:n]
        for q in (0.1, 0.5, 0.9):
            sk = P2Quantile(q)
            for x in xs:
                sk.observe(x)
            assert sk.value() == pytest.approx(
                float(np.percentile(xs, q * 100)), abs=1e-12)


def test_p2_quantile_all_equal_stream():
    """A constant stream must not wobble: every marker collapses onto
    the value and the parabolic step must not divide by zero."""
    for n in (3, 5, 100):
        sk = P2Quantile(0.95)
        for _ in range(n):
            sk.observe(0.25)
        assert sk.value() == 0.25


def test_p2_quantile_extreme_tail_vs_numpy():
    """p = 0.999 sits between the 4th and 5th marker; on a heavy-tailed
    stream the estimate must stay within 10% of numpy's exact value."""
    rng = np.random.default_rng(7)
    xs = rng.lognormal(-3.0, 0.8, 200_000)
    sk = P2Quantile(0.999)
    for x in xs:
        sk.observe(float(x))
    exact = float(np.percentile(xs, 99.9))
    assert abs(sk.value() - exact) <= 0.10 * exact


def test_stage_stats_merge_matches_single_stream():
    """Folding per-slot sketches must agree with one sketch that saw the
    union stream: exact moments, near-identical quantiles."""
    rng = np.random.default_rng(8)
    xs = rng.exponential(0.01, 6_000)
    whole = StageStats()
    for x in xs:
        whole.observe(float(x))
    parts = [StageStats() for _ in range(3)]
    for i, x in enumerate(xs):
        parts[i % 3].observe(float(x))
    merged = parts[0].merge(parts[1]).merge(parts[2])
    assert merged is parts[0]
    assert merged.count == whole.count == len(xs)
    assert merged.mean == pytest.approx(whole.mean, rel=1e-12)
    assert merged.min == whole.min and merged.max == whole.max
    for q in (0.5, 0.95, 0.99):
        assert merged.quantile(q) == whole.quantile(q)  # same histogram
    # merging an empty sketch is the identity
    before = merged.summary()
    assert merged.merge(StageStats()).summary() == before


def test_stage_stats_merge_exactness_rules():
    """Two warm-up-resident sketches whose union still fits stay exact;
    a union that overflows the buffer graduates to sketch-only."""
    a, b = StageStats(exact_cap=16), StageStats(exact_cap=16)
    for i in range(6):
        a.observe(0.001 * (i + 1))
        b.observe(0.002 * (i + 1))
    a.merge(b)
    assert a.exact and a.count == 12
    xs = sorted([0.001 * (i + 1) for i in range(6)]
                + [0.002 * (i + 1) for i in range(6)])
    assert a.quantile(0.5) == pytest.approx(
        float(np.percentile(xs, 50)), rel=1e-12)
    big = StageStats(exact_cap=16)
    for i in range(12):
        big.observe(0.003 * (i + 1))
    a.merge(big)                        # 24 > exact_cap: graduates
    assert not a.exact and a.count == 24
    # different binning geometry must be refused, not silently merged
    with pytest.raises(AssertionError):
        a.merge(StageStats(ratio=1.1))


def test_stage_stats_dict_round_trip():
    """to_dict -> from_dict preserves every observable: moments,
    exactness, and quantiles — both in warm-up and sketch-only states."""
    rng = np.random.default_rng(9)
    for n in (0, 5, 40, 2_000):         # empty, tiny, buffered, graduated
        st = StageStats(exact_cap=64)
        for x in rng.exponential(0.01, n):
            st.observe(float(x))
        st2 = StageStats.from_dict(json.loads(json.dumps(st.to_dict())))
        assert st2.count == st.count and st2.exact == st.exact
        assert st2.mean == pytest.approx(st.mean, rel=1e-12)
        if n:
            assert (st2.min, st2.max) == (st.min, st.max)
        for q in (0.0, 0.5, 0.95, 0.99, 1.0):
            assert st2.quantile(q) == st.quantile(q)
        # the round-tripped sketch keeps observing correctly
        st2.observe(0.5)
        assert st2.count == n + 1 and st2.max == 0.5


# -- InstanceTracker: long-horizon bounded memory -----------------------------

def _chain_graph():
    g = WorkflowGraph("chain")
    g.add_tier("t", 2, dict(RES))
    g.add_pool("/a", tier="t", shards=2)
    g.add_pool("/b", tier="t", shards=2)
    g.add_stage("s0", pool="/a", resource="gpu", cost=1e-3,
                emits=[Emit("/b", fanout=1, size=64)], sink=True)
    return g.validate()


def test_tracker_evicts_completed_at_100k_instances():
    """100k instances through the tracker: records stay bounded by
    in-flight concurrency, per-stage stats bounded by the sketch."""
    from repro.workflows import InstanceTracker
    tr = InstanceTracker(_chain_graph(), evict_completed=True)
    peak = 0
    for i in range(100_000):
        t = i * 1e-3
        inst = f"i{i}"
        tr.admit(inst, t, deadline=0.5)
        tr.arrive(inst, "s0", f"/a/{inst}_event_0", t)
        tr.fire(inst, "s0")
        tr.stage_done(inst, "s0", t, t + 2e-3)
        peak = max(peak, len(tr.records))
    assert len(tr.records) == 0 and peak <= 1
    assert tr.retired == 100_000 and tr.admitted == 100_000
    s = tr.summary()
    assert s["n"] == 100_000
    assert s["p99"] == pytest.approx(2e-3, rel=0.05)
    assert s["slo_miss_rate"] == 0.0
    assert tr.stage_stats["s0"].footprint()[0] == 0     # sketch-only


def test_evicting_and_retaining_trackers_agree():
    """Same stream, evict on/off: identical completion counts and SLO
    accounting, quantiles within the sketch tolerance."""
    outs = []
    for evict in (False, True):
        g = _chain_graph()
        wrt = WorkflowRuntime(g, **mode_kwargs("atomic"),
                              evict_completed=evict)
        for i in range(600):
            wrt.submit(f"i{i}", at=0.001 + i * 5e-4, deadline=0.3)
        wrt.run()
        outs.append(wrt.summary())
    keep, evicted = outs
    assert keep["n"] == evicted["n"] == 600
    assert keep.get("slo_misses", 0) == evicted.get("slo_misses", 0)
    for k in ("median", "p99"):
        assert evicted[k] == pytest.approx(keep[k], rel=0.05)


# -- BatchPlanner decisions ---------------------------------------------------

def test_largest_within_monotone_and_bounded():
    m = BatchCostModel(max_batch=16)
    assert m.largest_within(0.01, budget=1e-6) == 1      # always >= 1
    assert m.largest_within(0.01, budget=1e9) == 16
    prev = 1
    for budget in (0.02, 0.05, 0.1, 0.5):
        n = m.largest_within(0.01, budget, wait_per_member=0.01)
        assert n >= prev
        prev = n


def _planner(graph=None, **pol):
    g = graph or rag_workflow(shards=2)
    from repro.workflows import InstanceTracker
    tr = InstanceTracker(g)
    return BatchPlanner(g, tr, policy=AdaptiveBatchPolicy(**pol)), g


def test_planner_gap_ewma_tracks_arrivals():
    p, g = _planner()
    for i in range(10):
        p.note_arrival("generate", "s0", i * 0.010)
    assert p._gap[("generate", "s0")] == pytest.approx(0.010)
    p.note_arrival("generate", "s0", 0.090 + 0.040)
    assert p._gap[("generate", "s0")] > 0.010     # EWMA moved toward 40ms


def test_planner_window_tracks_pending_backlog():
    p, g = _planner()
    gen = next(s for s in g.stages if s.name == "generate")
    for i in range(6):
        p.note_arrival("generate", "s0", i * 0.010)
    w_idle, _ = p.plan(gen, "s0", 0.06, deadline=None, pending=0.0)
    w_busy, _ = p.plan(gen, "s0", 0.06, deadline=None, pending=0.040)
    assert w_busy > w_idle
    assert w_busy == pytest.approx(
        0.040 * p.policy.pending_gain, rel=1e-6)


def test_planner_throughput_mode_when_headroom_gone():
    """A hopeless deadline must not shrink the batch — it flips the
    planner into max-throughput mode for everyone behind."""
    p, g = _planner()
    gen = next(s for s in g.stages if s.name == "generate")
    _, cap = p.plan(gen, "s0", now=1.0, deadline=1.001)   # < unit cost
    assert cap == p.cost_model.max_batch
    assert p.throughput_mode == 1


def test_planner_cap_respects_deadline_budget():
    p, g = _planner()
    gen = next(s for s in g.stages if s.name == "generate")   # 30ms unit
    for i in range(6):
        p.note_arrival("generate", "s0", i * 0.020)
    # generous headroom -> big cap; tight (but feasible) -> small cap
    _, cap_loose = p.plan(gen, "s0", 0.1, deadline=10.0)
    _, cap_tight = p.plan(gen, "s0", 0.1, deadline=0.1 + 0.055)
    assert cap_loose > cap_tight >= 1


def test_planner_window_clamped_to_policy_bounds():
    p, g = _planner(min_window=0.001, max_window=0.010)
    gen = next(s for s in g.stages if s.name == "generate")
    for i in range(6):
        p.note_arrival("generate", "s0", i * 0.010)
    w, _ = p.plan(gen, "s0", 0.06, deadline=None, pending=10.0)
    assert w == 0.010
    # no arrival signal on a fresh slot: nothing to wait for -> min clamp
    w, _ = p.plan(gen, "s1", 0.06, deadline=None, pending=0.0)
    assert w == 0.001


def test_planner_gap_window_floor_catches_next_arrival():
    """With an observed cadence, the window never closes faster than
    ``gap_window`` arrival gaps — a batch that flushes between bursts
    can never coalesce (the sustained-overload fix)."""
    p, g = _planner(min_window=0.0005)
    gen = next(s for s in g.stages if s.name == "generate")
    for i in range(6):
        p.note_arrival("generate", "s0", i * 0.010)
    w, _ = p.plan(gen, "s0", 0.06, deadline=None, pending=0.0)
    assert w == pytest.approx(p.policy.gap_window * 0.010)
    # backlog additionally floors by unit_window service times
    w, _ = p.plan(gen, "s0", 0.06, deadline=None, pending=1e-4)
    assert w >= p.policy.unit_window * gen.cost


def test_planner_economic_idle_hold():
    """Holding an idle lane is worth it iff the next member's
    amortization saving (unit x fixed share) beats the expected gap."""
    p, g = _planner()
    gen = next(s for s in g.stages if s.name == "generate")    # 30ms
    rer = next(s for s in g.stages if s.name == "rerank")      # 8ms
    assert not p.hold_when_idle("generate", "s0", gen.cost)    # no signal
    for i in range(6):
        p.note_arrival("generate", "s0", i * 0.010)
        p.note_arrival("rerank", "s0", i * 0.010)
    # fixed share 0.65: generate saves ~19.5ms/member > 10ms gap -> hold;
    # rerank saves ~5.2ms < 10ms gap -> flush
    assert p.hold_when_idle("generate", "s0", gen.cost)
    assert not p.hold_when_idle("rerank", "s0", rer.cost)


def test_node_load_prefers_free_lanes_then_shallow_queues():
    a = Node("a", {"gpu": 2})
    b = Node("b", {"gpu": 1})
    a.in_use["gpu"] = 1                     # one of two lanes busy
    b.in_use["gpu"] = 1
    assert node_load(a, "gpu") < node_load(b, "gpu")
    b.queues["gpu"].append((0.0, lambda: None))
    assert node_load(b, "gpu") == 2.0


# -- StageBatcher window-timer coalescing -------------------------------------

def _burst_runtime(max_batch, window, n=18, idle_flush=False):
    g = rag_workflow(shards=2)
    mk = dict(mode_kwargs("atomic"), batching=True,
              batch_policy=BatchPolicy(window=window, max_batch=max_batch,
                                       idle_flush=idle_flush))
    wrt = WorkflowRuntime(g, **mk)
    preload_index(wrt)
    for i in range(n):
        wrt.submit(f"req{i}", at=0.01 + i * 1e-4)
    wrt.run()
    return wrt


def test_no_timer_for_batches_flushed_at_enrollment():
    """max_batch=1: every batch closes by the size rule at its first
    enrollment — the window timer must never be scheduled."""
    wrt = _burst_runtime(max_batch=1, window=0.5)
    b = wrt.batcher
    assert b.n_batches == b.enrolled > 0
    assert b.timers_scheduled == 0


def test_one_pending_timer_per_batch_key():
    """Size-flushed batches leave their timer to roll to the next open
    batch on the key: far fewer timer events than batches."""
    wrt = _burst_runtime(max_batch=3, window=1.0)
    b = wrt.batcher
    assert b.n_batches > 4
    # one live timer per (stage, slot) at a time — the heap never holds
    # a dead timer per flushed batch
    n_keys = len(wrt.graph.stages) * 2          # stages x shard slots
    assert b.timers_scheduled + b.timer_rolls <= n_keys * 2
    assert b.timers_scheduled < b.n_batches
    assert not b._timer_at                 # all discharged at drain


def test_hopeless_deadline_does_not_arm_slo_flush():
    """A member whose deadline cannot be met even by an immediate
    singleton flush must not force singleton batches — max-throughput
    mode batches it with everyone behind instead."""
    g = rag_workflow(shards=1)
    mk = dict(mode_kwargs("atomic"), batching=True,
              batch_policy=BatchPolicy(window=0.050, max_batch=16,
                                       idle_flush=False))
    wrt = WorkflowRuntime(g, **mk)
    preload_index(wrt)
    # deadlines below even one unit of the cheapest stage (retrieve,
    # 4ms): hopeless at every enrollment, so the SLO rule must stay
    # unarmed and batches must still coalesce via the window/size rules
    for i in range(8):
        wrt.submit(f"req{i}", at=0.001 + i * 1e-3, deadline=0.002)
    wrt.run()
    s = wrt.summary()
    assert s["slo_flushes"] == 0
    assert s["mean_batch"] > 1.0


def test_window_timer_still_flushes_open_batches():
    """The coalesced timer must still fire the window rule itself."""
    g = rag_workflow(shards=1)
    mk = dict(mode_kwargs("atomic"), batching=True,
              batch_policy=BatchPolicy(window=0.005, max_batch=64,
                                       idle_flush=False))
    wrt = WorkflowRuntime(g, **mk)
    preload_index(wrt)
    wrt.submit("only", at=0.01)
    wrt.run()
    assert wrt.summary()["n"] == 1         # completed via timer flushes
    assert wrt.batcher.timers_scheduled >= 1


# -- end to end: adaptive never loses to the tuned static window --------------

def run_mode(mode, n=160, shards=4, rate=320.0, deadline=0.5, window=None):
    g = rag_workflow(shards=shards)
    kw = mode_kwargs(mode)
    if window is not None and kw.get("batching"):
        kw["batch_policy"] = BatchPolicy(window=window)
    wrt = WorkflowRuntime(g, **kw)
    preload_index(wrt)
    for i in range(n):
        wrt.submit(f"req{i}", at=0.05 + i / rate, deadline=deadline)
    wrt.run()
    return wrt


def test_adaptive_is_accounting_transparent():
    a = run_mode("atomic")
    b = run_mode("atomic+abatch")
    assert set(a.tracker.records) == set(b.tracker.records)
    for inst, ra in a.tracker.records.items():
        rb = b.tracker.records[inst]
        assert ra.t_complete is not None and rb.t_complete is not None
        assert dict(ra.arrivals) == dict(rb.arrivals), inst
        assert dict(ra.fired) == dict(rb.fired), inst
        assert dict(ra.done) == dict(rb.done), inst


def test_adaptive_beats_or_matches_static_under_overload():
    """The fig9 claim at test scale: adaptive p99 <= best static p99
    across windows, same policy instance, no tuning."""
    static = [run_mode("atomic+batch", window=w).summary()["p99"]
              for w in (0.008, 0.016, 0.032)]
    adaptive = run_mode("atomic+abatch").summary()
    assert adaptive["p99"] <= min(static) * 1.001
    assert adaptive["plans"] > 0


def test_mode_kwargs_abatch_suffix():
    mk = mode_kwargs("atomic+abatch")
    assert mk["batching"] is False and mk["adaptive_batching"] is True
    assert mode_kwargs("atomic+batch")["adaptive_batching"] is False
    with pytest.raises(ValueError):
        mode_kwargs("atomic+abatch+bogus")


# -- benchmark regression deltas (run.py satellite) ---------------------------

def test_bench_deltas_flags_only_regressions():
    from benchmarks.common import bench_deltas
    prior = {"rows": [
        {"name": "x/a", "p99_ms": 100.0, "wall_s": 1.0},
        {"name": "x/b", "p99_ms": 50.0},
    ]}
    fresh = [("x/a", 0.0, {"p99_ms": 120.0, "wall_s": 1.1}),   # +20% p99
             ("x/b", 0.0, {"p99_ms": 50.0}),                   # unchanged
             ("x/new", 0.0, {"p99_ms": 1.0})]                  # no prior
    lines = bench_deltas("x", prior, fresh)
    assert any("x/a p99_ms 100.0 -> 120.0" in ln for ln in lines)
    assert not any("x/b" in ln for ln in lines)
    assert not any("x/new" in ln for ln in lines)
    assert "regressed" in lines[-1]
    assert bench_deltas("x", None, fresh) == []
