"""Fault injection + tolerance: node failures, shard failover, stragglers,
bounded retry budgets, and serving-row outages.

Failure semantics mirror a replicated Cascade deployment:
  * when a node dies, compute admissions still queued on it are
    re-dispatched to a surviving shard member (replication >= 2) or stall
    until recovery (replication == 1 — objects are memory-resident, so an
    unreplicated shard is unavailable);
  * work already in service when the node dies drains in place: the paper's
    deployments fail nodes out of *scheduling*, they do not model losing
    in-flight kernels, and this keeps lane accounting exact;
  * recovery re-admits the stalled queue through the normal release
    accounting (``Simulator.kick``) and then notifies listeners;
  * stragglers are modeled as per-node service-speed multipliers.

With a :class:`RetryPolicy`, a stalled entry is not abandoned to the
recovery kick: the injector probes it on an exponential backoff schedule
and fails it over the moment *any* shard member is back up — bounded by
``max_attempts`` and ``timeout``, after which the entry degrades to the
plain stall-until-recovery path (liveness is never lost, only the eager
re-dispatch).  The same policy class prices serving-turn retries in
``repro.serving.ServingEngine``, so both planes share one budget
vocabulary.

The injector is deliberately layer-blind: it only flips ``Node.up`` and
moves typed queue entries.  Higher layers subscribe via ``on_down`` /
``on_up`` to react in their own vocabulary — the workflow runtime re-pins
stranded gangs and migrates their objects, the autoscaler reads the down
fraction as SLO pressure, the stage batcher hedges batches stuck behind a
dead or straggling slot.  Serving rows are driven through the same
injector (``fail_row``): the engine owns the mechanics (failing in-flight
turns, re-routing session groups, pricing recovery), the injector owns
the schedule and the unified :class:`FailureEvent` record.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

from .executor import Runtime
from .simulation import _ComputeStart


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry/timeout/backoff budget for remote operations.

    ``max_attempts`` counts every attempt including the first dispatch;
    backoff before re-attempt ``k`` (1-based) is
    ``min(backoff * multiplier**(k-1), max_backoff)``.  ``timeout`` is the
    deadline-aware give-up: measured from the first failure, no re-attempt
    is scheduled past it.  Exhausting the budget degrades gracefully —
    DES entries fall back to stall-until-recovery, serving turns shed to
    the caller (admission's problem, not an infinite retry loop's).
    """
    max_attempts: int = 3
    backoff: float = 0.01
    multiplier: float = 2.0
    max_backoff: float = 1.0
    timeout: Optional[float] = None

    def backoff_of(self, attempt: int) -> float:
        """Backoff before re-attempt ``attempt`` (1-based)."""
        return min(self.backoff * self.multiplier ** (attempt - 1),
                   self.max_backoff)


@dataclasses.dataclass
class FailureEvent:
    """One scheduled down/up cycle, with per-event outcome counters.

    ``failed_over`` counts queued compute admissions re-dispatched to a
    surviving replica at down time; ``stalled`` counts entries that had no
    replica to go to and waited out the outage in place.  ``retries`` /
    ``retry_failovers`` / ``retries_exhausted`` account the backoff probes
    a :class:`RetryPolicy` fires against stalled entries.  The serving
    counters (``turns_failed``, ``sessions_displaced``,
    ``groups_rerouted``) are filled by the engine when the event targets a
    serving row instead of a DES node.

    ``kind`` records the triggering fault: ``"node"`` (independent kill),
    ``"domain"`` (correlated zone kill — one event per member node, all
    carrying the zone in ``domain``), ``"partition"`` (network split; the
    synthetic ``node`` names the minority group), or ``"row"`` (serving
    row).  ``domain`` is the failure-domain label of the affected node
    when it has one.
    """
    node: str
    t_down: float
    t_up: float
    kind: str = "node"
    domain: str = ""
    failed_over: int = 0
    stalled: int = 0
    retries: int = 0
    retry_failovers: int = 0
    retries_exhausted: int = 0
    turns_failed: int = 0
    sessions_displaced: int = 0
    groups_rerouted: int = 0


@dataclasses.dataclass
class AvailabilityReport:
    """Aggregate over every ``FailureEvent`` an injector has fired.

    ``domain_downtime`` sums node-outage seconds per failure-domain label
    (only nodes carrying a label appear); ``partition_time`` sums the
    wall-clock of every network split scheduled on the injector.
    """
    downtime: float
    tasks_failed_over: int
    tasks_stalled: int
    tasks_retried: int = 0
    turns_failed: int = 0
    sessions_displaced: int = 0
    domain_downtime: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    partition_time: float = 0.0


class FaultInjector:
    """Schedules outages against a :class:`Runtime`'s simulator and/or a
    serving engine's rows.

    ``on_down`` / ``on_up`` listeners are called as ``fn(event)`` after the
    injector has finished its own queue surgery, so listeners observe a
    consistent node state (``up`` flag set, queues settled).
    """

    def __init__(self, runtime: Optional[Runtime] = None,
                 serving: Optional[Any] = None,
                 retry: Optional[RetryPolicy] = None):
        self.rt = runtime
        self.serving = serving
        self.retry = retry
        self.events: List[FailureEvent] = []
        self.on_down: List[Callable[[FailureEvent], None]] = []
        self.on_up: List[Callable[[FailureEvent], None]] = []
        # network-split listeners: fn(event) at cut time / heal time
        self.on_partition: List[Callable[[FailureEvent], None]] = []
        self.on_heal: List[Callable[[FailureEvent], None]] = []
        self._active_partition: Optional[Dict[str, int]] = None

    def fail_node(self, node: str, at: float, duration: float) -> FailureEvent:
        assert self.rt is not None, "fail_node needs a DES runtime"
        if node not in self.rt.nodes:
            raise KeyError(f"unknown node {node!r}")
        ev = FailureEvent(node=node, t_down=at, t_up=at + duration,
                          domain=self.rt.nodes[node].domain)
        self.events.append(ev)
        self.rt.sim.at(at, self._down, ev)
        self.rt.sim.at(ev.t_up, self._up, ev)
        return ev

    def fail_domain(self, domain: str, at: float,
                    duration: float) -> List[FailureEvent]:
        """Correlated outage: kill every node labeled ``domain`` at the
        same instant (rack/zone loss).  One :class:`FailureEvent` per
        member, all stamped ``kind="domain"``, so per-node failover
        accounting stays exact while the report can aggregate the zone."""
        assert self.rt is not None, "fail_domain needs a DES runtime"
        members = sorted(n for n, nd in self.rt.nodes.items()
                         if nd.domain == domain)
        if not members:
            raise KeyError(f"no nodes in domain {domain!r}")
        evs = []
        for n in members:
            ev = FailureEvent(node=n, t_down=at, t_up=at + duration,
                              kind="domain", domain=domain)
            self.events.append(ev)
            self.rt.sim.at(at, self._down, ev)
            self.rt.sim.at(ev.t_up, self._up, ev)
            evs.append(ev)
        return evs

    def partition(self, groups: Sequence[Sequence[str]], at: float,
                  duration: float) -> FailureEvent:
        """Schedule a network split: nodes in different ``groups`` entries
        cannot reach each other for ``duration`` seconds (nodes in no
        entry form the implicit majority, group 0).  Nodes stay *up* —
        "up" no longer implies "reachable": replica reads, failover,
        hedging, and repair all route through ``Simulator.reachable``
        while the split is active.  Heal re-drives every read the cut
        parked.  One split at a time (a second cut replaces the first)."""
        assert self.rt is not None, "partition needs a DES runtime"
        pmap: Dict[str, int] = {}
        for gid, members in enumerate(groups):
            for n in members:
                if n not in self.rt.nodes:
                    raise KeyError(f"unknown node {n!r}")
                pmap[n] = gid
        minority = sorted(n for n, g in pmap.items() if g != 0)
        ev = FailureEvent(node="cut(" + ",".join(minority) + ")",
                          t_down=at, t_up=at + duration, kind="partition")
        self.events.append(ev)
        self.rt.sim.at(at, self._partition_start, (ev, pmap))
        self.rt.sim.at(ev.t_up, self._partition_heal, (ev, pmap))
        return ev

    def fail_row(self, row: int, at: float, duration: float) -> FailureEvent:
        """Schedule a serving-row outage; the engine owns the semantics
        (in-flight turns fail, sessions lose state and re-route, recovery
        is priced per session) — see ``ServingEngine.fail_row``."""
        assert self.serving is not None, "fail_row needs a serving engine"
        ev = self.serving.fail_row(row, at, duration)
        self.events.append(ev)
        return ev

    def report(self) -> AvailabilityReport:
        outages = [ev for ev in self.events if ev.kind != "partition"]
        per_domain: Dict[str, float] = {}
        for ev in outages:
            if ev.domain:
                per_domain[ev.domain] = per_domain.get(ev.domain, 0.0) \
                    + (ev.t_up - ev.t_down)
        return AvailabilityReport(
            downtime=sum(ev.t_up - ev.t_down for ev in outages),
            tasks_failed_over=sum(ev.failed_over for ev in self.events),
            tasks_stalled=sum(ev.stalled for ev in self.events),
            tasks_retried=sum(ev.retries for ev in self.events),
            turns_failed=sum(ev.turns_failed for ev in self.events),
            sessions_displaced=sum(ev.sessions_displaced
                                   for ev in self.events),
            domain_downtime=per_domain,
            partition_time=sum(ev.t_up - ev.t_down for ev in self.events
                               if ev.kind == "partition"))

    # -- event bodies -------------------------------------------------------

    def _down(self, ev: FailureEvent) -> None:
        sim = self.rt.sim
        node = self.rt.nodes[ev.node]
        node.up = False
        if sim.tracer is not None:
            # the recorder keeps per-node down intervals so lane waits
            # overlapping an outage are blamed fault_stall, not queueing
            sim.tracer.note_down(ev.node, sim.now)
        # Re-dispatch queued compute admissions to a surviving shard
        # member.  Only _ComputeStart entries move: they carry their op and
        # re-price at the target (requeue_compute keeps the pending-seconds
        # signal exact on both nodes).  Anything else queued (hedge lanes,
        # custom callbacks) stays put — its owner holds a reference and
        # decides for itself.
        for resource, q in list(node.queues.items()):
            stranded = list(q)
            q.clear()
            for enq, fn in stranded:
                target = None
                if isinstance(fn, _ComputeStart):
                    target = self._failover_target(ev.node)
                if target is None:
                    # no replica (or unmovable entry): stall until recovery
                    entry = (enq, fn)
                    q.append(entry)
                    ev.stalled += 1
                    if self.retry is not None and \
                            isinstance(fn, _ComputeStart):
                        sim.at(sim.now + self.retry.backoff_of(1),
                               self._retry_probe,
                               (ev, resource, entry, 2))
                else:
                    ev.failed_over += 1
                    sim.requeue_compute(fn, self.rt.nodes[target],
                                        enq_time=enq)
        for fn in self.on_down:
            fn(ev)

    def _retry_probe(self, arg) -> None:
        """One backoff probe for a stalled entry: fail it over if any
        shard member recovered, else re-arm within the budget.  Attempt
        numbers are 1-based over *placements* (the initial dispatch was
        attempt 1), so probes stop at ``max_attempts`` placements total —
        the budget invariant the chaos property test asserts."""
        ev, resource, entry, attempt = arg
        node = self.rt.nodes[ev.node]
        if node.up or entry not in node.queues[resource]:
            return      # recovery (or an earlier probe) already owns it
        ev.retries += 1
        target = self._failover_target(ev.node)
        if target is not None:
            node.queues[resource].remove(entry)
            enq, fn = entry
            ev.retry_failovers += 1
            self.rt.sim.requeue_compute(fn, self.rt.nodes[target],
                                        enq_time=enq)
            return
        sim = self.rt.sim
        if attempt < self.retry.max_attempts:
            delay = self.retry.backoff_of(attempt)
            if self.retry.timeout is None or \
                    sim.now + delay <= ev.t_down + self.retry.timeout:
                sim.at(sim.now + delay, self._retry_probe,
                       (ev, resource, entry, attempt + 1))
                return
        # budget exhausted: graceful degradation to stall-until-recovery
        ev.retries_exhausted += 1

    def _up(self, ev: FailureEvent) -> None:
        node = self.rt.nodes[ev.node]
        node.up = True
        if self.rt.sim.tracer is not None:
            self.rt.sim.tracer.note_up(ev.node, self.rt.sim.now)
        for resource in list(node.queues):
            self.rt.sim.kick(node, resource)
        for fn in self.on_up:
            fn(ev)

    # -- partition bodies ---------------------------------------------------

    def _partition_start(self, arg) -> None:
        ev, pmap = arg
        sim = self.rt.sim
        sim.partition = pmap
        sim.store.partition = pmap
        self._active_partition = pmap
        for fn in self.on_partition:
            fn(ev)

    def _partition_heal(self, arg) -> None:
        ev, pmap = arg
        if self._active_partition is not pmap:
            return                       # a later cut replaced this one
        self._active_partition = None
        self.rt.sim.heal_partition()
        for fn in self.on_heal:
            fn(ev)

    def _failover_target(self, failed: str) -> Optional[str]:
        # a surviving up member of any shard containing the failed node —
        # and, under a partition, one on the failed node's side of the
        # cut: its queue entries are only observable from there, so a
        # minority-side death cannot fail work over across the split
        sim = self.rt.sim
        for pool in self.rt.store.pools.values():
            for shard in pool.shards.values():
                if failed in shard.nodes:
                    for n in shard.nodes:
                        if n != failed and self.rt.nodes[n].up and \
                                sim.reachable(failed, n):
                            return n
        return None


def set_straggler(runtime: Runtime, node: str, speed: float) -> None:
    """speed < 1.0 slows the node's compute (e.g. 0.5 = 2x slower)."""
    runtime.nodes[node].speed = speed
