"""Affinity-driven prefetching (paper §3.4 'Prefetching' + §4.6 replication).

When a task with affinity key `a` is scheduled onto a node, every stored
object with the same affinity key is a prefetch candidate: the developer has
declared the correlation, so the platform can warm the node's cache *before*
the task (or a downstream stage) reads the objects.  The engine returns
prefetch plans; the runtime executes them (overlapping with compute) and the
store's cache makes subsequent gets local.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .object_store import CascadeStore, ObjectRecord


@dataclasses.dataclass
class PrefetchPlan:
    node: str
    keys: List[str]
    total_bytes: int


class PrefetchEngine:
    def __init__(self, store: CascadeStore, max_bytes_per_plan: int = 1 << 30):
        self.store = store
        self.max_bytes = max_bytes_per_plan
        self.issued: int = 0
        self.bytes_issued: int = 0

    def plan_for_task(self, pool_prefix: str, label: str, node: str
                      ) -> Optional[PrefetchPlan]:
        """All same-affinity objects not yet cached/local at `node`."""
        pool = self.store.pools[pool_prefix]
        keys, total = [], 0
        for shard in pool.shards.values():
            local = node in shard.nodes
            for k, rec in shard.objects.items():
                if rec.affinity != label:
                    continue
                if local:
                    continue
                cached = self.store.caches.get(node, {}).get(k)
                if cached is not None and cached.version == rec.version:
                    continue
                if total + rec.size > self.max_bytes:
                    break
                keys.append(k)
                total += rec.size
        if not keys:
            return None
        self.issued += 1
        self.bytes_issued += total
        return PrefetchPlan(node=node, keys=keys, total_bytes=total)

    def execute(self, plan: PrefetchPlan) -> int:
        """Warm the cache (the DES charges the transfer time separately)."""
        moved = 0
        for k in plan.keys:
            rec, local = self.store.get(k, node=plan.node)
            if rec is not None and not local:
                moved += rec.size
        return moved
