"""Fault tolerance under chaos: injector semantics (failover, stall,
recovery drain), workflow-atomic gang repair, replicated-read liveness,
hedged batch execution, and the randomized chaos accounting invariants
(slow tier)."""
import random

import pytest

from repro.core import CascadeStore, workflow_key
from repro.runtime import (Compute, FaultInjector, Node, RetryPolicy,
                           Runtime, set_straggler)
from repro.runtime.scheduler import hedge_candidates
from repro.workflows import (BatchPolicy, Emit, WorkflowGraph,
                             WorkflowRuntime, mode_kwargs)

RES = {"gpu": 1, "cpu": 2, "nic": 2}


def _bare(n=2, shards=1, replication=2):
    store = CascadeStore([f"n{i}" for i in range(n)])
    store.create_object_pool("/x", store.nodes, shards,
                             replication=replication,
                             affinity_set_regex=r"/[a-z0-9]+_")
    return Runtime(store), store


def _compute_job(rt, node, cost, done, tag, resource="gpu"):
    def gen():
        yield Compute(resource, cost)
        done[tag] = rt.sim.now
    rt.sim.spawn(node, gen())


# -- injector unit semantics --------------------------------------------------

def test_node_death_fails_queued_work_over_to_replica():
    """Queued compute moves to a surviving shard member; in-service work
    drains in place; pending accounting nets to zero on both nodes."""
    rt, _ = _bare(n=2, shards=1, replication=2)
    inj = FaultInjector(rt)
    done = {}
    for tag in ("j0", "j1", "j2"):
        _compute_job(rt, "n0", 0.1, done, tag)
    ev = inj.fail_node("n0", at=0.05, duration=10.0)
    rt.run()
    assert done["j0"] == pytest.approx(0.1)     # in service: drains in place
    assert done["j1"] == pytest.approx(0.15)    # failed over at t=0.05
    assert done["j2"] == pytest.approx(0.25)    # behind j1 on the replica
    assert ev.failed_over == 2 and ev.stalled == 0
    for node in rt.nodes.values():
        assert node.pending["gpu"] == pytest.approx(0.0)


def test_unreplicated_queue_stalls_until_recovery():
    rt, _ = _bare(n=2, shards=2, replication=1)
    inj = FaultInjector(rt)
    done = {}
    for tag in ("j0", "j1"):
        _compute_job(rt, "n0", 0.1, done, tag)
    ev = inj.fail_node("n0", at=0.05, duration=0.3)
    rt.run()
    assert done["j0"] == pytest.approx(0.1)
    assert done["j1"] == pytest.approx(0.45)    # t_up 0.35 + service 0.1
    assert ev.stalled == 1 and ev.failed_over == 0
    assert inj.report().downtime == pytest.approx(0.3)


def test_failover_target_prefers_up_shard_member():
    rt, _ = _bare(n=3, shards=1, replication=3)
    inj = FaultInjector(rt)
    assert inj._failover_target("n0") == "n1"
    rt.nodes["n1"].up = False
    assert inj._failover_target("n0") == "n2"
    rt.nodes["n2"].up = False
    assert inj._failover_target("n0") is None


def test_recovery_drain_respects_capacity():
    """kick() re-admits the stalled queue up to capacity with release
    accounting — not a free-for-all drain."""
    rt, _ = _bare(n=1, shards=1, replication=1)
    inj = FaultInjector(rt)
    inj.fail_node("n0", at=0.0, duration=0.3)
    done = {}
    # scheduled (not spawned inline) so the down event at t=0 fires first
    # and all five jobs park in the dead node's queue
    for i in range(5):
        rt.sim.at(0.0, lambda i=i: _compute_job(rt, "n0", 0.1, done,
                                                f"j{i}", resource="cpu"))
    probes = {}

    def probe():
        probes["in_use"] = rt.nodes["n0"].in_use["cpu"]
        probes["queued"] = len(rt.nodes["n0"].queues["cpu"])
    rt.sim.at(0.31, probe)
    rt.run()
    assert probes == {"in_use": 2, "queued": 3}      # cpu capacity is 2
    assert sorted(done.values()) == pytest.approx([0.4, 0.4, 0.5, 0.5,
                                                   0.6])
    assert rt.nodes["n0"].in_use["cpu"] == 0


def test_requeue_compute_transfers_pending_and_reprices():
    rt, _ = _bare(n=2, shards=1, replication=2)
    rt.nodes["n1"].speed = 0.5                  # half rate: re-priced 2x
    done = {}
    _compute_job(rt, "n0", 0.1, done, "a")
    _compute_job(rt, "n0", 0.1, done, "b")
    n0, n1 = rt.nodes["n0"], rt.nodes["n1"]
    assert n0.pending["gpu"] == pytest.approx(0.2)
    enq, entry = n0.queues["gpu"].popleft()
    rt.sim.requeue_compute(entry, n1, enq_time=enq)
    assert n0.pending["gpu"] == pytest.approx(0.1)
    assert n1.pending["gpu"] == pytest.approx(0.2)   # 0.1 / rate 0.5
    rt.run()
    assert done["a"] == pytest.approx(0.1)
    assert done["b"] == pytest.approx(0.2)           # started at 0 on n1
    assert n0.pending["gpu"] == pytest.approx(0.0)
    assert n1.pending["gpu"] == pytest.approx(0.0)


# -- bounded retry probes on stalled entries ----------------------------------

def test_retry_probe_fails_over_when_a_replica_recovers_early():
    """A stalled entry armed with a RetryPolicy re-dispatches on the
    first backoff probe that finds a recovered shard member — instead of
    sleeping out the dead node's full outage."""
    rt, _ = _bare(n=3, shards=1, replication=3)
    inj = FaultInjector(rt, retry=RetryPolicy(max_attempts=4, backoff=0.1))
    # every replica is down when n0 dies, so its queue must stall ...
    inj.fail_node("n1", at=0.0, duration=0.12)   # ... but n1 is back early
    inj.fail_node("n2", at=0.0, duration=100.0)
    done = {}
    for tag in ("j0", "j1"):
        _compute_job(rt, "n0", 0.1, done, tag)
    ev = inj.fail_node("n0", at=0.05, duration=10.0)
    rt.run(until=20.0)
    assert ev.stalled == 1                       # j1 had nowhere to go
    assert done["j0"] == pytest.approx(0.1)      # in service: drains
    # probe at t_down + backoff_of(1) = 0.15 finds n1 up and moves j1
    assert done["j1"] == pytest.approx(0.25)
    assert ev.retries == 1 and ev.retry_failovers == 1
    assert ev.retries_exhausted == 0
    assert ev.retries <= ev.stalled * (4 - 1)    # budget invariant


def test_retry_budget_exhaustion_degrades_to_stall_until_recovery():
    """max_attempts (or timeout) exhausted: the entry stays put and the
    recovery kick still completes it — liveness is never lost."""
    rt, _ = _bare(n=3, shards=1, replication=3)
    pol = RetryPolicy(max_attempts=4, backoff=0.1, multiplier=2.0)
    inj = FaultInjector(rt, retry=pol)
    inj.fail_node("n1", at=0.0, duration=100.0)
    inj.fail_node("n2", at=0.0, duration=100.0)
    done = {}
    for tag in ("j0", "j1"):
        _compute_job(rt, "n0", 0.1, done, tag)
    ev = inj.fail_node("n0", at=0.05, duration=1.0)
    rt.run(until=50.0)
    # probes at 0.15 / 0.35 / 0.75 all find nobody; attempt 4 is the last
    assert ev.retries == 3 and ev.retries_exhausted == 1
    assert ev.retry_failovers == 0
    assert ev.retries <= ev.stalled * (pol.max_attempts - 1)
    assert done["j1"] == pytest.approx(1.15)     # n0 up at 1.05 + 0.1


def test_retry_timeout_gives_up_before_max_attempts():
    rt, _ = _bare(n=3, shards=1, replication=3)
    inj = FaultInjector(rt, retry=RetryPolicy(max_attempts=8, backoff=0.1,
                                              timeout=0.15))
    inj.fail_node("n1", at=0.0, duration=100.0)
    inj.fail_node("n2", at=0.0, duration=100.0)
    done = {}
    for tag in ("j0", "j1"):
        _compute_job(rt, "n0", 0.1, done, tag)
    ev = inj.fail_node("n0", at=0.05, duration=1.0)
    rt.run(until=50.0)
    # probe at 0.15 is within budget; the next would land at 0.35, past
    # t_down + timeout = 0.2 — deadline-aware give-up
    assert ev.retries == 1 and ev.retries_exhausted == 1
    assert done["j1"] == pytest.approx(1.15)


# -- workflow-atomic gang repair ----------------------------------------------

def _wgraph(fast=2, cost=0.01):
    g = WorkflowGraph("chaos")
    g.add_tier("fast", fast, RES)
    g.add_pool("/in", tier="fast", shards=fast)
    g.add_pool("/out", tier="fast", shards=fast)
    g.add_stage("work", pool="/in", resource="gpu", cost=cost,
                emits=[Emit("/out", fanout=1, size=4096)], sink=True)
    return g.validate()


def test_node_death_repins_gangs_atomically_and_migrates_objects():
    wrt = WorkflowRuntime(_wgraph(), **mode_kwargs("atomic"))
    inj = wrt.enable_faults()
    inj.fail_node("fast0", at=0.03, duration=0.2)
    for i in range(20):
        wrt.submit(f"i{i}", at=0.001 + i * 0.005, size=2048)
    wrt.run()
    s = wrt.summary()
    assert s["n"] == 20                          # zero lost instances
    assert s["fault_repins"] > 0
    assert s["migrations"] > 0 and s["bytes_migrated"] > 0
    # every gang ends up off the dead slot, equal slot index in every pool
    anchor = wrt.store.pools["/in"].engine
    out_eng = wrt.store.pools["/out"].engine
    assert anchor.pins
    for lbl, sh in anchor.pins.items():
        idx = anchor.shards.index(sh)
        assert idx == 1                          # fast0's slot is s0
        assert out_eng.shards.index(out_eng.pins[lbl]) == idx


def _drive_outage(read_replicas, wire_faults):
    wrt = WorkflowRuntime(_wgraph(), read_replicas=read_replicas,
                          **mode_kwargs("atomic"))
    inj = wrt.enable_faults() if wire_faults else FaultInjector(wrt.rt)
    inj.fail_node("fast0", at=0.0, duration=5.0)
    for i in range(30):
        wrt.submit(f"i{i}", at=0.001 + i * 0.003, size=2048)
    wrt.run()
    return wrt


def test_replicated_reads_keep_instances_alive_through_outage():
    """With replication >= 2 an outage-long node loss costs latency, not
    liveness: every instance completes without waiting for recovery.
    The unreplicated, unrepaired contrast run strands the gangs placed
    on the dead slot until the node returns."""
    rep = _drive_outage(read_replicas=2, wire_faults=False)
    assert rep.summary()["n"] == 30
    assert max(r.t_complete
               for r in rep.tracker.records.values()) < 1.0
    naked = _drive_outage(read_replicas=1, wire_faults=False)
    assert naked.summary()["n"] == 30            # still zero lost
    assert max(r.t_complete
               for r in naked.tracker.records.values()) > 5.0


def test_fault_aware_admission_avoids_dead_slots():
    """Fresh gangs admitted during an outage never pin to a slot with no
    live member (policy placement is blind to Node.up; the fault-aware
    admission path is not)."""
    wrt = WorkflowRuntime(_wgraph(), **mode_kwargs("atomic"))
    inj = wrt.enable_faults()
    inj.fail_node("fast0", at=0.0, duration=5.0)
    for i in range(10):
        wrt.submit(f"i{i}", at=0.001 + i * 0.002)
    wrt.run()
    anchor = wrt.store.pools["/in"].engine
    assert len(anchor.pins) == 10
    assert all(anchor.shards.index(sh) == 1
               for sh in anchor.pins.values())
    assert max(r.t_complete
               for r in wrt.tracker.records.values()) < 1.0


# -- exactly-once ordered replay ----------------------------------------------

def _chain_graph(fast=2, cost=0.005):
    g = WorkflowGraph("chain")
    g.add_tier("fast", fast, RES)
    g.add_pool("/in", tier="fast", shards=fast)
    g.add_pool("/mid", tier="fast", shards=fast)
    g.add_pool("/out", tier="fast", shards=fast)
    g.add_stage("first", pool="/in", resource="gpu", cost=cost,
                emits=[Emit("/mid", fanout=1, size=1024)])
    g.add_stage("second", pool="/mid", resource="gpu", cost=cost,
                emits=[Emit("/out", fanout=1, size=1024)], sink=True)
    return g.validate()


def test_exactly_once_dedupes_replayed_triggers():
    """A re-delivered trigger key (client retry, failover replay) is
    dropped on its idempotence key: stage fired/done counters stay exact
    and the duplicate is counted, not executed."""
    wrt = WorkflowRuntime(_wgraph(), exactly_once=True,
                          **mode_kwargs("atomic"))
    for i in range(10):
        wrt.submit(f"i{i}", at=0.001 + i * 0.005, size=2048)
    for i in (2, 5):     # duplicated deliveries mid-run
        key = workflow_key(wrt.graph.source_pool, f"i{i}", "event", 0)
        wrt.rt.client_put(0.03 + i * 0.001, key, None, size=2048)
    wrt.run()
    s = wrt.summary()
    assert s["n"] == 10
    assert s["dup_triggers_dropped"] == 2
    for inst, rec in wrt.tracker.records.items():
        assert rec.arrivals["work"] == 1, inst
        assert rec.fired["work"] == 1 and rec.done["work"] == 1, inst
    assert wrt.sequencer.n_labels() == 0         # fully drained


def test_exactly_once_serializes_stages_per_group_in_order():
    """The sequencer gate admits one stage body per instance label at a
    time, in admission order — replays and parallel deliveries cannot
    reorder one group's effects; distinct groups stay concurrent."""
    wrt = WorkflowRuntime(_chain_graph(), exactly_once=True,
                          **mode_kwargs("atomic"))
    order = []
    wrt.on_sequenced = (
        lambda lbl, stage, key, t: order.append((lbl, stage)))
    for i in range(8):
        wrt.submit(f"i{i}", at=0.001 + i * 0.003)
    wrt.run()
    assert wrt.summary()["n"] == 8
    per_label = {}
    for lbl, stage in order:
        per_label.setdefault(lbl, []).append(stage)
    assert len(per_label) == 8
    for lbl, stages in per_label.items():
        assert stages == ["first", "second"], lbl   # per-group FIFO
    assert wrt.sequencer.n_labels() == 0
    assert wrt.sequencer.max_queue_len >= 1


def test_exactly_once_gate_is_latency_transparent_when_uncontended():
    """Without replays or faults a group's stages are already causally
    ordered, so every gate resolves before its WaitFor parks — turning
    exactly_once on reproduces the default run's completion times."""
    def drive(exactly_once):
        wrt = WorkflowRuntime(_chain_graph(),
                              exactly_once=exactly_once,
                              **mode_kwargs("atomic"))
        for i in range(12):
            wrt.submit(f"i{i}", at=0.001 + i * 0.002)
        wrt.run()
        return wrt

    base, gated = drive(False), drive(True)
    for inst, a in base.tracker.records.items():
        assert gated.tracker.records[inst].t_complete == a.t_complete


# -- hedged execution x StageBatcher ------------------------------------------

def _hedge_graph(members=2, cost=0.01):
    g = WorkflowGraph("hedge")
    g.add_tier("m", members, RES)
    g.add_pool("/in", tier="m", shards=1, replication=members)
    g.add_stage("work", pool="/in", resource="gpu", cost=cost, sink=True)
    return g.validate()


def test_hedge_candidates_excludes_primary_and_down_nodes():
    store = CascadeStore(["a", "b", "c"])
    store.create_object_pool("/x", ["a", "b", "c"], 1, replication=3,
                             affinity_set_regex=r"/[a-z0-9]+_")
    nodes = {n: Node(n, dict(RES)) for n in "abc"}
    shard = store.shard_of("/x/k_0")
    assert hedge_candidates(store, shard, "/x/k_0", nodes,
                            exclude=("a",)) == ["b", "c"]
    nodes["b"].up = False
    assert hedge_candidates(store, shard, "/x/k_0", nodes,
                            exclude=("a",)) == ["c"]


def test_hedge_rescues_batch_stuck_on_straggler():
    """A batch in service on a crawling node is duplicated to the replica
    after hedge_after; the winner resolves the shared future, the loser
    is cancelled with its backlog refunded and only its rendered service
    billed."""
    wrt = WorkflowRuntime(_hedge_graph(), hedge_after=0.02,
                          **mode_kwargs("atomic+batch"))
    set_straggler(wrt.rt, "m0", 1e-3)
    for i, at in enumerate((0.0, 0.001, 0.002, 0.003)):
        wrt.submit(f"i{i}", at=at)
    wrt.run()
    s = wrt.summary()
    assert s["n"] == 4
    assert wrt.rt.hedges >= 1
    assert max(r.t_complete
               for r in wrt.tracker.records.values()) < 0.1
    m0, m1 = wrt.rt.nodes["m0"], wrt.rt.nodes["m1"]
    # loser-lane cancellation refunded the backlog seconds
    assert m0.pending["gpu"] == pytest.approx(0.0)
    assert m1.pending["gpu"] == pytest.approx(0.0)
    # mid-service cancel bills only the service actually rendered (the
    # straggler's full batch would have billed ~10s)
    assert 0.0 < m0.busy_time["gpu"] < 0.1
    # a hedged batch lands exactly once in the coalescing stats
    assert sum(wrt.rt.sim.metrics["batch_sizes"]) == wrt.batcher.enrolled


def test_hedge_rescues_batch_queued_on_dead_node():
    wrt = WorkflowRuntime(_hedge_graph(), hedge_after=0.005,
                          batch_policy=BatchPolicy(window=0.0005),
                          **mode_kwargs("atomic+batch"))
    inj = wrt.enable_faults()
    # i0/i1 occupy both lanes; i2's batch queues on m0, which then dies
    for i, at in enumerate((0.0, 0.001, 0.002)):
        wrt.submit(f"i{i}", at=at)
    ev = inj.fail_node("m0", at=0.003, duration=10.0)
    wrt.run()
    recs = wrt.tracker.records
    assert wrt.summary()["n"] == 3
    assert wrt.rt.hedges >= 1
    assert max(r.t_complete for r in recs.values()) < 1.0   # not 10+
    assert ev.stalled == 1          # the dead batch lane stayed queued
    for node in wrt.rt.nodes.values():
        assert node.pending["gpu"] == pytest.approx(0.0)
        assert node.in_use["gpu"] == 0      # recovery drained the no-op


def test_hedging_is_accounting_transparent_when_it_never_fires():
    """hedge_after large enough to never trigger: per-instance completion
    times and arrival/fired/done counters are identical to the unhedged
    run, batch stats included."""
    def drive(hedge_after):
        wrt = WorkflowRuntime(_hedge_graph(), hedge_after=hedge_after,
                              **mode_kwargs("atomic+batch"))
        for i in range(20):
            wrt.submit(f"i{i}", at=i * 0.002)
        wrt.run()
        return wrt

    plain, hedged = drive(None), drive(10.0)
    assert hedged.rt.hedges == 0
    assert plain.batcher.n_batches == hedged.batcher.n_batches
    assert plain.rt.sim.metrics["batch_sizes"] == \
        hedged.rt.sim.metrics["batch_sizes"]
    for inst, a in plain.tracker.records.items():
        b = hedged.tracker.records[inst]
        assert a.t_complete == b.t_complete, inst
        assert dict(a.arrivals) == dict(b.arrivals)
        assert dict(a.fired) == dict(b.fired)
        assert dict(a.done) == dict(b.done)
    # forming_seconds never double-counts: everything flushed and closed
    assert not plain.batcher._open and not hedged.batcher._open


# -- partition reachability at dispatch ---------------------------------------

def test_replica_scheduler_prefers_reachable_replica_over_unreachable_home():
    """Under a partition "up" is not "usable": dispatch is client-driven
    and the client sits on the majority side (group 0), so a minority-side
    home shard — alive, idle-looking — must lose to a reachable replica
    member even when the replica carries queued work."""
    from repro.core import HashPlacement, ReplicatedPlacement
    from repro.runtime import ReplicaScheduler, dispatchable

    store = CascadeStore([f"n{i}" for i in range(8)])
    store.create_object_pool("/p", store.nodes, 8,
                             affinity_set_regex=r"/[a-z0-9]+_[0-9]+_",
                             policy=ReplicatedPlacement(HashPlacement(),
                                                        n_replicas=2))
    store.put("/p/vid_1_0", b"x")
    home = store.shard_of("/p/vid_1_0")
    homes = store.pools["/p"].replica_homes("/p/vid_1_0")
    replica = next(h for h in homes if h.name != home.name)
    nodes = {n: Node(n, dict(RES)) for n in store.nodes}
    sched = ReplicaScheduler(store)
    members = {n for h in homes for n in h.nodes}

    # fault-free: any replica member is a legal pick
    assert sched.pick(home, "/p/vid_1_0", nodes, store.nodes) in members

    # cut the home's members onto the minority side; leave them up and
    # idle while the reachable replica carries work — reachability must
    # dominate the load signal
    store.partition = {n: 1 for n in home.nodes}
    for n in replica.nodes:
        nodes[n].in_use["gpu"] = 1
    assert all(not dispatchable(store, n, nodes) for n in home.nodes)
    picked = sched.pick(home, "/p/vid_1_0", nodes, store.nodes)
    assert picked in replica.nodes and picked not in home.nodes
    picked = sched.pick_batch(home, ["/p/vid_1_0"], nodes, store.nodes,
                              resource="gpu")
    assert picked in replica.nodes and picked not in home.nodes

    # heal: the home's members become dispatchable again
    store.partition = None
    assert all(dispatchable(store, n, nodes) for n in home.nodes)
    for n in replica.nodes:
        nodes[n].in_use["gpu"] = 0
    assert sched.pick(home, "/p/vid_1_0", nodes, store.nodes) in members


# -- randomized chaos property (slow job) -------------------------------------

def _chaos_trial(rng):
    """One randomized chaos episode: random workflow shape, random fault
    schedule, then the accounting invariants that must hold regardless —
    no instance lost or duplicated, admitted = completed + rejected, and
    the gang equal-slot invariant after every re-pin."""
    from repro.workflows import (WORKFLOW_SHAPES, preload_adapters,
                                 preload_index)

    shape = rng.choice(sorted(WORKFLOW_SHAPES))
    shards = rng.randint(2, 3)
    domains = rng.choice([1, 2])
    replicas = rng.choice([1, 2])
    mode = rng.choice(["atomic", "atomic+batch", "atomic+abatch"])
    hedge = rng.choice([None, 0.02]) if mode != "atomic" else None
    admission = rng.choice([None, "reject"])
    exactly_once = rng.choice([False, True])
    retry = rng.choice([None, RetryPolicy(
        max_attempts=rng.randint(2, 4), backoff=0.01,
        timeout=rng.choice([None, 0.2]))])
    n_inst = rng.randint(10, 30)
    rate = rng.uniform(100.0, 400.0)

    graph = WORKFLOW_SHAPES[shape](shards=shards)
    if domains > 1:
        # stripe the primary tier over failure domains: placement spreads
        # replicas anti-affinity and the fault schedule below may take a
        # whole zone down at once
        graph.tiers[shape].domains = domains
    wrt = WorkflowRuntime(graph, read_replicas=replicas,
                          hedge_after=hedge, admission=admission,
                          exactly_once=exactly_once,
                          **mode_kwargs(mode))
    if shape == "rag":
        preload_index(wrt)
    inj = wrt.enable_faults(retry=retry)
    if exactly_once:
        # instrument the gate: at most one body per label at a time — the
        # mutual exclusion the per-group FIFO guarantee rests on
        active = set()
        orig_ready = wrt.sequencer.ready
        orig_complete = wrt.sequencer.complete

        def seq_ready(lbl):
            item = orig_ready(lbl)
            if item is not None:
                assert lbl not in active, lbl
                active.add(lbl)
            return item

        def seq_complete(lbl):
            active.discard(lbl)
            orig_complete(lbl)

        wrt.sequencer.ready = seq_ready
        wrt.sequencer.complete = seq_complete
    horizon = n_inst / rate
    tier_nodes = graph.tiers[shape].nodes
    for _ in range(rng.randint(1, 3)):
        inj.fail_node(rng.choice(tier_nodes),
                      at=rng.uniform(0.0, horizon),
                      duration=rng.uniform(0.01, 0.5))
    if domains > 1 and rng.random() < 0.5:
        # correlated outage: a whole zone dies at once
        inj.fail_domain(f"{shape}-d{rng.randrange(domains)}",
                        at=rng.uniform(0.0, horizon),
                        duration=rng.uniform(0.01, 0.5))
    partitioned = rng.random() < 0.5
    if partitioned:
        # network split: a random strict subset of the primary tier is cut
        # off (up but unreachable) for a while mid-stream
        minority = rng.sample(sorted(tier_nodes),
                              rng.randint(1, max(1, len(tier_nodes) - 1)))
        inj.partition(((), minority), at=rng.uniform(0.0, horizon),
                      duration=rng.uniform(0.01, 0.3))
    deadline = 1.0 if admission else None
    for i in range(n_inst):
        wrt.submit(f"i{i}", at=0.001 + i / rate, deadline=deadline)
        if shape == "agent":
            # the act stage's required adapter reads (same virtual time
            # as the submit, so gang pins place them)
            preload_adapters(wrt, f"i{i}", at=0.001 + i / rate)
    n_dups = 0
    if exactly_once and admission is None:
        # duplicated trigger deliveries (client retries / replays): the
        # idempotence key must absorb every one of them
        for i in rng.sample(range(n_inst), k=min(3, n_inst)):
            key = workflow_key(graph.source_pool, f"i{i}", "event", 0)
            wrt.rt.client_put(0.001 + i / rate + rng.uniform(1e-4, horizon),
                              key, None, size=0)
            n_dups += 1
    wrt.run()

    # admitted = completed + rejected, and nothing lost
    assert wrt.tracker.admitted + wrt.admission_rejects == n_inst
    assert wrt.tracker.e2e.count == wrt.tracker.admitted
    # zero lost or duplicated per-stage events on every instance
    for inst, rec in wrt.tracker.records.items():
        for s in graph.stages:
            assert rec.fired[s.name] == s.firings, (inst, s.name)
            assert rec.done[s.name] == s.firings, (inst, s.name)
            assert rec.arrivals[s.name] == s.expected_arrivals, \
                (inst, s.name)
    # gang equal-slot invariant preserved after every re-pin
    anchor = wrt.store.pools[wrt.anchor_pool].engine
    for lbl, sh in anchor.pins.items():
        idx = anchor.shards.index(sh)
        for prefix in wrt._instance_pools:
            eng = wrt.store.pools[prefix].engine
            assert eng.shards.index(eng.pins[lbl]) == idx, (lbl, prefix)
    # every node's lane accounting settled
    for node in wrt.rt.nodes.values():
        for r in ("gpu", "cpu"):
            assert node.pending[r] == pytest.approx(0.0, abs=1e-9)
    # retry probes stayed inside the budget on every event
    if retry is not None:
        for ev in inj.events:
            assert ev.retries <= ev.stalled * (retry.max_attempts - 1)
            assert ev.retry_failovers + ev.retries_exhausted <= ev.stalled
    # every duplicated delivery was absorbed, none executed (the fired /
    # done exactness above already proves no duplicate completions), and
    # the sequencer drained back to its bounded-empty state.  With a
    # partition in the schedule the same exactness holds ACROSS the cut:
    # the fired/done equality above is the zero-double-commit witness,
    # and the gate instrumentation saw at most one body per label even
    # while work was parked at the boundary
    if exactly_once:
        if n_dups:
            assert wrt.dup_triggers_dropped >= n_dups
        assert wrt.sequencer.n_labels() == 0
        assert not active
    # the cut healed and left nothing parked behind: zero pending leak
    # across the partition boundary
    if partitioned:
        sim = wrt.rt.sim
        assert sim.partition is None
        assert not sim._partition_parked
        assert not sim._partition_parked_calls


try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    @pytest.mark.slow
    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(0, 2**32 - 1))
    def test_chaos_accounting_invariants(seed):
        _chaos_trial(random.Random(seed))
except ImportError:
    # hypothesis is an optional test dep: fall back to fixed-seed trials
    # so the chaos invariants still execute in minimal environments
    @pytest.mark.slow
    @pytest.mark.parametrize("seed", range(25))
    def test_chaos_accounting_invariants(seed):
        _chaos_trial(random.Random(seed))
