"""Fault injection + tolerance: node failures, shard failover, stragglers,
bounded retry budgets, and serving-row outages.

Failure semantics mirror a replicated Cascade deployment:
  * when a node dies, compute admissions still queued on it are
    re-dispatched to a surviving shard member (replication >= 2) or stall
    until recovery (replication == 1 — objects are memory-resident, so an
    unreplicated shard is unavailable);
  * work already in service when the node dies drains in place: the paper's
    deployments fail nodes out of *scheduling*, they do not model losing
    in-flight kernels, and this keeps lane accounting exact;
  * recovery re-admits the stalled queue through the normal release
    accounting (``Simulator.kick``) and then notifies listeners;
  * stragglers are modeled as per-node service-speed multipliers.

With a :class:`RetryPolicy`, a stalled entry is not abandoned to the
recovery kick: the injector probes it on an exponential backoff schedule
and fails it over the moment *any* shard member is back up — bounded by
``max_attempts`` and ``timeout``, after which the entry degrades to the
plain stall-until-recovery path (liveness is never lost, only the eager
re-dispatch).  The same policy class prices serving-turn retries in
``repro.serving.ServingEngine``, so both planes share one budget
vocabulary.

The injector is deliberately layer-blind: it only flips ``Node.up`` and
moves typed queue entries.  Higher layers subscribe via ``on_down`` /
``on_up`` to react in their own vocabulary — the workflow runtime re-pins
stranded gangs and migrates their objects, the autoscaler reads the down
fraction as SLO pressure, the stage batcher hedges batches stuck behind a
dead or straggling slot.  Serving rows are driven through the same
injector (``fail_row``): the engine owns the mechanics (failing in-flight
turns, re-routing session groups, pricing recovery), the injector owns
the schedule and the unified :class:`FailureEvent` record.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional

from .executor import Runtime
from .simulation import _ComputeStart


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry/timeout/backoff budget for remote operations.

    ``max_attempts`` counts every attempt including the first dispatch;
    backoff before re-attempt ``k`` (1-based) is
    ``min(backoff * multiplier**(k-1), max_backoff)``.  ``timeout`` is the
    deadline-aware give-up: measured from the first failure, no re-attempt
    is scheduled past it.  Exhausting the budget degrades gracefully —
    DES entries fall back to stall-until-recovery, serving turns shed to
    the caller (admission's problem, not an infinite retry loop's).
    """
    max_attempts: int = 3
    backoff: float = 0.01
    multiplier: float = 2.0
    max_backoff: float = 1.0
    timeout: Optional[float] = None

    def backoff_of(self, attempt: int) -> float:
        """Backoff before re-attempt ``attempt`` (1-based)."""
        return min(self.backoff * self.multiplier ** (attempt - 1),
                   self.max_backoff)


@dataclasses.dataclass
class FailureEvent:
    """One scheduled down/up cycle, with per-event outcome counters.

    ``failed_over`` counts queued compute admissions re-dispatched to a
    surviving replica at down time; ``stalled`` counts entries that had no
    replica to go to and waited out the outage in place.  ``retries`` /
    ``retry_failovers`` / ``retries_exhausted`` account the backoff probes
    a :class:`RetryPolicy` fires against stalled entries.  The serving
    counters (``turns_failed``, ``sessions_displaced``,
    ``groups_rerouted``) are filled by the engine when the event targets a
    serving row instead of a DES node.
    """
    node: str
    t_down: float
    t_up: float
    failed_over: int = 0
    stalled: int = 0
    retries: int = 0
    retry_failovers: int = 0
    retries_exhausted: int = 0
    turns_failed: int = 0
    sessions_displaced: int = 0
    groups_rerouted: int = 0


@dataclasses.dataclass
class AvailabilityReport:
    """Aggregate over every ``FailureEvent`` an injector has fired."""
    downtime: float
    tasks_failed_over: int
    tasks_stalled: int
    tasks_retried: int = 0
    turns_failed: int = 0
    sessions_displaced: int = 0


class FaultInjector:
    """Schedules outages against a :class:`Runtime`'s simulator and/or a
    serving engine's rows.

    ``on_down`` / ``on_up`` listeners are called as ``fn(event)`` after the
    injector has finished its own queue surgery, so listeners observe a
    consistent node state (``up`` flag set, queues settled).
    """

    def __init__(self, runtime: Optional[Runtime] = None,
                 serving: Optional[Any] = None,
                 retry: Optional[RetryPolicy] = None):
        self.rt = runtime
        self.serving = serving
        self.retry = retry
        self.events: List[FailureEvent] = []
        self.on_down: List[Callable[[FailureEvent], None]] = []
        self.on_up: List[Callable[[FailureEvent], None]] = []

    def fail_node(self, node: str, at: float, duration: float) -> FailureEvent:
        assert self.rt is not None, "fail_node needs a DES runtime"
        if node not in self.rt.nodes:
            raise KeyError(f"unknown node {node!r}")
        ev = FailureEvent(node=node, t_down=at, t_up=at + duration)
        self.events.append(ev)
        self.rt.sim.at(at, self._down, ev)
        self.rt.sim.at(ev.t_up, self._up, ev)
        return ev

    def fail_row(self, row: int, at: float, duration: float) -> FailureEvent:
        """Schedule a serving-row outage; the engine owns the semantics
        (in-flight turns fail, sessions lose state and re-route, recovery
        is priced per session) — see ``ServingEngine.fail_row``."""
        assert self.serving is not None, "fail_row needs a serving engine"
        ev = self.serving.fail_row(row, at, duration)
        self.events.append(ev)
        return ev

    def report(self) -> AvailabilityReport:
        return AvailabilityReport(
            downtime=sum(ev.t_up - ev.t_down for ev in self.events),
            tasks_failed_over=sum(ev.failed_over for ev in self.events),
            tasks_stalled=sum(ev.stalled for ev in self.events),
            tasks_retried=sum(ev.retries for ev in self.events),
            turns_failed=sum(ev.turns_failed for ev in self.events),
            sessions_displaced=sum(ev.sessions_displaced
                                   for ev in self.events))

    # -- event bodies -------------------------------------------------------

    def _down(self, ev: FailureEvent) -> None:
        sim = self.rt.sim
        node = self.rt.nodes[ev.node]
        node.up = False
        if sim.tracer is not None:
            # the recorder keeps per-node down intervals so lane waits
            # overlapping an outage are blamed fault_stall, not queueing
            sim.tracer.note_down(ev.node, sim.now)
        # Re-dispatch queued compute admissions to a surviving shard
        # member.  Only _ComputeStart entries move: they carry their op and
        # re-price at the target (requeue_compute keeps the pending-seconds
        # signal exact on both nodes).  Anything else queued (hedge lanes,
        # custom callbacks) stays put — its owner holds a reference and
        # decides for itself.
        for resource, q in list(node.queues.items()):
            stranded = list(q)
            q.clear()
            for enq, fn in stranded:
                target = None
                if isinstance(fn, _ComputeStart):
                    target = self._failover_target(ev.node)
                if target is None:
                    # no replica (or unmovable entry): stall until recovery
                    entry = (enq, fn)
                    q.append(entry)
                    ev.stalled += 1
                    if self.retry is not None and \
                            isinstance(fn, _ComputeStart):
                        sim.at(sim.now + self.retry.backoff_of(1),
                               self._retry_probe,
                               (ev, resource, entry, 2))
                else:
                    ev.failed_over += 1
                    sim.requeue_compute(fn, self.rt.nodes[target],
                                        enq_time=enq)
        for fn in self.on_down:
            fn(ev)

    def _retry_probe(self, arg) -> None:
        """One backoff probe for a stalled entry: fail it over if any
        shard member recovered, else re-arm within the budget.  Attempt
        numbers are 1-based over *placements* (the initial dispatch was
        attempt 1), so probes stop at ``max_attempts`` placements total —
        the budget invariant the chaos property test asserts."""
        ev, resource, entry, attempt = arg
        node = self.rt.nodes[ev.node]
        if node.up or entry not in node.queues[resource]:
            return      # recovery (or an earlier probe) already owns it
        ev.retries += 1
        target = self._failover_target(ev.node)
        if target is not None:
            node.queues[resource].remove(entry)
            enq, fn = entry
            ev.retry_failovers += 1
            self.rt.sim.requeue_compute(fn, self.rt.nodes[target],
                                        enq_time=enq)
            return
        sim = self.rt.sim
        if attempt < self.retry.max_attempts:
            delay = self.retry.backoff_of(attempt)
            if self.retry.timeout is None or \
                    sim.now + delay <= ev.t_down + self.retry.timeout:
                sim.at(sim.now + delay, self._retry_probe,
                       (ev, resource, entry, attempt + 1))
                return
        # budget exhausted: graceful degradation to stall-until-recovery
        ev.retries_exhausted += 1

    def _up(self, ev: FailureEvent) -> None:
        node = self.rt.nodes[ev.node]
        node.up = True
        if self.rt.sim.tracer is not None:
            self.rt.sim.tracer.note_up(ev.node, self.rt.sim.now)
        for resource in list(node.queues):
            self.rt.sim.kick(node, resource)
        for fn in self.on_up:
            fn(ev)

    def _failover_target(self, failed: str) -> Optional[str]:
        # a surviving up member of any shard containing the failed node
        for pool in self.rt.store.pools.values():
            for shard in pool.shards.values():
                if failed in shard.nodes:
                    for n in shard.nodes:
                        if n != failed and self.rt.nodes[n].up:
                            return n
        return None


def set_straggler(runtime: Runtime, node: str, speed: float) -> None:
    """speed < 1.0 slows the node's compute (e.g. 0.5 = 2x slower)."""
    runtime.nodes[node].speed = speed
