"""Session registry + affinity routing for the serving engine.

Paper §7.2 applied: a session's decode state (KV cache / SSM state / LRU
state) and its LoRA adapter are *data objects*; each decode request is a
*task*.  One affinity function covers both: requests and state share the
session's affinity key, so the placement engine sends every turn of a
session to the row that already holds its state.  Baselines (random /
least-loaded) are exactly the cloud load-balancer patterns of paper §5 and
pay a state-migration penalty whenever the row changes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.core import (CallableAffinity, Descriptor, PlacementEngine,
                        stable_hash)


@dataclasses.dataclass
class Session:
    sid: str
    adapter: Optional[str] = None
    row: Optional[int] = None        # current home row
    slot: Optional[int] = None
    length: int = 0                  # tokens in decode state
    turns: int = 0
    migrations: int = 0
    migrated_bytes: int = 0
    # -- recovery state (serving fault tolerance) ---------------------------
    # the exact token sequence fed into the decode cache (prompts + the
    # replayed decode inputs): replaying it through the prefill path
    # reconstructs the cache bit-for-bit, so losing a row never loses a
    # session — only time
    transcript: List[int] = dataclasses.field(default_factory=list)
    lost_state: bool = False         # row died under us; state must rebuild
    ckpt: Any = None                 # periodic KV snapshot (device tree)
    ckpt_len: int = 0                # transcript prefix the snapshot covers
    recoveries: int = 0
    shed: int = 0                    # turns given up after retry budget


class SessionRouter:
    """policy: 'affinity' | 'adapter_affinity' | 'random' | 'least_loaded'"""

    def __init__(self, n_rows: int, policy: str = "affinity", seed: int = 0):
        self.n_rows = n_rows
        self.policy = policy
        self._rr = stable_hash(str(seed))

        def fn(desc: Descriptor):
            if policy == "affinity":
                return desc.get("sid")
            if policy == "adapter_affinity":
                return desc.get("adapter") or desc.get("sid")
            return None   # random baseline: hash the unique request key

        self.engine = PlacementEngine(
            [str(i) for i in range(n_rows)],
            affinity_fn=CallableAffinity(fn, name=policy))

    def route(self, session: Session, request_id: str,
              row_loads: Optional[List] = None) -> int:
        # row_loads entries are any comparable load signal; the engine
        # passes (no-free-lane, virtual backlog, active sessions) tuples
        # so least-loaded dispatch prefers free lanes and shallow queues
        if self.policy == "least_loaded" and row_loads is not None:
            return min(range(self.n_rows), key=lambda r: row_loads[r])
        desc = Descriptor.of(f"/requests/{request_id}", kind="task",
                             sid=session.sid, adapter=session.adapter)
        return int(self.engine.place(desc).shard)

    # -- group migration (serving side) -------------------------------------

    def label_of(self, session: Session) -> str:
        """The session's affinity-group label under the active policy."""
        desc = Descriptor.of(f"/requests/{session.sid}:probe", kind="task",
                             sid=session.sid, adapter=session.adapter)
        return self.engine.place(desc).label

    def pin_group(self, label: str, row: int) -> None:
        """Re-home a whole session group; every member's next turn follows
        (paying its state migration once) — serving-side GroupMigrator."""
        self.engine.pin(label, str(row))

    def unpin_group(self, label: str) -> None:
        self.engine.unpin(label)
