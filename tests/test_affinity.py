"""Core affinity-grouping mechanism: paper §3/§4.3 semantics."""
import numpy as np
import pytest

from repro.core import (AtomicGroupUpdate, CascadeStore, Descriptor,
                        GroupRegistry, GroupSequencer, HashPlacement,
                        InstrumentedAffinity, PlacementEngine, PrefetchEngine,
                        RegexAffinity, RendezvousPlacement, ServiceClientAPI,
                        stable_hash)

# -- Table 1 regex fidelity ---------------------------------------------------

TABLE1 = [
    ("/frames", "/frames/little3_42", r"/[a-zA-Z0-9]+_", "/little3_"),
    ("/states", "/states/little3_42", r"/[a-zA-Z0-9]+_", "/little3_"),
    ("/positions", "/positions/little3_7_42", r"/[a-zA-Z0-9]+_[0-9]+_",
     "/little3_7_"),
    ("/predictions", "/predictions/little3_42_7", r"/[a-zA-Z0-9]+_[0-9]+_",
     "/little3_42_"),
]


@pytest.mark.parametrize("pool,key,regex,want", TABLE1)
def test_table1_affinity_keys(pool, key, regex, want):
    store = CascadeStore([f"n{i}" for i in range(4)])
    store.create_object_pool(pool, store.nodes, 4, affinity_set_regex=regex)
    assert store.affinity_of(key) == want


def test_listing1_api():
    """Paper Listing 1: create pools with/without grouping."""
    store = CascadeStore(["n0", "n1", "n2", "n3"])
    capi = ServiceClientAPI(store)
    capi.create_object_pool("/no_grouping")
    capi.create_object_pool("/grouping", affinity_set_regex="_[0-9]+")
    capi.put("/no_grouping/example_1", None)
    capi.put("/grouping/example_1", None)
    assert capi.get_affinity_key("/grouping/example_1") == "_1"
    # ungrouped pool: affinity key degrades to the raw (pool-relative) key
    assert capi.get_affinity_key("/no_grouping/example_1") == "/example_1"


def test_same_affinity_same_shard():
    store = CascadeStore([f"n{i}" for i in range(8)])
    store.create_object_pool("/positions", store.nodes, 8,
                             affinity_set_regex=r"/[a-z0-9]+_[0-9]+_")
    shards = {store.shard_of(f"/positions/little3_7_{f}").name
              for f in range(50)}
    assert len(shards) == 1, "one actor's positions must collocate"


def test_different_groups_spread():
    store = CascadeStore([f"n{i}" for i in range(8)])
    store.create_object_pool("/positions", store.nodes, 8,
                             affinity_set_regex=r"/[a-z0-9]+_[0-9]+_")
    shards = {store.shard_of(f"/positions/little3_{a}_0").name
              for a in range(64)}
    assert len(shards) >= 6, "groups should load-balance across shards"


def test_task_and_data_collocate():
    """Unified placement: a trigger routes to the object's home shard."""
    store = CascadeStore([f"n{i}" for i in range(6)])
    store.create_object_pool("/positions", store.nodes, 6,
                             affinity_set_regex=r"/[a-z0-9]+_[0-9]+_")
    data_shard, _ = store.put("/positions/vid_3_10", b"x")
    task_shard, _ = store.trigger("/positions/vid_3_11")
    assert data_shard.name == task_shard.name


def test_rendezvous_minimal_movement():
    labels = [f"group_{i}" for i in range(500)]
    pol = RendezvousPlacement()
    old = [f"s{i}" for i in range(8)]
    new = old + ["s8"]
    moved = sum(pol.place(l, old) != pol.place(l, new) for l in labels)
    # HRW: only ~1/9 of groups move, and only TO the new shard
    assert moved < 500 * 2 / 9
    for l in labels:
        if pol.place(l, old) != pol.place(l, new):
            assert pol.place(l, new) == "s8"


def test_hash_placement_balance():
    pol = HashPlacement()
    shards = [f"s{i}" for i in range(10)]
    counts = {s: 0 for s in shards}
    for i in range(5000):
        counts[pol.place(f"label{i}", shards)] += 1
    assert max(counts.values()) < 2.0 * min(counts.values())


def test_affinity_overhead_micro():
    """Paper §4.3: regex matching must be cheap (<300us; re is ~us)."""
    fn = InstrumentedAffinity(RegexAffinity(r"/[a-zA-Z0-9]+_[0-9]+_"))
    d = Descriptor.of("/little3_7_42")
    for _ in range(2000):
        fn(d)
    assert fn.stats.mean_us < 300.0


def test_group_sequencer_fifo():
    seq = GroupSequencer()
    for i in range(5):
        seq.admit("g", i)
    out = []
    while True:
        item = seq.ready("g")
        if item is None:
            break
        out.append(item)
        seq.complete("g")
    assert out == [0, 1, 2, 3, 4]


def test_sequencer_groups_independent():
    seq = GroupSequencer()
    seq.admit("a", 1)
    seq.admit("b", 2)
    assert seq.ready("a") == 1
    assert seq.ready("b") == 2      # 'a' being busy doesn't block 'b'
    assert seq.ready("a") is None   # 'a' is busy


def test_atomic_group_update():
    store = CascadeStore([f"n{i}" for i in range(4)])
    store.create_object_pool("/positions", store.nodes, 4,
                             affinity_set_regex=r"/[a-z0-9]+_[0-9]+_")
    AtomicGroupUpdate(store).apply([
        (f"/positions/vid_1_{f}", b"p") for f in range(8)])
    with pytest.raises(ValueError):
        AtomicGroupUpdate(store).apply([
            ("/positions/vid_1_0", b"p"), ("/positions/vid_2_0", b"p")])
    with pytest.raises(ValueError):
        AtomicGroupUpdate(store).apply([])


def test_atomic_update_rolls_back_on_midbatch_failure():
    """A put that dies mid-batch must not leave a partial group visible:
    the staged snapshot restores every pre-batch record (all-or-nothing,
    not first-half-committed)."""
    store = CascadeStore([f"n{i}" for i in range(2)])
    store.create_object_pool("/positions", store.nodes, 2,
                             affinity_set_regex=r"/[a-z0-9]+_[0-9]+_")
    store.put("/positions/vid_1_0", b"old")
    calls = {"n": 0}
    orig = store.put

    def flaky(key, value, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("injected put failure")
        return orig(key, value, **kw)

    store.put = flaky
    try:
        with pytest.raises(RuntimeError):
            AtomicGroupUpdate(store).apply([
                ("/positions/vid_1_0", b"new"),
                ("/positions/vid_1_1", b"new")])
    finally:
        store.put = orig
    home = store.shard_of("/positions/vid_1_0")
    assert home.objects["/positions/vid_1_0"].value == b"old"
    assert "/positions/vid_1_1" not in home.objects


def test_atomic_move_group_all_or_nothing():
    """Gang-repair commit: a group's records relocate in one validated
    commit; mixed-label or cross-shard batches are rejected before any
    mutation."""
    store = CascadeStore([f"n{i}" for i in range(4)])
    store.create_object_pool("/positions", store.nodes, 2,
                             affinity_set_regex=r"/[a-z0-9]+_[0-9]+_")
    pool = store.pools["/positions"]
    for f in range(4):
        store.put(f"/positions/vid_1_{f}", b"p")
    home = pool.home("/positions/vid_1_0")
    src = next(s for s in pool.shards.values() if s.name != home.name)
    # strand the group on the wrong shard, then commit it home atomically
    for f in range(4):
        k = f"/positions/vid_1_{f}"
        src.objects[k] = home.objects.pop(k)
    moves = [(src, k, src.objects[k])
             for k in sorted(src.objects)]
    n = AtomicGroupUpdate(store).move_group(pool, "/vid_1_", moves)
    assert n == 4
    assert all(f"/positions/vid_1_{f}" in home.objects for f in range(4))
    assert not any(k.startswith("/positions/vid_1_")
                   for k in src.objects)
    with pytest.raises(ValueError):
        AtomicGroupUpdate(store).move_group(pool, "/vid_1_", [])
    store.put("/positions/vid_2_0", b"q")
    bad = [(home, "/positions/vid_1_0",
            home.objects["/positions/vid_1_0"]),
           (home, "/positions/vid_2_0",
            store.shard_of("/positions/vid_2_0").objects[
                "/positions/vid_2_0"])]
    with pytest.raises(ValueError):
        AtomicGroupUpdate(store).move_group(pool, "/vid_1_", bad)


def test_sequencer_memory_is_bounded_by_in_flight_labels():
    """A sequencer that has processed many distinct groups retains state
    only for groups with work currently in flight — drained labels are
    pruned, so long-horizon runs don't accrete one queue per label."""
    seq = GroupSequencer()
    for i in range(10_000):
        lbl = f"g{i}"
        seq.admit(lbl, i)
        assert seq.ready(lbl) == i
        seq.complete(lbl)
    assert seq.n_labels() == 0
    assert not seq._queues and not seq._busy
    # the executor's retire pattern — ready() after complete() on a
    # drained label — must stay a cheap no-op on pruned labels
    assert seq.ready("g0") is None
    assert seq.pending("g123") == 0
    # only in-flight labels hold state
    seq.admit("a", 1)
    seq.admit("a", 2)
    assert seq.ready("a") == 1
    seq.admit("b", 3)
    assert seq.n_labels() == 2
    seq.complete("a")
    assert seq.ready("a") == 2
    seq.complete("a")
    assert seq.ready("b") == 3
    seq.complete("b")
    assert seq.n_labels() == 0


def test_prefetch_plan_covers_group():
    store = CascadeStore([f"n{i}" for i in range(4)])
    store.create_object_pool("/positions", store.nodes, 4,
                             affinity_set_regex=r"/[a-z0-9]+_[0-9]+_")
    for f in range(8):
        store.put(f"/positions/vid_1_{f}", b"p" * 64)
    home = store.shard_of("/positions/vid_1_0")
    other = next(n for n in store.nodes if n not in home.nodes)
    plan = PrefetchEngine(store).plan_for_task("/positions", "/vid_1_", other)
    assert plan is not None and len(plan.keys) == 8
    # after executing the plan, gets from `other` are cache-local
    PrefetchEngine(store).execute(plan)
    _, local = store.get("/positions/vid_1_3", node=other)
    assert local


def test_migration_plan_fraction():
    store = CascadeStore([f"n{i}" for i in range(16)])
    store.create_object_pool("/positions", store.nodes, 8,
                             affinity_set_regex=r"/[a-z0-9]+_[0-9]+_",
                             policy=RendezvousPlacement())
    for a in range(100):
        for f in range(3):
            store.put(f"/positions/vid_{a}_{f}", b"x" * 10)
    plan = GroupRegistry(store).plan_resharding("/positions", 9)
    assert 0 < plan.fraction_moved < 0.3   # ~1/9 expected
