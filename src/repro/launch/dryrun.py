import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh, extract memory/cost/collective analysis, emit one JSON per cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all          # whole grid

The XLA_FLAGS line above MUST stay the first statement: jax locks the device
count on first backend init.  Tests/benches import other modules and see 1
device.
"""
import argparse
import json
import re
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.shapes import SHAPES
from repro.launch import mesh as meshlib
from repro.launch import steps as steplib
from repro.distributed import sharding_rules as sr

ARTIFACTS = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\[?([^]}]*)")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_devices: int) -> int:
    """Parse replica group size from an HLO collective line."""
    m = _GROUPS_RE.search(line)
    if not m:
        return n_devices
    body = m.group(1)
    # iota format: replica_groups=[8,64]<=[512] -> group size = last dim
    im = re.match(r"\s*(\d+)\s*,\s*(\d+)", body)
    if "<=" in line and im:
        return int(im.group(2))
    # explicit format: {{0,1,2,...},{...}} -> first group length
    first = body.split("}")[0].lstrip("{")
    ids = [x for x in first.split(",") if x.strip().isdigit()]
    return max(len(ids), 1)


def collective_stats(hlo_text: str, n_devices: int):
    """Estimated per-device bytes moved over the interconnect, by op type.

    ring-model factors: all-reduce 2(n-1)/n x buffer; all-gather /
    reduce-scatter / all-to-all (n-1)/n x full buffer; permute 1x.
    """
    stats = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        out_bytes = _shape_bytes(m.group(1))
        op = m.group(2)
        n = _group_size(line, n_devices)
        if n <= 1:
            continue
        if op == "all-reduce":
            moved = 2.0 * (n - 1) / n * out_bytes
        elif op == "all-gather":
            moved = (n - 1) / n * out_bytes
        elif op == "reduce-scatter":
            moved = (n - 1) * out_bytes          # output is the shard
        elif op == "all-to-all":
            moved = (n - 1) / n * out_bytes
        else:                                     # collective-permute
            moved = float(out_bytes)
        d = stats.setdefault(op, {"count": 0, "bytes": 0.0})
        d["count"] += 1
        d["bytes"] += moved
        total += moved
    return stats, total


def shape_tweaks(cfg, shape):
    """Per-shape lowering tweaks applied to every compile of a cell.

    Long sequences use q-block-chunked attention so the full compile's
    memory analysis reflects a deployable (flash-style) footprint instead of
    a materialized S x S score tensor.
    """
    import dataclasses as dc
    if shape.kind in ("train", "prefill") and shape.seq_len >= 4096 \
            and cfg.family != "ssm":
        cfg = dc.replace(cfg, attn_chunk=2048)
    return cfg


def _aux_layer_plan(cfg):
    """(L1, L2, L_eff) for per-layer cost extrapolation."""
    if cfg.block_pattern and len(set(cfg.block_pattern)) > 1:
        period = len(cfg.block_pattern)
        n_groups = cfg.n_layers // period
        tail = cfg.n_layers - n_groups * period
        return period, 2 * period, n_groups + tail / period
    return 1, 2, float(cfg.n_layers)


def _compile_cell(cfg, shape, mesh, rules, extra):
    bundle = steplib.make_step(shape.kind, cfg, shape, mesh, rules,
                               **(extra or {}))
    with mesh:
        jitted = jax.jit(
            bundle.fn,
            in_shardings=steplib.to_shardings(mesh, bundle.in_shardings),
            out_shardings=steplib.to_shardings(mesh, bundle.out_shardings),
            donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.input_specs)
        compiled = lowered.compile()
    return compiled


def _cost_of(compiled, n_devices):
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll_stats, coll_bytes = collective_stats(hlo, n_devices)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": coll_bytes,
        "coll_stats": coll_stats,
    }


def extrapolated_costs(cfg, shape, mesh, rules, extra):
    """Exact per-device costs via small unrolled aux compiles.

    XLA's cost analysis counts while-loop bodies ONCE, so the scanned-layer
    full compile under-reports flops/bytes/collectives.  We re-compile the
    step with the layer stack AND all inner chunk loops python-unrolled, at
    a 2x2 grid of (layers L, global batch B).  Per-device cost is affine in
    both:  c(L, B) = f0 + fB*B + L*(g0 + gB*B)
    (f0/g0: batch-independent terms like gradient all-reduces; fB/gB:
    per-token compute/IO).  Solving the grid gives the exact full-shape
    cost  c(L_eff, B_full)  with compile time bounded by the tiny aux
    shapes, independent of the deployed batch / chunk counts.
    """
    import dataclasses as dc
    L1, L2, L_eff = _aux_layer_plan(cfg)
    dp_total = mesh.devices.size // mesh.shape["model"]
    B_full = shape.global_batch
    if B_full >= 2 * dp_total:
        B1, B2 = dp_total, 2 * dp_total
    elif B_full >= 2 and B_full % 2 == 0:
        B1, B2 = B_full // 2, B_full
    else:
        B1, B2 = B_full, None   # B=1 (long_500k): no B extrapolation

    # inner chunk-loop budget: if the deployed config would unroll too many
    # chunk bodies, switch to a (L x chunk) grid instead and use the
    # affine-in-chunk identity  M(ch) = alpha*T + beta*T*ch  (see below).
    NC_BUDGET = 32
    T_aux = B1 * shape.seq_len
    ch_mode = None
    if cfg.family == "moe" and shape.kind != "decode":
        nc = T_aux // cfg.moe_chunk
        if nc > NC_BUDGET:
            ch_mode = ("moe_chunk", cfg.moe_chunk,
                       (T_aux // 2, T_aux // 4))
    if cfg.family == "ssm" and shape.kind != "decode":
        nc = shape.seq_len // cfg.ssm_chunk
        if nc > NC_BUDGET:
            ch_mode = ("ssm_chunk", cfg.ssm_chunk,
                       (shape.seq_len // 2, shape.seq_len // 4))

    def compile_point(L, B, ch_override=None):
        kw = {"n_layers": L, "scan_layers": False, "unroll_inner": True}
        if ch_override is not None:
            kw[ch_mode[0]] = ch_override
        aux_cfg = dc.replace(cfg, **kw)
        aux_shape = dc.replace(shape, global_batch=B)
        compiled = _compile_cell(aux_cfg, aux_shape, mesh, rules, extra)
        return _cost_of(compiled, mesh.devices.size)

    cost = {}
    if ch_mode is not None:
        # (L x ch) grid at B1; per-token cost has no batch-independent part
        # for fwd-only steps, so scale linearly to B_full afterwards.
        _, ch_deploy, (ch_a, ch_b) = ch_mode
        for L in (L1, L2):
            for ch in (ch_a, ch_b):
                cost[(L, ch)] = compile_point(L, B1, ch)

        def solve(get):
            def layer_at(ch):
                c1, c2 = get(cost[(L1, ch)]), get(cost[(L2, ch)])
                g = (c2 - c1) / ((L2 - L1) / L1)
                return g, c1 - g                      # (per-unit, fixed)
            gA, fA = layer_at(ch_a)
            gB_, fB_ = layer_at(ch_b)
            slope = (gA - gB_) / (ch_a - ch_b)        # beta*T
            g_deploy = gB_ + slope * (ch_deploy - ch_b)
            fixed = 0.5 * (fA + fB_)                  # ch-independent
            total_B1 = fixed + L_eff * g_deploy
            return total_B1 * (B_full / B1)
    else:
        for L in (L1, L2):
            for B in ((B1,) if B2 is None else (B1, B2)):
                cost[(L, B)] = compile_point(L, B)

        def solve(get):
            if B2 is None:
                c1, c2 = get(cost[(L1, B1)]), get(cost[(L2, B1)])
                g = (c2 - c1) / ((L2 - L1) / L1)
                return (c1 - g) + L_eff * g
            c11, c12 = get(cost[(L1, B1)]), get(cost[(L1, B2)])
            c21, c22 = get(cost[(L2, B1)]), get(cost[(L2, B2)])
            gB1 = (c21 - c11) / ((L2 - L1) / L1)
            gB2 = (c22 - c12) / ((L2 - L1) / L1)
            g_slope = (gB2 - gB1) / (B2 - B1)
            g0 = gB1 - g_slope * B1
            f_at_B1, f_at_B2 = c11 - gB1, c12 - gB2
            f_slope = (f_at_B2 - f_at_B1) / (B2 - B1)
            f0 = f_at_B1 - f_slope * B1
            return (f0 + f_slope * B_full) + L_eff * (g0 + g_slope * B_full)

    out = {}
    for key in ("flops", "bytes", "coll_bytes"):
        out[key] = max(solve(lambda c, k=key: c[k]), 0.0)
    types = set()
    for c in cost.values():
        types |= set(c["coll_stats"])
    coll = {}
    for t in sorted(types):
        coll[t] = max(solve(
            lambda c, t=t: c["coll_stats"].get(t, {}).get("bytes", 0.0)), 0.0)
    out["coll_by_type"] = coll
    out["aux_points"] = {
        f"{a}_{b}": {k: cost[(a, b)][k]
                     for k in ("flops", "bytes", "coll_bytes")}
        for (a, b) in cost}
    return out


def analytic_model_flops(cfg, shape) -> float:
    """6*N_active*T (+attention quadratic term) — the 'useful' flops."""
    N = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    H, Dh = cfg.n_heads, cfg.resolved_head_dim
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.block_kind(i) == "attn")
    if shape.kind == "train":
        T = B * S
        attn = 6.0 * B * n_attn * H * Dh * (
            S * min(S, cfg.attn_window or S))
        return 6.0 * N * T + attn
    if shape.kind == "prefill":
        T = B * S
        attn = 2.0 * B * n_attn * H * Dh * S * min(S, cfg.attn_window or S)
        return 2.0 * N * T + attn
    # decode: one token per row against an S-deep cache
    if cfg.mla:
        kv_read = 2.0 * B * cfg.n_layers * H * S * (
            cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
    else:
        kv_read = 4.0 * B * n_attn * H * Dh * min(S, cfg.attn_window or S)
    return 2.0 * N * B + kv_read


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             rules_name: str = "baseline", extra: dict | None = None,
             with_aux: bool = True):
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    skip = configs.skip_reason(arch, shape_name)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "rules": rules_name, "skip": skip,
    }
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_kind}__{rules_name}.json"
    out_dir.mkdir(parents=True, exist_ok=True)
    if skip:
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"SKIP {arch} x {shape_name}: {skip}")
        return rec

    mesh = meshlib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_devices = mesh.devices.size
    rules = make_rules(rules_name, mesh, cfg, shape)
    cfg, extra = apply_ruleset(rules_name, cfg, extra, shape)
    cfg = shape_tweaks(cfg, shape)
    if RULESETS[rules_name].get("no_attn_chunk"):
        import dataclasses as _dc
        cfg = _dc.replace(cfg, attn_chunk=0)
    t0 = time.time()
    # full compile: proves the cell lowers+compiles; memory analysis
    compiled = _compile_cell(cfg, shape, mesh, rules, extra)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_rec = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            mem_rec[attr] = getattr(mem, attr, None)

    # exact costs from unrolled aux compiles (see extrapolated_costs);
    # multi-pod cells are compile-proof + memory only (roofline table is
    # single-pod per EXPERIMENTS.md §Roofline).
    if with_aux:
        t1 = time.time()
        costs = extrapolated_costs(cfg, shape, mesh, rules, extra)
        t_aux = time.time() - t1
    else:
        t_aux = 0.0
        costs = {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0,
                 "coll_by_type": {}, "aux_points": {}}
    flops = costs["flops"]
    bytes_acc = costs["bytes"]
    coll_bytes = costs["coll_bytes"]
    coll_stats = costs["coll_by_type"]
    model_flops = analytic_model_flops(cfg, shape)

    chips = n_devices
    # cost_analysis of an SPMD module is per-partition.
    t_comp = flops / meshlib.PEAK_FLOPS_BF16
    t_mem = bytes_acc / meshlib.HBM_BW
    # per-device collective bytes over ICI links (v5e: ~4 usable links/chip)
    t_coll = coll_bytes / (4 * meshlib.ICI_BW_PER_LINK)
    dom = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))

    rec.update({
        "n_devices": chips,
        "compile_s": round(t_compile, 2),
        "aux_compile_s": round(t_aux, 2),
        "aux_points": costs["aux_points"],
        "memory": mem_rec,
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll_bytes,
        "collectives": coll_stats,
        "model_flops_total": model_flops,
        "model_flops_per_device": model_flops / chips,
        "useful_flop_ratio": (model_flops / chips) / flops if flops else None,
        "roofline": {
            "compute_s": t_comp,
            "memory_s": t_mem,
            "collective_s": t_coll,
            "dominant": dom[1],
            "roofline_fraction": t_comp / max(t_comp, t_mem, t_coll)
            if max(t_comp, t_mem, t_coll) > 0 else None,
        },
    })
    out_path.write_text(json.dumps(rec, indent=2))
    print(f"OK {arch} x {shape_name} x {mesh_kind} [{rules_name}] "
          f"compile={t_compile:.1f}s flops/dev={flops:.3e} "
          f"bytes/dev={bytes_acc:.3e} coll/dev={coll_bytes:.3e} "
          f"dom={dom[1]}")
    return rec


# Named rule-sets for the §Perf hillclimb.  'baseline' is the recorded
# paper-faithful sweep; 'opt_*' sets flip the beyond-paper knobs (see
# ModelConfig + make_train_step) and re-lower the same cell.
RULESETS = {
    "baseline": {},
    "tp_only": {"fsdp": False},
    "fsdp": {"fsdp": True},
    # seq-sharded attention for non-TP-divisible head counts
    "opt_attnseq": {"cfg": {"attn_seq_shard": True}},
    # + one-hot loss + grads pinned to FSDP layout (reduce-scatter)
    "opt_train": {"cfg": {"attn_seq_shard": True, "onehot_loss": True},
                  "extra": {"constrain_grads": True}},
    # MoE decode: keep expert weights sharded (no per-step gather)
    "opt_moedec": {"cfg": {"moe_hoist_gather": False}},
    # + Megatron-style sequence-parallel residual stream
    "opt_train2": {"cfg": {"attn_seq_shard": True, "onehot_loss": True,
                           "seq_parallel_residual": True},
                   "extra": {"constrain_grads": True}},
    # dsv2: drop q-block-chunked attention (GSPMD full-remat pathology in
    # the chunk scan's bwd — 'Involuntary full rematerialization' warnings)
    "opt_dsv2": {"cfg": {"onehot_loss": True},
                 "extra": {"constrain_grads": True},
                 "no_attn_chunk": True},
    # MoE train: 4x bigger token chunks -> 4x fewer per-chunk expert-grad
    # partial reductions (the dominant collective in llama4/dsv2 train)
    "opt_moetrain": {"cfg": {"attn_seq_shard": True, "onehot_loss": True,
                             "moe_chunk": 16384,
                             "seq_parallel_residual": True},
                     "extra": {"constrain_grads": True}},
    # everything on
    "opt_all": {"cfg": {"attn_seq_shard": True, "onehot_loss": True,
                        "moe_hoist_gather": False,
                        "seq_parallel_residual": True},
                "extra": {"constrain_grads": True}},
}


def make_rules(name: str, mesh, cfg, shape):
    rs = RULESETS[name]
    if "fsdp" in rs:
        return sr.default_rules(mesh, fsdp=rs["fsdp"])
    # FSDP (ZeRO-3 style param+opt sharding over 'data') is part of the
    # baseline wherever TP-only sharding cannot fit 16 GB/chip HBM.
    return sr.default_rules(mesh, fsdp=cfg.param_count() >= 8e9)


def apply_ruleset(name: str, cfg, extra: dict, shape):
    import dataclasses as dc
    rs = RULESETS[name]
    cfg_over = dict(rs.get("cfg", {}))
    if cfg_over:
        cfg = dc.replace(cfg, **cfg_over)
    extra = dict(extra or {})
    if shape.kind == "train":
        extra.update(rs.get("extra", {}))
    return cfg, extra


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--rules", default="baseline")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACTS))
    ap.add_argument("--accum-steps", type=int, default=0,
                    help="grad accumulation (train cells); 0 = default")
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.all:
        cells = [(a, s) for a, s, _ in configs.cells()]
        meshes = ["single", "multi"]
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]
        meshes = [args.mesh]

    failures = []
    for arch, shape in cells:
        for mk in meshes:
            out_path = out_dir / f"{arch}__{shape}__{mk}__{args.rules}.json"
            if args.skip_existing and out_path.exists():
                continue
            extra = {}
            if SHAPES[shape].kind == "train" and args.accum_steps:
                extra["accum_steps"] = args.accum_steps
            try:
                run_cell(arch, shape, mk, out_dir, args.rules, extra,
                         with_aux=(mk == "single"))
            except Exception as e:  # noqa: BLE001 — record, keep going
                failures.append((arch, shape, mk, repr(e)[:500]))
                print(f"FAIL {arch} x {shape} x {mk}: {e!r}", file=sys.stderr)
    if failures:
        print(json.dumps(failures, indent=2), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
