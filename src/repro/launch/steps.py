"""Step builders shared by the dry-run, the trainer, and the server.

``make_train_step`` / ``make_prefill_step`` / ``make_serve_step`` return
(fn, input_specs, in_shardings, out_shardings, donate) bundles ready for
``jax.jit(...).lower(...)``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.shapes import ShapeConfig
from repro.distributed import sharding_rules as sr
from repro.distributed import constraints
from repro.models import build_model
from repro.models.common import ModelConfig
from repro.training import optimizer as opt


def to_shardings(mesh: Mesh, tree: Any) -> Any:
    """PartitionSpec tree -> NamedSharding tree (None passes through)."""
    if tree is None:
        return None
    from jax.sharding import NamedSharding
    return jax.tree_util.tree_map(
        lambda s: None if s is None
        else (NamedSharding(mesh, s) if isinstance(s, P) else s),
        tree, is_leaf=lambda x: isinstance(x, P) or x is None)


@dataclasses.dataclass
class StepBundle:
    fn: Any
    input_specs: Tuple[Any, ...]        # ShapeDtypeStructs (positional)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    meta: Dict[str, Any]


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "audio":
        return {"features": sds((B, S, cfg.frontend_dim), jnp.bfloat16),
                "labels": sds((B, S), jnp.int32)}
    out = {"tokens": sds((B, S), jnp.int32)}
    if cfg.frontend == "vision":
        out["patches"] = sds((B, cfg.n_patches, cfg.frontend_dim),
                             jnp.bfloat16)
    return out


def batch_pspecs(mesh: Mesh, rules: sr.ShardingRules, cfg: ModelConfig,
                 shape: ShapeConfig) -> Dict[str, Any]:
    B = shape.global_batch
    out = {}
    for k, v in batch_specs(cfg, shape).items():
        out[k] = sr.batch_pspec(mesh, rules, B, extra_dims=len(v.shape) - 1)
    return out


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    rules: Optional[sr.ShardingRules] = None,
                    ocfg: Optional[opt.AdamWConfig] = None,
                    accum_steps: int = 1,
                    constrain_grads: bool = False) -> StepBundle:
    constraints.set_mesh(mesh)
    model = build_model(cfg)
    rules = rules or sr.default_rules(mesh)
    ocfg = ocfg or opt.AdamWConfig()

    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    axes = model.param_axes()
    pspecs = sr.specs_for_params(mesh, rules, params_shapes, axes)
    opt_shapes = jax.eval_shape(
        functools.partial(opt.init_opt_state,
                          state_dtype=cfg.opt_state_dtype,
                          factored=cfg.opt_factored),
        params_shapes)

    def v_spec(ps, p):
        if cfg.opt_factored and p.ndim >= 2 and p.shape[-1] > 1 \
                and p.shape[-2] > 1:
            t = tuple(ps)
            return {"vr": P(*t[:-1]), "vc": P(*t[:-2], t[-1])}
        return ps
    vspecs = jax.tree_util.tree_map(
        v_spec, pspecs, params_shapes,
        is_leaf=lambda x: isinstance(x, P))
    mspecs = {"m": pspecs, "v": vspecs, "step": P()}
    state_shapes = {"params": params_shapes, "opt": opt_shapes}
    state_specs = {"params": pspecs, "opt": mspecs}

    bspecs = batch_specs(cfg, shape)
    bpspecs = batch_pspecs(mesh, rules, cfg, shape)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    if cfg.opt_factored and (ocfg is None or not ocfg.factored):
        ocfg = dataclasses.replace(ocfg or opt.AdamWConfig(), factored=True)

    def train_step(state, batch):
        params = state["params"]
        if accum_steps > 1:
            def micro(carry, mb):
                (l, g) = carry
                (li, mi), gi = grad_fn(params, mb)
                g = jax.tree_util.tree_map(jnp.add, g, gi)
                return (l + li, g), None
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            micro_batch = jax.tree_util.tree_map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)
            (tot_l, grads), _ = jax.lax.scan(micro, (0.0, zero_g), micro_batch)
            loss = tot_l / accum_steps
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        if constrain_grads:
            # pin grads to the param layout: GSPMD then reduce-scatters
            # partial grads onto the FSDP shards instead of all-reducing
            # full fp32 tensors (observed 5 GB/expert-tensor reduces in
            # the dsv2 baseline — EXPERIMENTS.md §Perf).
            gshard = to_shardings(mesh, pspecs)
            grads = jax.tree_util.tree_map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, gshard)
        new_params, new_opt, ometrics = opt.adamw_update(
            ocfg, grads, params, state["opt"])
        metrics = dict(metrics, **ometrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return StepBundle(
        fn=train_step,
        input_specs=(state_shapes, bspecs),
        in_shardings=(state_specs, bpspecs),
        out_shardings=(state_specs, None),
        donate_argnums=(0,),
        meta={"model": model, "pspecs": pspecs, "rules": rules,
              "state_specs": state_specs, "batch_pspecs": bpspecs},
    )


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      rules: Optional[sr.ShardingRules] = None) -> StepBundle:
    constraints.set_mesh(mesh)
    model = build_model(cfg)
    rules = rules or sr.default_rules(mesh)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = sr.specs_for_params(mesh, rules, params_shapes,
                                 model.param_axes())
    bspecs = batch_specs(cfg, shape)
    bpspecs = batch_pspecs(mesh, rules, cfg, shape)

    if cfg.family == "encoder":
        def prefill(params, batch):
            return model.forward_train(params, batch)
        cache_out = None
    else:
        def prefill(params, batch):
            return model.prefill(params, batch)
        cache_shapes = model.cache_spec(shape.global_batch, shape.seq_len)
        cache_out = sr.cache_pspecs(mesh, rules, cfg, cache_shapes,
                                    stacked=not getattr(model, "_hybrid"))
        if getattr(model, "_hybrid"):
            cache_out = _hybrid_cache_specs(mesh, rules, cfg, model,
                                            cache_shapes)

    out_shardings = None if cfg.family == "encoder" else (None, cache_out)
    return StepBundle(
        fn=prefill,
        input_specs=(params_shapes, bspecs),
        in_shardings=(pspecs, bpspecs),
        out_shardings=out_shardings,
        donate_argnums=(),
        meta={"model": model, "pspecs": pspecs, "rules": rules},
    )


def _hybrid_cache_specs(mesh, rules, cfg, model, cache_shapes):
    groups = sr.cache_pspecs(mesh, rules, cfg, cache_shapes["groups"],
                             stacked=True)
    tail = sr.cache_pspecs(mesh, rules, cfg, cache_shapes["tail"],
                           stacked=False)
    return {"groups": groups, "tail": tail}


# ---------------------------------------------------------------------------
# serve (decode)
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    rules: Optional[sr.ShardingRules] = None) -> StepBundle:
    assert cfg.family != "encoder", "encoder archs have no decode step"
    constraints.set_mesh(mesh)
    model = build_model(cfg)
    rules = rules or sr.default_rules(mesh)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = sr.specs_for_params(mesh, rules, params_shapes,
                                 model.param_axes())
    B, S = shape.global_batch, shape.seq_len
    cache_shapes = model.cache_spec(B, S)
    if getattr(model, "_hybrid"):
        cspecs = _hybrid_cache_specs(mesh, rules, cfg, model, cache_shapes)
    else:
        cspecs = sr.cache_pspecs(mesh, rules, cfg, cache_shapes, stacked=True)
    sds = jax.ShapeDtypeStruct
    tok_spec = sds((B,), jnp.int32)
    len_spec = sds((B,), jnp.int32)
    bp = sr.batch_pspec(mesh, rules, B, extra_dims=0)

    def serve_step(params, cache, tokens, lengths):
        logits, new_cache = model.decode_step(params, cache, tokens, lengths)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return StepBundle(
        fn=serve_step,
        input_specs=(params_shapes, cache_shapes, tok_spec, len_spec),
        in_shardings=(pspecs, cspecs, bp, bp),
        out_shardings=(bp, cspecs),
        donate_argnums=(1,),
        meta={"model": model, "pspecs": pspecs, "rules": rules,
              "cache_specs": cspecs},
    )


def make_step(kind: str, cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              rules: Optional[sr.ShardingRules] = None, **kw) -> StepBundle:
    if kind == "train":
        return make_train_step(cfg, shape, mesh, rules, **kw)
    if kind == "prefill":
        return make_prefill_step(cfg, shape, mesh, rules)
    if kind == "decode":
        return make_serve_step(cfg, shape, mesh, rules)
    raise ValueError(kind)
