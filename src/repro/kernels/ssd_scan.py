"""Mamba-2 SSD chunked scan for TPU.

Grid (batch, heads, chunks) with the chunk dimension innermost/sequential;
the (P, N) recurrent state lives in VMEM scratch and carries across chunk
steps.  Per chunk: intra-chunk quadratic term (L x L decay-weighted C.B^T),
inter-chunk contribution from the carried state, and the state update —
all fp32 in VMEM, MXU-shaped matmuls (L, N, P multiples of 128 at
production sizes).

Oracle: ``repro.kernels.ref.ssd``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, st_ref,
            h_ref, *, L, nc, has_d):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)                # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)                 # (L,)
    A = a_ref[0, 0]                                          # scalar
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)               # (L, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)               # (L, N)

    dA = dt * A                                              # (L,) <= 0
    cum = jnp.cumsum(dA)
    decay = jnp.exp(cum[:, None] - cum[None, :])             # (L, L)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    decay = jnp.where(tri, decay, 0.0)

    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (L, L)
    w = cb * decay * dt[None, :]
    y = jax.lax.dot(w, x)                                    # intra (L, P)

    h = h_ref[...]                                           # (P, N)
    cexp = Cm * jnp.exp(cum)[:, None]                        # (L, N)
    y = y + jax.lax.dot_general(cexp, h, (((1,), (1,)), ((), ())))

    last = cum[L - 1]
    sdecay = (jnp.exp(last - cum) * dt)[:, None]             # (L, 1)
    upd = jax.lax.dot_general(x, Bm * sdecay,
                              (((0,), (0,)), ((), ())))      # (P, N)
    h_new = h * jnp.exp(last) + upd
    h_ref[...] = h_new

    if has_d:
        y = y + x * d_ref[0, 0]
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    st_ref[0, 0] = h_new                                     # last write wins


def ssd_scan(x, dt, A, Bm, Cm, D=None, *, chunk: int = 256,
             init_state=None, interpret: bool = False
             ) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,G,N); D (H,) or None."""
    assert init_state is None, "kernel path starts from zero state"
    B, S_in, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    L = min(chunk, S_in)
    if S_in % L:
        pad = L - S_in % L            # dt=0 pad steps are exact no-ops
        z = lambda a: jnp.pad(a, [(0, 0), (0, pad)]
                              + [(0, 0)] * (a.ndim - 2))
        x, dt, Bm, Cm = z(x), z(dt), z(Bm), z(Cm)
    B, S, H, P = x.shape
    nc = S // L
    has_d = D is not None
    d_in = (D if has_d else jnp.zeros((H,), jnp.float32))
    kernel = functools.partial(_kernel, L=L, nc=nc, has_d=has_d)

    y, state = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, L, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, L, 1, N), lambda b, h, c: (b, c, h // hpg, 0)),
            pl.BlockSpec((1, L, 1, N), lambda b, h, c: (b, c, h // hpg, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt.astype(jnp.float32),
      A.astype(jnp.float32).reshape(H, 1), Bm, Cm,
      d_in.astype(jnp.float32).reshape(H, 1))
    return y[:, :S_in], state
