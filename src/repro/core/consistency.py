"""Per-affinity-group ordering and atomic group updates (paper §3.4).

Objects/tasks sharing an affinity key may need to be handled sequentially
and in order (e.g. frames of one video stream); groups with different keys
are independent and run in parallel.  Because a group lives entirely in one
shard, group-atomic multi-object updates need no cross-shard coordination —
the paper notes this fell out of the design for free.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from .object_store import CascadeStore


class GroupSequencer:
    """FIFO execution order within each affinity group.

    ``admit(label, item)`` enqueues; ``ready(label)`` yields the next item
    only when the previous one for that group was ``complete``d.  Different
    labels never block each other.
    """

    def __init__(self):
        self._queues: Dict[str, Deque[Any]] = defaultdict(deque)
        self._busy: Dict[str, bool] = defaultdict(bool)
        self.max_queue_len: int = 0

    def admit(self, label: str, item: Any) -> None:
        q = self._queues[label]
        q.append(item)
        self.max_queue_len = max(self.max_queue_len, len(q))

    def ready(self, label: str) -> Optional[Any]:
        if self._busy[label] or not self._queues[label]:
            return None
        self._busy[label] = True
        return self._queues[label].popleft()

    def complete(self, label: str) -> None:
        self._busy[label] = False

    def pending(self, label: str) -> int:
        return len(self._queues[label]) + (1 if self._busy[label] else 0)

    def drain_ready(self) -> List[Tuple[str, Any]]:
        out = []
        for label in list(self._queues):
            item = self.ready(label)
            if item is not None:
                out.append((label, item))
        return out


class AtomicGroupUpdate:
    """All-or-nothing multi-put of objects sharing one affinity key.

    Single-shard residency makes this a local transaction: we verify every
    key homes to the same shard, then apply the batch under one version.
    """

    def __init__(self, store: CascadeStore):
        self.store = store

    def apply(self, puts: List[Tuple[str, Any]]) -> str:
        assert puts, "empty atomic update"
        shards = {self.store.shard_of(k).name for k, _ in puts}
        labels = {self.store.affinity_of(k) for k, _ in puts}
        if len(labels) != 1:
            raise ValueError(f"atomic update spans affinity groups: {labels}")
        if len(shards) != 1:
            raise ValueError(f"group split across shards: {shards}")
        for k, v in puts:
            self.store.put(k, v, fire=False)
        return labels.pop()
