"""Paper Fig. 4: E2E latency, three simultaneous clients."""
from .common import emit, run_rcp

LAYOUTS = [(1, 3, 3), (3, 3, 3), (3, 5, 5)]
SCENES = ("little3", "hyang5", "gates3")


def run(quick=True):
    frames = 150 if quick else 700
    rows = []
    for layout in LAYOUTS:
        for grouped in (True, False):
            s = run_rcp(grouped, layout, SCENES, frames)
            name = f"fig4/{'/'.join(map(str, layout))}/" \
                   f"{'affinity' if grouped else 'random'}"
            rows.append((name, s["median"] * 1e6,
                         {"p75_ms": round(s["p75"] * 1e3, 1),
                          "p95_ms": round(s["p95"] * 1e3, 1),
                          "remote_gets": s["remote_gets"],
                          "bytes_remote": s["bytes_remote"]}))
    return rows


if __name__ == "__main__":
    emit(run())
