"""Fig. 8 (ours): cross-instance stage batching under load, at 64 slots.

Sweep axes: batch window x arrival rate x workflow shape x placement.

  * ``keyhash``          — ungrouped key-hash scatter (cloud baseline),
    run at the two highest rates (the "high load" comparison point);
  * ``atomic``           — workflow-atomic gang placement, one event per
    stage firing (the fig7 headline, unbatched);
  * ``atomic+batch/Wms`` — atomic plus the StageBatcher with a W ms
    formation window; same-stage firings pinned to the same shard slot
    coalesce into one amortized ``BatchCompute``.

Offered load is ``rate_x`` times a fixed per-slot base rate, so ``rate_x``
is a direct utilization dial over ``RATE_MULTS = (2, 8, 16)``: 2x runs
the bottleneck resource near saturation, 8x is sustained overload, and
16x is a burst well past it.  The paper-level claim this
figure records: affinity gives placement wins at any load (fig7), and the
*same* affinity signal gives batching wins exactly when load makes them
matter — batched-atomic p99 <= unbatched-atomic p99 at the two highest
rates, with zero accounting drift (the property test pins that).

Wall-clock note: this sweep runs at 64 slots — twice fig7's largest quick
scale — and `run()` records its total wall seconds in the emitted rows so
`BENCH_fig8.json` tracks the DES hot-path budget across PRs.
``PREPR_FIG7_32SLOT_WALL_S`` is the measured wall-clock of the pre-PR
fig7 machinery sweeping 32 slots on the dev machine this PR was tuned on
(both shapes x 4 modes, 30 instances/slot) — the acceptance reference the
64-slot sweep must beat.
"""
import time

from .common import emit

SLOTS = 64
PER_SLOT_RATE = 12.0           # instances/s per slot at rate_x=1
RATE_MULTS = (2, 8, 16)        # near-saturation, overload, burst
# formation windows scale with each shape's bottleneck stage cost
# (InferLine's lesson: the right batch knob is per-stage, not global) —
# roughly 0.5x and 1x the bottleneck service time
WINDOWS_MS = {"rag": (16, 32), "speech": (8, 16)}
DEADLINES = {"rag": 0.40, "speech": 0.30}
PER_SLOT_INSTANCES = 3         # kept small: 64*3=192 instances per config

# pre-PR reference (see module docstring); recorded, not recomputed
PREPR_FIG7_32SLOT_WALL_S = 4.70


def run_config(shape: str, mode: str, rate_x: int, window_ms: float = 0.0,
               slots: int = SLOTS, n_instances: int = None, seed: int = 0,
               tracing=False):
    from repro.workflows import (WORKFLOW_SHAPES, BatchPolicy,
                                 WorkflowRuntime, mode_kwargs,
                                 preload_index)
    graph = WORKFLOW_SHAPES[shape](shards=slots)
    kw = mode_kwargs(mode)
    if kw.get("batching"):
        kw["batch_policy"] = BatchPolicy(window=window_ms * 1e-3)
    wrt = WorkflowRuntime(graph, seed=seed, tracing=tracing, **kw)
    if shape == "rag":
        preload_index(wrt)
    rate = PER_SLOT_RATE * rate_x * slots
    n = n_instances if n_instances is not None else \
        PER_SLOT_INSTANCES * slots
    for i in range(n):
        wrt.submit(f"req{i}", at=0.05 + i / rate,
                   deadline=DEADLINES[shape])
    wrt.run()
    return wrt.summary()


def _configs():
    """(shape, mode, rate_x, window_ms, tag) for the full sweep.

    At the lowest rate one batched window suffices — idle flushing makes
    every window behave identically there (the "batching is free when
    unloaded" datapoint); the full window axis runs at the two highest
    rates, where formation actually happens.
    """
    out = []
    for shape in ("rag", "speech"):
        windows = WINDOWS_MS[shape]
        for rate_x in RATE_MULTS:
            high = rate_x >= RATE_MULTS[-2]
            out.append((shape, "atomic", rate_x, 0.0, "atomic"))
            for w in (windows if high else windows[:1]):
                out.append((shape, "atomic+batch", rate_x, float(w),
                            f"batch{w}ms"))
            if high:                          # high-load baseline points
                out.append((shape, "keyhash", rate_x, 0.0, "keyhash"))
    return out


def run(quick=True):
    per_slot = PER_SLOT_INSTANCES if quick else 4 * PER_SLOT_INSTANCES
    rows = []
    t_sweep = time.perf_counter()
    for shape, mode, rate_x, window_ms, tag in _configs():
        t0 = time.perf_counter()
        s = run_config(shape, mode, rate_x, window_ms,
                       n_instances=per_slot * SLOTS)
        wall = time.perf_counter() - t0
        name = f"fig8/{shape}/{SLOTS}sl/{rate_x}x/{tag}"
        derived = {"p50_ms": round(s["median"] * 1e3, 2),
                   "p99_ms": round(s["p99"] * 1e3, 2),
                   "slo_miss": round(s.get("slo_miss_rate", 0.0), 3),
                   "wall_s": round(wall, 3),
                   "n": s["n"]}
        if "mean_batch" in s:
            derived["mean_batch"] = round(s["mean_batch"], 2)
            derived["batches"] = s["batches"]
        rows.append((name, s["median"] * 1e6, derived))
    total = round(time.perf_counter() - t_sweep, 2)
    rows.append((f"fig8/sweep_wall/{SLOTS}sl", total * 1e6,
                 {"wall_s": total,
                  "ref_prepr_fig7_32slot_wall_s": PREPR_FIG7_32SLOT_WALL_S,
                  "beats_ref": total < PREPR_FIG7_32SLOT_WALL_S}))
    return rows


if __name__ == "__main__":
    emit(run())
