"""Event-triggered workflow graphs over affinity groups (paper §2, §4.5)."""
from .batching import BatchPolicy, StageBatcher
from .blame import BlameTable, critical_path, decompose, timeline
from .graph import (INSTANCE, Emit, Pool, Read, Stage, Tier, WorkflowGraph,
                    WorkflowGraphError)
from .planner import AdaptiveBatchPolicy, BatchPlanner
from .runtime import InstanceRecord, InstanceTracker, WorkflowRuntime
from .library import (WORKFLOW_SHAPES, adapter_keys, agent_workflow,
                      index_keys, mode_kwargs, preload_adapters,
                      preload_index, rag_workflow, speech_workflow)

__all__ = [
    "BatchPolicy", "StageBatcher",
    "BlameTable", "critical_path", "decompose", "timeline",
    "AdaptiveBatchPolicy", "BatchPlanner",
    "INSTANCE", "Emit", "Pool", "Read", "Stage", "Tier", "WorkflowGraph",
    "WorkflowGraphError",
    "InstanceRecord", "InstanceTracker", "WorkflowRuntime",
    "WORKFLOW_SHAPES", "adapter_keys", "agent_workflow", "index_keys",
    "mode_kwargs", "preload_adapters", "preload_index",
    "rag_workflow", "speech_workflow",
]
