"""Task scheduling policies: which node executes a triggered task.

Under affinity grouping the home *shard* is fixed by the placement engine
(data and compute collocate); the scheduler only picks among the shard's
member nodes.  The baseline policies mirror the systems the paper compares
against: random spray over a whole pool (cloud load balancer) and
least-loaded (queue-depth aware LB).
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Sequence

from repro.core.object_store import CascadeStore, Shard
from .simulation import Node


class Scheduler:
    def pick(self, shard: Shard, key: str, nodes: Dict[str, Node],
             pool_nodes: Sequence[str]) -> str:
        raise NotImplementedError

    def pick_batch(self, shard: Shard, keys: Sequence[str],
                   nodes: Dict[str, Node], pool_nodes: Sequence[str],
                   resource: str = "gpu") -> str:
        """Node for a *coalesced* batch of same-stage tasks.

        The batch runs as one resource occupancy, so the right node is the
        one whose `resource` lane frees up first — not a round-robin slot.
        The default delegates to ``pick`` for schedulers without a better
        signal (baselines keep their dispatch behavior under batching).
        """
        return self.pick(shard, keys[0], nodes, pool_nodes)

    def name(self) -> str:
        return type(self).__name__


def node_load(node: Node, resource: str) -> float:
    """Throughput-normalized occupancy of ``resource`` on ``node``.

    ``(in_use + queued) / capacity / rate`` — raw fractional occupancy
    (< 1.0 means a free lane, > 1.0 a backlog ``occ - 1`` service slots
    deep) divided by the node's effective service rate for ``resource``
    (tier speed x straggler dial), so the signal reads as *backlog in
    service-time units*: a fast tier drains a queued slot sooner than a
    slow tier runs an admitted one, and dispatch ranks them accordingly
    (an idle slow node still beats a saturated fast one — occupancy 0 is
    0 at any speed).  On the uniform single-profile cluster the divisor
    is exactly 1.0, so every pre-tier ranking is byte-identical.  This is
    THE load signal shared by batch-aware dispatch
    (``Scheduler.pick_batch``), the adaptive batch planner's queue-depth
    input, and the serving engine's row scheduler, so "prefer free lanes
    and shallow queues, weighted by how fast they drain" means the same
    thing at every layer.
    """
    cap = node.capacity.get(resource, 1) or 1
    occ = (node.in_use[resource] + len(node.queues[resource])) / cap
    return occ / max(node.rate(resource), 1e-9)


def _least_loaded_on(candidates: Sequence[str], nodes: Dict[str, Node],
                     resource: str) -> str:
    return min(candidates, key=lambda n: node_load(nodes[n], resource))


def dispatchable(store: CascadeStore, name: str, nodes: Dict[str, Node]
                 ) -> bool:
    """Up AND reachable from the dispatcher.  Dispatch is client-driven,
    and the client sits on the majority side of any active partition
    (group 0), so "node up" alone is not "node usable": a minority-side
    node is alive but cannot be handed work or serve replica reads until
    the cut heals.  Without a partition this is exactly ``Node.up``."""
    if not nodes[name].up:
        return False
    p = store.partition
    return p is None or p.get(name, 0) == 0


def hedge_candidates(store: CascadeStore, shard: Shard, key: str,
                     nodes: Dict[str, Node],
                     exclude: Sequence[str] = ()) -> List[str]:
    """Up, reachable nodes a hedged duplicate of work homed at
    ``(shard, key)`` may run on: the key's replica shards' members
    (replication >= 2 is what makes the duplicate's reads local) plus the
    home shard's own members, minus ``exclude`` (the primary lane's
    node).  Sorted for determinism; empty means the slot has no live
    alternative and the caller skips the hedge."""
    try:
        homes = store.pool_for(key).replica_homes(key)
    except KeyError:
        homes = [shard]
    cand = {n for h in homes for n in h.nodes}
    cand.update(shard.nodes)
    cand.difference_update(exclude)
    return [n for n in sorted(cand) if dispatchable(store, n, nodes)]


class ShardLocalScheduler(Scheduler):
    """Affinity mode: run on a member of the key's home shard (paper §4.3).

    Round-robins across shard members (relevant when replication > 1).
    """

    def __init__(self):
        self._rr: Dict[str, int] = {}

    def pick(self, shard, key, nodes, pool_nodes):
        up = [n for n in shard.nodes if nodes[n].up]
        members = up or shard.nodes
        i = self._rr.get(shard.name, 0)
        self._rr[shard.name] = i + 1
        return members[i % len(members)]

    def pick_batch(self, shard, keys, nodes, pool_nodes, resource="gpu"):
        # batch-aware dispatch: the whole batch is one occupancy, so take
        # the shard member with the least outstanding work on `resource`
        up = [n for n in shard.nodes if nodes[n].up]
        return _least_loaded_on(up or list(shard.nodes), nodes, resource)

    def name(self):
        return "affinity"


class ReplicaScheduler(Scheduler):
    """Affinity mode over replicated groups (read fan-out for compute).

    With ``ReplicatedPlacement`` a group lives on several shards; any
    replica member can serve a task locally, so we pick the least-loaded
    up node across ALL replica shards — the collocation benefit of
    ``ShardLocalScheduler`` plus the load-spreading of replication.
    """

    def __init__(self, store: CascadeStore):
        self.store = store

    def pick(self, shard, key, nodes, pool_nodes):
        try:
            homes = self.store.pool_for(key).replica_homes(key)
        except KeyError:
            homes = [shard]
        # up AND reachable: under a partition a reachable replica member
        # beats the unreachable home shard (the home being "up" across
        # the cut serves nothing this side of it)
        cand = [n for h in homes for n in h.nodes
                if dispatchable(self.store, n, nodes)]
        if not cand:
            cand = list(shard.nodes)

        def load(n):
            # total outstanding work over every resource: stages differ in
            # what they consume (MOT/PRED: gpu, CD: cpu), and a scheduler
            # that only counted gpu would see cpu-only nodes as idle
            node = nodes[n]
            return (sum(len(q) for q in node.queues.values())
                    + sum(node.in_use.values()))
        return min(cand, key=load)

    def pick_batch(self, shard, keys, nodes, pool_nodes, resource="gpu"):
        # same replica fan-out as pick, but ranked by the batch's resource
        try:
            homes = self.store.pool_for(keys[0]).replica_homes(keys[0])
        except KeyError:
            homes = [shard]
        cand = [n for h in homes for n in h.nodes
                if dispatchable(self.store, n, nodes)]
        return _least_loaded_on(cand or list(shard.nodes), nodes, resource)

    def name(self):
        return "replica_affinity"


class RandomScheduler(Scheduler):
    """Cloud-LB baseline: random spray over the pool, ignoring data homes."""

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)

    def pick(self, shard, key, nodes, pool_nodes):
        up = [n for n in pool_nodes if nodes[n].up]
        return self.rng.choice(up or list(pool_nodes))

    def name(self):
        return "random"


class LeastLoadedScheduler(Scheduler):
    """Queue-aware LB baseline (still data-oblivious)."""

    def __init__(self, resource: str = "gpu"):
        self.resource = resource

    def pick(self, shard, key, nodes, pool_nodes):
        up = [n for n in pool_nodes if nodes[n].up]
        cand = up or list(pool_nodes)

        def load(n):
            node = nodes[n]
            return (len(node.queues[self.resource])
                    + node.in_use[self.resource])
        return min(cand, key=load)

    def name(self):
        return "least_loaded"
