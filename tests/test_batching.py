"""Cross-instance stage batching: the shared cost model, the StageBatcher's
flush rules, accounting transparency vs the unbatched runtime, batch-aware
dispatch, and the DES hot-path regression envelope.

The hypothesis property test (random graphs x random windows) is marked
slow and runs in the dedicated CI slow job; everything else is tier-1.
"""
import time

import pytest

from repro.core import CascadeStore
from repro.runtime import (BatchCompute, BatchCostModel, Compute, Node, Put,
                           Runtime, ShardLocalScheduler, SimFuture,
                           Simulator, WaitFor)
from repro.workflows import (BatchPolicy, WorkflowRuntime, mode_kwargs,
                             preload_index, rag_workflow, speech_workflow)

RES = {"gpu": 1, "cpu": 2, "nic": 2}


# -- the shared cost model ----------------------------------------------------

def test_cost_model_transparent_at_one():
    m = BatchCostModel()
    assert m.batch_seconds(0.030, 1) == pytest.approx(0.030)
    assert m.step_seconds(0.030, 1) == pytest.approx(0.030)
    assert m.speedup(1) == 1.0


def test_cost_model_sublinear_then_segmented():
    m = BatchCostModel(fixed=0.65, marginal=0.35, max_batch=16)
    unit = 0.010
    for n in (2, 4, 8, 16):
        assert unit < m.batch_seconds(unit, n) < n * unit
        assert m.speedup(n) > 1.0
    # past max_batch amortization restarts: two full batches cost exactly
    # twice one full batch
    assert m.batch_seconds(unit, 32) == pytest.approx(
        2 * m.batch_seconds(unit, 16))


def test_cost_model_monotone_in_n():
    m = BatchCostModel()
    prev = 0.0
    for n in range(1, 40):
        cur = m.batch_seconds(1.0, n)
        assert cur > prev
        prev = cur


def test_cost_model_empty_batch_is_free():
    m = BatchCostModel()
    assert m.batch_seconds(0.030, 0) == 0.0
    assert m.batch_seconds(0.030, -3) == 0.0
    # step_seconds clamps to a unit step: an idle row still prices one
    # full decode step (the serving engine's n=max(slots,1) contract)
    assert m.step_seconds(0.030, 0) == pytest.approx(0.030)


def test_cost_model_speedup_monotone_up_to_hw_cap():
    """speedup(n) is nondecreasing on 1..max_batch (amortization only
    helps), >= 1 everywhere, and dips — but never below 1 — right past
    the cap where a second weight-stream starts."""
    m = BatchCostModel(fixed=0.65, marginal=0.35, max_batch=16)
    prev = 1.0
    for n in range(1, m.max_batch + 1):
        s = m.speedup(n)
        assert s >= prev - 1e-12
        prev = s
    assert m.speedup(m.max_batch + 1) < m.speedup(m.max_batch)
    for n in (17, 31, 32, 33, 100):
        assert m.speedup(n) >= 1.0


def test_cost_model_step_seconds_consistent_with_batch_seconds():
    m = BatchCostModel()
    for unit in (1e-4, 0.03, 2.0):
        for n in (1, 2, 7, 16, 17, 40):
            assert m.step_seconds(unit, n) * n == pytest.approx(
                m.batch_seconds(unit, n))


# -- sim primitives -----------------------------------------------------------

def make_sim(n_nodes=2):
    store = CascadeStore([f"n{i}" for i in range(n_nodes)])
    store.create_object_pool("/x", store.nodes, n_nodes,
                             affinity_set_regex=r"/[a-z0-9]+_")
    nodes = {n: Node(n, dict(RES)) for n in store.nodes}
    return Simulator(store, nodes), nodes


def test_batch_compute_is_one_occupancy():
    """A BatchCompute(n) occupies ONE lane for its amortized duration."""
    sim, nodes = make_sim()
    done = []

    def batch():
        yield BatchCompute("gpu", 0.013, n=4)
        done.append(sim.now)

    def single():
        yield Compute("gpu", 0.010)
        done.append(sim.now)

    sim.spawn("n0", batch())
    sim.spawn("n0", single())       # queues behind the batch (1 gpu lane)
    sim.run()
    assert done == [pytest.approx(0.013), pytest.approx(0.023)]
    assert nodes["n0"].busy_time["gpu"] == pytest.approx(0.023)
    assert sim.metrics["batch_sizes"] == [4]


def test_sim_future_resumes_all_waiters():
    sim, _ = make_sim()
    f = SimFuture()
    got = []

    def waiter(i):
        v = yield WaitFor(f)
        got.append((i, v, sim.now))

    def resolver():
        yield Compute("cpu", 0.5)
        sim.resolve(f, "val")

    for i in range(3):
        sim.spawn("n0", waiter(i))
    sim.spawn("n1", resolver())
    sim.run()
    assert sorted(got) == [(i, "val", pytest.approx(0.5)) for i in range(3)]


def test_wait_on_resolved_future_is_immediate():
    sim, _ = make_sim()
    f = SimFuture()
    sim.resolve(f, 7)
    got = []

    def waiter():
        v = yield WaitFor(f)
        got.append((v, sim.now))

    sim.spawn("n0", waiter())
    sim.run()
    assert got == [(7, 0.0)]


def test_run_until_preserves_future_events():
    """Stopping at `until` must not drop the event past the horizon."""
    sim, _ = make_sim()
    seen = []
    sim.at(1.0, lambda: seen.append(1.0))
    sim.at(3.0, lambda: seen.append(3.0))
    sim.run(until=2.0)
    assert seen == [1.0] and sim.now == 2.0
    sim.run()
    assert seen == [1.0, 3.0]


# -- batch-aware dispatch -----------------------------------------------------

def test_pick_batch_takes_least_loaded_member():
    store = CascadeStore(["a", "b"])
    pool = store.create_object_pool("/x", store.nodes, 1, replication=2,
                                    affinity_set_regex=r"/[a-z0-9]+_")
    nodes = {n: Node(n, dict(RES)) for n in store.nodes}
    shard = next(iter(pool.shards.values()))
    nodes["a"].in_use["gpu"] = 1          # a is busy
    sched = ShardLocalScheduler()
    assert sched.pick_batch(shard, ["/x/g_1"], nodes, store.nodes,
                            resource="gpu") == "b"
    nodes["b"].queues["gpu"].extend([(0.0, lambda: None)] * 2)
    assert sched.pick_batch(shard, ["/x/g_1"], nodes, store.nodes,
                            resource="gpu") == "a"


def test_mode_kwargs_batch_suffixes():
    assert mode_kwargs("atomic+batch")["batching"] is True
    assert mode_kwargs("atomic+batch")["gang_pin"] is True
    mk = mode_kwargs("atomic+mig+batch")
    assert mk["batching"] is True and mk["migrate_every"] is not None
    assert mode_kwargs("atomic")["batching"] is False
    for bad in ("atomic+bogus", "atomic+", "atomic++batch", "bogus+batch"):
        with pytest.raises(ValueError):
            mode_kwargs(bad)


# -- StageBatcher end to end --------------------------------------------------

def run_pair(make, n=160, shards=4, rate=240.0, window=0.024,
             deadline=2.0, **kw):
    """The same instance stream through unbatched and batched runtimes."""
    out = []
    for batching in (False, True):
        g = make(shards=shards)
        mk = dict(mode_kwargs("atomic"), batching=batching,
                  batch_policy=BatchPolicy(window=window))
        wrt = WorkflowRuntime(g, **mk, **kw)
        if make is rag_workflow:
            preload_index(wrt)
        for i in range(n):
            wrt.submit(f"req{i}", at=0.05 + i / rate, deadline=deadline)
        wrt.run()
        out.append(wrt)
    return out


def test_batching_coalesces_under_load():
    _, b = run_pair(rag_workflow)
    s = b.summary()
    assert s["batches"] < s["batched_tasks"]
    assert s["mean_batch"] > 1.0
    assert s["max_batch"] > 1


def test_batching_is_accounting_transparent():
    """Same completion sets, join-barrier arrivals, firings, and stage-done
    counts as the unbatched run — batching shares compute, never events."""
    a, b = run_pair(rag_workflow)
    assert set(a.tracker.records) == set(b.tracker.records)
    for inst, ra in a.tracker.records.items():
        rb = b.tracker.records[inst]
        assert ra.t_complete is not None and rb.t_complete is not None
        assert dict(ra.arrivals) == dict(rb.arrivals), inst
        assert dict(ra.fired) == dict(rb.fired), inst
        assert dict(ra.done) == dict(rb.done), inst


def test_batching_improves_overloaded_tail():
    a, b = run_pair(rag_workflow, n=240, rate=360.0)
    sa, sb = a.summary(), b.summary()
    assert sb["p99"] <= sa["p99"]
    assert sb["slo_miss_rate"] <= sa["slo_miss_rate"]


def test_idle_flush_keeps_unloaded_latency_exact():
    """At low load every batch flushes on the idle rule: zero added wait."""
    a, b = run_pair(speech_workflow, n=40, rate=30.0, window=0.050)
    sa, sb = a.summary(), b.summary()
    assert sb["idle_flushes"] > 0
    for inst, ra in a.tracker.records.items():
        assert b.tracker.records[inst].latency == pytest.approx(
            ra.latency, rel=1e-9), inst


def test_slo_flush_protects_tight_deadlines():
    """A member that cannot afford the window flushes the batch early."""
    _, b = run_pair(rag_workflow, n=120, rate=240.0, window=0.100,
                    deadline=0.150)
    s = b.summary()
    assert s["slo_flushes"] > 0


def test_slo_flush_rechecks_earlier_members_as_batch_grows():
    """A tight-deadline member admitted safely at n=1 must still force a
    flush when later loose members grow the batch past its headroom."""
    g = rag_workflow(shards=1)
    mk = dict(mode_kwargs("atomic"), batching=True,
              batch_policy=BatchPolicy(window=0.100, max_batch=16,
                                       idle_flush=False))
    wrt = WorkflowRuntime(g, **mk)
    preload_index(wrt)
    # one tight instance first, then a burst of loose ones: at n=1 the
    # tight deadline clears flush_at + est(1), but each loose enrollment
    # grows est — the re-check must flush before the tight member's
    # 0.16 s headroom is gone (generate: 0.030s gpu; est(16) ≈ 0.19s)
    wrt.submit("tight", at=0.001, deadline=0.160)
    for i in range(15):
        wrt.submit(f"loose{i}", at=0.002 + i * 1e-4, deadline=10.0)
    wrt.run()
    s = wrt.summary()
    assert s["slo_flushes"] > 0
    assert not wrt.tracker.records["tight"].missed_deadline


def test_size_cap_flushes_immediately():
    g = rag_workflow(shards=2)
    mk = dict(mode_kwargs("atomic"), batching=True,
              batch_policy=BatchPolicy(window=1.0, max_batch=3,
                                       idle_flush=False))
    wrt = WorkflowRuntime(g, **mk)
    preload_index(wrt)
    for i in range(18):
        wrt.submit(f"req{i}", at=0.01 + i * 1e-4)
    wrt.run()
    sizes = wrt.rt.sim.metrics["batch_sizes"]
    assert sizes and max(sizes) <= 3
    assert any(sz == 3 for sz in sizes)


def test_non_batchable_stage_stays_unbatched():
    g = rag_workflow(shards=2)
    for st in g.stages:
        st.batchable = False
    wrt = WorkflowRuntime(g, **mode_kwargs("atomic+batch"))
    preload_index(wrt)
    for i in range(12):
        wrt.submit(f"req{i}", at=0.01 + i * 1e-3)
    wrt.run()
    assert wrt.summary()["n"] == 12
    assert wrt.batcher.enrolled == 0


# -- DES hot-path regression envelope ----------------------------------------

def _event_trace_runtime(n_tasks):
    store = CascadeStore([f"n{i}" for i in range(8)])
    store.create_object_pool("/x", store.nodes, 8,
                             affinity_set_regex=r"/[a-z0-9]+_")
    rt = Runtime(store)

    def task(ctx, key, value):
        yield Compute("gpu", 0.001)
        yield Put(key + "o", size=64, fire=False)
    rt.register("/x", task)
    for i in range(n_tasks):
        rt.client_put(i * 1e-4, f"/x/g{i % 64}_{i}", size=16)
    return rt


def test_event_loop_50k_trace_envelope():
    """Regression guard for the DES hot path: a fixed 12.5k-task trace is
    exactly 50k heap events (op-count envelope — any extra per-op event
    is a hot-path regression) inside a generous wall budget that still
    catches accidental O(n^2) scans."""
    rt = _event_trace_runtime(12_500)
    t0 = time.perf_counter()
    rt.run()
    wall = time.perf_counter() - t0
    assert rt.sim.events_fired == 50_000
    assert rt.sim.completed_tasks == 12_500
    assert wall < 5.0, f"50k-event trace took {wall:.2f}s"


# -- property: batching transparency over random graphs (slow job) ------------

@pytest.mark.slow
def test_batching_transparency_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.workflows import Emit, WorkflowGraph

    def chain_workflow(chain, n_shards):
        g = WorkflowGraph("prop")
        g.add_tier("t", n_shards, dict(RES))
        for i in range(len(chain) + 1):
            g.add_pool(f"/p{i}", tier="t", shards=n_shards)
        for i, (fanout, join, cost) in enumerate(chain):
            g.add_stage(f"s{i}", pool=f"/p{i}", resource="gpu",
                        cost=cost * 1e-3,
                        emits=[Emit(f"/p{i + 1}", fanout=fanout, size=64)],
                        join=join and i > 0, sink=(i == len(chain) - 1))
        return g.validate()

    CHAINS = st.lists(
        st.tuples(st.integers(min_value=1, max_value=3),     # fanout
                  st.booleans(),                             # join barrier
                  st.integers(min_value=0, max_value=20)),   # cost (ms)
        min_size=1, max_size=4)

    @given(CHAINS,
           st.integers(min_value=1, max_value=6),            # shards
           st.integers(min_value=1, max_value=12),           # instances
           st.floats(min_value=1e-4, max_value=0.05))        # window
    @settings(max_examples=25, deadline=None)
    def prop(chain, n_shards, n_instances, window):
        runs = []
        for batching in (False, True):
            g = chain_workflow(chain, n_shards)
            mk = dict(mode_kwargs("atomic"), batching=batching,
                      batch_policy=BatchPolicy(window=window))
            wrt = WorkflowRuntime(g, **mk)
            for i in range(n_instances):
                wrt.submit(f"i{i}", at=0.001 + i * 0.002)
            wrt.run()
            runs.append(wrt)
        unb, bat = runs
        # 1) accounting transparency: identical completion sets and
        #    join-barrier/firing/done counters per instance
        assert set(unb.tracker.records) == set(bat.tracker.records)
        worst_extra = len(chain) * window + sum(
            BatchCostModel().batch_seconds(c * 1e-3, 16) - c * 1e-3
            for _, _, c in chain)
        for inst, ru in unb.tracker.records.items():
            rb = bat.tracker.records[inst]
            assert ru.t_complete is not None and rb.t_complete is not None
            assert dict(ru.arrivals) == dict(rb.arrivals)
            assert dict(ru.fired) == dict(rb.fired)
            assert dict(ru.done) == dict(rb.done)
            # 2) SLO bound: window waits + batch amortization can never
            #    push an instance past the unbatched latency plus one
            #    window and one worst-case batch per stage
            assert rb.latency <= ru.latency + worst_extra + 1e-9

    prop()
