"""Affinity-aware cross-instance stage batching.

Workflow-atomic placement pins every instance of a workflow to one shard
slot, so instances that fire the *same stage* on the *same slot* within a
short window are perfect batch candidates: their model weights, code and
data are already co-resident — the affinity label is exactly the grouping
signal serving systems (Vortex 2511.02062) and pipeline tuners (InferLine
1812.01776) have to infer from traffic.

``StageBatcher`` coalesces such firings into ONE
:class:`repro.runtime.simulation.BatchCompute` priced by the shared
:class:`repro.runtime.batching.BatchCostModel`, while leaving every piece
of per-instance accounting — join-barrier arrivals, per-stage spans,
deadlines, emitted objects — exact: only the compute op is shared, the
per-instance generators block on a :class:`repro.runtime.simulation.SimFuture`
and resume individually when the batch completes.

Flush rules (head-of-line-blocking control):

  * **window** — a batch holds at most ``window`` virtual seconds after it
    opens;
  * **size cap** — reaching the batch's size cap flushes immediately;
  * **idle flush** — if the stage's resource has a free lane on the slot's
    nodes when a batch opens, it flushes immediately: there is nothing to
    wait for, so an unloaded system pays zero added latency (batching only
    "turns on" under contention, exactly when it pays);
  * **SLO flush** — a member whose deadline cannot absorb the wait +
    amortized batch service flushes the batch at enrollment, so window
    waits never push a feasible instance past its deadline.

``window``/``max_batch`` come from the static :class:`BatchPolicy`, or —
with a :class:`repro.workflows.planner.BatchPlanner` attached — are
re-planned per batch from streaming arrival-rate / service-percentile /
queue-depth signals (see ``docs/batching.md``).

Window-flush timers never inflate the event heap: a batch flushed at
enrollment (idle/size/SLO rules) schedules no timer at all, and at most
ONE pending timer exists per (stage, slot) — when a batch flushes early,
its timer is left to roll forward to the next open batch on that key
instead of dying as a dead heap event.

With ``Runtime.hedge_after`` set, a flushed batch that has not completed
``hedge_after`` seconds later (primary stuck behind a backlog, on a
straggler, or on a dead node) is duplicated WHOLE to the least-loaded
live replica/member node; first completion wins, the losing lane's work
is cancelled and its backlog seconds refunded (:class:`_BatchLane`).
Only the shared compute op is duplicated, so per-instance accounting is
identical to an unhedged run whenever no hedge fires.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core import instance_of
from repro.runtime.batching import BatchCostModel
from repro.runtime.scheduler import (_least_loaded_on, dispatchable,
                                     hedge_candidates)
from repro.runtime.simulation import BatchCompute, SimFuture, WaitFor


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """Static knobs for batch formation (sweeps vary these; the adaptive
    planner supersedes ``window``/``max_batch`` per batch when attached)."""
    window: float = 0.004        # max virtual seconds a batch stays open
    max_batch: int = 16          # flush at this many members
    idle_flush: bool = True      # flush a fresh batch if the resource idles
    slo_margin: float = 0.0      # extra headroom reserved before deadlines


class _OpenBatch:
    __slots__ = ("stage", "slot", "resource", "unit_cost", "keys",
                 "future", "flush_at", "cap", "closed", "deadline_min",
                 "lanes", "traced", "flush_t", "plan", "id")

    def __init__(self, stage: str, slot: str, resource: str,
                 unit_cost: float, flush_at: float, cap: int):
        self.stage = stage
        self.slot = slot
        self.resource = resource
        self.unit_cost = unit_cost
        self.keys: List[str] = []
        self.future = SimFuture()
        # the batcher records exact batch_wait/queueing/compute spans for
        # traced members, so the tracer must skip the generic WaitFor
        self.future.blame = True
        self.flush_at = flush_at
        self.cap = cap
        self.closed = False
        self.deadline_min: Optional[float] = None   # tightest member deadline
        self.lanes: Optional[List["_BatchLane"]] = None  # hedged mode only
        # tracing (populated only when a tracer is attached)
        self.traced: Optional[List] = None     # [(InstanceTrace, enroll_t)]
        self.flush_t = 0.0
        self.plan: Optional[Tuple[float, int]] = None  # planner (window, cap)
        self.id = -1


class _BatchLane:
    """One execution lane of a hedged batch (primary or hedge duplicate).

    Hedging needs what ``Simulator.spawn`` cannot give: the losing lane's
    work must be cancellable whether it is still queued, or already in
    service.  So a hedged flush admits the lane itself as the typed queue
    entry (it is the callable ``acquire`` runs) and the batcher unrolls
    the compute accounting by hand — pending at issue, busy_time at
    completion (or partial, at mid-service cancel), release on exit —
    keeping every counter exactly as the unhedged spawn path would.
    State machine: queued -> running -> done, with cancelled reachable
    from queued (entry no-ops when popped, handing its admission slot
    back) and from running (lane freed now, stale done event ignored).
    """
    __slots__ = ("batcher", "batch", "node", "n", "dur", "state",
                 "t_start")

    def __init__(self, batcher: "StageBatcher", batch: _OpenBatch,
                 node: str, n: int, dur: float):
        self.batcher = batcher
        self.batch = batch
        self.node = node          # node NAME (lane accounting target)
        self.n = n
        self.dur = dur
        self.state = "queued"
        self.t_start = 0.0

    def __call__(self) -> None:   # the lane acquired its resource lane
        self.batcher._lane_start(self)


class StageBatcher:
    """Coalesce same-(stage, slot) firings into one ``BatchCompute``.

    Stage generators call :meth:`compute` (a sub-generator) in place of
    yielding a plain ``Compute``; the batcher enrolls them and they block
    on the batch's future.  The flush spawns one system task — placed by
    the runtime scheduler's batch-aware ``pick_batch`` — that executes the
    amortized ``BatchCompute`` and resolves the future, resuming every
    member at the batch's completion time.
    """

    def __init__(self, runtime, policy: Optional[BatchPolicy] = None,
                 cost_model: Optional[BatchCostModel] = None,
                 planner=None):
        self.rt = runtime                      # repro.runtime.Runtime
        self.sim = runtime.sim
        self.policy = policy or BatchPolicy()
        self.cost_model = cost_model or BatchCostModel(
            max_batch=self.policy.max_batch)
        self.planner = planner                 # BatchPlanner or None
        if planner is not None:
            # the planner's drain-rate controller plans generous windows
            # under backlog; the work-conserving release flush is what
            # makes them safe — a lane freeing with nothing queued flushes
            # every non-held open batch it could run, so a long window
            # never leaves hardware idle while members wait.  Static-
            # window batching (no planner) keeps the original semantics.
            self.sim.on_release = self._on_release
        self._open: Dict[Tuple[str, str], _OpenBatch] = {}
        # (node, resource) -> open-batch keys a lane release could flush
        self._open_by_node: Dict[Tuple[str, str], set] = {}
        # at most one pending window timer per (stage, slot): time it fires
        self._timer_at: Dict[Tuple[str, str], float] = {}
        # node -> the cost model pricing ITS batches: the node's hardware
        # profile's own curve when it declares one, else the shared model
        # (so a uniform cluster prices exactly as before tiers existed)
        self._node_cm: Dict[str, BatchCostModel] = {}
        # realized-coalescing stats (summary() reports them)
        self.n_batches = 0
        self.enrolled = 0
        self.slo_flushes = 0
        self.idle_flushes = 0
        self.timers_scheduled = 0
        self.timer_rolls = 0

    # -- enrollment (called from inside stage generators) -------------------

    def compute(self, ctx, stage, deadline: Optional[float] = None):
        """Sub-generator replacing ``yield Compute(stage.resource, cost)``.

        ``ctx`` is the stage's TaskContext (carries the dispatch shard —
        the batch key's slot); ``deadline`` the instance's absolute
        deadline, if any, for the SLO flush rule.
        """
        now = self.sim.now
        bkey = (stage.name, ctx.shard)
        planner = self.planner
        # the economic idle rule reads the arrival-gap EWMA as of BEFORE
        # this arrival (the wait it prices is the gap to the *next* one)
        hold = planner is not None and planner.hold_when_idle(
            stage.name, ctx.shard, stage.cost)
        if planner is not None:
            planner.note_arrival(stage.name, ctx.shard, now)
        batch = self._open.get(bkey)
        fresh = batch is None
        if fresh:
            if planner is not None:
                window, cap = planner.plan(
                    stage, ctx.shard, now, deadline,
                    pending=self._slot_pending(ctx.key, ctx.shard,
                                               stage.resource))
            else:
                window, cap = self.policy.window, self.policy.max_batch
            batch = _OpenBatch(stage.name, ctx.shard, stage.resource,
                               stage.cost, now + window, cap)
            if planner is not None and self.sim.tracer is not None:
                batch.plan = (window, cap)     # the planner's decision
            self._open[bkey] = batch
            if planner is not None:
                for n in self._shard_for(ctx.key, ctx.shard).nodes:
                    self._open_by_node.setdefault(
                        (n, stage.resource), {})[bkey] = None
        batch.keys.append(ctx.key)
        self.enrolled += 1
        if self.sim.tracer is not None:
            tr = self.sim.tracer.live.get(instance_of(ctx.key))
            if tr is not None:
                if batch.traced is None:
                    batch.traced = []
                batch.traced.append((tr, now))
        if deadline is not None and deadline >= now + \
                self.cost_model.batch_seconds(batch.unit_cost, 1) + \
                self.policy.slo_margin:
            # arm the SLO rule only with deadlines that an immediate
            # singleton flush could still meet: a hopeless member cannot
            # be saved by flushing early, and letting it force singleton
            # batches would starve the amortization everyone behind it
            # needs (the planner's max-throughput mode relies on this)
            if batch.deadline_min is None or deadline < batch.deadline_min:
                batch.deadline_min = deadline
        if fresh and self.policy.idle_flush and not hold and \
                self._resource_idle(batch):
            # nothing ahead of us: waiting can only add latency (unless
            # the planner's economic rule says the next member's
            # amortization saving is worth one arrival gap of idleness)
            self.idle_flushes += 1
            self._flush(batch)
        elif batch.deadline_min is not None and not batch.closed:
            # SLO-aware early flush, re-evaluated against the TIGHTEST
            # member deadline on every enrollment: growing the batch grows
            # its service time, so a member admitted safely at n=k can
            # become infeasible at n=k+1 — if riding out the window would
            # land that member past its headroom, go now
            est = self.cost_model.batch_seconds(batch.unit_cost,
                                                len(batch.keys))
            if batch.flush_at + est + self.policy.slo_margin > \
                    batch.deadline_min:
                self.slo_flushes += 1
                self._flush(batch)
            elif planner is not None:
                # adaptive mode: make the WINDOW TIMER enforce the SLO
                # too — if this slot's arrival stream dries up (e.g. a
                # scale-out diverts it), no further enrollment will ever
                # re-run the check above, and an un-tightened window
                # would ride past the member's headroom
                slo_at = batch.deadline_min - est - self.policy.slo_margin
                if slo_at < batch.flush_at:
                    batch.flush_at = max(now, slo_at)
        if not batch.closed and len(batch.keys) >= batch.cap:
            self._flush(batch)
        if not batch.closed:
            # a batch flushed at enrollment (idle/SLO/size) schedules no
            # timer at all, and an undischarged timer left by an earlier
            # early-flushed batch on this key is reused (it rolls forward
            # on fire) — so flushed batches never leave dead timer events
            # inflating the DES heap.  ``_timer_at`` tracks the EARLIEST
            # live timer per key; a new entry is pushed only when this
            # batch's window ends before it (possible under the adaptive
            # planner's per-batch windows), and the superseded later
            # entry becomes a stale no-op on pop.
            pending = self._timer_at.get(bkey)
            if pending is None or batch.flush_at < pending:
                self._timer_at[bkey] = batch.flush_at
                self.timers_scheduled += 1
                self.sim.at(batch.flush_at, self._window_flush, bkey)
        yield WaitFor(batch.future)

    # -- flushing -----------------------------------------------------------

    def _window_flush(self, bkey: Tuple[str, str]) -> None:
        if self._timer_at.get(bkey) != self.sim.now:
            return                    # superseded by an earlier/rolled timer
        del self._timer_at[bkey]
        batch = self._open.get(bkey)
        if batch is None:
            return
        if batch.flush_at <= self.sim.now:
            self._flush(batch)
        else:
            # a newer batch opened on this key after our batch flushed
            # early: roll the timer forward instead of letting the newer
            # batch push its own heap entry
            self._timer_at[bkey] = batch.flush_at
            self.timer_rolls += 1
            self.sim.at(batch.flush_at, self._window_flush, bkey)

    def _on_release(self, node, resource: str) -> None:
        """Work-conserving flush (adaptive mode): a lane just freed with
        an empty queue — flush every open batch it could run, except
        those the economic rule still holds for their next member."""
        keys = self._open_by_node.get((node.name, resource))
        if not keys:
            return
        planner = self.planner
        cap = node.capacity.get(resource, 1)
        for bkey in list(keys):
            # re-check per flush: the first flush's BatchCompute may
            # take the freed lane, and pushing the REMAINING batches
            # into its queue would truncate their formation windows for
            # no gain — they are no longer filling an idle lane
            if node.in_use[resource] >= cap or node.queues[resource]:
                return
            batch = self._open.get(bkey)
            if batch is None or batch.closed:
                keys.pop(bkey, None)
                continue
            if planner.hold_when_idle(batch.stage, batch.slot,
                                      batch.unit_cost):
                continue
            self.idle_flushes += 1
            self._flush(batch)

    def _flush(self, batch: _OpenBatch) -> None:
        batch.closed = True
        self._open.pop((batch.stage, batch.slot), None)
        if self.planner is not None:
            bkey = (batch.stage, batch.slot)
            for n in self._shard_for(batch.keys[0], batch.slot).nodes:
                m = self._open_by_node.get((n, batch.resource))
                if m is not None:
                    m.pop(bkey, None)
        n = len(batch.keys)
        binding = self.rt.bindings[batch.stage]
        shard = self._shard_for(batch.keys[0], batch.slot)
        node = self.rt.scheduler.pick_batch(
            shard, batch.keys, self.rt.nodes, binding.pool_nodes,
            resource=batch.resource)
        self.n_batches += 1
        if batch.traced is not None:
            batch.flush_t = self.sim.now
            batch.id = self.n_batches
        if self.rt.hedge_after is None:
            # price the batch with the EXECUTING backend's amortization
            # curve (per-tier batching economics); planning used the
            # shared model as its estimate, execution uses the hardware
            # truth
            seconds = self._cost_model_for(node).batch_seconds(
                batch.unit_cost, n)
            self.sim.spawn(node, self._run_batch(batch, seconds, n, node),
                           label=f"batch:{batch.stage}")
            return
        # hedged mode: issue the primary lane by hand so it stays
        # cancellable, and arm a one-shot hedge check
        batch.lanes = []
        self._issue_lane(batch, node, n)
        self.sim.at(self.sim.now + self.rt.hedge_after, self._maybe_hedge,
                    batch)

    def _cost_model_for(self, node_name: str) -> BatchCostModel:
        cm = self._node_cm.get(node_name)
        if cm is None:
            profile_cm = self.rt.nodes[node_name].profile.cost_model()
            cm = self._node_cm[node_name] = profile_cm or self.cost_model
        return cm

    def _run_batch(self, batch: _OpenBatch, seconds: float, n: int,
                   node_name: str):
        yield BatchCompute(batch.resource, seconds, n)
        if batch.traced is not None:
            # resolve-time arithmetic: the op just completed at now, and
            # its service time is seconds re-priced at the executing node
            node = self.rt.nodes[node_name]
            dur = seconds / max(node.rate(batch.resource), 1e-9)
            self._record_batch(batch, node_name,
                               max(batch.flush_t, self.sim.now - dur),
                               self.sim.now)
        self.sim.resolve(batch.future)

    def _record_batch(self, batch: _OpenBatch, node_name: str,
                      t_start: float, t_end: float) -> None:
        """Exact per-member blame spans for a completed batch: formation
        wait (enroll -> flush), slot queueing (flush -> service start,
        split against node down intervals), shared compute (start -> end).
        Together they tile each member's entire blocked interval, which is
        what lets blame sums stay exact under batching."""
        tracer = self.sim.tracer
        if tracer is None:
            return
        args = {"batch": batch.id, "n": len(batch.keys)}
        if batch.plan is not None:
            args["window_ms"] = round(batch.plan[0] * 1e3, 4)
            args["cap"] = batch.plan[1]
        for tr, enroll in batch.traced:
            tracer.span(tr, "batch_wait", f"batchform:{batch.stage}",
                        enroll, batch.flush_t, node=batch.slot)
            tracer.wait_span(tr, node_name, batch.flush_t, t_start,
                             name=f"batchq:{batch.stage}")
            tracer.span(tr, "compute", f"batch:{batch.stage}", t_start,
                        t_end, node=node_name, args=args)

    # -- hedged execution (Runtime.hedge_after is set) ----------------------

    def _issue_lane(self, batch: _OpenBatch, node_name: str,
                    n: int) -> "_BatchLane":
        """Admit one execution lane of ``batch`` on ``node_name``, with the
        same accounting a spawned BatchCompute would get at issue time."""
        node = self.rt.nodes[node_name]
        seconds = self._cost_model_for(node_name).batch_seconds(
            batch.unit_cost, n)
        dur = seconds / max(node.rate(batch.resource), 1e-9)
        lane = _BatchLane(self, batch, node_name, n, dur)
        batch.lanes.append(lane)
        node.n_tasks += 1
        node.pending[batch.resource] += dur
        self.sim.acquire(node, batch.resource, lane)
        return lane

    def _lane_start(self, lane: "_BatchLane") -> None:
        if lane.state == "cancelled":
            # cancelled while queued: hand the admission slot straight
            # back (release re-admits the next queue entry)
            self.sim.release(self.rt.nodes[lane.node], lane.batch.resource)
            return
        lane.state = "running"
        lane.t_start = self.sim.now
        self.sim.at(self.sim.now + lane.dur, self._lane_done, lane)

    def _lane_done(self, lane: "_BatchLane") -> None:
        if lane.state != "running":
            return                 # cancelled mid-service: stale event
        lane.state = "done"
        batch = lane.batch
        node = self.rt.nodes[lane.node]
        node.pending[batch.resource] -= lane.dur
        node.busy_time[batch.resource] += lane.dur
        # realized batch size lands once, for the WINNING lane only — a
        # hedged batch must never double-count in coalescing stats
        self.sim.metrics["batch_sizes"].append(lane.n)
        self.sim.completed_tasks += 1
        self.sim.release(node, batch.resource)
        for other in batch.lanes:
            if other is not lane:
                self._cancel_lane(other)
        if batch.traced is not None:
            # blame the WINNING lane only: its queueing/compute interval
            # is what the members actually waited out
            self._record_batch(batch, lane.node, lane.t_start, self.sim.now)
        self.sim.resolve(batch.future)

    def _cancel_lane(self, lane: "_BatchLane") -> None:
        if lane.state in ("done", "cancelled"):
            return
        batch = lane.batch
        node = self.rt.nodes[lane.node]
        node.pending[batch.resource] -= lane.dur   # refund backlog seconds
        if lane.state == "running":
            # bill only the service actually rendered, free the lane now
            node.busy_time[batch.resource] += self.sim.now - lane.t_start
            lane.state = "cancelled"
            self.sim.release(node, batch.resource)
        else:
            lane.state = "cancelled"   # queued entry no-ops when popped

    def _maybe_hedge(self, batch: _OpenBatch) -> None:
        """One-shot check ``hedge_after`` seconds after flush: if the
        batch is still unresolved (primary queued behind a backlog, on a
        straggler, or on a node that died), duplicate the WHOLE batch to
        the least-loaded live replica-or-member node.  First lane to
        finish resolves the shared future and cancels the loser."""
        if batch.future.done or len(batch.lanes) > 1:
            return
        primary = batch.lanes[0]
        cand = hedge_candidates(
            self.rt.store, self._shard_for(batch.keys[0], batch.slot),
            batch.keys[0], self.rt.nodes, exclude=(primary.node,))
        if not cand:
            return                 # nowhere to go: not hedgeable
        node = _least_loaded_on(cand, self.rt.nodes, batch.resource)
        self.rt.hedges += 1
        if batch.traced is not None:
            tracer = self.sim.tracer
            for tr, _ in batch.traced:
                tracer.instant(tr, f"hedge:{batch.stage}", self.sim.now,
                               {"primary": primary.node, "hedge": node,
                                "batch": batch.id})
        self._issue_lane(batch, node, primary.n)

    # -- helpers ------------------------------------------------------------

    def _shard_for(self, key: str, slot: str):
        return self.rt.store.pool_for(key).shards[slot]

    def forming_seconds(self, node_name: str, resource: str) -> float:
        """Service seconds held in OPEN batches dispatchable to ``node``
        — work committed but not yet visible in ``Node.pending`` (it
        lands there only at flush).  The admission gate adds this so a
        formation window cannot hide a queue from the feasibility check.
        Adaptive mode only (the index exists when a planner is attached).
        """
        m = self._open_by_node.get((node_name, resource))
        if not m:
            return 0.0
        cm = self._cost_model_for(node_name)
        total = 0.0
        for bkey in m:
            batch = self._open.get(bkey)
            if batch is not None and not batch.closed:
                total += cm.batch_seconds(batch.unit_cost,
                                          len(batch.keys))
        return total

    def _slot_pending(self, key: str, slot: str, resource: str) -> float:
        """Backlogged compute seconds per lane on the slot's least-backed-up
        member — the load signal the planner's window tracks (the same
        "prefer free lanes" member ``pick_batch`` will dispatch to)."""
        nodes = self.rt.nodes
        store = self.rt.store
        best = None
        for name in self._shard_for(key, slot).nodes:
            node = nodes[name]
            if not dispatchable(store, name, nodes):
                continue
            pending = (node.pending[resource]
                       / (node.capacity.get(resource, 1) or 1))
            if best is None or pending < best:
                best = pending
        return 0.0 if best is None else best

    def _resource_idle(self, batch: _OpenBatch) -> bool:
        """A free lane with an empty queue on any of the slot's nodes?"""
        nodes = self.rt.nodes
        store = self.rt.store
        for name in self._shard_for(batch.keys[0], batch.slot).nodes:
            node = nodes[name]
            if not dispatchable(store, name, nodes):
                continue
            if (node.in_use[batch.resource]
                    < node.capacity.get(batch.resource, 1)
                    and not node.queues[batch.resource]):
                return True
        return False

    # -- reporting ----------------------------------------------------------

    def summary(self) -> Dict[str, float]:
        sizes = self.sim.metrics.get("batch_sizes", [])
        out = {
            "batches": self.n_batches,
            "batched_tasks": self.enrolled,
            "slo_flushes": self.slo_flushes,
            "idle_flushes": self.idle_flushes,
            "window_timers": self.timers_scheduled,
        }
        if sizes:
            out["mean_batch"] = sum(sizes) / len(sizes)
            out["max_batch"] = max(sizes)
        if self.rt.hedge_after is not None:
            out["hedges"] = self.rt.hedges
        if self.planner is not None:
            out.update(self.planner.summary())
        return out
