"""Continuous-batching serving engine with affinity-grouped session state.

Real JAX execution (any local device count) + a virtual clock for the
network/queue components we cannot measure on CPU:

  * each *row* models one data-parallel replica group: it owns params, a
    slotted decode cache, and a virtual busy-until time;
  * requests route through ``SessionRouter`` (affinity vs baselines);
  * a routed turn whose session state lives on another row pays a
    migration: real `read_slot`/`write_slot` tensor movement + virtual
    transfer time = state_bytes / interconnect_bw (the cost affinity
    routing exists to avoid);
  * decode is genuinely batched: one ``decode_step`` advances every active
    slot of the row by one token, and the *virtual* cost of a step is
    priced by the shared ``repro.runtime.batching.BatchCostModel`` — the
    same curve the workflow layer's StageBatcher uses — amortized over the
    row's active slots, so co-residency (what affinity routing maximizes)
    directly buys decode throughput.

Service times (prefill/decode-step) are measured on the real model once and
reused by the virtual clock, so relative policy effects are grounded.

**Fault surface** (driven through ``repro.runtime.FaultInjector.fail_row``):
a row outage fails any turn whose service window overlaps it, wipes the
row's device state (cache, lengths, resident adapters), displaces its
sessions, and re-routes their groups via the router's ``pin_group`` path to
the best surviving row.  A failed turn retries under the engine's
:class:`~repro.runtime.faults.RetryPolicy` — exponential backoff, bounded
attempts, deadline-aware give-up that *sheds* the turn (session intact,
caller re-admits) instead of retrying forever.  A displaced session's state
rebuilds on its next turn, priced the cheaper of two ways and executed for
real either way:

  * **checkpoint restore**: ship the last periodic KV snapshot
    (``kv_cache.session_cache_bytes`` over the interconnect) and replay
    only the transcript suffix it misses;
  * **re-prefill**: replay the full transcript through the prefill path.

Every turn passes through a :class:`repro.core.GroupSequencer` keyed by the
session's affinity-group label and commits against a per-session turn
index, so a replayed or retried turn can neither apply its effects twice
nor commit ahead of an earlier uncommitted turn of its group — the
serving-plane half of the exactly-once story (``dup_effects`` and
``order_violations`` stay zero under chaos, asserted by fig12).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EpochFence, GroupSequencer
from repro.models import Model
from repro.runtime.batching import BatchCostModel
from repro.runtime.faults import FailureEvent, RetryPolicy
from repro.runtime.simulation import (CLUSTER_NET, UNIFORM, HardwareProfile,
                                      NetProfile)
from . import kv_cache as kvc
from .adapters import AdapterStore, apply_adapter
from .sessions import Session, SessionRouter


@dataclasses.dataclass
class TurnMetrics:
    sid: str
    row: int
    migrated: bool
    migration_bytes: int
    ttft: float              # virtual seconds to first token
    decode_time: float       # virtual seconds for the remaining tokens
    tokens: int
    e2e: float = 0.0         # request arrival -> last token (or give-up)
    attempts: int = 1
    retry_wait: float = 0.0  # failed-attempt + backoff seconds
    recovered: Optional[str] = None   # "ckpt" | "reprefill" | None
    recovery_time: float = 0.0
    shed: bool = False       # retry budget exhausted: turn not executed


@dataclasses.dataclass
class _RowOutage:
    row: int
    t_down: float
    t_up: float
    event: FailureEvent
    processed: bool = False


@dataclasses.dataclass
class _TurnPlan:
    """Virtual-cost schedule of one attempt — pure arithmetic, no tensor
    or residency mutation, so a planned attempt that dies with its row
    costs wasted time and nothing else."""
    row_idx: int
    t_q: float               # queue wait ends
    t_mig: float             # migration/adapter transfer ends
    t_rec: float             # recovery (restore or re-prefill) ends
    t_first: float           # prefill + first decode step ends
    t_end: float             # last decode step ends
    t_step: float            # virtual seconds per decode step
    mig_bytes: int
    migrated: bool
    recovery: Optional[str]  # "ckpt" | "reprefill" | None


class Row:
    def __init__(self, model: Model, params: Any, max_slots: int,
                 max_seq: int, profile: HardwareProfile = UNIFORM):
        self.model = model
        self.params = params
        self.cache = model.init_cache(max_slots, max_seq)
        self.lengths = jnp.zeros((max_slots,), jnp.int32)
        self.active = np.zeros((max_slots,), bool)
        self.slot_sid: List[Optional[str]] = [None] * max_slots
        self.busy_until = 0.0
        self.decoded_tokens = 0
        # backend tier: virtual decode time divides by the gpu speed, and
        # the tier's own batch curve (if declared) prices amortization
        self.profile = profile
        self.speed = profile.speed_of("gpu")
        self.cost_model = profile.cost_model()   # None -> engine-shared

    def free_slot(self) -> Optional[int]:
        for i, a in enumerate(self.active):
            if not a:
                return i
        return None

    def load(self) -> int:
        return int(self.active.sum())

    def backlog(self, now: float) -> float:
        """Virtual seconds of queued decode work still ahead of ``now`` —
        the row-scheduler analogue of a node's resource queue depth."""
        return max(0.0, self.busy_until - now)


class ServingEngine:
    def __init__(self, model: Model, params: Any, n_rows: int = 4,
                 max_slots: int = 8, max_seq: int = 256,
                 policy: str = "affinity",
                 net: NetProfile = CLUSTER_NET, seed: int = 0,
                 cost_model: Optional[BatchCostModel] = None,
                 row_profiles: Optional[Sequence[HardwareProfile]] = None,
                 tracer: Optional[Any] = None,
                 retry: Optional[RetryPolicy] = None,
                 checkpoint_every: Optional[int] = None):
        self.model = model
        # optional repro.runtime.tracing.TraceRecorder: every turn becomes
        # one completed trace (queueing/migration/prefill/decode spans
        # telescoping exactly over the turn's virtual window; failed
        # attempts and recovery add retry/recovery spans)
        self.tracer = tracer
        profs = list(row_profiles or [])
        profs += [UNIFORM] * (n_rows - len(profs))
        self.rows = [Row(model, params, max_slots, max_seq,
                         profile=profs[i]) for i in range(n_rows)]
        self.router = SessionRouter(n_rows, policy=policy, seed=seed)
        self.adapters = AdapterStore(n_rows)
        self.net = net
        self.cost_model = cost_model or BatchCostModel(max_batch=max_slots)
        self.max_seq = max_seq
        self.sessions: Dict[str, Session] = {}
        self.metrics: List[TurnMetrics] = []
        self.state_bytes = kvc.session_cache_bytes(model, max_seq)
        # fault surface: outage schedule + retry budget + periodic KV
        # checkpoints (None -> recovery always re-prefills the transcript)
        self.retry = retry or RetryPolicy()
        self.checkpoint_every = checkpoint_every
        self.outages: List[_RowOutage] = []
        # per-group FIFO commit order + exactly-once commit accounting;
        # the fence extends exactly-once from crash faults to split-brain:
        # every group re-route (gang repair) advances the group's epoch,
        # and a commit still holding the pre-repair token is rejected
        # into dup_effects instead of applied
        self.sequencer = GroupSequencer()
        self.fence = EpochFence()
        self.dup_effects = 0
        self.order_violations = 0
        self.shed_turns = 0
        self.turns_failed = 0
        self.recoveries_ckpt = 0
        self.recoveries_reprefill = 0
        self.recovery_bytes = 0
        self.checkpoint_bytes = 0
        self._hwm = 0.0          # high-water mark of driven virtual time
        self._decode = jax.jit(model.decode_step)
        self._decode_h = jax.jit(
            lambda p, c, t, l: model.decode_step(p, c, t, l,
                                                 return_hidden=True))
        self._prefill = jax.jit(model.prefill)
        self._svc = self._calibrate(params)

    # -- calibration -----------------------------------------------------------

    def _calibrate(self, params) -> Dict[str, float]:
        B = len(self.rows[0].active)
        tok = jnp.zeros((B,), jnp.int32)
        lens = jnp.zeros((B,), jnp.int32)
        cache = self.rows[0].cache
        out = self._decode(params, cache, tok, lens)
        jax.block_until_ready(out[0])
        t0 = time.perf_counter()
        for _ in range(3):
            out = self._decode(params, cache, tok, lens)
            jax.block_until_ready(out[0])
        step = (time.perf_counter() - t0) / 3
        return {"decode_step": step, "prefill_per_tok": step / 8}

    # -- public API ---------------------------------------------------------------

    def open_session(self, sid: str, adapter: Optional[str] = None) -> Session:
        s = Session(sid=sid, adapter=adapter)
        self.sessions[sid] = s
        return s

    # -- fault surface ------------------------------------------------------------

    def fail_row(self, row: int, at: float, duration: float) -> FailureEvent:
        """Schedule a row outage (normally via ``FaultInjector.fail_row``).

        The engine's clock is caller-driven, so outages must be scheduled
        ahead of the turns that will observe them; death effects apply
        lazily when the driven clock first reaches ``at``."""
        if not 0 <= row < len(self.rows):
            raise KeyError(f"unknown row {row!r}")
        assert at >= self._hwm, \
            f"fail_row at {at} is behind the driven clock {self._hwm}"
        ev = FailureEvent(node=f"row{row}", t_down=at, t_up=at + duration,
                          kind="row")
        self.outages.append(_RowOutage(row=row, t_down=at,
                                       t_up=at + duration, event=ev))
        self.outages.sort(key=lambda o: o.t_down)
        return ev

    def _row_down(self, idx: int, t: float) -> bool:
        return any(o.row == idx and o.t_down <= t < o.t_up
                   for o in self.outages)

    def _sweep_faults(self, t: float) -> None:
        """Apply every outage whose down time the clock has reached: wipe
        the row's device state, displace its sessions, and re-home their
        groups on the best surviving row (the ``pin_group`` repair path —
        the serving analogue of workflow gang re-pinning)."""
        for o in self.outages:
            if o.processed or o.t_down > t:
                continue
            o.processed = True
            row = self.rows[o.row]
            victims = [s for s in self.sessions.values() if s.row == o.row]
            labels = set()
            pin = self.router.policy in ("affinity", "adapter_affinity")
            for s in victims:
                s.row = None
                s.slot = None
                s.lost_state = True
                o.event.sessions_displaced += 1
                if pin:
                    labels.add(self.router.label_of(s))
            # the row's memory is gone: blank cache, stale adapters dropped
            row.cache = kvc.reset_cache(row.cache)
            row.lengths = jnp.zeros_like(row.lengths)
            row.active[:] = False
            row.slot_sid = [None] * len(row.slot_sid)
            row.busy_until = o.t_up          # serves nothing until recovery
            self.adapters.drop_row(o.row)
            if labels:
                live = [i for i in range(len(self.rows))
                        if not self._row_down(i, o.t_down)]
                proj = {i: self.rows[i].load() for i in live}
                for lbl in sorted(labels):
                    if not live:
                        break
                    tgt = min(live, key=lambda i: (
                        0 if self.rows[i].free_slot() is not None else 1,
                        self.rows[i].backlog(o.t_down), proj[i]))
                    # re-homing claims the group: any in-flight commit
                    # still holding the pre-repair token is fenced off
                    self.fence.advance(lbl)
                    self.router.pin_group(lbl, tgt)
                    proj[tgt] += 1
                    o.event.groups_rerouted += 1

    def _group_label(self, s: Session) -> str:
        """Sequencer label: the affinity-group label under group-aware
        policies, else the session itself (each session is its own group)."""
        if self.router.policy in ("affinity", "adapter_affinity"):
            return self.router.label_of(s)
        return s.sid

    # -- the turn -----------------------------------------------------------------

    def turn(self, sid: str, prompt: List[int], gen_tokens: int = 16,
             now: float = 0.0, deadline: Optional[float] = None
             ) -> Tuple[List[int], TurnMetrics]:
        """One chat turn: route, (maybe recover/migrate), prefill, decode.

        Under faults, a turn whose row dies mid-service fails and retries
        under the engine's retry budget; exhausting it sheds the turn
        (empty output, ``metrics.shed`` set, session untouched).
        ``deadline`` (seconds from ``now``) overrides the policy timeout.
        """
        s = self.sessions[sid]
        turn_idx = s.turns
        req_id = f"{sid}:{turn_idx}"
        self._hwm = max(self._hwm, now)
        if deadline is not None:
            deadline_abs = now + deadline
        elif self.retry.timeout is not None:
            deadline_abs = now + self.retry.timeout
        else:
            deadline_abs = float("inf")
        # per-group FIFO delivery: the synchronous engine serves one turn
        # at a time, so the sequencer acts as an order/duplication
        # invariant — a replay arriving out of admission order (or a turn
        # re-entering while its group is busy) is counted, not silently
        # committed
        label = self._group_label(s)
        self.sequencer.admit(label, req_id)
        if self.sequencer.ready(label) != req_id:
            self.order_violations += 1
        try:
            return self._turn_attempts(s, turn_idx, req_id, prompt,
                                       gen_tokens, now, deadline_abs)
        finally:
            self.sequencer.complete(label)

    def _turn_attempts(self, s: Session, turn_idx: int, req_id: str,
                       prompt: List[int], gen_tokens: int, now: float,
                       deadline_abs: float
                       ) -> Tuple[List[int], TurnMetrics]:
        attempt = 1
        t_att = now
        retry_spans: List[Tuple[str, float, float]] = []
        while True:
            self._sweep_faults(t_att)
            plan = self._plan_attempt(s, req_id, prompt, gen_tokens, t_att)
            fail_at = None if plan is None else \
                self._first_conflict(plan.row_idx, t_att, plan.t_end)
            if plan is not None and fail_at is None:
                return self._execute(s, turn_idx, req_id, prompt,
                                     gen_tokens, now, t_att, attempt,
                                     plan, retry_spans)
            if plan is None:
                # no live row with capacity: shed immediately (graceful
                # degradation — the caller's admission problem now)
                return self._shed(s, req_id, now, t_att, attempt,
                                  retry_spans)
            # the chosen row dies inside our service window: the attempt
            # fails at the death instant, its virtual time wasted
            self.turns_failed += 1
            for o in self.outages:
                if o.row == plan.row_idx and o.t_down == fail_at:
                    o.event.turns_failed += 1
                    break
            retry_spans.append((f"attempt{attempt}", t_att, fail_at))
            backoff = self.retry.backoff_of(attempt)
            attempt += 1
            t_next = fail_at + backoff
            if attempt > self.retry.max_attempts or t_next > deadline_abs:
                return self._shed(s, req_id, now, fail_at, attempt - 1,
                                  retry_spans)
            retry_spans.append(("backoff", fail_at, t_next))
            t_att = t_next

    def _plan_attempt(self, s: Session, req_id: str, prompt: List[int],
                      gen_tokens: int, t_att: float) -> Optional[_TurnPlan]:
        """Route + price one attempt without mutating anything."""
        have_faults = bool(self.outages)
        # the row scheduler's load signal mirrors the DES schedulers'
        # pick_batch ranking (repro.runtime.scheduler.node_load): prefer
        # rows with a free lane first, then the shallowest virtual queue,
        # then the fewest co-resident sessions; dead rows rank last so
        # least-loaded routing never picks one
        signals = [(0 if r.free_slot() is not None else 1,
                    r.backlog(t_att), r.load()) for r in self.rows]
        if have_faults:
            signals = [(2, float("inf"), float("inf"))
                       if self._row_down(i, t_att) else sig
                       for i, sig in enumerate(signals)]
        row_idx = self.router.route(s, req_id, row_loads=signals)
        # capacity overflow (or a dead routed row): spill to the
        # best-signal live row with a free slot
        down = have_faults and self._row_down(row_idx, t_att)
        if down or (s.row != row_idx
                    and self.rows[row_idx].free_slot() is None):
            cands = [i for i, r in enumerate(self.rows)
                     if (i == s.row or r.free_slot() is not None)
                     and not (have_faults and self._row_down(i, t_att))]
            if not cands:
                return None
            row_idx = s.row if s.row in cands else \
                min(cands, key=lambda i: signals[i])
        row = self.rows[row_idx]
        slot_free = (s.slot if s.row == row_idx else row.free_slot())
        if slot_free is None:
            return None

        t = max(t_att, row.busy_until)
        t_q = t                     # queue wait ends here
        mig_bytes = self.adapters.peek_bytes(row_idx, s.adapter)
        migrated = False
        if s.row is not None and s.row != row_idx:
            mig_bytes += self.state_bytes
            migrated = True
        t += self.net.transfer_time(mig_bytes) if mig_bytes else 0.0
        t_mig = t

        # recovery pricing: the engine picks per-session between shipping
        # the last KV checkpoint + replaying the suffix, and re-prefilling
        # the whole transcript — both real, both on the turn's critical
        # path (SAGA's point: session state is bytes, losing it costs
        # either wire time or recompute time, whichever is cheaper)
        recovery = None
        per_tok = self._svc["prefill_per_tok"] / row.speed
        if s.lost_state and s.transcript:
            t_repre = per_tok * len(s.transcript)
            if s.ckpt is not None:
                t_ckpt = (self.net.transfer_time(self.state_bytes)
                          + per_tok * (len(s.transcript) - s.ckpt_len))
                recovery = "ckpt" if t_ckpt <= t_repre else "reprefill"
                t += min(t_ckpt, t_repre)
            else:
                recovery = "reprefill"
                t += t_repre
        t_rec = t

        t_prefill = self._svc["prefill_per_tok"] * len(prompt) / row.speed
        cm = row.cost_model or self.cost_model
        load_after = row.load() + (0 if s.row == row_idx else 1)
        t_step = cm.step_seconds(self._svc["decode_step"],
                                 load_after) / row.speed
        t_dec = 0.0
        for _ in range(gen_tokens):     # repeated add: matches execution
            t_dec += t_step
        return _TurnPlan(row_idx=row_idx, t_q=t_q, t_mig=t_mig, t_rec=t_rec,
                         t_first=t_rec + t_prefill + t_step,
                         t_end=t_rec + t_prefill + t_dec, t_step=t_step,
                         mig_bytes=mig_bytes, migrated=migrated,
                         recovery=recovery)

    def _first_conflict(self, row_idx: int, t0: float,
                        t1: float) -> Optional[float]:
        """Earliest row death inside the attempt's window (t0, t1)."""
        hits = [o.t_down for o in self.outages
                if o.row == row_idx and t0 < o.t_down < t1]
        return min(hits) if hits else None

    def _execute(self, s: Session, turn_idx: int, req_id: str,
                 prompt: List[int], gen_tokens: int, now: float,
                 t_att: float, attempt: int, plan: _TurnPlan,
                 retry_spans: List[Tuple[str, float, float]]
                 ) -> Tuple[List[int], TurnMetrics]:
        """The surviving attempt: real tensor work + commit, priced by
        ``plan``.  Mirrors the original single-shot turn body exactly when
        there is no retry/recovery, so the fault-free path is unchanged."""
        row_idx = plan.row_idx
        row = self.rows[row_idx]
        # epoch token for the commit below: captured after the last fault
        # sweep of the attempt loop, so a repair that re-homed this group
        # BEFORE the surviving attempt is fine, while one racing the
        # attempt itself (an async replay scenario) gets fenced
        label = self._group_label(s)
        fence_tok = self.fence.current(label)
        self.adapters.ensure_resident(row_idx, s.adapter)

        if s.row is not None and s.row != row_idx:
            # migrate session state between rows: real tensor movement
            src = self.rows[s.row]
            payload = kvc.read_slot(src.cache, s.slot)
            src.cache = kvc.clear_slot(src.cache, s.slot)
            src.active[s.slot] = False
            src.slot_sid[s.slot] = None
            slot = row.free_slot()
            assert slot is not None, "row full"
            row.cache = kvc.write_slot(row.cache, payload, slot)
            row.lengths = row.lengths.at[slot].set(s.length)
            s.migrations += 1
            s.migrated_bytes += self.state_bytes
            s.row, s.slot = row_idx, slot
        elif s.row is None:
            slot = row.free_slot()
            assert slot is not None, "row full"
            s.row, s.slot = row_idx, slot
        slot = s.slot
        row.active[slot] = True
        row.slot_sid[slot] = s.sid

        if plan.recovery is not None:
            # real state reconstruction, exactly as priced
            if plan.recovery == "ckpt":
                row.cache = kvc.write_slot(row.cache, s.ckpt, slot)
                row.lengths = row.lengths.at[slot].set(s.ckpt_len)
                replay = s.transcript[s.ckpt_len:]
                self.recoveries_ckpt += 1
                self.recovery_bytes += self.state_bytes
            else:
                row.lengths = row.lengths.at[slot].set(0)
                replay = s.transcript
                self.recoveries_reprefill += 1
            for tok in replay:
                row.cache, row.lengths = self._advance(row, slot, tok)
            s.lost_state = False
            s.recoveries += 1

        # prefill the prompt token-by-token through decode_step (keeps the
        # slotted cache layout; fine at test scale); like decode, virtual
        # prefill time divides by the row's tier speed
        toks = list(prompt)
        for tok in toks:
            row.cache, row.lengths = self._advance(row, slot, tok)
        ttft = plan.t_first - now

        out: List[int] = []
        fed: List[int] = []
        adapter = (self.adapters.get(s.adapter) if s.adapter else None)
        tok = toks[-1] if toks else 0
        t_step = plan.t_step
        t_dec = 0.0
        for _ in range(gen_tokens):
            fed.append(int(tok))
            nxt, row.cache, row.lengths = self._decode_one(row, slot, tok,
                                                           adapter)
            out.append(int(nxt))
            tok = int(nxt)
            t_dec += t_step
            row.decoded_tokens += row.load()

        row.busy_until = plan.t_end
        s.length = int(row.lengths[slot])

        # -- exactly-once commit: effects apply against the turn index
        # captured at admission; a duplicated replay cannot re-commit,
        # and a stale-epoch attempt (its group re-homed mid-service by a
        # partitioned or superseding repair) is fenced instead of applied
        if s.turns != turn_idx or not self.fence.check(label, fence_tok):
            self.dup_effects += 1
            return out, self.metrics[-1]
        s.turns = turn_idx + 1
        s.transcript.extend(toks)
        s.transcript.extend(fed)
        if self.checkpoint_every and \
                s.turns % self.checkpoint_every == 0:
            # periodic KV snapshot, shipped off-row in the background
            # (not on this turn's critical path; restore pays the wire)
            s.ckpt = kvc.read_slot(row.cache, slot)
            s.ckpt_len = len(s.transcript)
            self.checkpoint_bytes += self.state_bytes

        m = TurnMetrics(sid=s.sid, row=row_idx, migrated=plan.migrated,
                        migration_bytes=plan.mig_bytes, ttft=ttft,
                        decode_time=t_dec, tokens=len(out),
                        e2e=plan.t_end - now, attempts=attempt,
                        retry_wait=t_att - now, recovered=plan.recovery,
                        recovery_time=plan.t_rec - plan.t_mig)
        self.metrics.append(m)
        if self.tracer is not None:
            tr = self.tracer.begin(req_id, now)
            if tr is not None:
                rname = f"row{row_idx}"
                tracer = self.tracer
                for name, a, b in retry_spans:
                    tracer.span(tr, "retry", name, a, b, node=rname)
                tracer.span(tr, "queueing", "row_queue", t_att, plan.t_q,
                            node=rname)
                tracer.span(tr, "migration", "session_migrate", plan.t_q,
                            plan.t_mig, node=rname,
                            args={"bytes": plan.mig_bytes})
                if plan.recovery is not None:
                    tracer.span(tr, "recovery", f"restore_{plan.recovery}",
                                plan.t_mig, plan.t_rec, node=rname,
                                args={"tokens": len(s.transcript),
                                      "from_ckpt": plan.recovery == "ckpt"})
                tracer.span(tr, "compute", "prefill", plan.t_rec,
                            plan.t_end - t_dec, node=rname)
                tracer.span(tr, "compute", "decode", plan.t_end - t_dec,
                            plan.t_end, node=rname,
                            args={"tokens": len(out), "slots": row.load()})
                tracer.complete(tr, plan.t_end)
        return out, m

    def _shed(self, s: Session, req_id: str, now: float, t_give_up: float,
              attempts: int, retry_spans: List[Tuple[str, float, float]]
              ) -> Tuple[List[int], TurnMetrics]:
        """Retry budget (or capacity) exhausted: give the turn up cleanly.
        The session and its transcript are untouched — the turn index is
        not consumed, so the caller can re-issue it later."""
        self.shed_turns += 1
        s.shed += 1
        m = TurnMetrics(sid=s.sid, row=-1, migrated=False,
                        migration_bytes=0, ttft=float("nan"),
                        decode_time=0.0, tokens=0,
                        e2e=t_give_up - now, attempts=attempts,
                        retry_wait=t_give_up - now, shed=True)
        self.metrics.append(m)
        if self.tracer is not None:
            tr = self.tracer.begin(req_id, now)
            if tr is not None:
                for name, a, b in retry_spans:
                    self.tracer.span(tr, "retry", name, a, b)
                self.tracer.instant(tr, "turn_shed", t_give_up,
                                    {"sid": s.sid, "attempts": attempts})
                self.tracer.complete(tr, t_give_up)
        return [], m

    # -- internals ---------------------------------------------------------------
    # Cache updates are committed per-slot through a mask so recurrent-state
    # families (SSM/LRU) never advance state for slots that didn't consume a
    # token this step.

    @staticmethod
    def _commit(old_cache, new_cache, mask):
        def sel(o, n):
            m = mask.reshape((1, -1) + (1,) * (o.ndim - 2))
            return jnp.where(m, n.astype(o.dtype), o)
        return jax.tree_util.tree_map(sel, old_cache, new_cache)

    def _advance(self, row: Row, slot: int, tok: int):
        """Feed one known token into the slot's cache (prefill path)."""
        B = len(row.active)
        toks = jnp.zeros((B,), jnp.int32).at[slot].set(tok)
        mask = jnp.zeros((B,), bool).at[slot].set(True)
        _, cache = self._decode(row.params, row.cache, toks, row.lengths)
        cache = self._commit(row.cache, cache, mask)
        lengths = row.lengths.at[slot].add(1)
        return cache, lengths

    def _decode_one(self, row: Row, slot: int, tok: int, adapter):
        B = len(row.active)
        toks = jnp.zeros((B,), jnp.int32).at[slot].set(tok)
        mask = jnp.zeros((B,), bool).at[slot].set(True)
        if adapter is not None:
            logits, cache, hidden = self._decode_h(
                row.params, row.cache, toks, row.lengths)
            logits = apply_adapter(logits, hidden, adapter)
        else:
            logits, cache = self._decode(row.params, row.cache, toks,
                                         row.lengths)
        cache = self._commit(row.cache, cache, mask)
        nxt = jnp.argmax(logits[slot], -1)
        lengths = row.lengths.at[slot].add(1)
        return nxt, cache, lengths

    # -- load-aware group rebalancing ---------------------------------------------

    def rebalance(self, imbalance: int = 2, max_moves: int = 1
                  ) -> List[Tuple[str, int]]:
        """Move whole session groups off overloaded rows.

        Mirrors the store-side ``GroupMigrator`` at the serving layer: when
        the hottest row holds `imbalance` more active sessions than the
        coldest, the smallest group on the hot row is pinned to the cold
        row.  Sessions follow their group lazily — each member's next turn
        routes to the new row and pays its state migration there (the
        engine's existing migration path), so no decode work is interrupted.
        Returns the (label, destination_row) moves made.
        """
        moves: List[Tuple[str, int]] = []
        # only affinity policies route through the placement engine, so only
        # they can honor a pin — anything else would report moves that
        # never take effect
        if self.router.policy not in ("affinity", "adapter_affinity"):
            return moves
        # migration is lazy (groups move on their next turn), so work on
        # *projected* loads — else the same group gets re-picked each pass
        loads = [r.load() for r in self.rows]
        moved_labels = set()
        for _ in range(max_moves):
            hot = max(range(len(loads)), key=lambda i: loads[i])
            cold = min(range(len(loads)), key=lambda i: loads[i])
            if loads[hot] - loads[cold] < imbalance:
                break
            groups: Dict[str, List[Session]] = {}
            for s in self.sessions.values():
                if s.row == hot:
                    lbl = self.router.label_of(s)
                    if lbl not in moved_labels:
                        groups.setdefault(lbl, []).append(s)
            if not groups:
                break
            # smallest group that still fits the cold row's free slots
            free = len(self.rows[cold].active) - loads[cold]
            cands = sorted(groups.items(), key=lambda kv: len(kv[1]))
            pick = next(((lbl, ss) for lbl, ss in cands if len(ss) <= free),
                        None)
            if pick is None:
                break
            label, members = pick
            self.router.pin_group(label, cold)
            moved_labels.add(label)
            loads[hot] -= len(members)
            loads[cold] += len(members)
            moves.append((label, cold))
        return moves

    # -- reporting ----------------------------------------------------------------

    def summary(self) -> Dict[str, float]:
        if not self.metrics:
            return {}
        ok = [m for m in self.metrics if not m.shed]
        ttfts = np.array([m.ttft for m in ok]) if ok else np.array([0.0])
        migs = sum(m.migrated for m in self.metrics)
        out = {
            "turns": len(self.metrics),
            "ttft_mean": float(ttfts.mean()),
            "ttft_p95": float(np.percentile(ttfts, 95)),
            "migrations": migs,
            "migration_bytes": sum(m.migration_bytes for m in self.metrics),
            "adapter_fetch_bytes": self.adapters.bytes_fetched,
        }
        if self.outages or self.shed_turns or self.dup_effects:
            e2e = np.array([m.e2e for m in ok]) if ok else np.array([0.0])
            out.update(
                turns_ok=len(ok),
                turn_p50=float(np.percentile(e2e, 50)),
                turn_p99=float(np.percentile(e2e, 99)),
                turns_failed=self.turns_failed,
                shed_turns=self.shed_turns,
                recoveries_ckpt=self.recoveries_ckpt,
                recoveries_reprefill=self.recoveries_reprefill,
                recovery_bytes=self.recovery_bytes,
                checkpoint_bytes=self.checkpoint_bytes,
                dup_effects=self.dup_effects,
                order_violations=self.order_violations,
                sessions_displaced=sum(o.event.sessions_displaced
                                       for o in self.outages),
                groups_rerouted=sum(o.event.groups_rerouted
                                    for o in self.outages),
            )
        return out
