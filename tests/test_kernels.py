"""Per-kernel correctness: Pallas (interpret=True) vs ref.py oracles,
swept over shapes and dtypes.

Interpret-mode Pallas sweeps take minutes — the whole module is marked
``slow`` so the fast tier-1 CI job (``-m "not slow"``) skips it; the
dedicated slow job runs it.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.rglru_scan import rglru_scan

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _arr(rng, shape, dtype):
    return jnp.asarray(rng.normal(0, 1, shape), dtype)


@pytest.mark.parametrize("B,S,H,K,D", [
    (1, 32, 2, 2, 8),      # MHA
    (2, 64, 4, 2, 16),     # GQA g=2
    (1, 48, 8, 2, 16),     # GQA g=4, odd block tail avoided (48%16==0)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["causal", "bidi", "window"])
def test_flash_attention(rng, B, S, H, K, D, dtype, mode):
    q = _arr(rng, (B, S, H, D), dtype)
    k = _arr(rng, (B, S, K, D), dtype)
    v = _arr(rng, (B, S, K, D), dtype)
    kw = dict(causal=(mode != "bidi"), window=8 if mode == "window" else 0)
    want = ref.mha(q, k, v, **kw)
    got = flash_attention(q, k, v, block_q=16, block_k=16, interpret=True,
                          **kw)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_q_offset(rng):
    """Chunked prefill: absolute positions via q_offset."""
    q = _arr(rng, (1, 16, 2, 8), jnp.float32)
    k = _arr(rng, (1, 64, 2, 8), jnp.float32)
    v = _arr(rng, (1, 64, 2, 8), jnp.float32)
    want = ref.mha(q, k, v, causal=True, q_offset=48)
    got = flash_attention(q, k, v, causal=True, q_offset=48,
                          block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_attention_mla_vdim(rng):
    """MLA-style: v head dim != qk head dim."""
    q = _arr(rng, (1, 32, 4, 24), jnp.float32)
    k = _arr(rng, (1, 32, 4, 24), jnp.float32)
    v = _arr(rng, (1, 32, 4, 16), jnp.float32)
    want = ref.mha(q, k, v, causal=True, scale=24 ** -0.5)
    got = flash_attention(q, k, v, causal=True, scale=24 ** -0.5,
                          block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,H,K,D,Smax", [
    (2, 4, 2, 16, 64),
    (3, 8, 1, 8, 32),      # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(rng, B, H, K, D, Smax, dtype):
    q = _arr(rng, (B, H, D), dtype)
    kc = _arr(rng, (B, Smax, K, D), dtype)
    vc = _arr(rng, (B, Smax, K, D), dtype)
    lengths = jnp.asarray(rng.integers(1, Smax, (B,)), jnp.int32)
    want = ref.decode_attention(q, kc, vc, lengths)
    got = decode_attention(q, kc, vc, lengths, block_s=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_decode_attention_window(rng):
    q = _arr(rng, (2, 4, 8), jnp.float32)
    kc = _arr(rng, (2, 32, 2, 8), jnp.float32)
    vc = _arr(rng, (2, 32, 2, 8), jnp.float32)
    lengths = jnp.array([20, 31], jnp.int32)
    want = ref.decode_attention(q, kc, vc, lengths, window=8)
    got = decode_attention(q, kc, vc, lengths, window=8, block_s=8,
                           interpret=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (1, 16, 2, 4, 1, 8, 4),
    (2, 32, 4, 8, 2, 16, 8),
    (1, 24, 2, 8, 2, 8, 24),   # single chunk
])
def test_ssd_scan(rng, B, S, H, P, G, N, chunk):
    x = _arr(rng, (B, S, H, P), jnp.float32)
    dt = jnp.abs(_arr(rng, (B, S, H), jnp.float32)) * 0.5 + 0.01
    A = -jnp.abs(_arr(rng, (H,), jnp.float32))
    Bm = _arr(rng, (B, S, G, N), jnp.float32)
    Cm = _arr(rng, (B, S, G, N), jnp.float32)
    D = _arr(rng, (H,), jnp.float32)
    yw, sw = ref.ssd(x, dt, A, Bm, Cm, D, chunk=chunk)
    yg, sg = ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    np.testing.assert_allclose(yg, yw, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(sg, sw, atol=5e-5, rtol=5e-5)


def test_ssd_chunk_invariance(rng):
    """The chunked algorithm must not depend on the chunk size."""
    B, S, H, P, G, N = 1, 32, 2, 4, 1, 8
    x = _arr(rng, (B, S, H, P), jnp.float32)
    dt = jnp.abs(_arr(rng, (B, S, H), jnp.float32)) * 0.5 + 0.01
    A = -jnp.abs(_arr(rng, (H,), jnp.float32))
    Bm = _arr(rng, (B, S, G, N), jnp.float32)
    Cm = _arr(rng, (B, S, G, N), jnp.float32)
    y4, s4 = ref.ssd(x, dt, A, Bm, Cm, None, chunk=4)
    y32, s32 = ref.ssd(x, dt, A, Bm, Cm, None, chunk=32)
    np.testing.assert_allclose(y4, y32, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s4, s32, atol=1e-4, rtol=1e-4)


def test_ssd_vs_sequential_decode(rng):
    """Chunked scan == step-by-step recurrent decode."""
    B, S, H, P, G, N = 1, 12, 2, 4, 1, 8
    x = _arr(rng, (B, S, H, P), jnp.float32)
    dt = jnp.abs(_arr(rng, (B, S, H), jnp.float32)) * 0.5 + 0.01
    A = -jnp.abs(_arr(rng, (H,), jnp.float32))
    Bm = _arr(rng, (B, S, G, N), jnp.float32)
    Cm = _arr(rng, (B, S, G, N), jnp.float32)
    D = _arr(rng, (H,), jnp.float32)
    y_chunk, s_chunk = ref.ssd(x, dt, A, Bm, Cm, D, chunk=4)
    state = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        y, state = ref.ssd_decode(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t],
                                  D, state)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_seq, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s_chunk, state, atol=1e-4, rtol=1e-4)


@pytest.mark.xfail(
    reason="known Pallas interpret-mode failure on current jax (seed "
           "baseline); tracked in ROADMAP — in-tree marker replaces the "
           "former CI-only --deselect so tier-1 passes without flags",
    strict=False)
@pytest.mark.parametrize("B,S,W,bs,bw", [
    (1, 16, 8, 4, 8),
    (2, 32, 24, 8, 8),
    (1, 8, 16, 8, 16),
])
@pytest.mark.parametrize("with_h0", [False, True])
def test_rglru_scan(rng, B, S, W, bs, bw, with_h0):
    a = jax.nn.sigmoid(_arr(rng, (B, S, W), jnp.float32)) * 0.95
    b = _arr(rng, (B, S, W), jnp.float32)
    h0 = _arr(rng, (B, W), jnp.float32) if with_h0 else None
    hw, fw = ref.rglru(a, b, h0)
    hg, fg = rglru_scan(a, b, h0, block_s=bs, block_w=bw, interpret=True)
    np.testing.assert_allclose(hg, hw, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(fg, fw, atol=2e-5, rtol=2e-5)


def test_rglru_matches_naive_loop(rng):
    """associative_scan oracle vs plain python recurrence."""
    B, S, W = 1, 10, 4
    a = jax.nn.sigmoid(_arr(rng, (B, S, W), jnp.float32))
    b = _arr(rng, (B, S, W), jnp.float32)
    hw, _ = ref.rglru(a, b)
    h = np.zeros((B, W), np.float32)
    for t in range(S):
        h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
        np.testing.assert_allclose(np.asarray(hw[:, t]), h, atol=1e-5)


def test_mha_q_chunk_invariance(rng):
    """q-block-chunked attention == dense attention."""
    q = _arr(rng, (2, 32, 4, 8), jnp.float32)
    k = _arr(rng, (2, 32, 2, 8), jnp.float32)
    v = _arr(rng, (2, 32, 2, 8), jnp.float32)
    dense = ref.mha(q, k, v, causal=True)
    chunked = ref.mha(q, k, v, causal=True, q_chunk=8)
    unrolled = ref.mha(q, k, v, causal=True, q_chunk=8, unroll=True)
    np.testing.assert_allclose(chunked, dense, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(unrolled, dense, atol=1e-5, rtol=1e-5)
