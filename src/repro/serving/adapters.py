"""LoRA adapter store with affinity grouping (paper §7.2's LoRA example).

Adapters are LM-head LoRA deltas: logits += (h @ A) @ B * scale.  They are
data objects in the store sense — each has an affinity key (its own id), so
sessions using adapter `a` can be routed to rows where `a` is resident
('adapter_affinity' policy); baselines fetch the adapter on first use per
row (transfer cost = adapter bytes).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Set, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class LoRAAdapter:
    name: str
    A: jax.Array        # (d_model, r)
    B: jax.Array        # (r, vocab)
    scale: float = 1.0

    @property
    def nbytes(self) -> int:
        return int(self.A.size * self.A.dtype.itemsize
                   + self.B.size * self.B.dtype.itemsize)


def make_adapter(rng: jax.Array, name: str, d_model: int, vocab: int,
                 rank: int = 8, dtype=jnp.float32) -> LoRAAdapter:
    k1, k2 = jax.random.split(rng)
    return LoRAAdapter(
        name=name,
        A=(jax.random.normal(k1, (d_model, rank)) * 0.02).astype(dtype),
        B=jnp.zeros((rank, vocab), dtype),   # standard LoRA init: B=0
        scale=1.0)


def apply_adapter(logits: jax.Array, hidden: jax.Array,
                  adapter: LoRAAdapter) -> jax.Array:
    delta = (hidden.astype(adapter.A.dtype) @ adapter.A) @ adapter.B
    return logits + adapter.scale * delta.astype(logits.dtype)


class AdapterStore:
    """Tracks which rows hold which adapters; charges fetch bytes on miss."""

    def __init__(self, n_rows: int):
        self.adapters: Dict[str, LoRAAdapter] = {}
        self.resident: Dict[int, Set[str]] = {r: set() for r in range(n_rows)}
        self.fetches = 0
        self.bytes_fetched = 0

    def register(self, adapter: LoRAAdapter) -> None:
        self.adapters[adapter.name] = adapter

    def peek_bytes(self, row: int, name: Optional[str]) -> int:
        """Fetch bytes ``ensure_resident`` WOULD charge, without fetching —
        the planning half of a turn attempt must not mutate residency."""
        if name is None or name in self.resident[row]:
            return 0
        return self.adapters[name].nbytes

    def drop_row(self, row: int) -> None:
        """A dead row loses its resident adapters with its memory."""
        self.resident[row].clear()

    def ensure_resident(self, row: int, name: Optional[str]) -> int:
        """Returns bytes that had to be fetched to make `name` resident."""
        if name is None or name in self.resident[row]:
            return 0
        ad = self.adapters[name]
        self.resident[row].add(name)
        self.fetches += 1
        self.bytes_fetched += ad.nbytes
        return ad.nbytes

    def get(self, name: str) -> LoRAAdapter:
        return self.adapters[name]
