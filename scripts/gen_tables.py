"""Generate the EXPERIMENTS.md tables from dry-run artifacts."""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

DRY = Path(__file__).resolve().parents[1] / "benchmarks" / "artifacts" / "dryrun"


def fmt(x, nd=3):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    if abs(x) >= 1000 or abs(x) < 0.01:
        return f"{x:.2e}"
    return f"{x:.{nd}f}"


def roofline_table(rules="baseline"):
    from benchmarks.roofline import load_cells, recompute
    from repro import configs
    print("| arch | shape | compute s | memory s | coll s | dominant | "
          "roofline frac | useful flops | fits 16GB |")
    print("|---|---|---|---|---|---|---|---|---|")
    seen = set()
    for d in load_cells(rules=rules):
        r = recompute(d)
        args = (d["memory"].get("argument_size_in_bytes") or 0)
        fits = "yes" if args < 16 * 2 ** 30 else f"NO ({args/2**30:.0f}GB)"
        print(f"| {d['arch']} | {d['shape']} | {fmt(r['compute_s'])} | "
              f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | "
              f"{r['dominant']} | {fmt(r['roofline_fraction'])} | "
              f"{fmt(d.get('useful_flop_ratio'))} | {fits} |")
        seen.add((d["arch"], d["shape"]))
    for arch, shape, skip in configs.cells():
        if skip and (arch, shape) not in seen:
            print(f"| {arch} | {shape} | - | - | - | skipped | - | - | "
                  f"{skip} |")
            seen.add((arch, shape))


def dryrun_table(mesh):
    print("| arch | shape | compile s | args GB | temps GB | "
          "flops/dev | coll B/dev |")
    print("|---|---|---|---|---|---|---|")
    for f in sorted(DRY.glob(f"*__{mesh}__baseline.json")):
        d = json.loads(f.read_text())
        if d.get("skip"):
            continue
        m = d["memory"]
        print(f"| {d['arch']} | {d['shape']} | {d['compile_s']} | "
              f"{(m.get('argument_size_in_bytes') or 0)/2**30:.1f} | "
              f"{(m.get('temp_size_in_bytes') or 0)/2**30:.1f} | "
              f"{fmt(d['flops_per_device'])} | "
              f"{fmt(d['collective_bytes_per_device'])} |")


def perf_cells():
    from benchmarks.roofline import recompute
    rows = {}
    for f in sorted(DRY.glob("*__single__*.json")):
        d = json.loads(f.read_text())
        if d.get("skip"):
            continue
        key = (d["arch"], d["shape"])
        rows.setdefault(key, {})[d["rules"]] = recompute(d)
    for (arch, shape), by_rules in sorted(rows.items()):
        if len(by_rules) < 2:
            continue
        print(f"\n### {arch} x {shape}")
        print("| ruleset | compute s | memory s | coll s | dominant | frac |")
        print("|---|---|---|---|---|---|")
        for rules, r in sorted(by_rules.items()):
            print(f"| {rules} | {fmt(r['compute_s'])} | {fmt(r['memory_s'])}"
                  f" | {fmt(r['collective_s'])} | {r['dominant']} | "
                  f"{fmt(r['roofline_fraction'])} |")


if __name__ == "__main__":
    what = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    if what == "roofline":
        roofline_table(sys.argv[2] if len(sys.argv) > 2 else "baseline")
    elif what == "dryrun":
        dryrun_table(sys.argv[2] if len(sys.argv) > 2 else "single")
    elif what == "perf":
        perf_cells()
