"""Single-token GQA decode attention against a (possibly ring) KV cache.

Grid (batch, kv_heads, kv_blocks): each step loads one (block_s, D) KV tile
into VMEM and updates an online-softmax accumulator for the g query heads
sharing that kv head.  `lengths` rides in SMEM (scalar per batch row) and
masks the tail block; a local `window` restricts attention to the last W
positions (ring caches pass window=0 and a clamped `lengths`).

Oracle: ``repro.kernels.ref.decode_attention``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, softcap, window, block_s, ns, g):
    js = pl.program_id(2)

    @pl.when(js == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[pl.program_id(0)]
    s_lo = js * block_s
    relevant = s_lo < length
    if window and window > 0:
        relevant = relevant & (s_lo + block_s > length - window)

    @pl.when(relevant)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # (g, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # (bs, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)             # (bs, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (g, bs)
        if softcap and softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        pos = s_lo + jax.lax.broadcasted_iota(jnp.int32, (g, block_s), 1)
        mask = pos < length
        if window and window > 0:
            mask = mask & (pos >= length - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[:, 0] = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
        m_ref[:, 0] = m_cur

    @pl.when(js == ns - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, softcap=0.0,
                     scale: Optional[float] = None, window=0,
                     block_s: int = 512, interpret: bool = False):
    """q (B,H,D); caches (B,Smax,K,D/Dv); lengths (B,). Returns (B,H,Dv)."""
    B, H, D = q.shape
    Smax, K = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    g = H // K
    scale = scale if scale is not None else D ** -0.5
    bs = min(block_s, Smax)
    assert Smax % bs == 0, (Smax, bs)
    ns = Smax // bs

    qr = q.reshape(B, K, g, D)
    kernel = functools.partial(_kernel, scale=scale, softcap=softcap,
                               window=window, block_s=bs, ns=ns, g=g)
    out = pl.pallas_call(
        kernel,
        grid=(B, K, ns),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # lengths, whole array
            pl.BlockSpec((1, 1, g, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bs, 1, Dv), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, Dv), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, g, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, Dv), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qr, k_cache, v_cache)
    return out.reshape(B, H, Dv)