"""Fig. 14 (ours): cold-cache ladder — admission-time prefetch + speculative
fan-in staging (paper §3.4).

The agent workflow (plan -> act x4 -> reduce) cold-starts per-instance
tool-adapter slabs: every act firing requires 4 x 8 MB adapters resident,
and the reduce stage is a 4-way fan-in over 2 MB observations.  Under
scatter (raw key-hash) placement both edges pay remote bytes, and the
adapter fetches sit on the act critical path.  The ladder:

  * ``none``     — scatter, caching off: every read pays the wire, every
    time (the floor the paper's §3.4 argues nobody should accept);
  * ``demand``   — scatter + demand-filled caches: first toucher pays,
    later firings on the same node piggyback;
  * ``prefetch`` — demand + admission-time prefetch: at submit the
    runtime walks the downstream stages, predicts each act leg's fire
    node from the trigger-key homes, and ships the adapter slabs there
    *during* plan's compute, on the bounded per-node prefetch channel
    (contends with demand fetches for NIC lanes — not free);
  * ``spec``     — prefetch + speculative fan-in staging: at the reduce
    barrier's *first* arrival, ship the already-arrived inputs (and the
    stage's declared reads) to the predicted fire node; mispredicted
    bytes are counted as ``wasted_speculative_bytes`` and bounded.

Acceptance (asserted below, hard-floored in SUITE_DELTA_METRICS):
prefetch p99 strictly below demand-cache p99 on the cold scatter config;
speculative <= prefetch-only; an armed engine on gang-pinned (atomic)
placement — where every read is already local — is byte-identical to
unarmed; the blame decomposition shows ``prefetch`` milliseconds with
reduced ``network``; wasted speculative bytes stay under the bound; zero
lost instances and zero stale installs everywhere.
"""
import time

from .common import emit, write_chrome_trace

SHARDS = 8
N_ADAPTERS = 4
ADAPTER_SLAB = 8 << 20
IA_MS = 12.5                 # instance interarrival (light overlap)
SPEC_BUDGET = 1 << 30        # speculative staging bound (bytes)

# (tag, mode, caching) — the cold ladder, then the all-local identity pair
MODES = (
    ("scatter/none", "keyhash", False),
    ("scatter/demand", "keyhash", True),
    ("scatter/prefetch", "keyhash+prefetch", True),
    ("scatter/spec", "keyhash+spec", True),
    ("atomic/demand", "atomic", True),
    ("atomic/spec", "atomic+spec", True),
)


def run_ladder(mode: str, caching: bool, n: int, tracing=False):
    """One cold-cache run: every cache starts empty, adapters preloaded
    at each instance's submit time (so gang pinning co-locates them and
    scatter placement hashes them away — the two ends of the ladder)."""
    from repro.workflows import (WorkflowRuntime, agent_workflow,
                                 mode_kwargs, preload_adapters)
    graph = agent_workflow(shards=SHARDS, n_adapters=N_ADAPTERS)
    wrt = WorkflowRuntime(graph, caching=caching, tracing=tracing,
                          speculative_budget=SPEC_BUDGET,
                          **mode_kwargs(mode))
    t = 0.0
    for i in range(n):
        inst = f"a{i}"
        wrt.submit(inst, at=t)
        preload_adapters(wrt, inst, at=t, n_parts=N_ADAPTERS,
                         slab_bytes=ADAPTER_SLAB)
        t += IA_MS / 1e3
    wrt.run()
    return wrt


def _latencies(wrt):
    return sorted(r.latency for r in wrt.tracker.records.values()
                  if r.latency is not None)


def _blame(wrt):
    from repro.workflows import BlameTable
    bt = BlameTable()
    for tr in wrt.tracer.traces():
        bt.add(tr)
    return bt.flat()


def trace_row(n: int):
    """Traced demand vs prefetch exemplars: the blame decomposition shows
    which network milliseconds the overlap removed, and the prefetch run
    exports the Perfetto artifact CI uploads."""
    t0 = time.perf_counter()
    demand = _blame(run_ladder("keyhash", True, n, tracing=True))
    wrt = run_ladder("keyhash+prefetch", True, n, tracing=True)
    pref = _blame(wrt)
    assert pref["blame_prefetch_ms"] > 0, \
        f"no prefetch blame: {pref['blame_prefetch_ms']}"
    assert pref["blame_network_ms"] < demand["blame_network_ms"], \
        (f"prefetch did not reduce network blame: "
         f"{demand['blame_network_ms']} -> {pref['blame_network_ms']}")
    path, payload = write_chrome_trace(wrt.tracer, "fig14")
    return ("fig14/trace/scatter/prefetch", pref["blame_network_ms"] * 1e3,
            {"blame_network_demand_ms": round(demand["blame_network_ms"], 3),
             "blame_network_ms": round(pref["blame_network_ms"], 3),
             "blame_prefetch_ms": round(pref["blame_prefetch_ms"], 3),
             "blame_compute_ms": round(pref["blame_compute_ms"], 3),
             "blame_top": pref["blame_top"],
             "trace_events": len(payload["traceEvents"]),
             "artifact": path.name,
             "wall_s": round(time.perf_counter() - t0, 3)})


def run(quick=True):
    import math
    n = 120 if quick else 240
    rows = []
    lat = {}
    summaries = {}
    for tag, mode, caching in MODES:
        t0 = time.perf_counter()
        wrt = run_ladder(mode, caching, n)
        lats = _latencies(wrt)
        lat[tag] = lats
        s = wrt.summary()
        summaries[tag] = s

        def pct(q):
            return lats[min(len(lats) - 1, math.ceil(q * len(lats)) - 1)]

        d = {"p50_ms": round(pct(0.50) * 1e3, 3),
             "p95_ms": round(pct(0.95) * 1e3, 3),
             "p99_ms": round(pct(0.99) * 1e3, 3),
             "remote_gets": s["remote_gets"],
             "lost": n - s["n"],
             "wall_s": round(time.perf_counter() - t0, 3),
             "n": s["n"]}
        if "prefetch_issued" in s:
            d.update(prefetch_issued=s["prefetch_issued"],
                     prefetch_hits=s["prefetch_hits"],
                     prefetch_stale=s["prefetch_stale"],
                     # hard floor: a cold-ladder run where prefetch never
                     # serves a read is a regression (0 == hits present)
                     no_prefetch_hits=int(s["prefetch_hits"] == 0
                                          and caching
                                          and tag.startswith("scatter")))
        if "wasted_speculative_bytes" in s:
            d["wasted_speculative_mb"] = round(
                s["wasted_speculative_bytes"] / (1 << 20), 1)
        rows.append((f"fig14/{tag}/{SHARDS}sh", pct(0.50) * 1e6, d))

    p99 = {tag: lats[min(len(lats) - 1, math.ceil(0.99 * len(lats)) - 1)]
           for tag, lats in lat.items()}
    # the ladder's contract (ISSUE 10 acceptance):
    assert p99["scatter/prefetch"] < p99["scatter/demand"], \
        (f"prefetch p99 {p99['scatter/prefetch']} not strictly below "
         f"demand-cache p99 {p99['scatter/demand']}")
    assert p99["scatter/spec"] <= p99["scatter/prefetch"], \
        (f"speculative p99 {p99['scatter/spec']} worse than prefetch-only "
         f"{p99['scatter/prefetch']}")
    # armed but all-local (gang-pinned adapters): byte-identical latencies
    assert lat["atomic/spec"] == lat["atomic/demand"], \
        "armed engine perturbed an all-local run"
    spec = summaries["scatter/spec"]
    assert spec["wasted_speculative_bytes"] <= SPEC_BUDGET, \
        (f"wasted speculative bytes {spec['wasted_speculative_bytes']} "
         f"over the configured bound {SPEC_BUDGET}")
    assert all(s.get("prefetch_stale", 0) == 0 for s in summaries.values())
    assert all(n - s["n"] == 0 for s in summaries.values()), "lost instances"

    rows.append(trace_row(n))
    return rows


if __name__ == "__main__":
    emit(run())
