"""Elastic scaling with affinity-stable resharding.

The paper's §3.2 'lightweight' requirement: resharding must not require a
synchronized key->shard map.  With rendezvous placement only ~1/n of
affinity GROUPS move when a shard joins/leaves; the autoscaler monitors
queue depth, proposes a new shard count, gets the migration plan from
``GroupRegistry`` and executes it as background transfers (group-granular —
a group is a unit of migration, which is exactly what makes migration safe
wrt ordering: the group's sequencer drains before the move).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core import GroupRegistry, MigrationPlan
from repro.core.object_store import Shard
from .executor import Runtime


@dataclasses.dataclass
class ScaleDecision:
    pool: str
    old_shards: int
    new_shards: int
    reason: str


class AutoScaler:
    def __init__(self, runtime: Runtime, pool_prefix: str,
                 spare_nodes: Sequence[str],
                 high_watermark: int = 8, low_watermark: int = 1):
        self.rt = runtime
        self.pool_prefix = pool_prefix
        self.spare = list(spare_nodes)
        self.high = high_watermark
        self.low = low_watermark
        self.registry = GroupRegistry(runtime.store)
        self.decisions: List[ScaleDecision] = []

    def queue_depth(self) -> int:
        pool = self.rt.store.pools[self.pool_prefix]
        depth = 0
        for shard in pool.shards.values():
            for n in shard.nodes:
                node = self.rt.nodes[n]
                depth = max(depth, len(node.queues["gpu"])
                            + node.in_use["gpu"])
        return depth

    def evaluate(self) -> Optional[ScaleDecision]:
        pool = self.rt.store.pools[self.pool_prefix]
        n = len(pool.shards)
        depth = self.queue_depth()
        if depth >= self.high and self.spare:
            return ScaleDecision(self.pool_prefix, n, n + 1,
                                 f"queue depth {depth} >= {self.high}")
        if depth <= self.low and n > 1:
            return ScaleDecision(self.pool_prefix, n, n - 1,
                                 f"queue depth {depth} <= {self.low}")
        return None

    def apply(self, decision: ScaleDecision) -> MigrationPlan:
        """Reshard the pool and physically move affected groups."""
        pool = self.rt.store.pools[self.pool_prefix]
        plan = self.registry.plan_resharding(self.pool_prefix,
                                             decision.new_shards)
        old_shards = dict(pool.shards)
        # build the new shard set
        members: List[str] = []
        for s in old_shards.values():
            members.extend(s.nodes)
        if decision.new_shards > len(old_shards):
            members.append(self.spare.pop(0))
        new_shards = []
        per = max(len(members) // decision.new_shards, 1)
        for i in range(decision.new_shards):
            new_shards.append(
                Shard(f"{pool.prefix}#s{i}", members[i * per:(i + 1) * per]))
        pool.shards = {s.name: s for s in new_shards}
        pool.engine.shards = [s.name for s in new_shards]
        # migrate objects into the new shard instances (group = migration
        # unit; unmoved groups land in the same-named shard at zero cost,
        # moved groups are the plan's transfer bytes)
        for shard in old_shards.values():
            for key, rec in list(shard.objects.items()):
                pool.home(key).objects[key] = rec
        self.decisions.append(decision)
        return plan
