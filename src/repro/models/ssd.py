"""Mamba-2 block built on the SSD (state-space duality) scan.

Block layout follows the Mamba-2 reference: in-proj produces
[z, x, B, C, dt]; causal depthwise conv over [x, B, C]; SSD; gated RMSNorm;
out-proj.  The SSD itself runs through ``repro.kernels.ops.ssd`` (chunked
jnp oracle / Pallas TPU kernel).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .common import ModelConfig, ParamFactory, scaled_init, zeros_init, ones_init
from . import layers

Params = Dict[str, Any]


def dims(cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    P = cfg.ssm_head_dim
    H = di // P
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    return d, di, P, H, G, N


def init_ssd_block(pf: ParamFactory, cfg: ModelConfig):
    d, di, P, H, G, N = dims(cfg)
    cw = cfg.conv_width
    layers.init_rmsnorm(pf, "ln", d)
    pf.param("wz", (d, di), ("embed", "ssm_inner"), fan_in=d)
    pf.param("wx", (d, di), ("embed", "ssm_inner"), fan_in=d)
    pf.param("wB", (d, G * N), ("embed", "ssm_bc"), fan_in=d)
    pf.param("wC", (d, G * N), ("embed", "ssm_bc"), fan_in=d)
    pf.param("wdt", (d, H), ("embed", "ssm_heads"), fan_in=d)
    pf.param("conv_x", (cw, di), ("conv", "ssm_inner"), fan_in=cw)
    pf.param("conv_B", (cw, G * N), ("conv", "ssm_bc"), fan_in=cw)
    pf.param("conv_C", (cw, G * N), ("conv", "ssm_bc"), fan_in=cw)
    pf.param("dt_bias", (H,), ("ssm_heads",), init=zeros_init)
    pf.param("A_log", (H,), ("ssm_heads",), init=zeros_init)
    pf.param("Dskip", (H,), ("ssm_heads",), init=ones_init)
    pf.param("gnorm", (di,), ("ssm_inner",), init=ones_init)
    pf.param("w_out", (di, d), ("ssm_inner", "embed"), fan_in=di)


def _conv(u, w):
    cw = w.shape[0]
    out = u * w[-1].astype(u.dtype)
    for i in range(1, cw):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, :u.shape[1]]
        out = out + shifted * w[cw - 1 - i].astype(u.dtype)
    return out


def _proj_inputs(p: Params, cfg: ModelConfig, h: jax.Array):
    cd = cfg.compute_dtype
    z = h @ p["wz"].astype(cd)
    xs = h @ p["wx"].astype(cd)
    Bm = h @ p["wB"].astype(cd)
    Cm = h @ p["wC"].astype(cd)
    dt = jax.nn.softplus(
        (h @ p["wdt"].astype(cd)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    return z, xs, Bm, Cm, dt


def _gated_out(p: Params, cfg: ModelConfig, x, y, z):
    cd = cfg.compute_dtype
    y = layers.rmsnorm(p["gnorm"], y * jax.nn.silu(z), cfg.norm_eps)
    return x + y @ p["w_out"].astype(cd)


def ssd_train(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    out, _ = _ssd_full(p, cfg, x)
    return out


def _ssd_full(p: Params, cfg: ModelConfig, x: jax.Array):
    B, S, _ = x.shape
    d, di, P, H, G, N = dims(cfg)
    h = layers.rmsnorm(p["ln"], x, cfg.norm_eps)
    z, xs_in, Bm_in, Cm_in, dt = _proj_inputs(p, cfg, h)
    xs = jax.nn.silu(_conv(xs_in, p["conv_x"]))
    Bm = jax.nn.silu(_conv(Bm_in, p["conv_B"]))
    Cm = jax.nn.silu(_conv(Cm_in, p["conv_C"]))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, state = ops.ssd(
        xs.reshape(B, S, H, P), dt, A,
        Bm.reshape(B, S, G, N), Cm.reshape(B, S, G, N),
        p["Dskip"], chunk=cfg.ssm_chunk, unroll=cfg.unroll_inner)
    out = _gated_out(p, cfg, x, y.reshape(B, S, di), z)
    cw = cfg.conv_width
    cache = {
        "state": state.astype(jnp.float32),
        "conv_x": xs_in[:, -(cw - 1):],
        "conv_B": Bm_in[:, -(cw - 1):],
        "conv_C": Cm_in[:, -(cw - 1):],
    }
    return out, cache


def ssd_prefill(p: Params, cfg: ModelConfig, x: jax.Array):
    return _ssd_full(p, cfg, x)


def ssd_decode(p: Params, cfg: ModelConfig, x: jax.Array,
               cache: Dict[str, jax.Array], lengths: jax.Array
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    del lengths
    Bsz, _ = x.shape
    d, di, P, H, G, N = dims(cfg)
    h = layers.rmsnorm(p["ln"], x[:, None, :], cfg.norm_eps)[:, 0]
    cd = cfg.compute_dtype
    z = h @ p["wz"].astype(cd)
    xs_in = h @ p["wx"].astype(cd)
    Bm_in = h @ p["wB"].astype(cd)
    Cm_in = h @ p["wC"].astype(cd)
    dt = jax.nn.softplus(
        (h @ p["wdt"].astype(cd)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                    # (B,H)

    def step_conv(state, new, w):
        hist = jnp.concatenate([state, new[:, None, :]], axis=1)
        out = jnp.einsum("bcw,cw->bw", hist, w.astype(cd))
        return out, hist[:, 1:]

    xs, cx = step_conv(cache["conv_x"], xs_in, p["conv_x"])
    Bm, cB = step_conv(cache["conv_B"], Bm_in, p["conv_B"])
    Cm, cC = step_conv(cache["conv_C"], Cm_in, p["conv_C"])
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, state = ops.ssd_decode(
        xs.reshape(Bsz, H, P), dt, A,
        Bm.reshape(Bsz, G, N), Cm.reshape(Bsz, G, N),
        p["Dskip"], cache["state"])
    out = _gated_out(p, cfg, x[:, None, :], y.reshape(Bsz, 1, di),
                     z[:, None, :])[:, 0]
    return out, {"state": state, "conv_x": cx, "conv_B": cB, "conv_C": cC}


def ssd_cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    del max_seq
    d, di, P, H, G, N = dims(cfg)
    cw = cfg.conv_width
    return {
        "state": jax.ShapeDtypeStruct((batch, H, P, N), jnp.float32),
        "conv_x": jax.ShapeDtypeStruct((batch, cw - 1, di), cfg.compute_dtype),
        "conv_B": jax.ShapeDtypeStruct((batch, cw - 1, G * N),
                                       cfg.compute_dtype),
        "conv_C": jax.ShapeDtypeStruct((batch, cw - 1, G * N),
                                       cfg.compute_dtype),
    }
