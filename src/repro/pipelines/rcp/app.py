"""The RCP application wired onto the affinity runtime (paper §4.5, Table 1).

Pools/keys/regexes follow Table 1 exactly:

  pool          example key                step   regex                    affinity key
  /frames       /frames/little3_42         MOT    /[a-zA-Z0-9]+_           /little3_
  /states       /states/little3_42         -      /[a-zA-Z0-9]+_           /little3_
  /positions    /positions/little3_7_42    PRED   /[a-zA-Z0-9]+_[0-9]+_    /little3_7_
  /predictions  /predictions/little3_42_7  CD     /[a-zA-Z0-9]+_[0-9]+_    /little3_42_
  /cd           /cd/little3_42_7           -      -                        -

Layouts are written x/y/z = shards for MOT/PRED/CD (paper §4.4); placement
strategy is either 'affinity' (grouped, shard-local execution) or 'random'
(standard key-hash placement).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.runtime import (CLUSTER_NET, Compute, Get, NetProfile, Put,
                           Scheduler)
from repro.workflows import Emit, WorkflowGraph, WorkflowRuntime
from .data import (FRAME_BYTES, P_HIST, POSITION_BYTES, PREDICTION_BYTES,
                   Scene, make_scene)
from .models import StageProfile

FRAME_RE = r"/[a-zA-Z0-9]+_"
ACTOR_RE = r"/[a-zA-Z0-9]+_[0-9]+_"


@dataclasses.dataclass
class Layout:
    mot: int = 3           # shards for MOT
    pred: int = 5
    cd: int = 5
    replication: int = 1

    def __str__(self):
        r = f" x{self.replication}" if self.replication > 1 else ""
        return f"{self.mot}/{self.pred}/{self.cd}{r}"


class FrameTracker:
    def __init__(self):
        self.sent: Dict[Tuple[str, int], float] = {}
        self.expected: Dict[Tuple[str, int], int] = {}
        self.done_count: Dict[Tuple[str, int], int] = defaultdict(int)
        self.completed: Dict[Tuple[str, int], float] = {}

    def frame_sent(self, vid: str, f: int, t: float, expected_cd: int):
        self.sent[(vid, f)] = t
        self.expected[(vid, f)] = expected_cd

    def mot_done(self, vid: str, f: int, t: float):
        if self.expected.get((vid, f), 0) == 0:
            self.completed[(vid, f)] = t

    def cd_done(self, vid: str, f: int, t: float):
        key = (vid, f)
        self.done_count[key] += 1
        if self.done_count[key] >= self.expected.get(key, 1 << 30):
            self.completed.setdefault(key, t)

    def latencies(self, warmup: int = 100) -> List[float]:
        out = []
        for (vid, f), t_end in self.completed.items():
            if f >= warmup and (vid, f) in self.sent:
                out.append(t_end - self.sent[(vid, f)])
        return out


class RCPApp:
    def __init__(self, scenes: List[Scene], layout: Layout,
                 grouped: bool = True,
                 scheduler: Optional[Scheduler] = None,
                 net: NetProfile = CLUSTER_NET,
                 profile: Optional[StageProfile] = None,
                 caching: bool = True,
                 placement: str = "hash",
                 read_replicas: int = 1,
                 migrate_every: Optional[float] = None,
                 seed: int = 0):
        """placement: 'hash' | 'load_aware' | 'rendezvous' — policy binding
        affinity groups to shards.  read_replicas > 1 wraps the policy in
        ``ReplicatedPlacement`` (writes fan out, reads hit the nearest
        replica).  migrate_every enables the runtime's GroupMigrator on the
        PRED/CD pools at that virtual-time interval."""
        self.scenes = {s.name: s for s in scenes}
        self.layout = layout
        self.grouped = grouped
        self.placement = placement
        self.read_replicas = read_replicas
        self.profile = profile or StageProfile()
        self.tracker = FrameTracker()

        self.graph = self.build_graph(layout)
        self.wrt = WorkflowRuntime(self.graph, grouped=grouped,
                                   placement=placement,
                                   read_replicas=read_replicas,
                                   caching=caching, net=net,
                                   scheduler=scheduler, seed=seed,
                                   migrate_every=migrate_every)
        self.rt = self.wrt.rt
        self.store = self.wrt.store
        self.mot_nodes = self.graph.tiers["mot"].nodes
        self.pred_nodes = self.graph.tiers["pred"].nodes
        self.cd_nodes = self.graph.tiers["cd"].nodes

    def build_graph(self, layout: Layout) -> WorkflowGraph:
        """RCP as a declarative workflow graph (Table 1 pools/regexes).

        The stage bodies stay custom generators — actors enter and leave,
        so the fan-out is dynamic and the app keeps its own FrameTracker
        (``instance_tracking=False``).  Emits are declared with fanout=1 as
        structural edges only: they give the graph its MOT→PRED→CD shape
        (validation, docs) while the bodies decide the real fan-out.

        Nodes: one physical server per shard slot (paper: 1 node/shard
        unless replication>1), GPU on MOT/PRED servers (config A), CD on
        config B (cpu).
        """
        r = layout.replication
        g = WorkflowGraph("rcp", instance_tracking=False)
        g.add_tier("mot", layout.mot * r, {"gpu": 1, "cpu": 2, "nic": 2})
        g.add_tier("pred", layout.pred * r, {"gpu": 1, "cpu": 2, "nic": 2})
        g.add_tier("cd", layout.cd * r, {"gpu": 0, "cpu": 2, "nic": 2})
        g.add_pool("/frames", tier="mot", shards=layout.mot,
                   replication=r, affinity=FRAME_RE)
        g.add_pool("/states", tier="mot", shards=layout.mot,
                   replication=r, affinity=FRAME_RE)
        g.add_pool("/positions", tier="pred", shards=layout.pred,
                   replication=r, affinity=ACTOR_RE, migratable=True)
        g.add_pool("/predictions", tier="cd", shards=layout.cd,
                   replication=r, affinity=ACTOR_RE, migratable=True)
        g.add_pool("/cd", tier="cd", shards=layout.cd,
                   replication=r, affinity=None)
        g.add_stage("MOT", pool="/frames", resource="gpu",
                    body=self._mot_task,
                    order_of=lambda k: k.split("/")[-1].rsplit("_", 1)[0],
                    emits=[Emit("/states"), Emit("/positions")])
        g.add_stage("PRED", pool="/positions", resource="gpu",
                    body=self._pred_task,
                    order_of=lambda k: k.split("/")[-1].rsplit("_", 1)[0],
                    emits=[Emit("/predictions")])
        g.add_stage("CD", pool="/predictions", resource="cpu",
                    body=self._cd_task,
                    order_of=lambda k: "_".join(
                        k.split("/")[-1].split("_")[:2]),
                    emits=[Emit("/cd")], sink=True)
        return g.validate()

    # -- stage tasks (generator UDLs) ---------------------------------------

    def _mot_task(self, ctx, key, value):
        name = key.split("/")[-1]
        vid, f_s = name.rsplit("_", 1)
        f = int(f_s)
        scene = self.scenes[vid]
        if f > 0:
            yield Get(f"/states/{vid}_{f - 1}", wait=True)
        yield Compute("gpu", self.profile.mot)
        yield Put(f"/states/{vid}_{f}", ("state", vid, f),
                  size=scene.state_bytes(f))
        self.tracker.mot_done(vid, f, ctx.now)
        for a in scene.actors_in_frame(f):
            yield Put(f"/positions/{vid}_{a}_{f}",
                      tuple(scene.position(a, f)), size=POSITION_BYTES)

    def _pred_task(self, ctx, key, value):
        name = key.split("/")[-1]
        vid, a_s, f_s = name.split("_")
        a, f = int(a_s), int(f_s)
        scene = self.scenes[vid]
        have = 1
        for i in range(f - P_HIST + 1, f):
            if i < 0:
                continue
            v = yield Get(f"/positions/{vid}_{a}_{i}", required=False)
            if v is not None:
                have += 1
        if have >= P_HIST:
            yield Compute("gpu", self.profile.pred)
            yield Put(f"/predictions/{vid}_{f}_{a}", ("traj", vid, f, a),
                      size=PREDICTION_BYTES)

    def _cd_task(self, ctx, key, value):
        name = key.split("/")[-1]
        vid, f_s, a_s = name.split("_")
        f, a = int(f_s), int(a_s)
        for other in self.predictable_actors(vid, f):
            if other != a:
                yield Get(f"/predictions/{vid}_{f}_{other}", required=False)
        yield Compute("cpu", self.profile.cd)
        yield Put(f"/cd/{vid}_{f}_{a}", ("cd", vid, f, a), size=128)
        self.tracker.cd_done(vid, f, ctx.now)

    # -- workload ----------------------------------------------------------------

    def predictable_actors(self, vid: str, f: int) -> List[int]:
        scene = self.scenes[vid]
        return [a for a in scene.actors_in_frame(f)
                if f - scene.enter[a] >= P_HIST - 1]

    def stream(self, n_frames: Optional[int] = None) -> None:
        for vid, scene in self.scenes.items():
            F = min(n_frames or scene.n_frames, scene.n_frames)
            for f in range(F):
                t = f / scene.fps
                self.tracker.frame_sent(
                    vid, f, t, expected_cd=len(self.predictable_actors(vid, f)))
                self.rt.client_put(t, f"/frames/{vid}_{f}",
                                   ("frame", vid, f), size=FRAME_BYTES)

    def run(self, until: float = float("inf")) -> None:
        self.rt.run(until)

    # -- results ------------------------------------------------------------------

    def summary(self, warmup: int = 100) -> Dict[str, float]:
        import numpy as np
        lats = self.tracker.latencies(warmup=warmup)
        if not lats:
            return {"n": 0}
        arr = np.array(lats)
        return {
            "n": len(arr),
            "median": float(np.median(arr)),
            "p75": float(np.percentile(arr, 75)),
            "p95": float(np.percentile(arr, 95)),
            "p99": float(np.percentile(arr, 99)),
            "mean": float(arr.mean()),
            "remote_gets": self.store.stats.remote_gets,
            "local_gets": self.store.stats.local_gets,
            "bytes_remote": self.store.stats.bytes_remote,
            "bytes_replica_sync": self.store.stats.bytes_replica_sync,
            "migrations": self.store.stats.migrations,
            "bytes_migrated": self.store.stats.bytes_migrated,
        }
