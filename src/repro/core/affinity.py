"""The paper's §3.3 affinity grouping mechanism.

Core abstraction: a developer-supplied *affinity function* ``f(descriptor) ->
affinity key``.  A descriptor carries metadata about a data object (to be
stored/retrieved) or a computational task (to be initiated); the affinity key
is an opaque string label.  Objects and tasks that share an affinity key are
*correlated* and the platform collocates them.

Requirements satisfied (paper §3.2):
  * deployment-agnostic — ``f`` sees only application metadata, never node
    identity; the placement engine owns the key -> location mapping;
  * unified — the SAME ``f`` drives both storage and compute placement;
  * expressive — ``f`` may be an arbitrary callable computed at runtime
    (e.g. after an input is classified), not a static dependency list;
  * lightweight — ``f`` is pure and replicated to every node; there is no
    synchronized mapping state (contrast: Redis hash tags / Cosmos partition
    maps).  ``RegexAffinity`` mirrors the paper's Cascade implementation:
    the affinity key is the substring of the object key matched by a
    registered regular expression.
"""
from __future__ import annotations

import dataclasses
import re
import time
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

AffinityKey = str


@dataclasses.dataclass(frozen=True)
class Descriptor:
    """Metadata about a data object or a computational task."""
    key: str                                  # unique name (path-style)
    kind: str = "object"                      # "object" | "task"
    size: int = 0                             # bytes (objects)
    meta: Tuple[Tuple[str, Any], ...] = ()    # free-form metadata

    def get(self, name: str, default=None):
        for k, v in self.meta:
            if k == name:
                return v
        return default

    @staticmethod
    def of(key: str, kind: str = "object", size: int = 0, **meta):
        return Descriptor(key=key, kind=kind, size=size,
                          meta=tuple(sorted(meta.items())))


class AffinityFunction:
    """Base class: maps a descriptor to an affinity key (or None).

    ``key_pure`` declares that the label depends ONLY on ``desc.key`` —
    key-pure functions let the store memoize key -> label on the hot
    put/get path.  It is opt-in (default False): a subclass must never
    inherit memoization it did not ask for, because a stale cached label
    silently misplaces objects rather than erroring.  The built-ins that
    only read the key (regex / instance / no-affinity) declare it.
    """

    key_pure = False

    def __call__(self, desc: Descriptor) -> Optional[AffinityKey]:
        raise NotImplementedError

    def describe(self) -> str:
        return type(self).__name__


class RegexAffinity(AffinityFunction):
    """Paper §4.3: affinity key = substring of the key matched by a regex.

    e.g. pattern ``/[a-zA-Z0-9]+_`` over key ``/positions/little3_7_42``
    applied to the part after the pool prefix yields ``/little3_``.
    """

    key_pure = True

    def __init__(self, pattern: str):
        self.pattern = pattern
        self._re = re.compile(pattern)

    def __call__(self, desc: Descriptor) -> Optional[AffinityKey]:
        m = self._re.search(desc.key)
        return m.group(0) if m else None

    def describe(self) -> str:
        return f"regex:{self.pattern}"


class CallableAffinity(AffinityFunction):
    """Arbitrary developer logic (e.g. keyed on a runtime classification)."""

    key_pure = False          # arbitrary logic may read size/meta

    def __init__(self, fn: Callable[[Descriptor], Optional[AffinityKey]],
                 name: str = "callable"):
        self._fn = fn
        self._name = name

    def __call__(self, desc: Descriptor) -> Optional[AffinityKey]:
        return self._fn(desc)

    def describe(self) -> str:
        return f"callable:{self._name}"


class NoAffinity(AffinityFunction):
    """Baseline: no grouping — placement hashes the raw object key."""

    key_pure = True

    def __call__(self, desc: Descriptor) -> Optional[AffinityKey]:
        return None


# ---------------------------------------------------------------------------
# Workflow-instance affinity (repro.workflows)
#
# A workflow instance is identified by an opaque token (no "_" or "/");
# every object a workflow stage reads or writes for that instance is keyed
#
#     <pool>/<instance>_<stage...>_<i>
#
# so the instance token is recoverable from any key and the whole instance
# forms ONE affinity group across every pool of the workflow.
# ---------------------------------------------------------------------------

def workflow_key(pool: str, instance: str, stage: str, index: int = 0) -> str:
    """Canonical key for a workflow-stage output object."""
    assert "_" not in instance and "/" not in instance, instance
    return f"{pool.rstrip('/')}/{instance}_{stage}_{index}"


def instance_of(key: str) -> Optional[str]:
    """Instance token of a workflow key (None if the key has no '_').

    find/rfind instead of split: this sits on the traced task-launch hot
    path, and the split variants allocate two intermediate lists."""
    i = key.rfind("/") + 1
    j = key.find("_", i)
    if j < 0:
        return None
    return key[i:j]


def instance_label(instance: str) -> AffinityKey:
    """The affinity key ``InstanceAffinity`` derives for an instance."""
    return f"/{instance}_"


class InstanceAffinity(AffinityFunction):
    """Affinity key = the workflow-instance token of the key.

    ``/req42_rerank_3`` -> ``/req42_``: every stage input/output of one
    workflow instance shares a label, so the placement engine collocates
    the entire instance (and, through unified placement, every stage task
    that touches it).  Equivalent to ``RegexAffinity(r"/[^_/]+_")`` but
    named, so pools can be declared instance-grouped without regex
    plumbing and the gang-pinning path can derive the label it must pin.
    """

    key_pure = True

    def __call__(self, desc: Descriptor) -> Optional[AffinityKey]:
        inst = instance_of(desc.key)
        return instance_label(inst) if inst else None

    def describe(self) -> str:
        return "instance"


@dataclasses.dataclass
class AffinityStats:
    """Microbenchmark counters for the matching overhead (paper: <300us)."""
    calls: int = 0
    total_ns: int = 0

    @property
    def mean_us(self) -> float:
        return (self.total_ns / self.calls / 1000.0) if self.calls else 0.0


class InstrumentedAffinity(AffinityFunction):
    def __init__(self, inner: AffinityFunction):
        self.inner = inner
        self.key_pure = inner.key_pure
        self.stats = AffinityStats()

    def __call__(self, desc: Descriptor) -> Optional[AffinityKey]:
        t0 = time.perf_counter_ns()
        out = self.inner(desc)
        self.stats.total_ns += time.perf_counter_ns() - t0
        self.stats.calls += 1
        return out

    def describe(self) -> str:
        return f"instrumented({self.inner.describe()})"


def affinity_key_for(fn: Optional[AffinityFunction],
                     desc: Descriptor) -> AffinityKey:
    """The effective placement label: affinity key if grouped, else raw key."""
    if fn is not None:
        k = fn(desc)
        if k is not None:
            return k
    return desc.key
