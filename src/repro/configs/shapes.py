"""Assigned input shapes (identical set for every LM arch)."""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
