"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import sys
import time
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"
ARTIFACTS.mkdir(exist_ok=True)


def run_rcp(grouped, layout, scenes, n_frames, caching=True, net=None,
            scheduler=None, replication=1, seed=0, placement="hash",
            read_replicas=1, migrate_every=None, straggler=None):
    from repro.pipelines.rcp.app import Layout, RCPApp
    from repro.pipelines.rcp.data import make_scene
    from repro.runtime import RandomScheduler, set_straggler
    lay = Layout(*layout, replication=replication)
    kw = {"net": net} if net is not None else {}
    app = RCPApp([make_scene(s, n_frames) for s in scenes], lay,
                 grouped=grouped,
                 scheduler=scheduler if scheduler is not None
                 else (None if grouped else RandomScheduler(seed)),
                 caching=caching, seed=seed, placement=placement,
                 read_replicas=read_replicas, migrate_every=migrate_every,
                 **kw)
    if straggler is not None:                  # (node, speed), e.g. ("pred0", 0.3)
        set_straggler(app.rt, *straggler)
    app.stream()
    t0 = time.perf_counter()
    app.run()
    wall = time.perf_counter() - t0
    s = app.summary(warmup=min(100, n_frames // 3))
    s["sim_wall_s"] = wall
    return s


def emit(rows):
    """Print ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        d = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{us:.1f},{d}")
