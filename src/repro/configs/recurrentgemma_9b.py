"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 pattern.
[arXiv:2402.19427]

Pattern (rglru, rglru, attn) cycled over 38 layers => 26 recurrent + 12
local-attention (window 2048, MQA kv=1) layers.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    mlp_variant="geglu",
    tie_embeddings=True,
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=4096,
    conv_width=4,
    attn_window=2048,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    n_layers=5,                       # 1 full group + 2 tail layers
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    mlp_variant="geglu",
    tie_embeddings=True,
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=64,
    conv_width=4,
    attn_window=8,
)
