"""Runtime: wires store + simulator + scheduler + per-group sequencing.

UDLs registered on the store are dispatched here when a put/trigger fires:
the scheduler picks the executing node (shard-local under affinity
grouping, pool-wide under the LB baselines), an application-supplied
*order label* serializes tasks that must run in order (frames of one video,
PRED steps of one actor), and straggler hedging optionally duplicates
long-queued tasks.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core import CascadeStore, GroupMigrator, GroupSequencer
from repro.core.object_store import Shard, UDL
from .simulation import (AZURE_NET, CLUSTER_NET, UNIFORM, Compute, Get,
                         HardwareProfile, NetProfile, Node, Put, Simulator,
                         Sleep, Trigger)
from .scheduler import Scheduler, ShardLocalScheduler


@dataclasses.dataclass
class TaskContext:
    runtime: "Runtime"
    node: str
    key: str
    shard: Optional[str] = None       # home-shard name the task dispatched on

    @property
    def now(self) -> float:
        return self.runtime.sim.now


@dataclasses.dataclass
class UDLBinding:
    udl: UDL
    make_task: Callable[[TaskContext, str, Any], Any]   # -> generator
    order_of: Optional[Callable[[str], str]] = None     # key -> order label
    resource: str = "gpu"
    pool_nodes: Sequence[str] = ()


class Runtime:
    def __init__(self, store: CascadeStore,
                 node_resources: Optional[Dict[str, Dict[str, int]]] = None,
                 net: NetProfile = CLUSTER_NET,
                 scheduler: Optional[Scheduler] = None,
                 seed: int = 0,
                 hedge_after: Optional[float] = None,
                 log_tasks: bool = True,
                 node_profiles: Optional[Dict[str, HardwareProfile]] = None):
        resources = node_resources or {
            n: {"gpu": 1, "cpu": 2, "nic": 2} for n in store.nodes}
        profiles = node_profiles or {}
        self.nodes = {n: Node(n, r, profile=profiles.get(n, UNIFORM))
                      for n, r in resources.items()}
        self.sim = Simulator(store, self.nodes, net=net, seed=seed)
        self.sim.udl_dispatch = self._dispatch
        self.store = store
        self.scheduler = scheduler or ShardLocalScheduler()
        self.sequencer = GroupSequencer()
        self.bindings: Dict[str, UDLBinding] = {}
        self.hedge_after = hedge_after
        self.hedges = 0
        # per-task records are handy for tests/debugging but grow with the
        # horizon; long-horizon runs turn them off (log_tasks=False) so
        # runtime memory stays bounded by concurrency, not event count
        self.log_tasks = log_tasks
        self.task_log: List[Dict[str, Any]] = []
        self.migrators: Dict[str, GroupMigrator] = {}   # pool -> migrator
        self.migration_log: List[Dict[str, Any]] = []
        self._pending_ticks = 0
        # tasks dispatched to a shard and not yet completed — includes work
        # parked in the per-group sequencer, which node queues never see
        self.shard_outstanding: Dict[str, int] = defaultdict(int)
        # key -> live InstanceTrace resolver (set by the workflow layer
        # when tracing is enabled); None keeps _launch at one check
        self.trace_of: Optional[Callable[[str], Any]] = None

    # -- registration ----------------------------------------------------------

    def register(self, prefix: str,
                 make_task: Callable[[TaskContext, str, Any], Any],
                 order_of: Optional[Callable[[str], str]] = None,
                 resource: str = "gpu",
                 pool_nodes: Optional[Sequence[str]] = None,
                 name: str = "") -> None:
        udl = UDL(prefix=prefix, fn=make_task, name=name or prefix)
        self.store.register_udl(prefix, make_task, name=udl.name)
        self.bindings[udl.name] = UDLBinding(
            udl=udl, make_task=make_task, order_of=order_of,
            resource=resource,
            pool_nodes=tuple(pool_nodes or self.store.nodes))

    # -- dispatch path ------------------------------------------------------------

    def _dispatch(self, udl: UDL, shard: Shard, key: str, value: Any) -> None:
        binding = self.bindings[udl.name]
        self.shard_outstanding[shard.name] += 1
        if binding.order_of is not None:
            label = f"{udl.name}::{binding.order_of(key)}"
            self.sequencer.admit(label, (binding, shard, key, value))
            item = self.sequencer.ready(label)
            if item is not None:
                self._launch(label, *item)
        else:
            self._launch(None, binding, shard, key, value)

    def _launch(self, label: Optional[str], binding: UDLBinding, shard: Shard,
                key: str, value: Any) -> None:
        node = self.scheduler.pick(shard, key, self.nodes,
                                   binding.pool_nodes)
        p = self.sim.partition
        if p is not None and p.get(node, 0) != 0:
            # every lane able to run this task sits across the cut:
            # dispatch is client-observable (majority-side), so hold the
            # launch until heal instead of starting work whose effects
            # the client could not see.  The node is re-picked at heal;
            # a sequencer label stays held, preserving order across the
            # cut.  The wait is blamed as a partition_stall span.
            self.sim.partition_parked_dispatches += 1
            t_park = self.sim.now

            def relaunch():
                tr = self.trace_of(key) if self.trace_of is not None \
                    else None
                if tr is not None and self.sim.tracer is not None:
                    self.sim.tracer.span(tr, "partition_stall",
                                         f"dispatch:{key}", t_park,
                                         self.sim.now)
                self._launch(label, binding, shard, key, value)
            self.sim._partition_parked_calls.append(relaunch)
            return
        ctx = TaskContext(runtime=self, node=node, key=key, shard=shard.name)
        gen = binding.make_task(ctx, key, value)
        t0 = self.sim.now
        trace = self.trace_of(key) if self.trace_of is not None else None

        def done():
            self.shard_outstanding[shard.name] -= 1
            if self.log_tasks:
                self.task_log.append({
                    "udl": binding.udl.name, "key": key, "node": node,
                    "t_start": t0, "t_end": self.sim.now,
                })
            if label is not None:
                self.sequencer.complete(label)
                nxt = self.sequencer.ready(label)
                if nxt is not None:
                    self._launch(label, *nxt)

        self.sim.spawn(node, gen, done=done, trace=trace)

    # -- load-aware group migration ----------------------------------------------

    def enable_migration(self, pool_prefix: str, interval: float = 0.5,
                         imbalance_ratio: float = 2.0, min_heat: float = 1.0,
                         max_moves: int = 1, decay: float = 0.5) -> GroupMigrator:
        """Run the GroupMigrator on a virtual-time interval.

        Every `interval` sim-seconds the migrator rebalances `pool_prefix`:
        hot affinity groups move (whole-group, cache-invalidating) to the
        coldest shard, and the move's bytes are charged as a background NIC
        transfer on a destination-shard node.  The tick stops rescheduling
        once the event heap drains so bounded workloads still terminate.
        """
        assert pool_prefix not in self.migrators, \
            f"migration already enabled for {pool_prefix}"
        migrator = GroupMigrator(self.store,
                                 imbalance_ratio=imbalance_ratio,
                                 min_heat=min_heat)
        self.migrators[pool_prefix] = migrator

        def shard_load():
            # pressure per shard: dispatched-but-incomplete tasks (counts
            # sequencer-parked work a node-queue sample would miss), plus
            # the worst member node's live queue
            out = {}
            for name, shard in self.store.pools[pool_prefix].shards.items():
                depth = float(self.shard_outstanding[name])
                for n in shard.nodes:
                    node = self.nodes[n]
                    q = (sum(len(qq) for qq in node.queues.values())
                         + sum(node.in_use.values()))
                    depth = max(depth, float(q))
                out[name] = depth
            return out

        def tick():
            self._pending_ticks -= 1
            # remote-traffic pass first (fixes placement-caused network
            # cost), then a queue-pressure pass (fixes compute hotspots
            # like stragglers that never show up as remote bytes)
            moves = migrator.rebalance(pool_prefix, max_moves=max_moves)
            moves += migrator.rebalance(pool_prefix, max_moves=max_moves,
                                        shard_load=shard_load())
            pool = self.store.pools[pool_prefix]
            for mv in moves:
                dst_nodes = pool.shards[mv.dst_shard].nodes
                if dst_nodes:
                    self.sim._charge_transfer(self.nodes[dst_nodes[0]],
                                              mv.bytes_moved)
                self.migration_log.append({
                    "t": self.sim.now, "pool": mv.pool, "label": mv.label,
                    "to": mv.dst_shard, "bytes": mv.bytes_moved,
                    "objects": mv.n_objects,
                })
            migrator.decay(decay, pool_prefix=pool_prefix)
            # reschedule only while the heap holds REAL work — other pools'
            # migration ticks don't count, else ticks keep each other alive
            # and a bounded workload never terminates
            if len(self.sim._heap) > self._pending_ticks:
                self._pending_ticks += 1
                self.sim.after(interval, tick)

        self._pending_ticks += 1
        self.sim.after(interval, tick)
        return migrator

    # -- client ingress --------------------------------------------------------------

    def client_put(self, at: float, key: str, value: Any = None,
                   size: int = 0, client_node: str = "client",
                   fire_udls: bool = True) -> None:
        """Schedule an external put at simulated time `at`.

        ``fire_udls=False`` stores without triggering (used to preload
        shared objects — e.g. a workflow's retrieval index — before any
        event stream starts)."""
        def fire():
            shard, udls = self.store.put(key, value, size=size,
                                         fire=fire_udls)
            dt = self.sim.net.transfer_time(size)

            def delivered():
                if key in self.sim._waiters:
                    for wnode, wop, wcont in self.sim._waiters.pop(key):
                        self.sim._execute(wnode, wop, wcont)
                for u in udls:
                    self._dispatch(u, shard, key, value)
            self.sim.after(dt, delivered)
        self.sim.at(at, fire)

    def run(self, until: float = float("inf")) -> None:
        self.sim.run(until)
