"""A RAG pipeline on the workflow layer in ~30 lines.

Declares nothing the library doesn't already ship — this is the
end-to-end shape of any workflow experiment: pick a graph, pick a
placement mode, stream events, read percentiles.

    PYTHONPATH=src python examples/workflow_rag.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.workflows import (WorkflowRuntime, mode_kwargs, preload_index,
                             rag_workflow)


def run(mode: str):
    wrt = WorkflowRuntime(rag_workflow(shards=4), **mode_kwargs(mode))
    preload_index(wrt)                      # shared corpus slabs (hot group)
    for i in range(120):
        wrt.submit(f"req{i}", at=0.05 + i / 48.0, deadline=0.3)
    wrt.run()
    return wrt.summary()


if __name__ == "__main__":
    print(f"{'mode':10} {'p50 ms':>8} {'p99 ms':>8} {'remote':>7} {'miss':>6}")
    for mode in ("keyhash", "affinity", "atomic"):
        s = run(mode)
        print(f"{mode:10} {s['median'] * 1e3:8.1f} {s['p99'] * 1e3:8.1f} "
              f"{s['remote_gets']:7d} {s['slo_miss_rate']:6.2f}")
