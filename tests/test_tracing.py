"""Causal tracing + blame attribution: the exactness invariant (blame
categories sum to e2e), DES transparency (tracing reproduces latencies
byte-for-byte), Chrome trace-event export schema, sampling/retention
bounds, and the 50k-event overhead envelope.

The hypothesis property variant (random chain graphs) is marked slow and
runs in the dedicated CI slow job; everything else is tier-1.
"""
import gc
import json
import time

import pytest

from repro.core import CascadeStore
from repro.core.affinity import instance_of
from repro.runtime import (Compute, FaultInjector, Put, Runtime,
                           TraceConfig, TraceRecorder)
from repro.runtime.tracing import CATEGORIES, InstanceTrace
from repro.workflows import (BlameTable, Emit, WorkflowGraph,
                             WorkflowRuntime, critical_path, decompose,
                             mode_kwargs, preload_index)

RES = {"gpu": 1, "cpu": 2, "nic": 2}
SHAPES = ("rag", "speech")
MODES = ("keyhash", "atomic", "atomic+batch", "atomic+abatch")
DEADLINES = {"rag": 0.30, "speech": 0.20}


def _shape_run(shape, mode, faults=False, tracing=True, n=16, shards=2,
               seed=0, rate=None):
    from repro.workflows import WORKFLOW_SHAPES
    graph = WORKFLOW_SHAPES[shape](shards=shards)
    wrt = WorkflowRuntime(graph, seed=seed, tracing=tracing,
                          **mode_kwargs(mode))
    if shape == "rag":
        preload_index(wrt)
    if faults:
        inj = wrt.enable_faults()
        inj.fail_node(sorted(wrt.rt.nodes)[0], at=0.08, duration=0.1)
    rate = rate if rate is not None else 12.0 * shards
    for i in range(n):
        wrt.submit(f"req{i}", at=0.05 + i / rate,
                   deadline=DEADLINES[shape])
    wrt.run()
    return wrt


# -- the exactness invariant --------------------------------------------------

@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("faults", (False, True))
def test_blame_sums_to_e2e_and_path_is_contiguous(shape, mode, faults):
    """Across workflow shapes x placement/batching modes x fault
    injection, every completed trace decomposes into exclusive category
    durations that sum to the end-to-end latency within 1e-6, and the
    critical path partitions [t_submit, t_complete] contiguously."""
    wrt = _shape_run(shape, mode, faults=faults)
    traces = wrt.tracer.traces()
    assert len(traces) == 16                    # all sampled + retained
    for tr in traces:
        parts = decompose(tr)
        assert set(parts) == set(CATEGORIES)
        assert all(v >= 0.0 for v in parts.values()), parts
        assert abs(sum(parts.values()) - tr.e2e) < 1e-6, (tr.instance,
                                                          parts, tr.e2e)
        segs = critical_path(tr)
        assert segs[0][2] == tr.t_submit
        assert segs[-1][3] == tr.t_complete
        for a, b in zip(segs, segs[1:]):
            assert a[3] == b[2], (a, b)
    # the on_complete aggregate saw the same population
    assert wrt.blame.n == wrt.tracer.n_completed == 16
    assert abs(sum(wrt.blame.totals.values())
               - wrt.blame.e2e_total) < 1e-6


def test_compute_dominates_an_unloaded_run():
    """At trivial load the blame table should charge mostly compute —
    a sanity anchor that categorization is not arbitrary."""
    wrt = _shape_run("rag", "atomic", n=4)
    assert wrt.blame.dominant() == "compute"
    assert wrt.blame.shares()["compute"] > 0.5


def test_fault_stall_is_blamed_under_unwired_chaos():
    """An unwired node death (no repair layer) stalls pinned work; the
    stall time must land in ``fault_stall``, not ``queueing``."""
    g = WorkflowGraph("chaos")
    g.add_tier("t", 2, RES)
    g.add_pool("/in", tier="t", shards=2)
    g.add_pool("/out", tier="t", shards=2)
    g.add_stage("work", pool="/in", resource="gpu", cost=0.004,
                emits=[Emit("/out", fanout=1, size=1024)], sink=True)
    wrt = WorkflowRuntime(g.validate(), tracing=True,
                          **mode_kwargs("atomic"))
    inj = FaultInjector(wrt.rt)                 # raw: nothing re-pins
    inj.fail_node(sorted(wrt.rt.nodes)[0], at=0.06, duration=0.2)
    for i in range(24):
        wrt.submit(f"w{i}", at=0.05 + i * 0.002)
    wrt.run()
    assert wrt.summary()["n"] == 24
    assert wrt.blame.totals["fault_stall"] > 0.0
    # the down/up window reached the recorder as global instants
    names = [n for n, _, _ in wrt.tracer.global_events]
    assert "node_down" in names and "node_up" in names


def test_blame_sums_to_e2e_with_partition_active():
    """Exactness survives a network cut: work held at the partition
    boundary surfaces as ``partition_stall`` (not silently as network or
    other), and every trace still decomposes to its e2e exactly."""
    g = WorkflowGraph("cut")
    g.add_tier("t", 4, RES)
    for p in ("/in", "/out"):
        g.add_pool(p, tier="t", shards=4)
    g.add_stage("work", pool="/in", resource="gpu", cost=0.004,
                emits=[Emit("/out", fanout=1, size=1024)], sink=True)
    wrt = WorkflowRuntime(g.validate(), read_replicas=2, tracing=True,
                          **mode_kwargs("affinity"))
    inj = wrt.enable_faults()
    # cut half the tier off mid-stream: groups whose every replica lane
    # sits across the cut park their dispatches until heal
    inj.partition(((), ("t1", "t3")), at=0.06, duration=0.2)
    for i in range(40):
        wrt.submit(f"w{i}", at=0.05 + i * 0.002)
    wrt.run()
    assert wrt.summary()["n"] == 40                     # nothing lost
    assert wrt.rt.sim.partition_parked_dispatches > 0   # the cut bit
    for tr in wrt.tracer.traces():
        parts = decompose(tr)
        assert set(parts) == set(CATEGORIES)
        assert all(v >= 0.0 for v in parts.values()), parts
        assert abs(sum(parts.values()) - tr.e2e) < 1e-6, (tr.instance,
                                                          parts, tr.e2e)
    assert wrt.blame.totals["partition_stall"] > 0.0
    assert abs(sum(wrt.blame.totals.values())
               - wrt.blame.e2e_total) < 1e-6


# -- DES transparency ---------------------------------------------------------

def _chaos_summary(tracing):
    g = WorkflowGraph("chaos")
    g.add_tier("t", 3, RES)
    for p in ("/in", "/mid", "/out"):
        g.add_pool(p, tier="t", shards=3)
    g.add_stage("prep", pool="/in", resource="cpu", cost=0.002,
                emits=[Emit("/mid", fanout=1, size=4096)])
    g.add_stage("infer", pool="/mid", resource="gpu", cost=0.008,
                emits=[Emit("/out", fanout=1, size=1024)], sink=True)
    wrt = WorkflowRuntime(g.validate(), read_replicas=2,
                          hedge_after=0.03, tracing=tracing,
                          **mode_kwargs("atomic+abatch"))
    inj = wrt.enable_faults()
    inj.fail_node("t0", at=0.2, duration=0.1)
    for i in range(60):
        wrt.submit(f"r{i}", at=0.05 + i / 200.0, deadline=0.12)
    wrt.run()
    return wrt


def test_tracing_reproduces_latencies_byte_for_byte():
    """The observability layer only observes: enabling tracing on a
    chaos run (faults + repair + replicas + hedging + adaptive batching)
    must not move a single latency, event count, or hedge."""
    off = _chaos_summary(tracing=False)
    on = _chaos_summary(tracing=True)
    assert off.rt.sim.tracer is None
    s_off, s_on = off.summary(), on.summary()
    for k in ("n", "median", "p95", "p99", "slo_miss_rate"):
        assert s_off[k] == s_on[k], k
    assert off.rt.sim.events_fired == on.rt.sim.events_fired
    assert off.rt.hedges == on.rt.hedges
    # and the traced run carries the observability keys the untraced
    # one must not pay for
    assert "blame_top" in s_on and "blame_top" not in s_off
    assert s_on["traces_completed"] == s_on["n"]
    if on.rt.hedges:
        hedge_marks = sum(1 for tr in on.tracer.traces()
                          for name, _, _ in tr.events
                          if name.startswith("hedge:"))
        assert hedge_marks > 0


def test_batched_stage_emits_exact_batch_spans():
    """A batched stage's member traces carry the batcher's exact
    decomposition: formation wait, queue wait, and the shared compute
    interval — never a generic barrier for the batch future."""
    wrt = _shape_run("rag", "atomic+batch", n=32, rate=400.0)
    cats = {}
    for tr in wrt.tracer.traces():
        for sp in tr.spans:
            cats.setdefault(sp.name.split(":")[0], set()).add(sp.cat)
    assert cats.get("batch") == {"compute"}
    assert cats.get("batchform") == {"batch_wait"}
    assert "wait" not in cats                   # batch futures skipped


# -- admission control --------------------------------------------------------

def test_admission_defer_time_is_blamed():
    """A deferred admission opens the trace window at the ORIGINAL
    submit time: the defer shows up as an ``admission_defer`` span and
    the trace e2e covers it even though the tracker's latency restarts
    at the admission instant."""
    from repro.runtime import GPU_A100, GPU_H100, AutoscalePolicy
    g = WorkflowGraph("elastic")
    g.add_tier("fast", 1, RES, profile=GPU_H100)
    g.add_tier("slow", 0, RES, profile=GPU_A100, spares=1)
    for p in ("/in", "/out"):
        g.add_pool(p, tier=("fast", "slow"), shards=1)
    g.add_stage("work", pool="/in", resource="gpu", cost=0.02,
                emits=[Emit("/out", fanout=1, size=1024)], sink=True)
    wrt = WorkflowRuntime(g.validate(), admission="defer",
                          admission_defer=0.02, admission_max_defer=0.5,
                          tracing=True, **mode_kwargs("atomic"))
    wrt.enable_autoscale(slo=0.2, policy=AutoscalePolicy(
        interval=0.02, min_samples=2, min_shards=1))
    for i in range(30):
        wrt.submit(f"w{i}", at=0.0)
    wrt.submit("d", at=0.001, deadline=0.3)
    wrt.run()
    assert wrt.summary()["admission_deferrals"] > 0
    tr = next(t for t in wrt.tracer.traces() if t.instance == "d")
    defer = [sp for sp in tr.spans if sp.cat == "admission_defer"]
    assert defer and defer[0].t0 == 0.001
    assert decompose(tr)["admission_defer"] > 0.0
    rec = wrt.tracker.records["d"]
    assert tr.e2e >= (rec.t_complete - rec.t_submit) - 1e-12


# -- sampling / retention -----------------------------------------------------

def test_sampling_is_a_deterministic_hash():
    a = TraceRecorder(TraceConfig(sample_rate=0.5))
    b = TraceRecorder(TraceConfig(sample_rate=0.5))
    ids = [f"req{i}" for i in range(400)]
    picks = [a.sampled(i) for i in ids]
    assert picks == [b.sampled(i) for i in ids]     # run-to-run stable
    assert 100 < sum(picks) < 300                   # ~rate, not degenerate
    none = TraceRecorder(TraceConfig(sample_rate=0.0))
    assert not any(none.sampled(i) for i in ids)
    assert none.begin("req0", 0.0) is None


def test_retention_is_bounded_and_tail_biased():
    rec = TraceRecorder(TraceConfig(max_traces=8, top_k=4))
    for i in range(200):
        tr = rec.begin(f"i{i}", 0.0)
        rec.complete(tr, (i % 100) * 1e-3)          # latency cycles 0..99ms
    assert rec.n_completed == 200 and not rec.live
    kept = rec.traces()
    assert len(kept) <= 8 + 4
    tail = rec.tail()
    assert len(tail) == 4
    assert [t.e2e for t in tail] == sorted((t.e2e for t in tail),
                                           reverse=True)
    assert tail[0].e2e == pytest.approx(0.099)      # the true max survives
    rec.complete(tail[0], 1.0)                      # idempotent
    assert rec.n_completed == 200


def test_blame_table_merge_matches_combined():
    def table(traces):
        t = BlameTable()
        for tr in traces:
            t.add(tr)
        return t

    def mk(i):
        tr = InstanceTrace(f"i{i}", 0.0)
        rec = TraceRecorder()
        rec.span(tr, "compute", "c", 0.0, 0.001 * (i + 1))
        rec.span(tr, "queueing", "q", 0.001 * (i + 1), 0.002 * (i + 1))
        tr.t_complete = 0.002 * (i + 1)
        return tr

    traces = [mk(i) for i in range(20)]
    combined = table(traces)
    merged = table(traces[:7]).merge(table(traces[7:]))
    assert merged.n == combined.n
    for c in CATEGORIES:
        assert merged.totals[c] == pytest.approx(combined.totals[c])
        if combined.stats[c].count:
            assert merged.stats[c].quantile(0.5) == pytest.approx(
                combined.stats[c].quantile(0.5))
    flat = merged.flat()
    assert flat["blame_top"] == "compute" and flat["blame_n"] == 20
    assert set(f"blame_{c}_ms" for c in CATEGORIES) <= set(flat)


# -- export -------------------------------------------------------------------

def test_chrome_trace_export_schema(tmp_path):
    """The exported payload is valid Chrome trace-event JSON: complete
    spans (ph=X with numeric us ts/dur), process/thread metadata, and
    instants with a scope — loadable in Perfetto."""
    wrt = _shape_run("rag", "atomic+batch", faults=True)
    path = tmp_path / "trace.json"
    payload = wrt.tracer.export_chrome_trace(str(path))
    reloaded = json.loads(path.read_text())
    assert reloaded == json.loads(json.dumps(payload))
    events = reloaded["traceEvents"]
    assert reloaded["displayTimeUnit"] == "ms"
    phs = {e["ph"] for e in events}
    assert phs <= {"X", "M", "i"} and {"X", "M", "i"} <= phs
    for e in events:
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        if e["ph"] == "X":
            assert isinstance(e["ts"], float) and e["ts"] >= 0.0
            assert isinstance(e["dur"], float) and e["dur"] > 0.0
            assert e["cat"] in CATEGORIES
            assert e["args"]["instance"]
        elif e["ph"] == "M":
            assert e["name"] == "process_name" and e["args"]["name"]
        else:
            assert e["s"] in ("t", "g")
    # one process per node plus the synthetic cluster track
    names = {e["args"]["name"] for e in events if e["ph"] == "M"}
    assert "cluster" in names and len(names) >= 2


# -- overhead envelope --------------------------------------------------------

def _microbench_runtime(n_tasks):
    store = CascadeStore([f"n{i}" for i in range(8)])
    store.create_object_pool("/x", store.nodes, 8,
                             affinity_set_regex=r"/[a-z0-9]+_")
    rt = Runtime(store)

    def task(ctx, key, value):
        yield Compute("gpu", 0.001)
        yield Put(key + "o", size=64, fire=False)
    rt.register("/x", task)
    for i in range(n_tasks):
        rt.client_put(i * 1e-4, f"/x/g{i % 64}_{i}", size=16)
    return rt


def _microbench_wall(traced, n_tasks=12_500):
    """One 50k-event run; traced mode attributes EVERY task (sample
    rate 1) and the timed region pays the full run lifecycle: raw op
    records on the hot path, then completion + retention for all 64
    instance traces.  Categorization is pay-per-query by design
    (``TraceRecorder.materialize`` runs when a retained trace is first
    read), so it's exercised — and its output asserted — outside the
    timed region, the way a post-run blame query would.

    The collector is off inside the timed region for BOTH variants: a
    collection pass landing in one variant and not the other measures
    generational phase alignment (and whatever heap the host process —
    e.g. pytest — retains), not the tracing code.  Tracing's own GC
    pressure is guarded separately: the returned tracked-object count
    asserts the raw record design (flat lists of atoms, no per-op
    containers) leaves the collector's workload untouched."""
    rt = _microbench_runtime(n_tasks)
    if traced:
        rec = TraceRecorder().attach(rt.sim)
        for g in range(64):
            rec.begin(f"g{g}", 0.0)
        rt.trace_of = lambda key: rec.live.get(instance_of(key))
    gc.collect()
    gc.disable()
    try:
        t0 = time.process_time()
        rt.run()
        if traced:              # pay completion + retention too
            for tr in list(rec.live.values()):
                rec.complete(tr, rt.sim.now)
        wall = time.process_time() - t0
        tracked = len(gc.get_objects())     # pre-materialization census
    finally:
        gc.enable()
    assert rt.sim.events_fired == 50_000    # tracing adds ZERO events
    assert rt.sim.completed_tasks == n_tasks
    if traced:
        assert rec.n_completed == 64
        retained = rec.traces()             # materializes deferred records
        assert len(retained) == 64
        assert rec.n_spans >= n_tasks       # every compute op attributed
        assert sum(len(tr.spans) for tr in retained) == rec.n_spans
    return wall, tracked


def test_tracing_overhead_within_10pct_on_50k_events():
    """The tier-1 overhead guard: tracing on the 50k-event DES
    microbench stays within 10% of the untraced CPU time, and adds a
    bounded number of GC-tracked objects (50k raw op records must not
    grow the collector's workload — the flat-atom record design).
    Interleaved off/on pairs (host speed drifts over seconds —
    back-to-back blocks bias the comparison), min-of-3 each, and a
    small absolute floor for timer noise on short runs."""
    offs, ons = [], []
    for _ in range(3):
        offs.append(_microbench_wall(False))
        ons.append(_microbench_wall(True))
    off, on = min(w for w, _ in offs), min(w for w, _ in ons)
    assert on <= off * 1.10 + 0.05, (on, off)
    # tracked-object census: 12.5k recorded ops may cost a few hundred
    # bookkeeping containers (traces, their lists), never one per op
    tracked_off, tracked_on = offs[-1][1], ons[-1][1]
    assert tracked_on - tracked_off < 3_000, (tracked_on, tracked_off)


# -- property: exactness over random graphs (slow job) ------------------------

@pytest.mark.slow
def test_blame_exactness_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    def chain_workflow(chain, n_shards):
        g = WorkflowGraph("prop")
        g.add_tier("t", n_shards, dict(RES))
        for i in range(len(chain) + 1):
            g.add_pool(f"/p{i}", tier="t", shards=n_shards)
        for i, (fanout, join, cost) in enumerate(chain):
            g.add_stage(f"s{i}", pool=f"/p{i}", resource="gpu",
                        cost=cost * 1e-3,
                        emits=[Emit(f"/p{i + 1}", fanout=fanout, size=64)],
                        join=join and i > 0, sink=(i == len(chain) - 1))
        return g.validate()

    CHAINS = st.lists(
        st.tuples(st.integers(min_value=1, max_value=3),
                  st.booleans(),
                  st.integers(min_value=0, max_value=20)),
        min_size=1, max_size=4)

    @given(CHAINS,
           st.integers(min_value=1, max_value=6),            # shards
           st.integers(min_value=1, max_value=12),           # instances
           st.sampled_from(MODES),
           st.booleans())                                    # faults
    @settings(max_examples=25, deadline=None)
    def prop(chain, n_shards, n_instances, mode, faults):
        g = chain_workflow(chain, n_shards)
        wrt = WorkflowRuntime(g, tracing=True, **mode_kwargs(mode))
        if faults:
            inj = wrt.enable_faults()
            inj.fail_node(sorted(wrt.rt.nodes)[0], at=0.02, duration=0.05)
        for i in range(n_instances):
            wrt.submit(f"req{i}", at=0.01 + i * 1e-3)
        wrt.run()
        assert wrt.tracer.n_completed == n_instances
        for tr in wrt.tracer.traces():
            parts = decompose(tr)
            assert abs(sum(parts.values()) - tr.e2e) < 1e-6
            segs = critical_path(tr)
            assert segs[0][2] == tr.t_submit
            assert segs[-1][3] == tr.t_complete

    prop()
