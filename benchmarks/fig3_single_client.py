"""Paper Fig. 3: E2E latency, one client (gates3), layouts x placement."""
from .common import emit, run_rcp

LAYOUTS = [(1, 1, 1), (1, 3, 3), (3, 3, 3), (3, 5, 5)]


def run(quick=True):
    frames = 200 if quick else 700
    rows = []
    for layout in LAYOUTS:
        for grouped in (True, False):
            s = run_rcp(grouped, layout, ["gates3"], frames)
            name = f"fig3/{'/'.join(map(str, layout))}/" \
                   f"{'affinity' if grouped else 'random'}"
            rows.append((name, s["median"] * 1e6,
                         {"p75_ms": round(s["p75"] * 1e3, 1),
                          "p95_ms": round(s["p95"] * 1e3, 1),
                          "remote_gets": s["remote_gets"]}))
    return rows


if __name__ == "__main__":
    emit(run())
