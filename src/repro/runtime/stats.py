"""Bounded streaming statistics for the metrics hot path.

``InstanceTracker`` used to append every stage span to a per-stage list
and run ``np.percentile`` over the whole history on demand — per-sample
memory growth and O(n log n) summary scans, quadratic once a planner
starts reading percentiles on every flush decision.  This module replaces
that with fixed-footprint streaming estimators in the P²/HDR family:

  * :class:`P2Quantile` — the P² algorithm (Jain & Chlamtac, CACM 1985):
    one quantile tracked with five markers updated in O(1) per
    observation, no sample retention.  Excellent on stationary streams,
    five floats of state.
  * :class:`StageStats` — the per-stage primitive the tracker and the
    batch planner read.  Count/mean/min/max exactly, quantiles from a
    fixed log-binned (HDR-histogram-style) count array: O(1) update,
    permutation-invariant, and the geometric bin spacing *guarantees*
    every quantile is within ``2·(√ratio−1) ≈ 2%`` of the exact sample
    quantile regardless of distribution or arrival order — the property
    the planner's flush decisions rely on.  A small exact warm-up buffer
    makes short streams numpy-exact before the histogram takes over.

The planner (``repro.workflows.planner.BatchPlanner``) reads
``StageStats.quantile`` on every batch-open decision; the whole point of
this module is that doing so costs the same at event 10 and event 10
million.
"""
from __future__ import annotations

import bisect
import math
from typing import Dict, List, Optional, Sequence, Tuple

DEFAULT_QUANTILES = (0.5, 0.95, 0.99)


class P2Quantile:
    """Streaming estimate of one quantile via the P² algorithm.

    Five markers track (min, p/2, p, (1+p)/2, max); each observation
    shifts marker positions and adjusts heights with a piecewise-parabolic
    (hence P²) interpolation — O(1) time, O(1) space, no samples kept.
    """

    __slots__ = ("p", "count", "_h", "_pos", "_want", "_inc")

    def __init__(self, p: float):
        assert 0.0 < p < 1.0, p
        self.p = p
        self.count = 0
        self._h: List[float] = []              # marker heights
        self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]  # actual marker positions
        self._want = [1.0, 1 + 2 * p, 1 + 4 * p, 3 + 2 * p, 5.0]
        self._inc = [0.0, p / 2, p, (1 + p) / 2, 1.0]

    def observe(self, x: float) -> None:
        self.count += 1
        h = self._h
        if self.count <= 5:
            bisect.insort(h, x)
            return
        pos = self._pos
        # locate the cell and bump the extremes
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and x >= h[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            pos[i] += 1.0
        want = self._want
        for i in range(5):
            want[i] += self._inc[i]
        # adjust the three interior markers toward their desired positions
        for i in (1, 2, 3):
            d = want[i] - pos[i]
            if (d >= 1.0 and pos[i + 1] - pos[i] > 1.0) or \
                    (d <= -1.0 and pos[i - 1] - pos[i] < -1.0):
                step = 1.0 if d >= 1.0 else -1.0
                cand = self._parabolic(i, step)
                if h[i - 1] < cand < h[i + 1]:
                    h[i] = cand
                else:                      # parabolic left the bracket
                    j = i + (1 if step > 0 else -1)
                    h[i] += step * (h[j] - h[i]) / (pos[j] - pos[i])
                pos[i] += step

    def _parabolic(self, i: int, d: float) -> float:
        h, pos = self._h, self._pos
        return h[i] + d / (pos[i + 1] - pos[i - 1]) * (
            (pos[i] - pos[i - 1] + d) * (h[i + 1] - h[i])
            / (pos[i + 1] - pos[i])
            + (pos[i + 1] - pos[i] - d) * (h[i] - h[i - 1])
            / (pos[i] - pos[i - 1]))

    def value(self) -> float:
        """Current estimate (exact while count <= 5)."""
        h = self._h
        if not h:
            return 0.0
        if self.count <= 5:
            return _interp_sorted(h, self.p)
        return h[2]


def _interp_sorted(sorted_xs: Sequence[float], q: float) -> float:
    """numpy-style ('linear') quantile of an already-sorted sequence."""
    n = len(sorted_xs)
    if n == 1:
        return sorted_xs[0]
    rank = q * (n - 1)
    lo = int(rank)
    hi = min(lo + 1, n - 1)
    frac = rank - lo
    return sorted_xs[lo] * (1.0 - frac) + sorted_xs[hi] * frac


class StageStats:
    """Fixed-footprint summary of one observation stream.

    Count / mean / min / max are exact.  Quantiles are exact (numpy
    'linear') while the stream fits the ``exact_cap`` warm-up buffer;
    beyond it they come from a geometric (log-binned) histogram spanning
    ``[lo, hi]`` with bin ratio ``ratio`` — every estimate is the
    geometric midpoint of its bin, so the relative value error is bounded
    by ``√ratio − 1`` (≈2% at the default 1.04) for any distribution and
    any arrival order.  Memory never grows past the warm-up buffer plus
    the fixed bucket array; updates are O(1).

    Negative observations are clamped to zero (spans are time deltas);
    exact zeros get a dedicated bucket so zero-cost stages report 0.0.
    """

    __slots__ = ("count", "mean", "min", "max", "_buf", "exact_cap",
                 "_counts", "_zeros", "_lo", "_log_ratio", "_ratio",
                 "_nbins")

    def __init__(self, exact_cap: int = 512, lo: float = 1e-7,
                 hi: float = 1e4, ratio: float = 1.04):
        assert 0 < lo < hi and ratio > 1.0
        self.count = 0
        self.mean = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.exact_cap = exact_cap
        self._buf: Optional[List[float]] = []
        self._lo = lo
        self._ratio = ratio
        self._log_ratio = math.log(ratio)
        self._nbins = int(math.ceil(math.log(hi / lo) / self._log_ratio))
        self._counts = [0] * self._nbins
        self._zeros = 0

    def observe(self, x: float) -> None:
        if x < 0.0:
            x = 0.0
        self.count += 1
        self.mean += (x - self.mean) / self.count
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        if x <= 0.0:
            self._zeros += 1
        else:
            i = int(math.log(x / self._lo) / self._log_ratio)
            if i < 0:
                i = 0
            elif i >= self._nbins:
                i = self._nbins - 1
            self._counts[i] += 1
        buf = self._buf
        if buf is not None:
            if self.count <= self.exact_cap:
                bisect.insort(buf, x)
            else:                 # graduate to sketch-only: free the buffer
                self._buf = None

    def quantile(self, q: float) -> float:
        """Quantile estimate — exact inside the warm-up buffer, log-binned
        (±(√ratio−1) relative) beyond it.  Any ``q`` in [0, 1] works."""
        if self.count == 0:
            return 0.0
        if self._buf is not None:
            return _interp_sorted(self._buf, q)
        rank = q * self.count
        seen = self._zeros
        if rank <= seen:
            # inside the zero bucket — unless it is empty (q == 0 on an
            # all-positive stream), where the observed min is the answer
            return 0.0 if seen else self.min
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= rank:
                # geometric midpoint of the bin, clamped to observed range
                mid = self._lo * self._ratio ** (i + 0.5)
                return min(max(mid, self.min), self.max)
        return self.max

    @property
    def exact(self) -> bool:
        """True while quantiles are exact (stream within the buffer)."""
        return self._buf is not None

    # -- combination / serialization ---------------------------------------

    def merge(self, other: "StageStats") -> "StageStats":
        """Fold ``other``'s stream into this sketch (e.g. per-slot stats
        combined into a cluster-wide one).  Requires identical binning
        parameters — merging histograms with different geometry would
        silently corrupt quantiles.  The merged sketch stays exact only
        while the combined stream still fits the warm-up buffer;
        otherwise it graduates to sketch-only, like a long stream would.
        """
        assert (self._lo, self._ratio, self._nbins) == \
            (other._lo, other._ratio, other._nbins), \
            "merge() needs identical binning parameters"
        if other.count == 0:
            return self
        total = self.count + other.count
        self.mean += (other.mean - self.mean) * other.count / total
        self.count = total
        if other.min < self.min:
            self.min = other.min
        if other.max > self.max:
            self.max = other.max
        self._zeros += other._zeros
        counts = self._counts
        for i, c in enumerate(other._counts):
            if c:
                counts[i] += c
        if self._buf is not None and other._buf is not None and \
                total <= self.exact_cap:
            for x in other._buf:
                bisect.insort(self._buf, x)
        else:
            self._buf = None
        return self

    def to_dict(self) -> Dict[str, object]:
        """JSON-serializable state (sparse histogram), round-tripped by
        :meth:`from_dict` — the shape BENCH records embed sketches as."""
        out: Dict[str, object] = {
            "count": self.count, "mean": self.mean,
            "exact_cap": self.exact_cap, "lo": self._lo,
            "ratio": self._ratio, "nbins": self._nbins,
            "zeros": self._zeros,
            "bins": {str(i): c for i, c in enumerate(self._counts) if c},
        }
        if self.count:
            out["min"] = self.min
            out["max"] = self.max
        if self._buf is not None:
            out["buf"] = list(self._buf)
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "StageStats":
        st = cls(exact_cap=int(d["exact_cap"]), lo=float(d["lo"]),
                 ratio=float(d["ratio"]))
        assert st._nbins == int(d["nbins"]), \
            (st._nbins, d["nbins"], "binning drifted across versions")
        st.count = int(d["count"])
        st.mean = float(d["mean"])
        st.min = float(d.get("min", float("inf")))
        st.max = float(d.get("max", float("-inf")))
        st._zeros = int(d["zeros"])
        for i, c in d["bins"].items():
            st._counts[int(i)] = int(c)
        buf = d.get("buf")
        st._buf = sorted(float(x) for x in buf) if buf is not None \
            else None
        return st

    def footprint(self) -> Tuple[int, int]:
        """(buffered samples, histogram bins) — both bounded by design."""
        n_buf = len(self._buf) if self._buf is not None else 0
        return n_buf, self._nbins

    def summary(self, quantiles: Sequence[float] = DEFAULT_QUANTILES
                ) -> Dict[str, float]:
        out = {"n": self.count, "mean": self.mean}
        if self.count:
            out["min"] = self.min
            out["max"] = self.max
            for q in quantiles:
                out[f"p{round(q * 100)}"] = self.quantile(q)
        return out

    def __repr__(self):
        return (f"StageStats(n={self.count}, mean={self.mean:.6g}, "
                f"exact={self.exact})")
