"""The batching cost model shared by the serving engine and StageBatcher.

Both batched-decode serving (Vortex, 2511.02062) and per-stage pipeline
batching (InferLine, 1812.01776) rest on the same hardware fact: one
batched invocation of a model costs far less than ``n`` sequential
invocations, because weights stream through the compute units once.  We
model that with the standard affine service curve

    batch_seconds(unit, n) = unit * (fixed + marginal * n) / (fixed + marginal)

normalized so a batch of one costs exactly ``unit`` — batching is
transparent at n=1 and sub-linear beyond it.  ``fixed`` is the
weight-streaming / kernel-launch share of a unit invocation, ``marginal``
the per-item (activation) share; the serving engine's measured decode
behavior (one ``decode_step`` advances every active slot) corresponds to a
high fixed share, which is the default.

One instance of this class is the single source of batching economics:
``repro.serving.engine.ServingEngine`` uses it for virtual decode time
(replacing its former private always-fully-amortized decode accounting)
and ``repro.workflows.batching.StageBatcher`` uses it to cost coalesced
stage executions.  Sweeps that change the curve therefore move both
layers coherently.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class BatchCostModel:
    """Affine amortized batch cost, normalized to ``unit`` at n=1.

    ``fixed``    — weight-streaming/launch share of a unit invocation;
    ``marginal`` — per-item share;
    ``max_batch`` — the largest batch the hardware shape admits; cost
    grows linearly (no further amortization) past it.
    """
    fixed: float = 0.65
    marginal: float = 0.35
    max_batch: int = 16

    def __post_init__(self):
        assert self.fixed >= 0 and self.marginal > 0, (self.fixed,
                                                       self.marginal)
        assert self.max_batch >= 1, self.max_batch

    def batch_seconds(self, unit_seconds: float, n: int) -> float:
        """Total service time of a batch of ``n`` unit tasks.

        An empty batch costs nothing; a batch of one costs exactly
        ``unit_seconds`` (transparency).
        """
        if n <= 0:
            return 0.0
        if n == 1:
            return unit_seconds
        norm = self.fixed + self.marginal
        full, rem = divmod(n, self.max_batch)
        t = full * unit_seconds * \
            (self.fixed + self.marginal * self.max_batch) / norm
        if rem:
            t += unit_seconds * (self.fixed + self.marginal * rem) / norm
        return t

    def step_seconds(self, unit_seconds: float, n: int) -> float:
        """Per-participant amortized time of one batched step.

        ``n <= 1`` (including an idle row) prices a full unit step —
        ``step_seconds(u, n) * n == batch_seconds(u, n)`` for n >= 1.
        """
        n = max(n, 1)
        return self.batch_seconds(unit_seconds, n) / n

    def largest_within(self, unit_seconds: float, budget: float,
                       wait_per_member: float = 0.0) -> int:
        """Largest ``n <= max_batch`` whose formation wait plus amortized
        service fits ``budget`` — the planner's feasibility search.

        ``wait_per_member`` is the expected extra formation wait each
        additional member adds (the arrival gap); total cost of a batch of
        ``n`` is ``(n-1)*wait_per_member + batch_seconds(unit, n)``, which
        is monotone in ``n``, so the search stops at the first overflow.
        Returns at least 1: a singleton is always admissible (batching
        never makes n=1 worse than unbatched).
        """
        n = 1
        for k in range(2, self.max_batch + 1):
            if (k - 1) * wait_per_member + \
                    self.batch_seconds(unit_seconds, k) > budget:
                break
            n = k
        return n

    def speedup(self, n: int) -> float:
        """Throughput gain of a batch of ``n`` over ``n`` sequential runs."""
        if n <= 1:
            return 1.0
        return n / self.batch_seconds(1.0, n)

    def drain_rate(self, unit_seconds: float, n: int) -> float:
        """Items/second one lane drains running back-to-back batches of
        ``n`` — the capacity side of the planner's utilization check
        (arrivals faster than this per lane means the queue only grows)."""
        if unit_seconds <= 0.0:
            return float("inf")
        return max(n, 1) / self.batch_seconds(unit_seconds, max(n, 1))


# the engine-calibrated default: decode batching on a serving row
DEFAULT_COST_MODEL = BatchCostModel()
