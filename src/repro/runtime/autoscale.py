"""SLO-driven elastic scaling inside the DES, with affinity-stable resharding.

The paper's §3.2 'lightweight' requirement: resharding must not require a
synchronized key->shard map.  With rendezvous (or pinned/sticky) placement
only a fraction of affinity GROUPS move when a shard slot joins or leaves;
the scaler executes exactly those moves and charges their bytes as
background NIC transfers (group = migration unit, which is what makes a
move safe wrt ordering: the group's sequencer drains before the switch).

This module used to be a standalone toy driven by an instantaneous queue
depth sample, invoked by nobody.  It is now a *periodic controller running
inside the simulation*:

  * **Pressure signal** — a windowed :class:`repro.runtime.StageStats`
    sketch of end-to-end latency (fed by the workflow tracker, reset every
    controller period) read at the SLO quantile, combined with the member
    nodes' backlogged compute-seconds per lane (``Node.pending``).  Both
    are O(1) reads; neither is an instantaneous queue peek.
  * **Actuation** — grow/shrink every managed pool by one shard slot *in
    lockstep* (the pools of one workflow share slot indices under gang
    placement, so scaling is workflow-atomic like admission), taking the
    new slot's nodes from the spare list and returning a retired slot's
    nodes to it.
  * **Cost** — every object whose home changes lands on its new shard via
    a charged background transfer; nothing moves for free.

``WorkflowRuntime.enable_autoscale`` wires a scaler to a workflow's
instance pools, tier spares, and tracker; the scaler also works directly
against a bare :class:`repro.runtime.Runtime` (see tests/test_elasticity).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.object_store import Shard
from .stats import StageStats


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """Controller gains/bounds — one instance serves every load level.

    ``interval`` is the controller period in sim seconds; pressure is
    ``max(latency_q / slo, backlog_seconds / slo, reject-shed,
    down-fraction)`` where
    ``latency_q`` is the window sketch's ``slo_quantile``,
    ``backlog_seconds`` the worst member node's admitted-but-unfinished
    compute per lane (the signal that still moves when overload stalls
    completions entirely), reject-shed the admission gate's turned-
    away demand, and down-fraction a saturating term for member nodes
    currently marked down (an outage is capacity shortfall before its
    backlog ever reaches the latency sketch).  Scale out above
    ``high_pressure`` with spares
    available — by up to ``max_step`` slots when pressure is a multiple
    of the threshold — and in below ``low_pressure``.  Cooldowns are
    asymmetric (``cooldown_out`` < ``cooldown_in``): capacity shortfall
    costs SLOs immediately, surplus only costs node-seconds, so the
    controller reacts fast upward and settles slowly downward.
    """
    interval: float = 0.05
    slo_quantile: float = 0.95
    high_pressure: float = 1.0
    low_pressure: float = 0.35
    min_samples: int = 12          # window observations before latency counts
    cooldown_out: int = 1          # quiet periods after a scale-out
    cooldown_in: int = 4           # quiet periods after a scale-in
    max_step: int = 2              # largest one-decision scale-out
    min_shards: int = 1
    backlog_weight: float = 1.0


def replace_gang_pins(store, pools: Sequence[str], labels: Sequence[str],
                      survivors: Sequence[str],
                      fence=None, epochs: Optional[Dict[str, int]] = None,
                      avoid_domain: str = "") -> Dict[str, int]:
    """Re-pin ``labels`` to one surviving slot each, in every lockstep pool.

    The workflow-atomic move shared by slot retirement (scale-in) and node
    death: the ANCHOR pool's policy picks a destination among
    ``survivors`` (anchor-pool shard names), and the same slot INDEX is
    pinned in every pool so a gang never straddles slots mid-flight.
    Existing pins on the labels are dropped first; object migration is the
    caller's business (the scaler's re-home pass, the fault path's
    stranded-object move).  Returns label -> destination slot index.

    ``fence``/``epochs`` (a ``repro.core.EpochFence`` plus the per-label
    tokens the caller advanced when it claimed the repair) make the move
    split-brain safe: a label whose token went stale between claim and
    commit is skipped — some fresher repair owns it now — instead of
    double-pinned.  ``avoid_domain`` biases the destination away from the
    failure domain that just died: survivors with any member in it rank
    last, so a repaired gang does not land back in the blast radius.
    """
    anchor = store.pools[pools[0]].engine
    placed: Dict[str, int] = {}
    survivors = list(survivors)
    if avoid_domain and len(survivors) > 1:
        doms = getattr(anchor, "shard_domains", {})
        safe = [s for s in survivors if doms.get(s, "") != avoid_domain]
        if safe:
            survivors = safe
    for lbl in labels:
        if fence is not None and \
                not fence.check(lbl, (epochs or {}).get(lbl, 0)):
            continue                     # a fresher repair owns this gang
        anchor.unpin(lbl)
        dst = anchor.policy.place(lbl, survivors)
        idx = anchor.shards.index(dst)
        for prefix in pools:
            eng = store.pools[prefix].engine
            eng.pin(lbl, eng.shards[idx])
        placed[lbl] = idx
    return placed


@dataclasses.dataclass
class ScaleDecision:
    t: float                       # virtual time of the decision
    old_shards: int
    new_shards: int
    pressure: float
    reason: str
    bytes_moved: int = 0
    groups_moved: int = 0


class AutoScaler:
    """Periodic SLO-pressure controller over a lockstep group of pools.

    ``pools`` are resharded together (equal slot counts — the gang-pin
    invariant); ``spare_nodes`` is the ordered standby list scale-out
    consumes from and scale-in returns to, so capacity is conserved
    across any out/in sequence.  ``slo`` is the latency objective
    pressure is normalized by.
    """

    def __init__(self, runtime, pools: Sequence[str],
                 spare_nodes: Sequence[str], slo: float,
                 policy: Optional[AutoscalePolicy] = None,
                 resources: Sequence[str] = ("gpu", "cpu")):
        assert slo > 0, slo
        self.rt = runtime
        self.pools = list(pools)
        assert self.pools, "autoscaler needs at least one managed pool"
        counts = {p: len(runtime.store.pools[p].engine.shards)
                  for p in self.pools}
        assert len(set(counts.values())) == 1, \
            f"managed pools must share a slot count, got {counts}"
        slot_nodes = None
        for p in self.pools:
            pool = runtime.store.pools[p]
            for shard in pool.shards.values():
                assert len(shard.nodes) == 1, \
                    "autoscaled pools use replication=1 (slot == node)"
            # lockstep actuation installs/retires ONE node per slot index
            # across every pool — that is only sound when slot i already
            # means the same node everywhere (the WorkflowRuntime layout)
            nodes = tuple(tuple(pool.shards[s].nodes)
                          for s in pool.engine.shards)
            if slot_nodes is None:
                slot_nodes = nodes
            else:
                assert nodes == slot_nodes, \
                    f"managed pools must share the slot->node mapping " \
                    f"({self.pools[0]} vs {p})"
        self.spare = list(spare_nodes)
        self.slo = slo
        self.policy = policy or AutoscalePolicy()
        self.resources = tuple(resources)
        self.decisions: List[ScaleDecision] = []
        self._window = StageStats()
        self._window_rejects = 0
        self._observed = 0          # completions ever seen (any window)
        self._cooldown = 0
        self._pending_ticks = 0
        # node-seconds accounting: (t, active_node_count) step function,
        # integrated by node_seconds() — the benchmark's cost axis
        self._active_log: List[Tuple[float, int]] = [
            (runtime.sim.now, self._n_active())]

    # -- signal feeds -------------------------------------------------------

    def observe_latency(self, x: float) -> None:
        """Feed one end-to-end completion span into the pressure window
        (the workflow tracker registers this as a completion sink)."""
        self._observed += 1
        self._window.observe(x)

    def observe_reject(self) -> None:
        """Feed one admission rejection into the pressure window.

        An admission gate only turns work away when its deadline provably
        cannot be met on the CURRENT tier mix — so rejected demand is
        capacity shortfall by definition, and without this feed the gate
        and the scaler deadlock: admission keeps queues bounded, bounded
        queues keep latency under the SLO, and the scaler sees a healthy
        cluster while users are being turned away."""
        self._observed += 1
        self._window_rejects += 1

    # -- introspection ------------------------------------------------------

    def _n_active(self) -> int:
        return len(self._active_nodes())

    def _active_nodes(self) -> List[str]:
        # engine.shards is the ACTIVE slot list; pool.shards additionally
        # retains retired (drained) shards so stragglers dispatched to a
        # just-removed slot still resolve it
        pool = self.rt.store.pools[self.pools[0]]
        return [n for name in pool.engine.shards
                for n in pool.shards[name].nodes]

    def node_seconds(self, until: Optional[float] = None) -> float:
        """Integral of active node count over virtual time (the capacity
        actually paid for — the fair-comparison axis vs static sizing)."""
        end = self.rt.sim.now if until is None else until
        total = 0.0
        log = self._active_log
        for i, (t, n) in enumerate(log):
            t1 = log[i + 1][0] if i + 1 < len(log) else end
            total += max(t1 - t, 0.0) * n
        return total

    def backlog_seconds(self) -> float:
        """Worst member node's admitted-but-unfinished compute seconds per
        lane over the managed resources (O(1) per node — ``Node.pending``
        is maintained by the compute handlers)."""
        worst = 0.0
        for name in self._active_nodes():
            node = self.rt.nodes[name]
            for r in self.resources:
                cap = node.capacity.get(r, 0)
                if cap:
                    worst = max(worst, node.pending[r] / cap)
        return worst

    def pressure(self) -> Tuple[float, str]:
        """(pressure, dominant-signal) — normalized so 1.0 means 'the SLO
        is exactly spent'."""
        pol = self.policy
        lat = 0.0
        if self._window.count >= pol.min_samples:
            lat = self._window.quantile(pol.slo_quantile) / self.slo
        backlog = self.backlog_seconds() / self.slo * pol.backlog_weight
        if backlog > lat:
            p, signal = backlog, "backlog"
        else:
            p, signal = lat, f"p{round(pol.slo_quantile * 100)}"
        if self._window_rejects:
            # shed demand saturates the signal (see observe_reject);
            # magnitude grows with the shed fraction so sustained heavy
            # rejection keeps scaling through consecutive cooldowns
            shed = self._window_rejects / max(
                self._window.count + self._window_rejects, 1)
            rej = pol.high_pressure * (1.0 + shed)
            if rej > p:
                p, signal = rej, "rejects"
        active = self._active_nodes()
        down = sum(1 for n in active if not self.rt.nodes[n].up)
        if down:
            # a dead member is capacity shortfall NOW, before its backlog
            # shows in latency: saturate the signal like rejects do, scaled
            # by the fraction of the fleet that is gone so multi-node
            # outages keep scaling through consecutive cooldowns
            dp = pol.high_pressure * (1.0 + down / max(len(active), 1))
            if dp > p:
                p, signal = dp, "down"
        return p, signal

    # -- the controller -----------------------------------------------------

    def start(self) -> "AutoScaler":
        """Begin periodic evaluation inside the DES.  Ticks reschedule only
        while the heap holds real work (same guard as the migration
        driver), so bounded workloads still terminate."""
        self._schedule_tick()
        return self

    def _schedule_tick(self) -> None:
        self._pending_ticks += 1
        self.rt._pending_ticks += 1
        self.rt.sim.after(self.policy.interval, self._tick)

    def _tick(self) -> None:
        self._pending_ticks -= 1
        self.rt._pending_ticks -= 1
        decision = self.evaluate()
        if decision is not None:
            self.apply(decision)
        self._window = StageStats()            # fresh pressure window
        self._window_rejects = 0
        if len(self.rt.sim._heap) > self.rt._pending_ticks:
            self._schedule_tick()

    def evaluate(self) -> Optional[ScaleDecision]:
        pol = self.policy
        if self._cooldown > 0:
            self._cooldown -= 1
            return None
        n = len(self.rt.store.pools[self.pools[0]].engine.shards)
        p, signal = self.pressure()
        if p >= pol.high_pressure and self.spare:
            # pressure at k x the threshold asks for k slots (cap at
            # max_step and the spare inventory): a cliff-shaped ramp
            # should not be climbed one cooldown at a time
            step = min(len(self.spare), pol.max_step,
                       max(1, int(p / pol.high_pressure)))
            return ScaleDecision(t=self.rt.sim.now, old_shards=n,
                                 new_shards=n + step, pressure=p,
                                 reason=f"{signal} pressure {p:.2f} >= "
                                        f"{pol.high_pressure}")
        if p <= pol.low_pressure and n > pol.min_shards and \
                self._observed > 0:
            return ScaleDecision(t=self.rt.sim.now, old_shards=n,
                                 new_shards=n - 1, pressure=p,
                                 reason=f"{signal} pressure {p:.2f} <= "
                                        f"{pol.low_pressure}")
        return None

    # -- actuation ----------------------------------------------------------

    def force(self, new_shards: int, reason: str = "forced"
              ) -> ScaleDecision:
        """Apply a manual resharding (static pre-provisioning, tests):
        bypasses pressure evaluation but uses the same actuation path —
        charged migrations, lockstep pools, spare accounting."""
        n = len(self.rt.store.pools[self.pools[0]].engine.shards)
        return self.apply(ScaleDecision(t=self.rt.sim.now, old_shards=n,
                                        new_shards=new_shards,
                                        pressure=0.0, reason=reason))

    def apply(self, decision: ScaleDecision) -> ScaleDecision:
        """Reshard every managed pool to ``decision.new_shards`` slots and
        physically move affected groups, charging their bytes.

        Scale-out consumes the next spare node; scale-in retires the
        highest slot and RETURNS its node to the spare list (capacity is
        conserved — the pre-rewrite scaler leaked it, so scale-out after
        scale-in permanently lost a node).
        """
        store = self.rt.store
        grow = decision.new_shards > decision.old_shards
        delta = abs(decision.new_shards - decision.old_shards)
        if grow:
            assert delta <= len(self.spare), \
                f"scale-out of {delta} exceeds spare inventory " \
                f"{self.spare}"
            new_nodes = [self.spare.pop(0) for _ in range(delta)]
        else:
            assert decision.new_shards >= 1, decision
            new_nodes = []
        retired_nodes: List[str] = []
        total_bytes = 0
        total_groups = 0
        # workflow-atomic retirement: a gang pinned to the retiring slot
        # would otherwise fall back to policy placement pool-by-pool,
        # scattering an in-flight workflow across slots mid-execution.
        # Re-pin every such label to ONE surviving slot (the anchor
        # pool's policy picks it; the same slot INDEX applies in every
        # lockstep pool), then the re-home pass below moves its objects
        # there as ordinary charged migrations.
        if not grow:
            anchor = store.pools[self.pools[0]].engine
            stranded = anchor.pinned_labels(anchor.shards[-delta:])
            replace_gang_pins(store, self.pools, stranded,
                              anchor.shards[:-delta])
        for prefix in self.pools:
            pool = store.pools[prefix]
            # snapshot current homes (dedup replays: key -> (shard, rec))
            old_homes: Dict[str, Tuple[str, object]] = {}
            for shard in pool.shards.values():
                for key, rec in shard.objects.items():
                    old_homes.setdefault(key, (shard.name, rec))
            if grow:
                stage_res = {b.resource for b in
                             self.rt.bindings.values()
                             if b.udl.prefix == prefix}
                for i, new_node in enumerate(new_nodes):
                    sname = f"{pool.prefix}#s{decision.old_shards + i}"
                    pool.shards[sname] = Shard(sname, [new_node])
                    pool.engine.add_shard(sname)
                    # heterogeneous spares: weight the new slot by its
                    # tier's throughput for the work this pool triggers
                    # so capacity-normalized placement fills it in
                    # proportion to what it can actually drain
                    prof = self.rt.nodes[new_node].profile
                    pool.engine.set_capacity(
                        sname,
                        max((prof.speed_of(r) for r in stage_res),
                            default=prof.nominal_speed))
            else:
                for _ in range(delta):
                    sname = pool.engine.shards[-1]
                    if prefix == self.pools[0]:
                        retired_nodes.extend(pool.shards[sname].nodes)
                    pool.engine.remove_shard(sname)
                    # slot is gone for placement; its objects drain
                    # below.  The (empty) shard object stays in
                    # pool.shards so work already dispatched to the slot
                    # still resolves it.
            # re-home: move every object whose home changed under the new
            # slot set (pins/sticky bindings keep in-flight groups put;
            # rendezvous moves ~1/n of the rest)
            moved_labels = set()
            for key, (old_shard, rec) in old_homes.items():
                new_shard = pool.home(key)
                if new_shard.name == old_shard:
                    continue
                pool.shards[old_shard].objects.pop(key, None)
                new_shard.objects[key] = rec
                total_bytes += rec.size
                moved_labels.add(rec.affinity)
                store.stats.bytes_migrated += rec.size
                # ledger transfer for capacity-normalized policies:
                # credit the destination ONLY for moves off retired
                # slots (whose whole counter remove_shard just dropped)
                # — a surviving source keeps its charge, so crediting
                # again would double-count the bytes
                if old_shard not in pool.engine.shards:
                    pool.engine.record_load(new_shard.name, rec.size)
                if new_shard.nodes:
                    self.rt.sim._charge_transfer(
                        self.rt.nodes[new_shard.nodes[0]], rec.size)
                store.invalidate_cached([key])
            store.stats.migrations += len(moved_labels)
            total_groups += len(moved_labels)
        self.spare.extend(retired_nodes)          # capacity conserved
        decision.bytes_moved = total_bytes
        decision.groups_moved = total_groups
        self.decisions.append(decision)
        if self.rt.sim.tracer is not None:
            self.rt.sim.tracer.instant(
                None, "scale", self.rt.sim.now,
                {"old": decision.old_shards, "new": decision.new_shards,
                 "pressure": round(decision.pressure, 3),
                 "reason": decision.reason, "bytes": total_bytes})
        self._cooldown = (self.policy.cooldown_out if grow
                          else self.policy.cooldown_in)
        self._active_log.append((self.rt.sim.now, self._n_active()))
        return decision
