"""Serving launcher: affinity-routed multi-row engine over a smoke model.

``python -m repro.launch.serve --arch granite-3-2b --policy affinity``
drives synthetic multi-turn sessions through the continuous-batching engine
and prints the TTFT / migration summary (paper §7.2 applied).
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.models import build_model
from repro.serving import ServingEngine, make_adapter


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--policy", default="affinity",
                    choices=["affinity", "adapter_affinity", "random",
                             "least_loaded"])
    ap.add_argument("--rows", type=int, default=4)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--sessions", type=int, default=16)
    ap.add_argument("--turns", type=int, default=4)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, n_rows=args.rows,
                        max_slots=args.slots, max_seq=args.max_seq,
                        policy=args.policy)
    eng.adapters.register(
        make_adapter(jax.random.PRNGKey(1), "support-bot", cfg.d_model,
                     cfg.vocab_size))
    for i in range(args.sessions):
        eng.open_session(f"s{i}",
                         adapter="support-bot" if i % 3 == 0 else None)
    t = 0.0
    for turn in range(args.turns):
        for i in range(args.sessions):
            prompt = [1 + (i + turn) % 17, 2, 3]
            _, m = eng.turn(f"s{i}", prompt, gen_tokens=args.gen, now=t)
            t += 0.002
    print(f"policy={args.policy}")
    for k, v in eng.summary().items():
        print(f"  {k:22s} {v}")


if __name__ == "__main__":
    main()
