"""Paper Fig. 6 regime: replication x placement policy x migration sweep.

The paper's Fig. 6 shows affinity grouping keeps end-to-end latency flat as
replication and scale-out grow.  This benchmark extends that comparison to
the dynamic subsystem: for each replication factor it runs

  * the ungrouped hash baseline ("random placement"),
  * affinity grouping with static hash placement,
  * affinity grouping with load-aware placement (least-loaded shard at
    group-creation time),

each with the runtime GroupMigrator off and on, and emits the paper-style
comparison table (median / p95 / p99 latency, remote-get bytes, migration
traffic).  The affinity-grouped load-aware row should beat the hash
baseline on both remote bytes and tail latency.
"""
from .common import emit, run_rcp

SCENES = ("little3", "hyang5", "gates3")
LAYOUT = (3, 5, 5)


def sweep(quick=True):
    """Full replication x policy x migration grid -> list of result dicts."""
    frames = 150 if quick else 700
    grid = []
    for repl in ((1, 2) if quick else (1, 2, 3)):
        for grouped, placement in ((False, "hash"), (True, "hash"),
                                   (True, "load_aware")):
            for migrate in (False, True):
                if not grouped and migrate:
                    continue   # migration is group-granular by definition
                grid.append((repl, grouped, placement, migrate))
    results = []
    for repl, grouped, placement, migrate in grid:
        s = run_rcp(grouped, LAYOUT, SCENES, frames, placement=placement,
                    read_replicas=repl,
                    migrate_every=0.25 if migrate else None)
        name = ("affinity" if grouped else "random") + f"_{placement}" \
            + f"_r{repl}" + ("_mig" if migrate else "")
        s["case"] = name
        results.append(s)
    # straggler scenario: one PRED server at 1/3 speed.  Remote-traffic
    # heat never sees this (compute follows data, reads stay local), so it
    # isolates the queue-pressure migration path: groups drain off the
    # slow shard and tail latency recovers.
    for migrate in (False, True):
        s = run_rcp(True, LAYOUT, SCENES, frames, placement="load_aware",
                    migrate_every=0.25 if migrate else None,
                    straggler=("pred0", 0.33))
        s["case"] = "affinity_load_aware_r1_straggler" + \
            ("_mig" if migrate else "")
        results.append(s)
    return results


def table(results):
    cols = ("case", "median_ms", "p95_ms", "p99_ms", "remote_MB",
            "sync_MB", "migrations", "mig_MB")
    lines = ["  ".join(f"{c:>26}" if c == "case" else f"{c:>10}"
                       for c in cols)]
    for s in results:
        row = (s["case"],
               f"{s['median'] * 1e3:.2f}", f"{s['p95'] * 1e3:.2f}",
               f"{s['p99'] * 1e3:.2f}",
               f"{s['bytes_remote'] / 1e6:.2f}",
               f"{s['bytes_replica_sync'] / 1e6:.2f}",
               str(s["migrations"]),
               f"{s['bytes_migrated'] / 1e6:.2f}")
        lines.append("  ".join(f"{v:>26}" if i == 0 else f"{v:>10}"
                               for i, v in enumerate(row)))
    return "\n".join(lines)


def run(quick=True):
    results = sweep(quick)
    print(table(results))
    rows = []
    for s in results:
        rows.append((f"fig6/{s['case']}", s["median"] * 1e6,
                     {"p95_ms": round(s["p95"] * 1e3, 1),
                      "p99_ms": round(s["p99"] * 1e3, 1),
                      "remote_gets": s["remote_gets"],
                      "bytes_remote": s["bytes_remote"],
                      "migrations": s["migrations"]}))
    return rows


if __name__ == "__main__":
    emit(run())
