"""Discrete-event cluster simulation that executes real stage logic.

The paper's evaluation (17-server RDMA cluster / Azure) is reproduced with a
DES whose primitives are the ones that determine placement behavior:

  * nodes with FIFO *resources* (gpu, cpu, nic) and service queues,
  * links with bandwidth + RTT (cluster and cloud profiles),
  * the affinity-grouped CascadeStore for placement/caching,
  * UDL tasks written as python *generators* yielding ops
    (Get / Put / Trigger / Compute / Sleep) — the sim advances virtual time
    around them, so the RCP application code reads like the paper's
    pseudo-code while queueing/transfer effects are modeled faithfully.

Node failures, stragglers (per-node slowdown factors) and hedged retries are
injectable (see repro.runtime.faults).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import random
from collections import defaultdict, deque
from typing import Any, Callable, Dict, Generator, List, Optional, Tuple

from repro.core import CascadeStore


# ---------------------------------------------------------------------------
# Network / hardware profiles
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NetProfile:
    bandwidth: float          # bytes/s
    rtt: float                # seconds per transfer
    store_latency: float = 0.0   # extra per remote storage op (cloud)

    def transfer_time(self, nbytes: int) -> float:
        return self.rtt + self.store_latency + nbytes / self.bandwidth


# paper §4.4: 100 Gbps RDMA backbone, PTP-synced cluster
CLUSTER_NET = NetProfile(bandwidth=12.5e9, rtt=10e-6)
# paper §5: Azure — EH/blob/cosmos hops, ~10 Gbps effective, ms-scale RTTs
AZURE_NET = NetProfile(bandwidth=1.25e9, rtt=1e-3, store_latency=4e-3)


# ---------------------------------------------------------------------------
# Ops yielded by task generators
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Get:
    key: str
    required: bool = True
    wait: bool = False        # True: block until the key is put


@dataclasses.dataclass
class Put:
    key: str
    value: Any = None
    size: int = 0
    fire: bool = True         # trigger downstream UDLs


@dataclasses.dataclass
class Trigger:
    key: str
    value: Any = None
    size: int = 0


@dataclasses.dataclass
class Compute:
    resource: str             # "gpu" | "cpu"
    seconds: float


@dataclasses.dataclass
class Sleep:
    seconds: float


TaskGen = Generator[Any, Any, None]


# ---------------------------------------------------------------------------
# Node model
# ---------------------------------------------------------------------------

class Node:
    def __init__(self, name: str, resources: Dict[str, int],
                 speed: float = 1.0):
        self.name = name
        self.capacity = dict(resources)           # resource -> lanes
        self.in_use: Dict[str, int] = defaultdict(int)
        self.queues: Dict[str, deque] = defaultdict(deque)
        self.speed = speed                        # <1.0 => straggler
        self.up = True
        # metrics
        self.busy_time: Dict[str, float] = defaultdict(float)
        self.n_tasks = 0
        self.queue_wait: float = 0.0

    def __repr__(self):
        return f"Node({self.name})"


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------

class Simulator:
    def __init__(self, store: CascadeStore, nodes: Dict[str, Node],
                 net: NetProfile = CLUSTER_NET, seed: int = 0,
                 local_get_cost: float = 2e-6):
        self.store = store
        self.nodes = nodes
        self.net = net
        self.now = 0.0
        self.rng = random.Random(seed)
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self.local_get_cost = local_get_cost
        # task bookkeeping
        self.completed_tasks = 0
        self.events_fired = 0
        self.metrics: Dict[str, Any] = defaultdict(list)
        self.udl_dispatch: Optional[Callable] = None  # set by Runtime
        self._waiters: Dict[str, List[Tuple[Node, Any, Callable]]] = \
            defaultdict(list)

    # -- event loop ---------------------------------------------------------

    def at(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (max(t, self.now), next(self._seq), fn))

    def after(self, dt: float, fn: Callable[[], None]) -> None:
        self.at(self.now + dt, fn)

    def run(self, until: float = float("inf")) -> None:
        while self._heap:
            t, _, fn = heapq.heappop(self._heap)
            if t > until:
                self.now = until
                return
            self.now = t
            self.events_fired += 1
            fn()

    # -- resources ------------------------------------------------------------

    def acquire(self, node: Node, resource: str, fn: Callable[[], None],
                enq_time: Optional[float] = None) -> None:
        enq = self.now if enq_time is None else enq_time
        if not node.up:
            # node down: park in queue; failover logic re-dispatches
            node.queues[resource].append((enq, fn))
            return
        if node.in_use[resource] < node.capacity.get(resource, 1):
            node.in_use[resource] += 1
            node.queue_wait += self.now - enq
            fn()
        else:
            node.queues[resource].append((enq, fn))

    def release(self, node: Node, resource: str) -> None:
        node.in_use[resource] -= 1
        q = node.queues[resource]
        while q and node.up:
            enq, fn = q.popleft()
            node.in_use[resource] += 1
            node.queue_wait += self.now - enq
            fn()
            return

    # -- task execution ---------------------------------------------------------

    def spawn(self, node_name: str, gen: TaskGen, done: Optional[Callable] = None,
              label: str = "") -> None:
        """Run a generator task on a node, advancing sim time per op."""
        node = self.nodes[node_name]
        node.n_tasks += 1

        def step(send_value=None):
            try:
                op = gen.send(send_value)
            except StopIteration:
                self.completed_tasks += 1
                if done is not None:
                    done()
                return
            self._execute(node, op, step)

        step(None)

    def _execute(self, node: Node, op: Any, cont: Callable[[Any], None]):
        if isinstance(op, Compute):
            dur = op.seconds / max(node.speed, 1e-9)

            def start():
                def finish():
                    node.busy_time[op.resource] += dur
                    self.release(node, op.resource)
                    cont(None)
                self.after(dur, finish)
            self.acquire(node, op.resource, start)

        elif isinstance(op, Sleep):
            self.after(op.seconds, lambda: cont(None))

        elif isinstance(op, Get):
            rec, local = self.store.get(op.key, node=node.name)
            if rec is None:
                if op.wait:
                    self._waiters[op.key].append((node, op, cont))
                    return
                if op.required:
                    raise KeyError(f"missing object {op.key} at t={self.now}")
                self.after(self.local_get_cost, lambda: cont(None))
                return
            if local:
                self.after(self.local_get_cost, lambda: cont(rec.value))
            else:
                dt = self.net.transfer_time(rec.size)

                def start_xfer():
                    def finish():
                        self.release(node, "nic")
                        cont(rec.value)
                    self.after(dt, finish)
                self.acquire(node, "nic", start_xfer)

        elif isinstance(op, (Put, Trigger)):
            fire = isinstance(op, Trigger) or op.fire
            if isinstance(op, Put):
                sync0 = self.store.stats.bytes_replica_sync
                shard, udls = self.store.put(op.key, op.value, size=op.size,
                                             fire=fire)
                # replication cost: object ships to every member not local
                remote = [n for n in shard.nodes if n != node.name]
                dt = self.net.transfer_time(op.size) if remote else \
                    self.local_get_cost
                # cross-shard replica fan-out (ReplicatedPlacement): async
                # sync that still occupies the writer's NIC
                sync_bytes = self.store.stats.bytes_replica_sync - sync0
                if sync_bytes:
                    self._charge_transfer(node, sync_bytes)
            else:
                shard, udls = self.store.trigger(op.key, op.value,
                                                 size=op.size)
                remote = [n for n in shard.nodes if n != node.name]
                dt = self.net.transfer_time(op.size) if remote else \
                    self.local_get_cost

            def delivered():
                if isinstance(op, Put) and op.key in self._waiters:
                    for wnode, wop, wcont in self._waiters.pop(op.key):
                        self._execute(wnode, wop, wcont)
                if fire and udls and self.udl_dispatch is not None:
                    for u in udls:
                        self.udl_dispatch(u, shard, op.key, op.value)
                cont(None)
            self.after(dt, delivered)

        else:
            raise TypeError(f"unknown op {op!r}")

    # -- background transfers ------------------------------------------------

    def _charge_transfer(self, node: Node, nbytes: int,
                         done: Optional[Callable[[], None]] = None) -> None:
        """Occupy `node`'s NIC for a background transfer (replica sync,
        group migration).  Does not block the initiating task."""
        dt = self.net.transfer_time(nbytes)

        def start():
            def finish():
                self.release(node, "nic")
                self.metrics["background_xfer_s"].append(dt)
                if done is not None:
                    done()
            self.after(dt, finish)
        self.acquire(node, "nic", start)
