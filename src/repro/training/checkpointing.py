"""Sharded, rotating, optionally-async checkpointing (fault tolerance).

Layout: <dir>/step_<N>/
    manifest.json        tree structure + shapes + dtypes + step + meta
    arrays.npz           flattened leaves keyed by tree path

Restore is exact (same tree), tolerant to extra keys, and verifiable via a
content checksum.  ``AsyncCheckpointer`` offloads serialization to a
background thread so the train loop never blocks on disk (the standard
overlap trick); ``save_on_signal`` gives crash-consistent behavior for the
node-failure drill in the tests.
"""
from __future__ import annotations

import hashlib
import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save_checkpoint(directory: str, step: int, tree: Any,
                    meta: Optional[Dict] = None, keep: int = 3) -> Path:
    root = Path(directory)
    tmp = root / f".tmp_step_{step}"
    final = root / f"step_{step}"
    tmp.mkdir(parents=True, exist_ok=True)

    arrays = {}
    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    for key, leaf in _flatten(tree):
        arr = np.asarray(leaf)
        dtype = str(arr.dtype)
        if dtype == "bfloat16":          # npz can't store ml_dtypes
            arr = arr.view(np.uint16)
        arrays[key] = arr
        manifest["leaves"][key] = {
            "shape": list(arr.shape), "dtype": dtype}
    np.savez(tmp / "arrays.npz", **{k: v for k, v in arrays.items()})
    digest = hashlib.blake2b(
        (tmp / "arrays.npz").read_bytes(), digest_size=16).hexdigest()
    manifest["checksum"] = digest
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                       # atomic publish
    _rotate(root, keep)
    return final


def _rotate(root: Path, keep: int) -> None:
    steps = sorted(
        (int(p.name.split("_")[1]), p)
        for p in root.glob("step_*") if p.name.split("_")[1].isdigit())
    for _, p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    root = Path(directory)
    if not root.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in root.glob("step_*")
             if p.name.split("_")[1].isdigit()]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like: Any,
                       step: Optional[int] = None,
                       verify: bool = True) -> Tuple[Any, Dict]:
    root = Path(directory)
    step = step if step is not None else latest_step(directory)
    assert step is not None, f"no checkpoints under {directory}"
    path = root / f"step_{step}"
    manifest = json.loads((path / "manifest.json").read_text())
    if verify:
        digest = hashlib.blake2b(
            (path / "arrays.npz").read_bytes(), digest_size=16).hexdigest()
        assert digest == manifest["checksum"], "checkpoint corrupted"
    arrays = np.load(path / "arrays.npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    import ml_dtypes
    for kpath, like in flat:
        key = jax.tree_util.keystr(kpath)
        arr = arrays[key]
        if manifest["leaves"][key]["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        assert list(arr.shape) == list(like.shape), (key, arr.shape,
                                                     like.shape)
        leaves.append(arr.astype(like.dtype) if hasattr(like, "dtype")
                      else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


class AsyncCheckpointer:
    """Snapshot to host memory synchronously; write to disk in background."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: List[int] = []
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, meta: Optional[Dict] = None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # device->host

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, meta,
                                self.keep)
                self.saved_steps.append(step)
            except BaseException as e:     # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            raise self.last_error
