"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import sys
import time
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"
ARTIFACTS.mkdir(exist_ok=True)


def run_rcp(grouped, layout, scenes, n_frames, caching=True, net=None,
            scheduler=None, replication=1, seed=0, placement="hash",
            read_replicas=1, migrate_every=None, straggler=None):
    from repro.pipelines.rcp.app import Layout, RCPApp
    from repro.pipelines.rcp.data import make_scene
    from repro.runtime import RandomScheduler, set_straggler
    lay = Layout(*layout, replication=replication)
    kw = {"net": net} if net is not None else {}
    app = RCPApp([make_scene(s, n_frames) for s in scenes], lay,
                 grouped=grouped,
                 scheduler=scheduler if scheduler is not None
                 else (None if grouped else RandomScheduler(seed)),
                 caching=caching, seed=seed, placement=placement,
                 read_replicas=read_replicas, migrate_every=migrate_every,
                 **kw)
    if straggler is not None:                  # (node, speed), e.g. ("pred0", 0.3)
        set_straggler(app.rt, *straggler)
    app.stream()
    t0 = time.perf_counter()
    app.run()
    wall = time.perf_counter() - t0
    s = app.summary(warmup=min(100, n_frames // 3))
    s["sim_wall_s"] = wall
    return s


def emit(rows):
    """Print ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        d = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{us:.1f},{d}")


def write_bench_json(suite: str, rows, wall_s: float) -> Path:
    """Write ``BENCH_<suite>.json`` — the machine-readable benchmark record.

    One file per suite under ``benchmarks/artifacts/`` (uploaded as a CI
    artifact) so the perf trajectory — p50/p99/SLO-hit/wall-clock per
    config — is diffable across PRs instead of living in CI logs.

    ``BENCH_fig7.json`` / ``BENCH_fig8.json`` are golden-file style: the
    committed copies are the current PR's reference numbers and each perf
    PR refreshes them (that IS the trajectory record); a local run
    rewriting them is expected — commit the refresh or discard it, like
    any golden file.  Every other suite's record is gitignored.
    """
    import json
    payload = {
        "suite": suite,
        "wall_s": round(wall_s, 3),
        "rows": [{"name": name, "us_per_call": round(us, 1), **derived}
                 for name, us, derived in rows],
    }
    path = ARTIFACTS / f"BENCH_{suite}.json"
    path.write_text(json.dumps(payload, indent=1, sort_keys=True))
    return path


def load_bench_json(suite: str):
    """The previously recorded ``BENCH_<suite>.json`` payload, or None.

    Read BEFORE a fresh run overwrites the file — for the versioned
    suites the committed copy is the cross-PR reference the regression
    deltas compare against.
    """
    import json
    path = ARTIFACTS / f"BENCH_{suite}.json"
    if not path.exists():
        return None
    try:
        return json.loads(path.read_text())
    except ValueError:
        return None


# metric -> warn threshold (relative).  All are lower-is-better; wall
# clocks get a loose bound because they measure the host, not the code.
DELTA_METRICS = {"p50_ms": 0.05, "p99_ms": 0.05, "slo_miss": 0.0,
                 "wall_s": 0.5}

# host-measured metrics: advisory even under --strict (they time the CI
# runner, not the code — a noisy neighbor must not fail the build)
WALL_METRICS = {"wall_s", "sim_wall_s"}

# suite-specific thresholds layered on the defaults: fig11's chaos
# counters are hard floors — a single lost instance, or late completions
# creeping past 10%, is a fault-tolerance regression worth a warn line.
# fig12's recovery-correctness counters are the same: one lost session,
# one duplicate group effect, or one shed turn is a failover regression
SUITE_DELTA_METRICS = {
    "fig11": {**DELTA_METRICS, "lost": 0.0, "late_completions": 0.10},
    "fig12": {**DELTA_METRICS, "lost_sessions": 0.0, "dup_effects": 0.0,
              "shed_turns": 0.0, "order_violations": 0.0},
    # fig13's correlated-failure counters are hard floors too: one lost
    # instance under a zone kill or cut, or one duplicate effect past
    # the split-brain fence, is a survival regression
    "fig13": {**DELTA_METRICS, "lost_instances": 0.0, "dup_effects": 0.0,
              "order_violations": 0.0, "fence_rejected": 0.0},
    # fig14's cold-ladder counters are hard floors: a lost instance, a
    # stale prefetch install serving a read, or a cold scatter run where
    # prefetch never serves anything (no_prefetch_hits flips to 1) is a
    # prefetch-correctness regression
    "fig14": {**DELTA_METRICS, "lost": 0.0, "prefetch_stale": 0.0,
              "no_prefetch_hits": 0.0},
}


def bench_regressions(suite: str, prior, rows, metrics=None):
    """Structured regression records of a fresh run vs the prior record.

    Returns ``(regressions, compared)`` where each regression is a dict
    with the suite/row/metric, old and new values, the relative change,
    and ``wall`` (True for host-clock metrics, which stay advisory even
    under ``--strict``).  Every metric in the suite's threshold table is
    lower-is-better.
    """
    if not prior:
        return [], 0
    thresholds = metrics or SUITE_DELTA_METRICS.get(suite, DELTA_METRICS)
    old = {r["name"]: r for r in prior.get("rows", ())}
    regs = []
    compared = 0
    for name, _, derived in rows:
        ref = old.get(name)
        if ref is None:
            continue
        for metric, rel in thresholds.items():
            a, b = ref.get(metric), derived.get(metric)
            if not (isinstance(a, (int, float)) and
                    isinstance(b, (int, float))) or \
                    isinstance(a, bool) or isinstance(b, bool):
                continue
            compared += 1
            floor = abs(a) * rel + 1e-9
            if b > a + floor:
                regs.append({
                    "suite": suite, "name": name, "metric": metric,
                    "old": a, "new": b,
                    "pct": (b - a) / a * 100 if a else float("inf"),
                    "wall": metric in WALL_METRICS,
                })
    return regs, compared


def bench_deltas(suite: str, prior, rows, metrics=None):
    """Per-metric regression lines of a fresh run vs the prior record.

    Returns human-readable strings (``<suite> <row> <metric> a -> b
    (+x%)``) for every matched row whose metric regressed past its
    threshold, plus a one-line summary.  Purely advisory: the caller
    prints them (warn-only in CI) so the committed BENCH files become an
    actual perf trajectory instead of a write-only artifact.
    ``run.py --strict`` escalates the non-wall ones to failures.
    """
    regs, compared = bench_regressions(suite, prior, rows, metrics)
    out = [f"{r['suite']} {r['name']} {r['metric']} {r['old']} -> "
           f"{r['new']} (+{r['pct']:.1f}%)" for r in regs]
    if compared:
        out.append(f"{suite}: {compared} metric(s) compared vs prior "
                   f"record, {len(out)} regressed")
    return out


def write_chrome_trace(tracer, name: str):
    """Export a recorder's retained traces as ``trace_<name>.json`` in
    the artifacts dir (Perfetto-loadable; CI uploads these)."""
    path = ARTIFACTS / f"trace_{name}.json"
    payload = tracer.export_chrome_trace(path=str(path))
    return path, payload
