"""Kernel-fused HBM-traffic model for the roofline memory term.

XLA:CPU's ``bytes accessed`` counts every unfused elementwise op as an HBM
round trip, overstating TPU traffic by 1-2 orders of magnitude (on TPU the
XLA fusion pass + our Pallas kernels keep chains in VMEM/registers — e.g.
flash attention never materializes the S x S score tensor).  This module
gives the memory term a TPU-realistic estimate from first principles; the
measured XLA number is reported alongside as ``bytes_xla_unfused``.

Model assumptions (documented per term):
  * flash attention: q/k/v read + o write only (fwd), x3 for train
    (fwd + remat-fwd + bwd);
  * weights: read once per pass (FSDP all-gathers materialize the gathered
    tensor once per pass — traffic == gathered size);
  * optimizer: read m,v + write m,v,p on the LOCAL (FSDP) shard;
  * activations: ACT_RW r/w-equivalents of the (T_local, d) residual stream
    per layer per pass — covers norms/gates/residuals after fusion;
  * MoE: dispatched-token tensors ~ topk*cf oversampled copies of the
    stream + touched expert weights (decode touches min(E, B*topk) experts
    — the MoE-decode wall);
  * decode: full KV (or latent/SSM state) read per step, sharded over
    'model' when the layout shards it.
"""
from __future__ import annotations

from typing import Dict

from repro.models.common import ModelConfig

ACT_RW = 10        # residual-stream r/w equivalents per layer per fwd pass
BF16 = 2


def tpu_memory_model(cfg: ModelConfig, shape, *, dp: int = 16, tp: int = 16,
                     fsdp: bool = None) -> Dict[str, float]:
    if fsdp is None:
        fsdp = cfg.param_count() >= 8e9
    B, S = shape.global_batch, shape.seq_len
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    P_total = cfg.param_count()
    kind = shape.kind

    n_dev = dp * tp
    # tokens per device (batch shards over dp when divisible)
    dp_eff = dp if B % dp == 0 else 1
    T_loc = (B // dp_eff) * (S if kind != "decode" else 1)
    opt_bytes = 2 if str(cfg.opt_state_dtype).endswith("bfloat16") else 4

    w_read = P_total * BF16 / tp                 # gathered weights, per pass
    p_local = P_total * BF16 / (tp * (dp if fsdp else 1))

    terms: Dict[str, float] = {}

    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.block_kind(i) == "attn")
    win = cfg.attn_window or S

    if kind == "train":
        passes = 3                                # fwd + remat-fwd + bwd
        terms["weights"] = passes * w_read
        terms["grads_opt"] = p_local * (1 + 1) + \
            (P_total / (tp * (dp if fsdp else 1))) * opt_bytes * 4 + p_local
        terms["activations"] = passes * ACT_RW * T_loc * d * BF16 * L
        terms["attention_io"] = passes * n_attn * T_loc * (
            2 * H * Dh + 2 * K * Dh) * BF16
        terms["logits"] = 2 * T_loc * (V / tp) * BF16 * 2
    elif kind == "prefill":
        terms["weights"] = w_read
        terms["activations"] = ACT_RW * T_loc * d * BF16 * L
        terms["attention_io"] = n_attn * T_loc * (2 * H * Dh
                                                  + 2 * K * Dh) * BF16
        terms["kv_write"] = n_attn * T_loc * 2 * K * Dh * BF16 / \
            (tp if (K % tp == 0 or True) else 1)
        terms["logits"] = T_loc * (V / tp) * BF16
    else:                                         # decode
        if cfg.family == "moe":
            touched = min(cfg.n_experts, B * cfg.moe_top_k)
            e_params = (cfg.n_layers * cfg.n_experts
                        * cfg.mlp_params(cfg.moe_d_ff))
            dense = P_total - e_params
            terms["weights"] = (dense * BF16 / tp
                                + e_params * BF16 / tp
                                * touched / cfg.n_experts)
        else:
            terms["weights"] = w_read
        # per-step KV / state read, sharded over tp when the layout can
        if cfg.mla:
            kv = L * B * S * (cfg.kv_lora_rank + cfg.qk_rope_dim) * BF16
            kv /= tp                              # seq-sharded latent
        elif cfg.family == "ssm":
            _, di = d, cfg.ssm_expand * d
            Hs = di // cfg.ssm_head_dim
            kv = L * B * (Hs * cfg.ssm_head_dim * cfg.ssm_state * 4
                          + 3 * di * BF16)
            kv /= tp if Hs % tp == 0 else 1
        else:
            eff = min(S, win)
            kv = n_attn * B * eff * 2 * K * Dh * BF16
            kv /= tp                              # kv-head or seq sharded
            if cfg.block_pattern:                 # hybrid: + LRU states
                n_rec = L - n_attn
                kv += n_rec * B * cfg.lru_width * BF16 / tp
        terms["kv_state"] = kv / dp_eff
        terms["activations"] = ACT_RW * T_loc * d * BF16 * L
        terms["logits"] = T_loc * (V / tp) * BF16

    if cfg.family == "moe" and kind != "decode":
        passes = 3 if kind == "train" else 1
        over = cfg.moe_top_k * cfg.moe_capacity_factor
        terms["moe_dispatch"] = passes * 3 * T_loc * over * d * BF16

    terms["total"] = sum(terms.values())
    return terms
