"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + 1 shared expert,
early fusion. [hf:meta-llama/Llama-4-Scout-17B-16E]

Optimizer states are kept in bf16 so (params + AdamW m/v) fit a 16 GB/chip
single-pod mesh (see EXPERIMENTS.md §Dry-run).
"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    mlp_variant="swiglu",
    n_experts=128,
    n_shared_experts=1,
    moe_top_k=1,
    moe_d_ff=8192,
    moe_chunk=4096,
    rope_theta=500000.0,
    opt_state_dtype=jnp.bfloat16,
    # params(bf16)+m(bf16)+v(full) = 18 GB/chip on the single pod —
    # factoring the 2nd moment brings the train state under the 16 GB HBM
    # budget (see EXPERIMENTS.md §Dry-run).
    opt_factored=True,
)

SMOKE = ModelConfig(
    name="llama4-maverick-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    mlp_variant="swiglu",
    n_experts=8,
    n_shared_experts=1,
    moe_top_k=1,
    moe_d_ff=64,
    moe_chunk=64,
)
