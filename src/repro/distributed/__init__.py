from .sharding_rules import (ShardingRules, default_rules, specs_for_params,
                             batch_pspec, cache_pspecs)

__all__ = ["ShardingRules", "default_rules", "specs_for_params",
           "batch_pspec", "cache_pspecs"]
