"""Multi-head Latent Attention (DeepSeek-V2) with the compressed-KV cache.

Decode uses the *absorbed* formulation: the per-head key up-projection is
folded into the query, so attention runs directly against the (kv_lora_rank +
rope_dim)-wide latent cache — this is what makes MLA's decode cache ~an order
of magnitude smaller than GQA's and is the reason dsv2 is a serving-friendly
arch.  Prefill/train use the expanded (materialized K/V) form + flash mha.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .common import ModelConfig, ParamFactory, scaled_init
from . import layers

Params = Dict[str, Any]


def init_mla(pf: ParamFactory, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    qlr, kvlr = cfg.q_lora_rank, cfg.kv_lora_rank
    qk_n, qk_r, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    layers.init_rmsnorm(pf, "ln", d)
    pf.param("wq_a", (d, qlr), ("embed", "q_lora"), fan_in=d)
    layers.init_rmsnorm(pf, "q_norm", qlr)
    pf.param("wq_b", (qlr, H, qk_n + qk_r), ("q_lora", "heads", "head_dim"),
             fan_in=qlr)
    pf.param("wkv_a", (d, kvlr + qk_r), ("embed", "kv_lora"), fan_in=d)
    layers.init_rmsnorm(pf, "kv_norm", kvlr)
    pf.param("wk_nope", (kvlr, H, qk_n), ("kv_lora", "heads", "head_dim"),
             fan_in=kvlr)
    pf.param("wv", (kvlr, H, vd), ("kv_lora", "heads", "head_dim"), fan_in=kvlr)
    pf.param("wo", (H, vd, d), ("heads", "head_dim", "embed"), fan_in=H * vd)


def _project_q(p: Params, cfg: ModelConfig, h: jax.Array, positions: jax.Array):
    cd = cfg.compute_dtype
    cq = layers.rmsnorm(p["q_norm"], h @ p["wq_a"].astype(cd), cfg.norm_eps)
    q = jnp.einsum("bsl,lhk->bshk", cq, p["wq_b"].astype(cd))
    q_nope = q[..., :cfg.qk_nope_dim]
    q_rope = layers.rope(q[..., cfg.qk_nope_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(p: Params, cfg: ModelConfig, h: jax.Array,
                       positions: jax.Array):
    cd = cfg.compute_dtype
    ckv_full = h @ p["wkv_a"].astype(cd)
    ckv = layers.rmsnorm(p["kv_norm"], ckv_full[..., :cfg.kv_lora_rank],
                         cfg.norm_eps)
    k_rope = ckv_full[..., cfg.kv_lora_rank:][:, :, None, :]  # (B,S,1,rope)
    k_rope = layers.rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return ckv, k_rope                                         # (B,S,kvlr),(B,S,r)


def _expanded_attention(p: Params, cfg: ModelConfig, q_nope, q_rope, ckv,
                        k_rope, window: int = 0):
    cd = cfg.compute_dtype
    H = cfg.n_heads
    k_nope = jnp.einsum("bsl,lhk->bshk", ckv, p["wk_nope"].astype(cd))
    v = jnp.einsum("bsl,lhk->bshk", ckv, p["wv"].astype(cd))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  k_nope.shape[:3] + (cfg.qk_rope_dim,))],
        axis=-1)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    o = ops.mha(q, k, v, causal=True, scale=scale, window=window,
                q_chunk=cfg.attn_chunk, unroll=cfg.unroll_inner)
    return jnp.einsum("bshv,hvd->bsd", o, p["wo"].astype(cd))


def mla_train(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    B, S, _ = x.shape
    h = layers.rmsnorm(p["ln"], x, cfg.norm_eps)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q_nope, q_rope = _project_q(p, cfg, h, pos)
    ckv, k_rope = _project_kv_latent(p, cfg, h, pos)
    return x + _expanded_attention(p, cfg, q_nope, q_rope, ckv, k_rope)


def mla_prefill(p: Params, cfg: ModelConfig, x: jax.Array
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, _ = x.shape
    h = layers.rmsnorm(p["ln"], x, cfg.norm_eps)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q_nope, q_rope = _project_q(p, cfg, h, pos)
    ckv, k_rope = _project_kv_latent(p, cfg, h, pos)
    out = x + _expanded_attention(p, cfg, q_nope, q_rope, ckv, k_rope)
    return out, {"ckv": ckv, "k_rope": k_rope}


def mla_decode(p: Params, cfg: ModelConfig, x: jax.Array,
               cache: Dict[str, jax.Array], lengths: jax.Array
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Absorbed-matmul MLA decode against the latent cache.

    x: (B, d); cache ckv (B, Smax, kvlr), k_rope (B, Smax, rope).
    """
    B, _ = x.shape
    cd = cfg.compute_dtype
    h = layers.rmsnorm(p["ln"], x[:, None, :], cfg.norm_eps)
    pos = lengths[:, None]
    q_nope, q_rope = _project_q(p, cfg, h, pos)                # (B,1,H,*)
    ckv_new, k_rope_new = _project_kv_latent(p, cfg, h, pos)   # (B,1,*)
    bidx = jnp.arange(B)
    ckv_c = cache["ckv"].at[bidx, lengths].set(
        ckv_new[:, 0].astype(cache["ckv"].dtype))
    kr_c = cache["k_rope"].at[bidx, lengths].set(
        k_rope_new[:, 0].astype(cache["k_rope"].dtype))

    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    # absorb wk_nope into q: (B,H,nope) @ (kvlr,H,nope) -> (B,H,kvlr)
    q_lat = jnp.einsum("bhn,lhn->bhl", q_nope[:, 0], p["wk_nope"].astype(cd))
    logits = (jnp.einsum("bhl,btl->bht", q_lat.astype(jnp.float32),
                         ckv_c.astype(jnp.float32))
              + jnp.einsum("bhr,btr->bht", q_rope[:, 0].astype(jnp.float32),
                           kr_c.astype(jnp.float32))) * scale
    Smax = ckv_c.shape[1]
    mask = jnp.arange(Smax)[None] < (lengths + 1)[:, None]
    logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bht,btl->bhl", probs,
                       ckv_c.astype(jnp.float32)).astype(cd)
    o = jnp.einsum("bhl,lhv->bhv", o_lat, p["wv"].astype(cd))
    out = jnp.einsum("bhv,hvd->bd", o, p["wo"].astype(cd))
    return x + out, {"ckv": ckv_c, "k_rope": kr_c}


def mla_cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    return {
        "ckv": jax.ShapeDtypeStruct((batch, max_seq, cfg.kv_lora_rank),
                                    cfg.compute_dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, max_seq, cfg.qk_rope_dim),
                                       cfg.compute_dtype),
    }
