"""Affinity-driven prefetch + speculative stage warm-up (paper §3.4).

Covers the engine's global deterministic byte budget (the per-shard
``break`` bug regression), version-checked installs under migration and
gang-repair re-pins, the DES prefetch channel (bounded inflight bytes,
queue + promotion, demand-get join), speculative fan-in accounting, the
armed-but-all-local identity, and the ``prefetch`` blame category's
round-trip through ``BlameTable.flat()`` and ``scripts/bench_explain``.

The hypothesis accounting-transparency property is marked slow and runs
in the dedicated CI slow job; everything else is tier-1.
"""
import importlib.util
import math
from pathlib import Path

import pytest

from repro.core import (CascadeStore, GroupMigrator, PrefetchEngine,
                        workflow_key)
from repro.runtime import replace_gang_pins
from repro.workflows import (BlameTable, WorkflowRuntime, agent_workflow,
                             decompose, mode_kwargs, preload_adapters)


def make_store(n_nodes=8, n_shards=8):
    store = CascadeStore([f"n{i}" for i in range(n_nodes)])
    store.create_object_pool("/p", store.nodes, n_shards,
                             affinity_set_regex=r"/[a-z0-9]+_[0-9]+_")
    return store


def remote_node(store, *keys):
    """A node that is home to none of ``keys``."""
    homes = {n for k in keys for n in store.shard_of(k).nodes}
    return next(n for n in store.nodes if n not in homes)


def agent_run(mode, n=12, shards=4, n_adapters=2, slab=4 << 20,
              ia_ms=12.5, caching=True, tracing=False, **kw):
    wrt = WorkflowRuntime(agent_workflow(shards=shards,
                                         n_adapters=n_adapters),
                          caching=caching, tracing=tracing,
                          **mode_kwargs(mode), **kw)
    t = 0.0
    for i in range(n):
        inst = f"a{i}"
        wrt.submit(inst, at=t)
        preload_adapters(wrt, inst, at=t, n_parts=n_adapters,
                         slab_bytes=slab)
        t += ia_ms / 1e3
    wrt.run()
    return wrt


# -- engine: global deterministic byte budget ---------------------------------


def test_budget_cap_is_global_and_deterministic():
    """Regression for the per-shard ``break``: the byte cap applies to
    the whole plan in sorted-key order, so a large object early in one
    shard skips (counted) without shadowing smaller objects that sort
    after it in *any* shard."""
    store = make_store()
    sizes = {"a": 250, "b": 250, "c": 40, "d": 30}
    for suffix, size in sizes.items():
        store.put(f"/p/g_1_{suffix}", b"x" * size)
    node = remote_node(store, *(f"/p/g_1_{s}" for s in sizes))
    eng = PrefetchEngine(store, max_bytes_per_plan=300)
    plan = eng.plan_for_task("/p", "/g_1_", node)
    # greedy over sorted keys: a(250) in, b(250) over, c(40) in -> 290,
    # d(30) over.  The old code's break inside one shard's loop made the
    # outcome depend on shard iteration order.
    assert plan.keys == ["/p/g_1_a", "/p/g_1_c"]
    assert plan.total_bytes == 290
    assert eng.skipped_over_budget == 2
    assert eng.issued == 1 and eng.bytes_issued == 290
    # deterministic: replanning yields the identical shipment
    again = PrefetchEngine(store, max_bytes_per_plan=300)
    assert again.plan_for_task("/p", "/g_1_", node).keys == plan.keys


def test_plan_for_keys_order_dedup_and_filters():
    store = make_store()
    for s in ("a", "b"):
        store.put(f"/p/g_1_{s}", b"x" * 10)
    node = remote_node(store, "/p/g_1_a", "/p/g_1_b")
    local = store.shard_of("/p/g_1_a").nodes[0]
    eng = PrefetchEngine(store)
    plan = eng.plan_for_keys(["/p/g_1_b", "/p/g_1_a", "/p/g_1_b",
                              "/p/missing_9_x"], node)
    assert plan.keys == ["/p/g_1_b", "/p/g_1_a"]   # caller order, deduped
    # node-local and already-validly-cached keys are not candidates
    assert eng.plan_for_keys(["/p/g_1_a"], local) is None
    store.prefetch_install(node, "/p/g_1_a")
    assert eng.plan_for_keys(["/p/g_1_a"], node) is None


# -- store: version-checked installs, marks, hits -----------------------------


def test_prefetch_install_versions_marks_and_hits():
    store = make_store()
    store.put("/p/g_1_a", b"v1" * 5)
    node = remote_node(store, "/p/g_1_a")
    rec = store.shard_of("/p/g_1_a").objects["/p/g_1_a"]
    assert store.prefetch_install(node, "/p/g_1_a", rec.version) == 10
    assert store.prefetch_marks[node]["/p/g_1_a"] == rec.version
    assert store.stats.prefetch_installs == 1
    assert store.stats.bytes_prefetched == 10
    # a served read from the warmed cache counts a prefetch hit
    hits0 = store.stats.prefetch_hits
    got, _ = store.get("/p/g_1_a", node=node)
    assert got.value == b"v1" * 5
    assert store.stats.prefetch_hits == hits0 + 1
    # home-node installs are no-ops
    home = store.shard_of("/p/g_1_a").nodes[0]
    assert store.prefetch_install(home, "/p/g_1_a") == 0
    # a write between plan and install makes the transfer a counted no-op
    store.put("/p/g_1_a", b"v2" * 5)
    assert store.prefetch_install(node, "/p/g_1_a", rec.version) == 0
    assert store.stats.prefetch_stale == 1
    # the stale cached copy must not serve: demand refill drops the mark
    got, _ = store.get("/p/g_1_a", node=node)
    assert got.value == b"v2" * 5
    assert "/p/g_1_a" not in store.prefetch_marks[node]


def test_prefetch_install_blocked_across_partition():
    store = make_store()
    store.put("/p/g_1_a", b"x" * 10)
    node = remote_node(store, "/p/g_1_a")
    store.partition = {node: 1}            # node alone on the minority side
    assert store.prefetch_install(node, "/p/g_1_a") == 0
    assert store.stats.prefetch_stale == 1
    store.partition = None
    assert store.prefetch_install(node, "/p/g_1_a") == 10


def test_candidate_skipped_across_partition():
    store = make_store()
    store.put("/p/g_1_a", b"x" * 10)
    node = remote_node(store, "/p/g_1_a")
    store.partition = {node: 1}
    assert PrefetchEngine(store).plan_for_keys(["/p/g_1_a"], node) is None
    store.partition = None
    assert PrefetchEngine(store).plan_for_keys(
        ["/p/g_1_a"], node).keys == ["/p/g_1_a"]


# -- invalidation under migration and gang repair -----------------------------


def test_migration_invalidates_prefetched_entries():
    """A prefetched entry on a node the group migrates away from must
    not serve: the move drops the mark + cache, and an install planned
    before the move is version-rejected after it."""
    store = make_store()
    for f in range(3):
        store.put(f"/p/vid_1_{f}", b"x" * 50)
    node = remote_node(store, *(f"/p/vid_1_{f}" for f in range(3)))
    plan = PrefetchEngine(store).plan_for_keys(
        [f"/p/vid_1_{f}" for f in range(3)], node)
    store.prefetch_install(node, plan.keys[0], plan.versions[0])
    assert plan.keys[0] in store.prefetch_marks[node]

    pool = store.pools["/p"]
    home = store.shard_of("/p/vid_1_0").name
    target = next(s for s, sh in pool.shards.items()
                  if s != home and node not in sh.nodes)
    GroupMigrator(store).migrate("/p", "/vid_1_", to_shard=target)
    # installed entry: invalidated (mark and cache both gone)
    assert plan.keys[0] not in store.prefetch_marks[node]
    assert plan.keys[0] not in store.caches[node]
    # in-flight entry: the move bumped versions, install is a no-op
    stale0 = store.stats.prefetch_stale
    assert store.prefetch_install(node, plan.keys[1],
                                  plan.versions[1]) == 0
    assert store.stats.prefetch_stale == stale0 + 1
    # reads see the post-move record, never a stale prefetch
    got, _ = store.get(plan.keys[0], node=node)
    assert got.value == b"x" * 50


def test_gang_repin_replay_rejects_stale_install():
    """Gang repair: after ``replace_gang_pins`` + replayed writes land
    the group on a new slot (bumped versions), an install stamped from
    the pre-repair plan is rejected and reads serve the new version."""
    store = make_store()
    store.pools["/p"].engine.pin("/g_1_", store.shard_of("/p/g_1_a").name)
    store.put("/p/g_1_a", b"old")
    node = remote_node(store, "/p/g_1_a")
    plan = PrefetchEngine(store).plan_for_keys(["/p/g_1_a"], node)

    old_slot = store.shard_of("/p/g_1_a").name
    survivors = [s for s in store.pools["/p"].shards if s != old_slot]
    placed = replace_gang_pins(store, ["/p"], ["/g_1_"], survivors)
    assert placed["/g_1_"] is not None
    store.put("/p/g_1_a", b"new")              # replayed write, re-pinned
    assert store.shard_of("/p/g_1_a").name != old_slot

    stale0 = store.stats.prefetch_stale
    assert store.prefetch_install(node, "/p/g_1_a",
                                  plan.versions[0]) == 0
    assert store.stats.prefetch_stale == stale0 + 1
    got, _ = store.get("/p/g_1_a", node=node)
    assert got.value == b"new"


# -- DES channel: bounded inflight, promotion, runtime wiring -----------------


def test_runtime_prefetch_reduces_remote_gets():
    base = agent_run("keyhash").summary()
    pref = agent_run("keyhash+prefetch").summary()
    assert pref["prefetch_hits"] > 0
    assert pref["prefetch_stale"] == 0
    assert pref["remote_gets"] < base["remote_gets"]
    assert pref["n"] == base["n"] == 12


def test_prefetch_channel_bounded_inflight_promotes_on_demand():
    """With the per-node inflight byte cap below one plan's size, later
    entries queue; a demand get for a queued key promotes it instead of
    double-fetching, and the run still completes with hits."""
    wrt = WorkflowRuntime(agent_workflow(shards=4, n_adapters=4),
                          caching=True, **mode_kwargs("keyhash+prefetch"))
    wrt.rt.sim.prefetch_inflight_cap = 16 << 20    # one 16 MB slab at a time
    t = 0.0
    for i in range(8):
        inst = f"a{i}"
        wrt.submit(inst, at=t)
        # 16 MB slabs: ~1.3 ms each, so one instance's 4-deep queue is
        # still draining when the next instance's act legs land on the
        # same node and demand keys that are still queued
        preload_adapters(wrt, inst, at=t, n_parts=4, slab_bytes=16 << 20)
        t += 0.002
    wrt.run()
    s = wrt.summary()
    assert s["n"] == 8
    assert s["prefetch_hits"] > 0
    assert s["prefetch_promotions"] > 0
    assert s["prefetch_stale"] == 0


def test_speculative_budget_bounds_waste():
    cap = 8 << 20
    spec = agent_run("keyhash+spec", speculative_budget=cap).summary()
    assert spec["wasted_speculative_bytes"] <= cap
    # a zero budget disables staging entirely without breaking the run
    off = agent_run("keyhash+spec", speculative_budget=0).summary()
    assert off["wasted_speculative_bytes"] == 0
    assert off["n"] == 12


def test_armed_all_local_is_byte_identical():
    """Gang-pinned placement lands every adapter on the pinned slot, so
    the armed engine finds nothing to ship and must not perturb a single
    latency."""
    def lats(mode):
        wrt = agent_run(mode)
        return [wrt.tracker.records[f"a{i}"].latency for i in range(12)]
    assert lats("atomic+spec") == lats("atomic")
    armed = agent_run("atomic+spec").summary()
    assert armed["prefetch_issued"] == 0
    assert armed["wasted_speculative_bytes"] == 0


# -- blame: the prefetch category round-trip ----------------------------------


def _explain_mod():
    path = Path(__file__).resolve().parents[1] / "scripts" / "bench_explain.py"
    spec = importlib.util.spec_from_file_location("bench_explain", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_blame_prefetch_category_roundtrip():
    """Slabs sized past plan's compute put demand gets mid-transfer, so
    joined waits land in the ``prefetch`` category; the category
    round-trips through decompose -> BlameTable.flat -> bench_explain's
    record differ with network visibly reduced."""
    demand = agent_run("keyhash", n=6, slab=48 << 20, tracing=True)
    pref = agent_run("keyhash+prefetch", n=6, slab=48 << 20, tracing=True)

    def flat(wrt):
        bt = BlameTable()
        for tr in wrt.tracer.traces():
            assert abs(sum(decompose(tr).values()) - tr.e2e) < 1e-6
            bt.add(tr)
        return bt.flat()
    fd, fp = flat(demand), flat(pref)
    assert fp["blame_prefetch_ms"] > 0.0
    assert fd["blame_prefetch_ms"] == 0.0
    assert fp["blame_network_ms"] < fd["blame_network_ms"]

    mod = _explain_mod()
    row_a = {"name": "fig14/demand", "p99_ms": 30.0,
             **{k: round(v, 3) for k, v in fd.items()
                if k.endswith("_ms") and isinstance(v, float)}}
    row_b = {"name": "fig14/prefetch", "p99_ms": 28.0,
             **{k: round(v, 3) for k, v in fp.items()
                if k.endswith("_ms") and isinstance(v, float)}}
    assert mod.blame_of(row_b)["prefetch"] > 0.0
    lines = mod.explain(row_a, row_b, "demand", "prefetch")
    text = "\n".join(lines)
    assert "| prefetch |" in text
    assert "Dominant mover" in text


# -- hypothesis: accounting transparency (slow job) ---------------------------


try:                      # optional test dep — the CI slow job installs it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover — tier-1 keeps the rest
    HAVE_HYPOTHESIS = False


def _transparency_case(shards, n_tools, n_adapters, slab, n):
    """Arming prefetch changes *when* bytes move, never *what* runs: the
    per-instance arrival/fired/done counts and input sets are identical
    to the unprefetched run; with no fan-out contention (one tool call,
    serial instances — the network-bound regime) e2e is never worse."""
    def run(mode):
        wrt = WorkflowRuntime(agent_workflow(shards=shards,
                                             n_tools=n_tools,
                                             n_adapters=n_adapters),
                              caching=True, **mode_kwargs(mode))
        t = 0.0
        for i in range(n):
            inst = f"a{i}"
            wrt.submit(inst, at=t)
            preload_adapters(wrt, inst, at=t, n_parts=n_adapters,
                             slab_bytes=slab)
            t += 0.05                      # serial: no cross-instance load
        wrt.run()
        return wrt

    base, pref = run("keyhash"), run("keyhash+prefetch")
    assert pref.summary()["prefetch_stale"] == 0
    for i in range(n):
        rb = base.tracker.records[f"a{i}"]
        rp = pref.tracker.records[f"a{i}"]
        assert dict(rb.arrivals) == dict(rp.arrivals)
        assert dict(rb.fired) == dict(rp.fired)
        assert dict(rb.done) == dict(rp.done)
        assert {s: sorted(ks) for s, ks in rb.inputs.items()} == \
            {s: sorted(ks) for s, ks in rp.inputs.items()}
        assert rb.latency is not None and rp.latency is not None
        if n_tools == 1:
            assert rp.latency <= rb.latency + 1e-9


if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=25, deadline=None)
    @given(shards=st.integers(2, 6), n_tools=st.integers(1, 4),
           n_adapters=st.integers(1, 4),
           slab=st.sampled_from([256 << 10, 2 << 20, 8 << 20]),
           n=st.integers(2, 5))
    def test_prefetch_is_accounting_transparent(shards, n_tools,
                                                n_adapters, slab, n):
        _transparency_case(shards, n_tools, n_adapters, slab, n)
else:                                          # pragma: no cover
    @pytest.mark.slow
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_prefetch_is_accounting_transparent():
        pass


def test_transparency_fixed_point():
    """One deterministic exemplar of the property, tier-1 (the
    hypothesis sweep above is the slow-job generalization)."""
    _transparency_case(shards=4, n_tools=1, n_adapters=2,
                       slab=2 << 20, n=3)
