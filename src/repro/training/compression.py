"""Gradient compression for cross-pod data parallelism.

The 'pod' mesh axis crosses DCN (slow) while 'data'/'model' stay on ICI, so
the cross-pod gradient all-reduce is the step's slowest collective.  We
compress it: int8 quantization with per-tensor scales (8x fewer DCN bytes
than fp32 / 2x vs bf16) plus *error feedback* (the quantization residual is
carried into the next step), which keeps SGD/Adam convergence intact in
practice (1-bit Adam lineage).

``compressed_pod_psum`` runs inside the jitted train step via shard_map
over the 'pod' axis: quantize -> psum(int32) -> dequantize.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def quantization_error(x: jax.Array) -> jax.Array:
    q, s = quantize_int8(x)
    return x.astype(jnp.float32) - dequantize_int8(q, s)


def compressed_pod_psum(grads: Any, mesh: Mesh, in_specs: Any,
                        error: Optional[Any] = None) -> Tuple[Any, Any]:
    """All-reduce grads across the 'pod' axis with int8 payloads.

    grads: pytree already reduced within each pod (ICI), sharded per
    `in_specs`.  Returns (reduced grads, new error-feedback state).
    """
    assert "pod" in mesh.axis_names

    def leaf_fn(g, e):
        gf = g.astype(jnp.float32)
        if e is not None:
            gf = gf + e
        q, scale = quantize_int8(gf)
        # sum int8 payloads in int32, and scales in fp32
        qsum = jax.lax.psum(q.astype(jnp.int32), "pod")
        # per-pod scales differ: send scale alongside (scalar, negligible)
        ssum = jax.lax.psum(scale, "pod") / mesh.shape["pod"]
        out = qsum.astype(jnp.float32) * ssum / mesh.shape["pod"]
        new_e = gf - dequantize_int8(q, scale)      # local residual
        return out.astype(g.dtype), new_e

    def wrapped(g_tree, e_tree):
        return jax.tree_util.tree_map(leaf_fn, g_tree, e_tree)

    if error is None:
        error = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    specs = jax.tree_util.tree_map(
        lambda s: s, in_specs, is_leaf=lambda x: isinstance(x, P))
    fn = jax.shard_map(
        wrapped, mesh=mesh,
        in_specs=(specs, specs), out_specs=(specs, specs),
        check_vma=False)
    return fn(grads, error)


def dcn_bytes_saved(grads: Any) -> Tuple[int, int]:
    """(bytes fp32 all-reduce, bytes int8 all-reduce) for reporting."""
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(grads))
    return 4 * n, 1 * n + 4 * len(jax.tree_util.tree_leaves(grads))
