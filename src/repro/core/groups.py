"""Affinity-group registry + migration planning for elastic scaling.

Scaling out adds shards; with rendezvous placement only ~1/n of groups move.
The registry tracks live groups (labels seen recently) so the autoscaler can
produce a migration plan (which groups move where, how many bytes) and the
runtime can execute it without a global pause.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .object_store import CascadeStore
from .placement import PlacementEngine, RendezvousPlacement


@dataclasses.dataclass
class GroupInfo:
    label: str
    pool: str
    n_objects: int = 0
    bytes: int = 0
    last_seen: float = 0.0


@dataclasses.dataclass
class MigrationPlan:
    moves: List[Tuple[str, str, str, int]]   # (label, from_shard, to_shard, bytes)
    total_bytes: int
    fraction_moved: float


class GroupRegistry:
    def __init__(self, store: CascadeStore):
        self.store = store

    def snapshot(self, pool_prefix: str) -> Dict[str, GroupInfo]:
        pool = self.store.pools[pool_prefix]
        groups: Dict[str, GroupInfo] = {}
        seen = set()
        for shard in pool.shards.values():
            for key, rec in shard.objects.items():
                if key in seen:          # replicas count once
                    continue
                seen.add(key)
                g = groups.setdefault(
                    rec.affinity,
                    GroupInfo(label=rec.affinity, pool=pool_prefix))
                g.n_objects += 1
                g.bytes += rec.size
                g.last_seen = time.time()
        return groups

    def plan_resharding(self, pool_prefix: str, new_n_shards: int
                        ) -> MigrationPlan:
        """What moves if the pool is resized to new_n_shards shards."""
        pool = self.store.pools[pool_prefix]
        groups = self.snapshot(pool_prefix)
        old_shards = list(pool.shards)
        new_shards = [f"{pool.prefix}#s{i}" for i in range(new_n_shards)]
        moves = []
        total = 0
        for label, info in groups.items():
            old = pool.engine.policy.place(label, old_shards)
            new = pool.engine.policy.place(label, new_shards)
            if old != new:
                moves.append((label, old, new, info.bytes))
                total += info.bytes
        frac = len(moves) / max(len(groups), 1)
        return MigrationPlan(moves=moves, total_bytes=total,
                             fraction_moved=frac)
