"""Flash attention (causal / local-window / bidirectional) for TPU.

Online-softmax tiling: grid (batch, q_heads, q_blocks, kv_blocks) with the
kv dimension innermost (sequential on TPU), fp32 accumulator + running
max/sum in VMEM scratch.  Block sizes default to (128, 128) — MXU-aligned —
and q/k/v tiles stream HBM->VMEM per BlockSpec.  Irrelevant kv blocks
(beyond the causal frontier or before the local window) are skipped with
``pl.when`` so a local-window pass does O(S*W) work, not O(S^2).

Oracle: ``repro.kernels.ref.mha`` (asserted in tests with interpret=True).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, softcap, q_offset, block_q, block_k,
            nk, kv_len):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_lo = q_offset + iq * block_q
    k_lo = ik * block_k
    relevant = jnp.array(True)
    if causal:
        relevant = relevant & (k_lo <= q_lo + block_q - 1)
    if window and window > 0:
        relevant = relevant & (k_lo + block_k - 1 > q_lo - window)

    @pl.when(relevant)
    def _update():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale     # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)             # (bk, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq,bk)
        if softcap and softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32,
                                               (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                               (block_q, block_k), 1)
        mask = kpos < kv_len
        if causal:
            mask = mask & (kpos <= qpos)
        if window and window > 0:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[:, 0] = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot(p, v))
        m_ref[:, 0] = m_cur

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale: Optional[float] = None, q_offset=0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q (B,S,H,D); k/v (B,T,K,D/Dv) with GQA H = g*K. Returns (B,S,H,Dv)."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = H // K
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, S)
    bk = min(block_k, T)
    nq = -(-S // bq)
    nk = -(-T // bk)
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset, block_q=bq, block_k=bk, nk=nk, kv_len=T)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j: (b, j, h // g, 0)),
            pl.BlockSpec((1, bk, 1, Dv), lambda b, h, i, j: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, Dv), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dv), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
