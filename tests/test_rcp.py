"""RCP application: stage-model correctness + the paper's §4.6 claims."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.pipelines.rcp.app import Layout, RCPApp
from repro.pipelines.rcp.data import P_HIST, Q_PRED, make_scene
from repro.pipelines.rcp import models as rcp_models
from repro.runtime.scheduler import RandomScheduler


# -- stage models -------------------------------------------------------------

def test_pred_shapes(rng):
    params = rcp_models.init_pred(jax.random.PRNGKey(0))
    hist = jnp.asarray(rng.normal(size=(P_HIST, 2)), jnp.float32)
    out = rcp_models.pred_trajectory(params, hist)
    assert out.shape == (Q_PRED, 2)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_cd_detects_crossing():
    # two straight trajectories crossing at the middle
    t = jnp.linspace(0, 1, Q_PRED)
    a = jnp.stack([t, t], axis=1)                      # diagonal up
    b = jnp.stack([t, 1.0 - t], axis=1)                # diagonal down
    c = jnp.stack([t, t + 10.0], axis=1)               # far away
    trajs = jnp.stack([b, c])
    valid = jnp.array([True, True])
    out = rcp_models.cd_collisions(a, trajs, valid)
    assert bool(out[0]) and not bool(out[1])


def test_mot_reidentifies_nearest(rng):
    params = rcp_models.init_mot(jax.random.PRNGKey(0))
    frame = jnp.zeros((64, 64, 3))
    prev = jnp.zeros((64, 2)).at[0].set(jnp.array([0.5, 0.5]))
    prev_valid = jnp.zeros((64,), bool).at[0].set(True)
    det = jnp.zeros((64, 2)).at[0].set(jnp.array([0.51, 0.5]))
    det_valid = jnp.zeros((64,), bool).at[0].set(True)
    ids, feats = rcp_models.mot_detect(params, frame, prev, prev_valid,
                                       det, det_valid)
    assert int(ids[0]) == 0          # matched to previous actor 0
    assert feats.shape[0] == 64


def test_scene_determinism():
    s1, s2 = make_scene("gates3", 100), make_scene("gates3", 100)
    np.testing.assert_array_equal(s1.pos, s2.pos)
    assert s1.actors_in_frame(50) == s2.actors_in_frame(50)


# -- paper claims (§4.6) -----------------------------------------------------

def run_app(grouped, layout=Layout(3, 5, 5), caching=True, n_frames=150,
            scenes=("gates3",), replication=None):
    lay = layout if replication is None else Layout(
        layout.mot, layout.pred, layout.cd, replication)
    app = RCPApp([make_scene(s, n_frames) for s in scenes], lay,
                 grouped=grouped,
                 scheduler=None if grouped else RandomScheduler(0),
                 caching=caching)
    app.stream()
    app.run()
    return app.summary(warmup=40)


def test_affinity_zero_remote_gets():
    s = run_app(grouped=True)
    assert s["remote_gets"] == 0


def test_affinity_beats_random():
    sa = run_app(grouped=True)
    sr = run_app(grouped=False)
    assert sa["median"] <= sr["median"] * 1.05
    assert sa["p95"] <= sr["p95"]
    assert sr["remote_gets"] > 0


def test_no_cache_hurts_random_not_affinity():
    """Paper Fig. 5: disabling caching collapses random placement only."""
    sa_c = run_app(grouped=True, caching=True)
    sa_n = run_app(grouped=True, caching=False)
    sr_c = run_app(grouped=False, caching=True)
    sr_n = run_app(grouped=False, caching=False)
    # affinity: local gets make caching irrelevant (zero-copy claim)
    assert abs(sa_n["median"] - sa_c["median"]) < 0.02
    # random: no cache -> every reuse refetches
    assert sr_n["bytes_remote"] > sr_c["bytes_remote"]
    assert sr_n["median"] >= sr_c["median"]


def test_scale_out_no_remote_growth_under_affinity():
    """Paper: adding shards grows random's misses, never affinity's."""
    small_a = run_app(grouped=True, layout=Layout(1, 3, 3))
    big_a = run_app(grouped=True, layout=Layout(3, 5, 5))
    small_r = run_app(grouped=False, layout=Layout(1, 3, 3))
    big_r = run_app(grouped=False, layout=Layout(3, 5, 5))
    assert small_a["remote_gets"] == big_a["remote_gets"] == 0
    assert big_r["remote_gets"] >= small_r["remote_gets"]


def test_three_clients_affinity_stays_low():
    """Paper Fig. 4: 3 simultaneous clients."""
    sa = run_app(grouped=True, scenes=("little3", "hyang5", "gates3"),
                 n_frames=120)
    sr = run_app(grouped=False, scenes=("little3", "hyang5", "gates3"),
                 n_frames=120)
    assert sa["n"] > 0 and sr["n"] > 0
    assert sa["median"] <= sr["median"] * 1.05
    assert sa["p95"] <= sr["p95"] * 1.05


def test_frames_processed_in_order():
    app = RCPApp([make_scene("little3", 60)], Layout(2, 2, 2), grouped=True)
    app.stream()
    app.run()
    mot_ends = [(r["key"], r["t_end"]) for r in app.rt.task_log
                if r["udl"] == "MOT"]
    frames = [int(k.split("_")[-1]) for k, _ in mot_ends]
    ends = [t for _, t in mot_ends]
    order = np.argsort(ends)
    assert list(np.array(frames)[order]) == sorted(frames), \
        "MOT must process one video's frames sequentially (state dep)"
