"""Discrete-event runtime: queueing, transfers, faults, stragglers, scaling."""
import pytest

from repro.core import CascadeStore
from repro.runtime import (AZURE_NET, CLUSTER_NET, AutoScaler, Compute,
                           FaultInjector, Get, Put, RandomScheduler, Runtime,
                           set_straggler)


def make_rt(n=4, regex=r"/[a-z0-9]+_", scheduler=None, **kw):
    store = CascadeStore([f"n{i}" for i in range(n)])
    store.create_object_pool("/x", store.nodes, n, affinity_set_regex=regex)
    return Runtime(store, scheduler=scheduler, **kw), store


def test_compute_queues_serialize():
    rt, store = make_rt(1)
    done = []

    def task(ctx, key, value):
        yield Compute("gpu", 1.0)
        done.append(ctx.now)

    rt.register("/x", task)
    for i in range(3):
        rt.client_put(0.0, f"/x/a_{i}", size=0)
    rt.run()
    # capacity 1 gpu: tasks serialize at ~1s each
    assert [round(t, 3) for t in done] == [1.0, 2.0, 3.0]


def test_transfer_time_charged_for_remote_get():
    rt, store = make_rt(4)
    times = {}

    def task(ctx, key, value):
        t0 = ctx.now
        yield Get("/x/target_obj")          # may be remote
        times["get"] = ctx.now - t0

    store.put("/x/target_obj", b"z", size=125_000_000)  # 0.01s at 12.5GB/s
    home = store.shard_of("/x/target_obj").nodes[0]
    # register the task and trigger it from a key on a DIFFERENT group
    rt.register("/x/other", task)
    rt.client_put(0.0, "/x/other_1", size=0)
    rt.run()
    task_node = rt.task_log[0]["node"]
    expect_remote = task_node != home
    if expect_remote:
        assert times["get"] >= 125_000_000 / CLUSTER_NET.bandwidth
    else:
        assert times["get"] < 1e-3


def test_grouped_gets_are_always_local():
    """The paper's central invariant (§4.6 Fig 5)."""
    rt, store = make_rt(8)
    store.cache_enabled = False

    def task(ctx, key, value):
        g = key.split("/")[-1].split("_")[0]
        for i in range(5):
            yield Get(f"/x/{g}_obj{i}", required=False)
        yield Compute("gpu", 0.001)

    rt.register("/x", task)
    for g in range(8):
        for i in range(5):
            store.put(f"/x/g{g}_obj{i}", b"d", size=1000, fire=False)
    for g in range(8):
        rt.client_put(0.0, f"/x/g{g}_req", size=10)
    rt.run()
    assert store.stats.remote_gets == 0
    assert store.stats.local_gets > 0


def test_random_placement_pays_remote_gets():
    rt, store = make_rt(8, regex=None, scheduler=RandomScheduler(1))

    def task(ctx, key, value):
        g = key.split("/")[-1].split("_")[0]
        for i in range(5):
            yield Get(f"/x/{g}_obj{i}", required=False)
        yield Compute("gpu", 0.001)

    rt.register("/x", task)
    store.cache_enabled = False
    for g in range(8):
        for i in range(5):
            store.put(f"/x/g{g}_obj{i}", b"d", size=1000, fire=False)
    for g in range(8):
        rt.client_put(0.0, f"/x/g{g}_req", size=10)
    rt.run()
    assert store.stats.remote_gets > 0


def test_node_failure_with_replication_fails_over():
    store = CascadeStore([f"n{i}" for i in range(4)])
    store.create_object_pool("/x", store.nodes, 2, replication=2,
                             affinity_set_regex=r"/[a-z0-9]+_")
    rt = Runtime(store)
    done = []

    def task(ctx, key, value):
        yield Compute("gpu", 0.5)
        done.append((key, ctx.node, ctx.now))

    rt.register("/x", task)
    fi = FaultInjector(rt)
    # find which node would execute group g0, then kill it just before
    target = store.pools["/x"].home("/x/g0_1").nodes[0]
    fi.fail_node(target, at=0.05, duration=10.0)
    for i in range(4):
        rt.client_put(0.1 + 0.01 * i, f"/x/g0_{i}", size=0)
    rt.run()
    assert len(done) == 4, "all tasks must complete despite the failure"
    assert all(n != target or t > 10.0 for _, n, t in done)


def test_straggler_slows_only_its_node():
    rt, store = make_rt(2)
    done = {}

    def task(ctx, key, value):
        yield Compute("gpu", 1.0)
        done[key] = ctx.now

    rt.register("/x", task)
    # find two groups homed on different nodes
    keys = {}
    for g in range(20):
        n = store.pools["/x"].home(f"/x/g{g}_0").nodes[0]
        keys.setdefault(n, f"/x/g{g}_0")
        if len(keys) == 2:
            break
    (fast_node, fast_key), (slow_node, slow_key) = list(keys.items())
    set_straggler(rt, slow_node, 0.25)      # 4x slower
    rt.client_put(0.0, fast_key, size=0)
    rt.client_put(0.0, slow_key, size=0)
    rt.run()
    assert done[fast_key] == pytest.approx(1.0, abs=1e-3)
    assert done[slow_key] == pytest.approx(4.0, abs=1e-3)


def test_autoscaler_scales_out_and_migrates():
    store = CascadeStore([f"n{i}" for i in range(3)] + ["spare0"])
    store.create_object_pool("/x", [f"n{i}" for i in range(3)], 3,
                             affinity_set_regex=r"/[a-z0-9]+_")
    rt = Runtime(store)
    for g in range(30):
        store.put(f"/x/g{g}_0", b"d" * 100, fire=False)
    sc = AutoScaler(rt, ["/x"], spare_nodes=["spare0"], slo=0.1)
    # backlog pressure: one slo worth of admitted-but-unfinished compute
    rt.nodes["n0"].pending["gpu"] = 0.5
    dec = sc.evaluate()
    assert dec is not None and dec.new_shards == 4
    dec = sc.apply(dec)
    assert len(store.pools["/x"].shards) == 4
    assert sc.spare == []
    # migration was charged, not free
    assert store.stats.bytes_migrated == dec.bytes_moved > 0
    # all objects still reachable at their (new) homes
    for g in range(30):
        rec, _ = store.get(f"/x/g{g}_0")
        assert rec is not None


def test_azure_profile_is_slower():
    assert AZURE_NET.transfer_time(10 ** 6) > CLUSTER_NET.transfer_time(10 ** 6)
