"""llava-next-mistral-7b [vlm] — mistral-7b backbone, anyres tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

The vision tower is a STUB: ``input_specs()`` supplies precomputed patch
embeddings (B, n_patches, 1024) which replace the first n_patches token
positions (early fusion).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    mlp_variant="swiglu",
    rope_theta=1000000.0,
    frontend="vision",
    frontend_dim=1024,
    n_patches=576,
)

SMOKE = ModelConfig(
    name="llava-next-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    mlp_variant="swiglu",
    frontend="vision",
    frontend_dim=24,
    n_patches=4,
)
