"""Deterministic synthetic token pipeline, shardable and checkpointable.

A stand-in for a tokenized corpus reader with the properties a real
large-scale pipeline needs: per-(epoch, step, dp-rank) determinism (so a
restarted job resumes byte-identically), host sharding by dp rank, and an
O(1) serializable state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    dp_rank: int = 0
    dp_size: int = 1
    kind: str = "lm"          # "lm" | "audio"
    frontend_dim: int = 0


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.dp_size == 0
        self.cfg = cfg
        self.step = 0

    @property
    def local_batch(self) -> int:
        return self.cfg.global_batch // self.cfg.dp_size

    def state(self) -> Dict:
        return {"step": self.step}

    def restore(self, state: Dict) -> None:
        self.step = int(state["step"])

    def _rng(self, step: int) -> np.random.Generator:
        c = self.cfg
        return np.random.default_rng(
            (c.seed * 1_000_003 + step) * 4096 + c.dp_rank)

    def next_batch(self) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = self._rng(self.step)
        self.step += 1
        B, S = self.local_batch, c.seq_len
        if c.kind == "audio":
            return {
                "features": rng.normal(0, 1, (B, S, c.frontend_dim)
                                       ).astype(np.float32),
                "labels": rng.integers(0, c.vocab_size, (B, S)
                                       ).astype(np.int32),
            }
        # structured pseudo-text: zipfian-ish marginals + local correlation
        z = rng.zipf(1.3, (B, S)).astype(np.int64)
        toks = (z % (c.vocab_size - 2)) + 1
        # repeat-previous with p=0.3 gives learnable bigram structure
        rep = rng.random((B, S)) < 0.3
        toks[:, 1:] = np.where(rep[:, 1:], toks[:, :-1], toks[:, 1:])
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
