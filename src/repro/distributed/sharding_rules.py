"""Logical-axis -> mesh-axis sharding rules.

Every model parameter carries a tuple of logical axis names (see
``models.common.ParamFactory``).  A ``ShardingRules`` maps logical names to
mesh axis names (or None = replicate); ``specs_for_params`` turns a params
tree + axes tree into a PartitionSpec tree, enforcing divisibility and
no-mesh-axis-reuse per tensor.  This module is the primary perf-hillclimb
knob: per-(arch, shape) overrides live in ``repro.launch.dryrun``'s
CELL_OVERRIDES and are recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Optional[Any]    # None | str | tuple[str, ...]


def abstract_mesh(axis_sizes: Sequence[int],
                  axis_names: Sequence[str]) -> Any:
    """Construct an ``AbstractMesh`` across JAX versions.

    The constructor signature changed twice upstream: old releases took
    ``(axis_sizes, axis_names)``, current ones take a single
    ``shape_tuple`` of ``(name, size)`` pairs.  Tests and dry-run tooling
    should build meshes through this helper only.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    except TypeError:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))


@dataclasses.dataclass
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of mesh axes, or None)."""
    rules: Dict[str, AxisName]
    dp_axes: Tuple[str, ...]            # data-parallel axes for activations
    fsdp_axis: Optional[str] = None     # shard params/opt over this axis too
    fsdp_min_size: int = 2 ** 20        # only FSDP tensors >= this many elems

    def mesh_axes_for(self, logical: str) -> Tuple[str, ...]:
        ax = self.rules.get(logical)
        if ax is None:
            return ()
        if isinstance(ax, str):
            return (ax,)
        return tuple(ax)


def default_rules(mesh: Mesh, *, fsdp: bool = False) -> ShardingRules:
    """Baseline TP-over-'model', DP-over-('pod','data') rules."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    rules = {
        "vocab": "model",
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "experts": "model",
        "layers": None,
        "norm": None,
        "conv": None,
        "lru": "model",
        "lru_blocks": None,
        "lru_in": None,
        "lru_out": None,
        "q_lora": None,
        "kv_lora": None,
        "ssm_inner": "model",
        "ssm_bc": None,
        "ssm_heads": "model",
        "frontend": None,
    }
    return ShardingRules(rules=rules, dp_axes=dp,
                         fsdp_axis="data" if fsdp else None)


def _axis_size(mesh: Mesh, ax: AxisName) -> int:
    if ax is None:
        return 1
    if isinstance(ax, str):
        return mesh.shape[ax]
    n = 1
    for a in ax:
        n *= mesh.shape[a]
    return n


def spec_for_tensor(mesh: Mesh, rules: ShardingRules,
                    logical: Sequence[str], shape: Sequence[int],
                    n_elems: Optional[int] = None) -> P:
    """Build a PartitionSpec for one tensor, dropping non-divisible axes."""
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        ax = rules.rules.get(name)
        axes = (ax,) if isinstance(ax, str) else tuple(ax or ())
        axes = tuple(a for a in axes if a not in used)
        if axes and dim % _axis_size(mesh, axes) == 0:
            used.update(axes)
            out.append(axes[0] if len(axes) == 1 else axes)
        else:
            out.append(None)
    # FSDP: additionally shard the largest still-unsharded dim over fsdp_axis
    n = n_elems if n_elems is not None else _prod(shape)
    if (rules.fsdp_axis and rules.fsdp_axis not in used
            and n >= rules.fsdp_min_size):
        fs = mesh.shape[rules.fsdp_axis]
        cands = sorted(
            (i for i, s in enumerate(out)
             if s is None and shape[i] % fs == 0 and shape[i] >= fs),
            key=lambda i: -shape[i])
        # never FSDP-shard a stacked-layer leading axis (scan carries it)
        cands = [i for i in cands if logical[i] != "layers"]
        if cands:
            out[cands[0]] = rules.fsdp_axis
    return P(*out)


def _prod(xs):
    n = 1
    for x in xs:
        n *= int(x)
    return n


def specs_for_params(mesh: Mesh, rules: ShardingRules, params_shapes: Any,
                     axes_tree: Any) -> Any:
    """PartitionSpec tree matching the params tree."""
    def is_axes_leaf(x):
        return isinstance(x, tuple) and all(isinstance(e, str) for e in x)

    flat_ax, treedef = jax.tree_util.tree_flatten(axes_tree,
                                                  is_leaf=is_axes_leaf)
    flat_sh = treedef.flatten_up_to(params_shapes)
    specs = [spec_for_tensor(mesh, rules, a, s.shape)
             for a, s in zip(flat_ax, flat_sh)]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Activation / input shardings
# ---------------------------------------------------------------------------

def batch_pspec(mesh: Mesh, rules: ShardingRules, batch_size: int,
                extra_dims: int = 1) -> P:
    """Spec for a (batch, ...) input: batch over as many dp axes as divide."""
    dp = []
    rem = batch_size
    for a in rules.dp_axes:
        if rem % mesh.shape[a] == 0:
            dp.append(a)
            rem //= mesh.shape[a]
    first = tuple(dp) if len(dp) > 1 else (dp[0] if dp else None)
    return P(first, *([None] * extra_dims))


def cache_pspecs(mesh: Mesh, rules: ShardingRules, cfg, cache_spec: Any,
                 *, stacked: bool = True) -> Any:
    """PartitionSpec tree for a decode cache.

    Layout per leaf (after optional leading stacked-layers axis):
      k/v:          (B, S, K, D)   -> kv_heads over 'model' if divisible,
                                      else seq over 'model' (flash-decoding)
      ckv/k_rope:   (B, S, L)      -> seq over 'model'
      ssm state:    (B, H, P, N)   -> heads over 'model'
      lru h/conv:   (B, [, c], W)  -> width over 'model'
    """
    tp = mesh.shape["model"]

    def leaf_spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        dims = list(leaf.shape)
        lead = []
        if stacked:
            lead, dims = [None], dims[1:]
        bs = dims[0]
        bspec = batch_pspec(mesh, rules, bs, extra_dims=0)[0]
        rest = [None] * (len(dims) - 1)
        if name in ("k", "v"):
            if dims[2] % tp == 0:
                rest[1] = "model"
            elif dims[1] % tp == 0:
                rest[0] = "model"
        elif name in ("ckv", "k_rope"):
            if dims[1] % tp == 0:
                rest[0] = "model"
        elif name == "state":
            if dims[1] % tp == 0:
                rest[0] = "model"
        elif name in ("h",):
            if dims[1] % tp == 0:
                rest[0] = "model"
        elif name.startswith("conv"):
            if dims[-1] % tp == 0:
                rest[-1] = "model"
        return P(*lead, bspec, *rest)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_spec)
