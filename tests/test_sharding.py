"""Sharding rules: divisibility, FSDP, cache specs (AbstractMesh: no
devices needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import sharding_rules as sr
from repro.models import build_model

MESH = sr.abstract_mesh((16, 16), ("data", "model"))
MESH3 = sr.abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_spec_divisible_axis_sharded():
    rules = sr.default_rules(MESH)
    spec = sr.spec_for_tensor(MESH, rules, ("embed", "mlp"), (2048, 8192))
    assert spec == P(None, "model")


def test_spec_non_divisible_axis_dropped():
    rules = sr.default_rules(MESH)
    # 40 heads not divisible by model=16 -> replicated
    spec = sr.spec_for_tensor(MESH, rules, ("embed", "heads", "head_dim"),
                              (5120, 40, 128))
    assert spec[1] is None


def test_fsdp_shards_largest_free_dim():
    rules = sr.default_rules(MESH, fsdp=True)
    spec = sr.spec_for_tensor(MESH, rules, ("experts", "embed", "mlp"),
                              (160, 5120, 1536))
    assert spec == P("model", "data", None)


def test_fsdp_skips_small_tensors():
    rules = sr.default_rules(MESH, fsdp=True)
    spec = sr.spec_for_tensor(MESH, rules, ("norm",), (4096,))
    assert spec == P(None)


def test_no_axis_reuse_within_tensor():
    rules = sr.default_rules(MESH)
    rules.rules["embed"] = "model"
    spec = sr.spec_for_tensor(MESH, rules, ("embed", "mlp"), (2048, 8192))
    used = [s for s in spec if s is not None]
    assert len(used) == len(set(used))


def test_batch_pspec_multi_pod():
    rules = sr.default_rules(MESH3)
    spec = sr.batch_pspec(MESH3, rules, 256, extra_dims=1)
    assert spec == P(("pod", "data"), None)


def test_batch_pspec_indivisible_batch():
    rules = sr.default_rules(MESH)
    spec = sr.batch_pspec(MESH, rules, 1, extra_dims=0)
    assert spec == P(None)


def test_params_specs_cover_whole_tree():
    cfg = configs.get_smoke("deepseek-v2-236b")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    axes = model.param_axes()
    specs = sr.specs_for_params(MESH, sr.default_rules(MESH), shapes, axes)
    n_specs = len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
    n_params = len(jax.tree_util.tree_leaves(shapes))
    assert n_specs == n_params


def test_cache_pspec_kv_heads_or_seq():
    cfg = configs.get_config("granite-3-2b")   # kv=8, not divisible by 16
    model = build_model(cfg)
    spec_tree = model.cache_spec(128, 1024)
    rules = sr.default_rules(MESH)
    specs = sr.cache_pspecs(MESH, rules, cfg, spec_tree, stacked=True)
    k_spec = specs["k"]
    # kv_heads=8 not divisible -> seq dim sharded instead (flash-decoding)
    assert k_spec == P(None, "data", "model", None, None)


def test_cache_pspec_divisible_kv_heads():
    cfg = configs.get_config("deepseek-7b")    # kv=32 divisible by 16
    model = build_model(cfg)
    spec_tree = model.cache_spec(128, 1024)
    specs = sr.cache_pspecs(MESH, sr.default_rules(MESH), cfg, spec_tree,
                            stacked=True)
    assert specs["k"] == P(None, "data", None, "model", None)


def test_production_mesh_constants():
    from repro.launch import mesh as meshlib
    assert meshlib.PEAK_FLOPS_BF16 == 197e12
    assert meshlib.HBM_BW == 819e9
    assert meshlib.ICI_BW_PER_LINK == 50e9
