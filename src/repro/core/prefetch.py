"""Affinity-driven prefetching (paper §3.4 'Prefetching' + §4.6 replication).

When a task with affinity key `a` is scheduled onto a node, every stored
object with the same affinity key is a prefetch candidate: the developer has
declared the correlation, so the platform can warm the node's cache *before*
the task (or a downstream stage) reads the objects.  The engine returns
prefetch plans; the runtime executes them (overlapping with compute) and the
store's cache makes subsequent gets local.

Two planners share one candidate filter and one byte budget:

  * :meth:`PrefetchEngine.plan_for_task` — the affinity sweep: every
    same-label object in a pool, for "a task with this label just landed
    here" callers;
  * :meth:`PrefetchEngine.plan_for_keys` — an explicit key list, for the
    workflow layer, which knows at gang admission exactly which keys every
    downstream stage will read (``Stage.reads``, join inputs).

The byte cap (``max_bytes_per_plan``) is enforced **globally and
deterministically**: candidates are gathered first (sorted by key in the
affinity sweep; caller order in the explicit form), then taken greedily
until the next object would overflow the cap.  Objects skipped for budget
are counted in ``skipped_over_budget`` — never silently dropped per-shard,
so a large object early in one shard cannot shadow small objects in
another.

Plans carry the **version** of every record at plan time.  Execution is
asynchronous (the DES charges NIC transfer time), and the store's
:meth:`~repro.core.object_store.CascadeStore.prefetch_install` re-checks
the version at arrival: a write, migration, or gang repair that bumped the
record between plan and install makes the transfer a counted no-op instead
of a stale cache entry.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from .object_store import CascadeStore, ObjectRecord


@dataclasses.dataclass
class PrefetchPlan:
    """One node's warm-up shipment: keys + the versions/sizes planned.

    ``keys``/``versions``/``sizes`` are parallel lists; ``speculative``
    marks fan-in staging plans (their bytes count against the runtime's
    wasted-speculation budget when the guess misses).
    """
    node: str
    keys: List[str]
    total_bytes: int
    versions: List[int] = dataclasses.field(default_factory=list)
    sizes: List[int] = dataclasses.field(default_factory=list)
    speculative: bool = False


class PrefetchEngine:
    def __init__(self, store: CascadeStore, max_bytes_per_plan: int = 1 << 30):
        self.store = store
        self.max_bytes = max_bytes_per_plan
        self.issued: int = 0
        self.bytes_issued: int = 0
        self.skipped_over_budget: int = 0

    # -- candidate filter ---------------------------------------------------

    def _candidate(self, key: str, node: str) -> Optional[ObjectRecord]:
        """The live record iff prefetching ``key`` to ``node`` would help:
        it exists, is not already node-local, is not validly cached, and
        (under an active partition) at least one holder is reachable."""
        try:
            pool = self.store.pool_for(key)
        except KeyError:
            return None
        rec = None
        p = self.store.partition
        rg = p.get(node, 0) if p is not None else 0
        reachable = p is None
        for shard in pool.replica_homes(key):
            r = shard.objects.get(key)
            if r is None:
                continue
            if node in shard.nodes:
                return None                       # already local
            rec = r
            if p is not None and any(p.get(m, 0) == rg
                                     for m in shard.nodes):
                reachable = True
        if rec is None or not reachable:
            return None                           # missing / across the cut
        cached = self.store.caches.get(node, {}).get(key)
        if cached is not None and cached.version == rec.version:
            return None                           # warm already
        return rec

    def _take(self, node: str, cands: Sequence[Tuple[str, ObjectRecord]],
              speculative: bool = False) -> Optional[PrefetchPlan]:
        """Apply the global byte cap over an ordered candidate list."""
        keys: List[str] = []
        versions: List[int] = []
        sizes: List[int] = []
        total = 0
        for k, rec in cands:
            if total + rec.size > self.max_bytes:
                self.skipped_over_budget += 1
                continue
            keys.append(k)
            versions.append(rec.version)
            sizes.append(rec.size)
            total += rec.size
        if not keys:
            return None
        self.issued += 1
        self.bytes_issued += total
        return PrefetchPlan(node=node, keys=keys, total_bytes=total,
                            versions=versions, sizes=sizes,
                            speculative=speculative)

    # -- planners -----------------------------------------------------------

    def plan_for_task(self, pool_prefix: str, label: str, node: str
                      ) -> Optional[PrefetchPlan]:
        """All same-affinity objects not yet cached/local at ``node``.

        Candidates are gathered across every shard first and sorted by
        key, so the byte cap is applied globally in a deterministic order
        — shard iteration order and a large object's position can never
        change which objects make the plan.
        """
        pool = self.store.pools[pool_prefix]
        cands: List[Tuple[str, ObjectRecord]] = []
        seen = set()
        for shard in pool.shards.values():
            for k, rec in shard.objects.items():
                if rec.affinity != label or k in seen:
                    continue
                seen.add(k)
                r = self._candidate(k, node)
                if r is not None:
                    cands.append((k, r))
        cands.sort(key=lambda kr: kr[0])
        return self._take(node, cands)

    def plan_for_keys(self, keys: Sequence[str], node: str,
                      speculative: bool = False) -> Optional[PrefetchPlan]:
        """Plan an explicit key list (deduped, caller order preserved)."""
        cands: List[Tuple[str, ObjectRecord]] = []
        seen = set()
        for k in keys:
            if k in seen:
                continue
            seen.add(k)
            rec = self._candidate(k, node)
            if rec is not None:
                cands.append((k, rec))
        return self._take(node, cands, speculative=speculative)

    def execute(self, plan: PrefetchPlan) -> int:
        """Warm the cache synchronously (store-level; the DES-overlapped
        path goes through ``Simulator.prefetch`` + ``prefetch_install``
        instead, which is what charges transfer time)."""
        moved = 0
        for k in plan.keys:
            rec, local = self.store.get(k, node=plan.node)
            if rec is not None and not local:
                moved += rec.size
        return moved
