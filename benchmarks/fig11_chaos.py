"""Fig. 11 (ours): workflow gangs under chaos — node deaths mid-stream.

fig10's heterogeneous cluster, steady load, and a chaos schedule that
kills base-tier nodes mid-run (staggered ~0.3 s outages) and turns one
node into a grey-failure straggler (up, but an order of magnitude slow —
the failure mode fail-stop repair cannot see).  At each chaos intensity
(number of nodes killed) the SAME arrival schedule runs under:

  * ``none``       — faults injected, nothing wired: gangs pinned to a
    dead slot stall until the node returns (the availability floor);
  * ``repin``      — :meth:`WorkflowRuntime.enable_faults`: node death
    triggers workflow-atomic gang re-pinning onto surviving slots,
    stranded objects migrate (charged), and fresh admissions avoid dead
    slots;
  * ``repl+hedge`` — repair plus group replication (reads survive the
    outage, dispatch spreads over replica slots) and hedged batch
    execution (a batch stuck behind a dead or straggling lane is
    duplicated to a replica slot after ``HEDGE_AFTER``; the loser is
    cancelled).  Re-pinning never fires for the straggler — it is up —
    so this is the only configuration that recovers from grey failure.

One ``auto`` run adds the SLO autoscaler on top of repair: the outage
itself is pressure ("down" signal), so spares are recruited within one
evaluation period and returned after recovery.

Recorded acceptance (all deterministic):

  1. ZERO lost instances in every configuration — chaos costs latency,
     never completions;
  2. ``repl+hedge`` p99 is strictly below the unreplicated-faulty
     (``none``) p99 at EVERY chaos intensity;
  3. repair actually engages (gang re-pins > 0 in the wired runs), and
     the autoscaled run scales out on the "down" signal while conserving
     capacity (spares return after recovery).
"""
import time

from .common import emit

BASE_SLOTS = 4               # fast tier (H100)
SPARE_SLOTS = 2              # standby tier the `auto` run may recruit
SLO = 0.120                  # end-to-end deadline, seconds
RATE = 300.0                 # steady arrivals/s — valley load for 4 slots
DURATION = 2.0               # submission horizon, seconds
HEDGE_AFTER = 0.040          # duplicate a batch not done after this long
# chaos schedules by intensity: (node, t_down, outage_seconds)
CHAOS = {
    1: (("fast1", 0.5, 0.3),),
    2: (("fast1", 0.5, 0.3), ("fast2", 0.9, 0.3)),
}
# grey failure alongside the kills: this node stays up at 1/10 speed
STRAGGLER = ("fast3", 0.1)


def build_graph():
    """fig10's prep (cpu) -> infer (gpu) shape on fast + standby tiers."""
    from repro.runtime import GPU_A100, GPU_H100
    from repro.workflows import Emit, WorkflowGraph
    g = WorkflowGraph("chaos")
    g.add_tier("fast", BASE_SLOTS, {"gpu": 1, "cpu": 2, "nic": 2},
               profile=GPU_H100)
    g.add_tier("slow", 0, {"gpu": 1, "cpu": 2, "nic": 2},
               profile=GPU_A100, spares=SPARE_SLOTS)
    pool_kw = dict(tier=("fast", "slow"), shards=BASE_SLOTS)
    g.add_pool("/req", **pool_kw)
    g.add_pool("/feat", **pool_kw)
    g.add_pool("/out", **pool_kw)
    g.add_stage("prep", pool="/req", resource="cpu", cost=0.002,
                emits=[Emit("/feat", fanout=1, size=256 * 1024)])
    g.add_stage("infer", pool="/feat", resource="gpu", cost=0.016,
                emits=[Emit("/out", fanout=1, size=16 * 1024)], sink=True)
    return g.validate()


def submit_stream(wrt):
    n = int(DURATION * RATE)
    for i in range(n):
        wrt.submit(f"r{i}", at=0.05 + i / RATE, deadline=SLO)
    return n


def run_chaos(intensity, wired, read_replicas=1, hedge=None,
              autoscale=False, straggler=True, seed=0, tracing=False):
    """One configuration over the shared schedule + chaos at ``intensity``.

    ``wired=False`` leaves the injector raw — failures flip nodes but the
    workflow layer never hears about them (the stall baseline).
    """
    from repro.runtime import FaultInjector, set_straggler
    from repro.workflows import WorkflowRuntime, mode_kwargs
    wrt = WorkflowRuntime(build_graph(), seed=seed,
                          read_replicas=read_replicas, hedge_after=hedge,
                          tracing=tracing,
                          **mode_kwargs("atomic+abatch"))
    if autoscale:
        wrt.enable_autoscale(slo=SLO)
    inj = wrt.enable_faults() if wired else FaultInjector(wrt.rt)
    for node, at, dur in CHAOS.get(intensity, ()):
        inj.fail_node(node, at=at, duration=dur)
    if intensity and straggler:
        set_straggler(wrt.rt, *STRAGGLER)
    n = submit_stream(wrt)
    wrt.run()
    return wrt, inj, n


def _row(tag, wrt, inj, n_submitted, t0):
    s = wrt.summary()
    rep = inj.report()
    completed = s["n"]
    misses = s.get("slo_misses", 0)
    d = {
        "p50_ms": round(s["median"] * 1e3, 2),
        "p99_ms": round(s["p99"] * 1e3, 2),
        "slo_hit_rate": round((completed - misses) / n_submitted, 4),
        "late_completions": misses,
        "completed": completed,
        "submitted": n_submitted,
        "lost": n_submitted - completed,
        "failovers": rep.tasks_failed_over,
        "stalled": rep.tasks_stalled,
        "repins": wrt.fault_repins,
        "hedges": wrt.rt.hedges,
        "downtime_s": round(rep.downtime, 3),
        "wall_s": round(time.perf_counter() - t0, 3),
    }
    if "scale_events" in s:
        d["scale_events"] = s["scale_events"]
    return (f"fig11/{tag}", s["median"] * 1e6, d)


def run(quick=True):
    rows = []
    p99 = {}
    repins = {}
    hedges = {}
    lost = {}

    t0 = time.perf_counter()
    wrt, inj, n = run_chaos(0, wired=True)
    rows.append(_row("healthy", wrt, inj, n, t0))
    lost["healthy"] = n - wrt.summary()["n"]

    configs = (("none", dict(wired=False)),
               ("repin", dict(wired=True)),
               ("repl+hedge", dict(wired=True, read_replicas=2,
                                   hedge=HEDGE_AFTER)))
    for k in sorted(CHAOS):
        for tag, kw in configs:
            t0 = time.perf_counter()
            wrt, inj, n = run_chaos(k, **kw)
            name = f"{tag}{k}"
            rows.append(_row(name, wrt, inj, n, t0))
            p99[name] = wrt.summary()["p99"]
            repins[name] = wrt.fault_repins
            hedges[name] = wrt.rt.hedges
            lost[name] = n - wrt.summary()["n"]

    # repair + elasticity: the outage is pressure, spares get recruited
    # (kills only — the down signal, not the straggler echo, must drive)
    t0 = time.perf_counter()
    wrt, inj, n = run_chaos(max(CHAOS), wired=True, autoscale=True,
                            straggler=False)
    rows.append(_row("auto", wrt, inj, n, t0))
    lost["auto"] = n - wrt.summary()["n"]
    sc = wrt.autoscaler
    scaled_on_down = any(d.new_shards > d.old_shards and "down" in d.reason
                         for d in sc.decisions)
    conserved = sc._n_active() + len(sc.spare) == BASE_SLOTS + SPARE_SLOTS

    # one traced chaos run (max intensity, full repair stack): the blame
    # table shows where the outage's latency went (fault_stall /
    # migration / queueing), and the exported chrome trace is the CI
    # artifact.  Tracing reproduces latencies byte-for-byte (tested).
    from .common import write_chrome_trace
    t0 = time.perf_counter()
    wrt, inj, n = run_chaos(max(CHAOS), wired=True, read_replicas=2,
                            hedge=HEDGE_AFTER, tracing=True)
    s = wrt.summary()
    path, payload = write_chrome_trace(wrt.tracer, "fig11")
    rows.append((f"fig11/trace/repl+hedge{max(CHAOS)}",
                 s["median"] * 1e6,
                 {"p99_ms": round(s["p99"] * 1e3, 2),
                  "spans": s["spans"],
                  "trace_events": len(payload["traceEvents"]),
                  "blame_top": s["blame_top"],
                  "blame_fault_stall_ms": s["blame_fault_stall_ms"],
                  "blame_queueing_ms": s["blame_queueing_ms"],
                  "artifact": path.name,
                  "wall_s": round(time.perf_counter() - t0, 3)}))
    traced_matches = abs(s["p99"] - p99[f"repl+hedge{max(CHAOS)}"]) \
        < 1e-12

    # -- acceptance ---------------------------------------------------------
    zero_lost = all(v == 0 for v in lost.values())
    hedging_beats_stall = all(p99[f"repl+hedge{k}"] < p99[f"none{k}"]
                              for k in CHAOS)
    hedging_beats_repair_alone = all(
        p99[f"repl+hedge{k}"] < p99[f"repin{k}"] for k in CHAOS)
    repair_engaged = all(repins[f"{tag}{k}"] > 0
                         for tag in ("repin", "repl+hedge")
                         for k in CHAOS)
    hedges_engaged = all(hedges[f"repl+hedge{k}"] > 0 for k in CHAOS)
    rows.append(("fig11/acceptance", 0.0, {
        "zero_lost_instances": zero_lost,
        "repl_hedge_p99_beats_faulty_baseline": hedging_beats_stall,
        "repl_hedge_p99_beats_repair_alone": hedging_beats_repair_alone,
        "repair_engaged": repair_engaged,
        "hedges_engaged": hedges_engaged,
        "auto_scaled_on_down_signal": scaled_on_down,
        "capacity_conserved": conserved,
        "traced_run_latency_identical": traced_matches,
    }))
    assert zero_lost and hedging_beats_stall \
        and hedging_beats_repair_alone and repair_engaged \
        and hedges_engaged and scaled_on_down and conserved \
        and traced_matches, rows[-1][2]
    return rows


if __name__ == "__main__":
    emit(run())
