"""launch.steps bundles execute end-to-end on a local (1,1) mesh."""
import dataclasses as dc

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.shapes import ShapeConfig
from repro.launch import steps as steplib
from repro.models import build_model


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _jit(mesh, bundle):
    with mesh:
        return jax.jit(
            bundle.fn,
            in_shardings=steplib.to_shardings(mesh, bundle.in_shardings),
            out_shardings=steplib.to_shardings(mesh, bundle.out_shardings),
            donate_argnums=bundle.donate_argnums)


@pytest.mark.parametrize("arch", ["granite-3-2b", "mamba2-780m",
                                  "deepseek-v2-236b"])
def test_train_step_executes(arch, mesh, rng):
    cfg = configs.get_smoke(arch)
    shape = ShapeConfig("t", seq_len=16, global_batch=2, kind="train")
    bundle = steplib.make_train_step(cfg, shape, mesh)
    model = bundle.meta["model"]
    params = model.init(jax.random.PRNGKey(0))
    from repro.training.optimizer import init_opt_state
    state = {"params": params,
             "opt": init_opt_state(params, cfg.opt_state_dtype,
                                   factored=cfg.opt_factored)}
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)}
    fn = _jit(mesh, bundle)
    state2, metrics = fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["opt"]["step"]) == 1


def test_serve_step_executes(mesh, rng):
    cfg = configs.get_smoke("granite-3-2b")
    shape = ShapeConfig("d", seq_len=32, global_batch=2, kind="decode")
    bundle = steplib.make_serve_step(cfg, shape, mesh)
    model = bundle.meta["model"]
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 32)
    toks = jnp.array([3, 5], jnp.int32)
    lengths = jnp.zeros((2,), jnp.int32)
    fn = _jit(mesh, bundle)
    nxt, cache2 = fn(params, cache, toks, lengths)
    assert nxt.shape == (2,) and nxt.dtype == jnp.int32


def test_prefill_step_executes_encoder(mesh, rng):
    cfg = configs.get_smoke("hubert-xlarge")
    shape = ShapeConfig("p", seq_len=16, global_batch=2, kind="prefill")
    bundle = steplib.make_prefill_step(cfg, shape, mesh)
    model = bundle.meta["model"]
    params = model.init(jax.random.PRNGKey(0))
    batch = {"features": jnp.zeros((2, 16, cfg.frontend_dim), jnp.bfloat16),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    fn = _jit(mesh, bundle)
    logits = fn(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)


def test_grad_accum_matches_single_shot(mesh, rng):
    """accum_steps=2 must reproduce the accum=1 loss (same tokens)."""
    cfg = dc.replace(configs.get_smoke("granite-3-2b"),
                     param_dtype=jnp.float32, compute_dtype=jnp.float32)
    shape = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
    losses = {}
    for accum in (1, 2):
        bundle = steplib.make_train_step(cfg, shape, mesh,
                                         accum_steps=accum)
        model = bundle.meta["model"]
        params = model.init(jax.random.PRNGKey(0))
        from repro.training.optimizer import init_opt_state
        state = {"params": params, "opt": init_opt_state(params)}
        _, metrics = _jit(mesh, bundle)(state, batch)
        losses[accum] = float(metrics["loss"])
    assert losses[1] == pytest.approx(losses[2], rel=1e-5)
