"""Affinity-aware cross-instance stage batching.

Workflow-atomic placement pins every instance of a workflow to one shard
slot, so instances that fire the *same stage* on the *same slot* within a
short window are perfect batch candidates: their model weights, code and
data are already co-resident — the affinity label is exactly the grouping
signal serving systems (Vortex 2511.02062) and pipeline tuners (InferLine
1812.01776) have to infer from traffic.

``StageBatcher`` coalesces such firings into ONE
:class:`repro.runtime.simulation.BatchCompute` priced by the shared
:class:`repro.runtime.batching.BatchCostModel`, while leaving every piece
of per-instance accounting — join-barrier arrivals, per-stage spans,
deadlines, emitted objects — exact: only the compute op is shared, the
per-instance generators block on a :class:`repro.runtime.simulation.SimFuture`
and resume individually when the batch completes.

Flush rules (head-of-line-blocking control):

  * **window** — a batch holds at most ``window`` virtual seconds after it
    opens;
  * **size cap** — reaching ``max_batch`` members flushes immediately;
  * **idle flush** — if the stage's resource has a free lane on the slot's
    nodes when a batch opens, it flushes immediately: there is nothing to
    wait for, so an unloaded system pays zero added latency (batching only
    "turns on" under contention, exactly when it pays);
  * **SLO flush** — a member whose deadline cannot absorb the wait +
    amortized batch service flushes the batch at enrollment, so window
    waits never push a feasible instance past its deadline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.runtime.batching import BatchCostModel
from repro.runtime.simulation import BatchCompute, SimFuture, WaitFor


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """Knobs for batch formation (per-runtime; sweeps vary these)."""
    window: float = 0.004        # max virtual seconds a batch stays open
    max_batch: int = 16          # flush at this many members
    idle_flush: bool = True      # flush a fresh batch if the resource idles
    slo_margin: float = 0.0      # extra headroom reserved before deadlines


class _OpenBatch:
    __slots__ = ("stage", "slot", "resource", "unit_cost", "keys",
                 "future", "flush_at", "closed", "deadline_min")

    def __init__(self, stage: str, slot: str, resource: str,
                 unit_cost: float, flush_at: float):
        self.stage = stage
        self.slot = slot
        self.resource = resource
        self.unit_cost = unit_cost
        self.keys: List[str] = []
        self.future = SimFuture()
        self.flush_at = flush_at
        self.closed = False
        self.deadline_min: Optional[float] = None   # tightest member deadline


class StageBatcher:
    """Coalesce same-(stage, slot) firings into one ``BatchCompute``.

    Stage generators call :meth:`compute` (a sub-generator) in place of
    yielding a plain ``Compute``; the batcher enrolls them and they block
    on the batch's future.  The flush spawns one system task — placed by
    the runtime scheduler's batch-aware ``pick_batch`` — that executes the
    amortized ``BatchCompute`` and resolves the future, resuming every
    member at the batch's completion time.
    """

    def __init__(self, runtime, policy: Optional[BatchPolicy] = None,
                 cost_model: Optional[BatchCostModel] = None):
        self.rt = runtime                      # repro.runtime.Runtime
        self.sim = runtime.sim
        self.policy = policy or BatchPolicy()
        self.cost_model = cost_model or BatchCostModel(
            max_batch=self.policy.max_batch)
        self._open: Dict[Tuple[str, str], _OpenBatch] = {}
        # realized-coalescing stats (summary() reports them)
        self.n_batches = 0
        self.enrolled = 0
        self.slo_flushes = 0
        self.idle_flushes = 0

    # -- enrollment (called from inside stage generators) -------------------

    def compute(self, ctx, stage, deadline: Optional[float] = None):
        """Sub-generator replacing ``yield Compute(stage.resource, cost)``.

        ``ctx`` is the stage's TaskContext (carries the dispatch shard —
        the batch key's slot); ``deadline`` the instance's absolute
        deadline, if any, for the SLO flush rule.
        """
        now = self.sim.now
        bkey = (stage.name, ctx.shard)
        batch = self._open.get(bkey)
        fresh = batch is None
        if fresh:
            batch = _OpenBatch(stage.name, ctx.shard, stage.resource,
                               stage.cost, now + self.policy.window)
            self._open[bkey] = batch
        batch.keys.append(ctx.key)
        self.enrolled += 1
        if deadline is not None:
            if batch.deadline_min is None or deadline < batch.deadline_min:
                batch.deadline_min = deadline
        if fresh and self.policy.idle_flush and \
                self._resource_idle(batch):
            # nothing ahead of us: waiting can only add latency
            self.idle_flushes += 1
            self._flush(batch)
        elif batch.deadline_min is not None and not batch.closed:
            # SLO-aware early flush, re-evaluated against the TIGHTEST
            # member deadline on every enrollment: growing the batch grows
            # its service time, so a member admitted safely at n=k can
            # become infeasible at n=k+1 — if riding out the window would
            # land that member past its headroom, go now
            est = self.cost_model.batch_seconds(batch.unit_cost,
                                                len(batch.keys))
            if batch.flush_at + est + self.policy.slo_margin > \
                    batch.deadline_min:
                self.slo_flushes += 1
                self._flush(batch)
        if not batch.closed and len(batch.keys) >= self.policy.max_batch:
            self._flush(batch)
        if fresh and not batch.closed:
            # schedule the window flush only for batches that actually
            # stay open — idle-flushed ones never touch the event heap
            self.sim.at(batch.flush_at, self._window_flush, batch)
        yield WaitFor(batch.future)

    # -- flushing -----------------------------------------------------------

    def _window_flush(self, batch: _OpenBatch) -> None:
        if not batch.closed:
            self._flush(batch)

    def _flush(self, batch: _OpenBatch) -> None:
        batch.closed = True
        self._open.pop((batch.stage, batch.slot), None)
        n = len(batch.keys)
        seconds = self.cost_model.batch_seconds(batch.unit_cost, n)
        binding = self.rt.bindings[batch.stage]
        shard = self._shard_of(batch)
        node = self.rt.scheduler.pick_batch(
            shard, batch.keys, self.rt.nodes, binding.pool_nodes,
            resource=batch.resource)
        self.n_batches += 1
        self.sim.spawn(node, self._run_batch(batch, seconds, n),
                       label=f"batch:{batch.stage}")

    def _run_batch(self, batch: _OpenBatch, seconds: float, n: int):
        yield BatchCompute(batch.resource, seconds, n)
        self.sim.resolve(batch.future)

    # -- helpers ------------------------------------------------------------

    def _shard_of(self, batch: _OpenBatch):
        pool = self.rt.store.pool_for(batch.keys[0])
        return pool.shards[batch.slot]

    def _resource_idle(self, batch: _OpenBatch) -> bool:
        """A free lane with an empty queue on any of the slot's nodes?"""
        nodes = self.rt.nodes
        for name in self._shard_of(batch).nodes:
            node = nodes[name]
            if not node.up:
                continue
            if (node.in_use[batch.resource]
                    < node.capacity.get(batch.resource, 1)
                    and not node.queues[batch.resource]):
                return True
        return False

    # -- reporting ----------------------------------------------------------

    def summary(self) -> Dict[str, float]:
        sizes = self.sim.metrics.get("batch_sizes", [])
        out = {
            "batches": self.n_batches,
            "batched_tasks": self.enrolled,
            "slo_flushes": self.slo_flushes,
            "idle_flushes": self.idle_flushes,
        }
        if sizes:
            out["mean_batch"] = sum(sizes) / len(sizes)
            out["max_batch"] = max(sizes)
        return out
