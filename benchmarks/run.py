"""Benchmark runner — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full] [--only fig3,...]``
prints ``name,us_per_call,derived`` CSV rows per the harness contract.
"""
import argparse
import sys
import time

from . import (azure_mode, fig3_single_client, fig4_three_clients,
               fig5_no_caching, fig6_replication, fig7_workflows,
               micro_affinity, roofline, serving_affinity)
from .common import emit

SUITES = {
    "fig3": fig3_single_client,
    "fig4": fig4_three_clients,
    "fig5": fig5_no_caching,
    "fig6": fig6_replication,
    "fig7": fig7_workflows,
    "azure": azure_mode,
    "micro": micro_affinity,
    "serving": serving_affinity,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale workloads (700 frames etc.)")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    names = (args.only.split(",") if args.only else list(SUITES))
    print("name,us_per_call,derived")
    for name in names:
        mod = SUITES[name]
        t0 = time.perf_counter()
        try:
            rows = mod.run(quick=not args.full)
        except Exception as e:   # noqa: BLE001 — keep the suite going
            print(f"{name}/ERROR,0,{e!r}", file=sys.stderr)
            continue
        emit(rows)
        print(f"# {name}: {time.perf_counter() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
