"""Placement engine: affinity key -> shard/location.

The paper's modified Cascade policy is ``hash(affinity_key) % n_shards``
(pseudo-random across *groups*, deterministic within a group -> load balance
+ collocation, §4.5 "best of both worlds").  Baseline is the same hash over
the raw object key ("random placement").

For elastic scaling we also provide rendezvous (HRW) hashing: when a shard
is added/removed only ~1/n of affinity groups move, and the mapping needs no
synchronized state — any node computes it locally (the paper's 'lightweight'
requirement under autoscaling).

Beyond the paper's static policies, two dynamic ones (Fig. 6 regime):

  * ``LoadAwarePlacement`` — a whole affinity group is bound to the
    least-loaded shard at group-creation time (first put of the group);
    later members follow the binding, so collocation is preserved while
    shards fill evenly by *bytes*, not by group count;
  * ``ReplicatedPlacement`` — each group lives on ``n_replicas`` shards
    (primary by the inner policy, extras by rendezvous rank); writes
    fan out, reads pick the nearest replica.

The engine additionally supports per-label *pins* — explicit
label -> shard overrides that ``GroupMigrator`` installs when it relocates
a hot group, so any policy (including plain hash) becomes migratable.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Sequence

from .affinity import AffinityFunction, AffinityKey, Descriptor, affinity_key_for


def stable_hash(s: str) -> int:
    """Deterministic across processes (unlike python's hash())."""
    return int.from_bytes(hashlib.blake2b(s.encode(), digest_size=8).digest(),
                          "big")


class PlacementPolicy:
    def place(self, label: str, shards: Sequence[str]) -> str:
        raise NotImplementedError

    def name(self) -> str:
        return type(self).__name__


class HashPlacement(PlacementPolicy):
    """hash(label) % n — Cascade's default mapping."""

    def place(self, label: str, shards: Sequence[str]) -> str:
        return shards[stable_hash(label) % len(shards)]

    def name(self) -> str:
        return "hash"


class RendezvousPlacement(PlacementPolicy):
    """Highest-random-weight hashing: minimal movement under resharding."""

    def place(self, label: str, shards: Sequence[str]) -> str:
        return max(shards, key=lambda s: stable_hash(f"{label}::{s}"))

    def name(self) -> str:
        return "rendezvous"


class LoadAwarePlacement(PlacementPolicy):
    """Bind each affinity group to the least-loaded shard at creation time.

    Load is tracked in bytes written (plus a small per-request charge so
    empty groups still spread).  The binding is sticky: every later member
    of the group lands on the same shard, so the collocation invariant
    holds while the *assignment* of groups to shards tracks actual load
    rather than hash luck.  ``record_load`` is fed by the store on puts and
    remote gets; ``rebind`` is used by the migrator.
    """

    REQUEST_COST = 64   # bytes-equivalent charge per placement request

    def __init__(self):
        self.assignments: Dict[str, str] = {}
        self.load: Dict[str, float] = defaultdict(float)
        # shard -> throughput weight (heterogeneous tiers): ranking divides
        # accumulated load by it, so a 2x-faster backend binds ~2x the
        # groups before looking as "full" as a reference shard.  Shards
        # keep the default 1.0 on homogeneous clusters, which makes the
        # ranking byte-identical to the unweighted one.
        self.capacity: Dict[str, float] = {}

    def set_capacity(self, shard: str, weight: float) -> None:
        assert weight > 0, (shard, weight)
        self.capacity[shard] = weight

    def place(self, label: str, shards: Sequence[str]) -> str:
        shard = self.assignments.get(label)
        if shard is None or shard not in shards:
            # tie-break by shard *position*, not name: pools that list their
            # shards in the same order (e.g. /frames and /states over the
            # same nodes) then bind identical labels to identical slots, so
            # cross-pool collocation survives the switch away from hashing
            cap = self.capacity
            i = min(range(len(shards)),
                    key=lambda j: (self.load[shards[j]]
                                   / cap.get(shards[j], 1.0), j))
            shard = shards[i]
            self.assignments[label] = shard
            self.load[shard] += self.REQUEST_COST
        return shard

    def record_load(self, shard: str, nbytes: int) -> None:
        self.load[shard] += nbytes

    def forget(self, label: str) -> None:
        """Drop a group's binding so its next placement re-ranks shards
        (admission deferral: a retry must see capacity added since the
        first attempt).  The group's small REQUEST_COST charge stays —
        repeated retries keep nudging later bindings off busy shards."""
        self.assignments.pop(label, None)

    def retire_shard(self, shard: str) -> None:
        """Drop a removed shard's accumulated load so a later scale-out
        reusing the slot NAME starts from zero (its former bytes are
        re-credited to wherever the data migrated)."""
        self.load.pop(shard, None)

    def rebind(self, label: str, shard: str, nbytes: int = 0) -> None:
        """Move a group's binding (migration): transfer its load charge."""
        old = self.assignments.get(label)
        if old is not None and nbytes:
            self.load[old] = max(self.load[old] - nbytes, 0.0)
        self.assignments[label] = shard
        if nbytes:
            self.load[shard] += nbytes

    def name(self) -> str:
        return "load_aware"


class ReplicatedPlacement(PlacementPolicy):
    """Group-granular replication over shards (paper §4.6 / Fig. 6).

    The *primary* home is the inner policy's choice; the remaining
    ``n_replicas - 1`` homes are the top shards by rendezvous rank,
    skipping the primary.  ``place`` returns the primary (writes are
    applied there first); ``replica_shards`` is the full ordered set the
    store fans writes out to and serves reads from.
    """

    def __init__(self, inner: Optional[PlacementPolicy] = None,
                 n_replicas: int = 2):
        assert n_replicas >= 1, n_replicas
        self.inner = inner or HashPlacement()
        self.n_replicas = n_replicas
        # shard -> failure-domain label (set_domain); empty = topology
        # blind, which keeps replica_shards byte-identical to the
        # pre-domain rendezvous ranking
        self.domains: Dict[str, str] = {}

    def place(self, label: str, shards: Sequence[str]) -> str:
        return self.inner.place(label, shards)

    def replica_shards(self, label: str, shards: Sequence[str]) -> List[str]:
        primary = self.place(label, shards)
        ranked = sorted((s for s in shards if s != primary),
                        key=lambda s: stable_hash(f"{label}::{s}"),
                        reverse=True)
        if not self.domains:
            return [primary] + ranked[:self.n_replicas - 1]
        # anti-affinity spreading: walk the rendezvous ranking but defer
        # shards whose failure domain is already represented, so replicas
        # land in distinct domains whenever enough domains exist; the
        # deferred shards fill any remaining slots in rank order.
        homes = [primary]
        used = {self.domains.get(primary, "")}
        deferred = []
        for s in ranked:
            d = self.domains.get(s, "")
            if d and d in used:
                deferred.append(s)
            else:
                homes.append(s)
                used.add(d)
        homes.extend(deferred)
        return homes[:self.n_replicas]

    def set_domain(self, shard: str, domain: str) -> None:
        if domain:
            self.domains[shard] = domain
        else:
            self.domains.pop(shard, None)
        sd = getattr(self.inner, "set_domain", None)
        if sd is not None:
            sd(shard, domain)

    def record_load(self, shard: str, nbytes: int) -> None:
        rec = getattr(self.inner, "record_load", None)
        if rec is not None:
            rec(shard, nbytes)

    def rebind(self, label: str, shard: str, nbytes: int = 0) -> None:
        rb = getattr(self.inner, "rebind", None)
        if rb is not None:
            rb(label, shard, nbytes)

    def set_capacity(self, shard: str, weight: float) -> None:
        sc = getattr(self.inner, "set_capacity", None)
        if sc is not None:
            sc(shard, weight)

    def forget(self, label: str) -> None:
        fg = getattr(self.inner, "forget", None)
        if fg is not None:
            fg(label)

    def retire_shard(self, shard: str) -> None:
        rs = getattr(self.inner, "retire_shard", None)
        if rs is not None:
            rs(shard)

    def name(self) -> str:
        return f"replicated({self.inner.name()},r={self.n_replicas})"


@dataclasses.dataclass
class PlacementDecision:
    shard: str
    label: str
    grouped: bool           # True if an affinity key drove the decision


class PlacementEngine:
    """Unified placement for data objects AND compute tasks (paper §3.3).

    ``affinity_fn=None`` (or a fn returning None) degrades to the baseline
    random (key-hash) placement the paper compares against.
    """

    def __init__(self, shards: Sequence[str],
                 affinity_fn: Optional[AffinityFunction] = None,
                 policy: Optional[PlacementPolicy] = None):
        self._shards: List[str] = list(shards)
        self.affinity_fn = affinity_fn
        self.policy = policy or HashPlacement()
        self.pins: Dict[str, str] = {}    # label -> shard (migration)
        # label -> home memo: placement is sticky per label for every
        # policy here (hash/rendezvous are pure, load-aware binds once),
        # so lookups after the first are dict hits instead of blake2b
        # hashes.  Invalidated per label on pin/unpin and wholesale when
        # the shard set changes (autoscaler resharding assigns .shards).
        self._home_cache: Dict[str, str] = {}
        self._replica_cache: Dict[str, List[str]] = {}
        # shard -> failure-domain label (see set_domain); empty until a
        # topology-aware caller threads one through
        self.shard_domains: Dict[str, str] = {}

    @property
    def shards(self) -> List[str]:
        return self._shards

    @shards.setter
    def shards(self, value: Sequence[str]) -> None:
        self._shards = list(value)
        self._home_cache.clear()
        self._replica_cache.clear()

    def place(self, desc: Descriptor) -> PlacementDecision:
        label = affinity_key_for(self.affinity_fn, desc)
        shard = self.home_of(label)
        return PlacementDecision(shard=shard, label=label,
                                 grouped=(label != desc.key))

    def home_of(self, label: str) -> str:
        shard = self._home_cache.get(label)
        if shard is not None:
            return shard
        pinned = self.pins.get(label)
        if pinned is not None and pinned in self._shards:
            shard = pinned
        else:
            shard = self.policy.place(label, self._shards)
        self._home_cache[label] = shard
        return shard

    def replica_homes(self, label: str) -> List[str]:
        """All shards holding the group (primary first). Length 1 unless
        the policy is replicated."""
        homes = self._replica_cache.get(label)
        if homes is not None:
            return homes
        rep = getattr(self.policy, "replica_shards", None)
        if rep is None:
            homes = [self.home_of(label)]
        else:
            homes = rep(label, self._shards)
            pinned = self.pins.get(label)
            if pinned is not None and pinned in self._shards:
                k = max(len(homes), 1)
                homes = ([pinned] + [s for s in homes if s != pinned])[:k]
        self._replica_cache[label] = homes
        return homes

    # -- load + migration hooks --------------------------------------------

    def record_load(self, shard: str, nbytes: int) -> None:
        rec = getattr(self.policy, "record_load", None)
        if rec is not None:
            rec(shard, nbytes)

    def set_capacity(self, shard: str, weight: float) -> None:
        """Tier-aware throughput weight for capacity-normalized policies
        (no-op for pure-hash policies, which ignore load entirely)."""
        sc = getattr(self.policy, "set_capacity", None)
        if sc is not None:
            sc(shard, weight)

    def set_domain(self, shard: str, domain: str) -> None:
        """Failure-domain (rack/zone) label for a shard.  Kept on the
        engine for repair-time topology queries and threaded to policies
        that spread over domains (``ReplicatedPlacement``); domain-blind
        policies ignore it."""
        self.shard_domains[shard] = domain
        sd = getattr(self.policy, "set_domain", None)
        if sd is not None:
            sd(shard, domain)
            self._replica_cache.clear()

    def pin(self, label: str, shard: str, nbytes: int = 0) -> None:
        """Override a group's home (installed by GroupMigrator)."""
        assert shard in self._shards, (shard, self._shards)
        self.pins[label] = shard
        self._home_cache.pop(label, None)
        self._replica_cache.pop(label, None)
        rb = getattr(self.policy, "rebind", None)
        if rb is not None:
            rb(label, shard, nbytes)

    def unpin(self, label: str) -> None:
        self.pins.pop(label, None)
        self._home_cache.pop(label, None)
        self._replica_cache.pop(label, None)

    def pinned_labels(self, shards: Sequence[str]) -> List[str]:
        """Labels currently pinned to any of ``shards``, in pin order —
        the gangs stranded when those slots retire or their nodes die."""
        ss = set(shards)
        return [lbl for lbl, sh in self.pins.items() if sh in ss]

    def forget(self, label: str) -> None:
        """Unpin AND drop any sticky policy binding for ``label`` — the
        next ``home_of`` re-runs placement from scratch (used when an
        admission attempt is rolled back)."""
        self.unpin(label)
        fg = getattr(self.policy, "forget", None)
        if fg is not None:
            fg(label)

    # -- elasticity ---------------------------------------------------------

    def add_shard(self, shard: str) -> None:
        if shard not in self._shards:
            self._shards.append(shard)
            self._home_cache.clear()
            self._replica_cache.clear()

    def remove_shard(self, shard: str) -> None:
        self._shards.remove(shard)
        self._home_cache.clear()
        self._replica_cache.clear()
        rs = getattr(self.policy, "retire_shard", None)
        if rs is not None:
            rs(shard)

    def moved_labels(self, labels: Sequence[str],
                     new_shards: Sequence[str]) -> Dict[str, str]:
        """Labels whose home changes under a new shard set (migration plan)."""
        out = {}
        for lbl in labels:
            old = self.policy.place(lbl, self.shards)
            new = self.policy.place(lbl, list(new_shards))
            if old != new:
                out[lbl] = new
        return out
