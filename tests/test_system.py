"""End-to-end behaviour: the paper's full story on one process.

1. RCP pipeline through the affinity runtime beats the LB baselines as the
   deployment scales (paper §4).
2. The same affinity core routes LLM serving sessions (paper §7.2).
3. A training job checkpoint-restarts deterministically (fault tolerance).
"""
import numpy as np
import pytest

from repro.pipelines.rcp.app import Layout, RCPApp
from repro.pipelines.rcp.data import make_scene
from repro.runtime import AZURE_NET
from repro.runtime.scheduler import LeastLoadedScheduler, RandomScheduler


def _run(grouped, scheduler, net=None, layout=Layout(3, 5, 5), frames=120):
    kw = {"net": net} if net is not None else {}
    app = RCPApp([make_scene("gates3", frames)], layout, grouped=grouped,
                 scheduler=scheduler, **kw)
    app.stream()
    app.run()
    return app.summary(warmup=30)


def test_e2e_policy_ladder():
    """affinity <= least-loaded <= random in median E2E latency."""
    aff = _run(True, None)
    ll = _run(False, LeastLoadedScheduler())
    rnd = _run(False, RandomScheduler(0))
    assert aff["median"] <= ll["median"] * 1.05
    assert aff["median"] <= rnd["median"] * 1.05
    assert aff["remote_gets"] == 0
    assert rnd["remote_gets"] > 0


def test_e2e_azure_gap_is_larger():
    """On the cloud profile (ms RTTs) the affinity gap widens (paper §5)."""
    aff_c = _run(True, None)
    rnd_c = _run(False, RandomScheduler(0))
    aff_a = _run(True, None, net=AZURE_NET)
    rnd_a = _run(False, RandomScheduler(0), net=AZURE_NET)
    gap_cluster = rnd_c["median"] - aff_c["median"]
    gap_azure = rnd_a["median"] - aff_a["median"]
    assert gap_azure >= gap_cluster


def test_e2e_throughput_sustained():
    """Affinity keeps up with the 2.5 FPS offered load (no queue growth)."""
    s = _run(True, None, frames=150)
    # p95 bounded -> the pipeline is stable, frames don't pile up
    assert s["p95"] < 2.0


@pytest.mark.slow
def test_e2e_full_paper_workload():
    """3 clients x 700 frames, the paper's full workload (slow)."""
    app = RCPApp([make_scene(v, 700) for v in
                  ("little3", "hyang5", "gates3")], Layout(3, 5, 5),
                 grouped=True)
    app.stream()
    app.run()
    s = app.summary()
    assert s["n"] >= 1700 and s["remote_gets"] == 0
