"""Compile a WorkflowGraph onto the store + DES runtime.

``WorkflowRuntime`` is the workflow layer's only imperative piece: it
turns a validated :class:`~repro.workflows.graph.WorkflowGraph` into

  * one node per tier slot with the tier's resource vector,
  * one ``CascadeStore`` object pool per declared pool — instance-grouped
    pools get :class:`repro.core.affinity.InstanceAffinity`, so every key
    of a workflow instance shares one affinity label across every pool,
  * one registered UDL per stage (custom generator bodies verbatim;
    declarative stages synthesized into Get/Compute/Put op streams with
    join-barrier fan-in),
  * optional ``GroupMigrator`` ticks on pools marked ``migratable``.

**Workflow-atomic placement** (SAGA-style): with ``gang_pin=True`` each
``submit`` installs, at its virtual admission time, a ``PlacementEngine``
pin for the instance's label in *every* instance-grouped pool, all on the
same shard slot.  The slot is chosen by the anchor pool's policy (so a
``load_aware`` policy yields admission-time least-loaded gang placement),
and because data and compute flow through the same engine, the pin drags
the whole instance — objects *and* stage tasks — onto one slot.

``InstanceTracker`` does the per-instance accounting the RCP app used to
hand-roll: join-barrier arrival counts, per-stage spans, end-to-end
latency, and deadline/SLO hits.

With ``batching=True`` a :class:`repro.workflows.batching.StageBatcher`
sits between the synthesized stage generators and the DES: same-stage
firings on the same shard slot within a window execute as one amortized
``BatchCompute`` (the slot is what gang placement made coincide), while
the tracker's per-instance accounting stays exact.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

from repro.core import (AtomicGroupUpdate, CascadeStore, EpochFence,
                        GroupSequencer,
                        HashPlacement, InstanceAffinity,
                        LoadAwarePlacement, PrefetchEngine,
                        RendezvousPlacement,
                        ReplicatedPlacement, instance_label, instance_of,
                        workflow_key)
from repro.core.placement import PlacementPolicy
from repro.runtime import (CLUSTER_NET, AutoScaler, AutoscalePolicy,
                           Compute, FailureEvent, FaultInjector, Get,
                           NetProfile, Put, ReplicaScheduler, RetryPolicy,
                           Runtime, Scheduler, ShardLocalScheduler,
                           SimFuture, StageStats, TraceConfig,
                           TraceRecorder, WaitFor, replace_gang_pins)
from repro.runtime.batching import BatchCostModel
from .batching import BatchPolicy, StageBatcher
from .blame import BlameTable
from .graph import INSTANCE, Stage, WorkflowGraph
from .planner import AdaptiveBatchPolicy, BatchPlanner

POLICIES = {"hash": HashPlacement,
            "load_aware": LoadAwarePlacement,
            "rendezvous": RendezvousPlacement}


# ---------------------------------------------------------------------------
# Per-instance accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class InstanceRecord:
    instance: str
    t_submit: float
    deadline: Optional[float] = None          # absolute virtual time
    t_complete: Optional[float] = None
    arrivals: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    inputs: Dict[str, List[str]] = dataclasses.field(
        default_factory=lambda: defaultdict(list))
    fired: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    done: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))

    @property
    def latency(self) -> Optional[float]:
        if self.t_complete is None:
            return None
        return self.t_complete - self.t_submit

    @property
    def missed_deadline(self) -> bool:
        return (self.deadline is not None and self.t_complete is not None
                and self.t_complete > self.deadline)


class InstanceTracker:
    """Fan-in counters + end-to-end / per-stage latency accounting.

    Per-stage spans land in bounded :class:`repro.runtime.StageStats`
    sketches (O(1) update, fixed memory) instead of per-sample lists, so
    the adaptive batch planner can read p50/p95/p99 on every flush
    decision at million-event scale.  End-to-end latency percentiles stay
    numpy-exact over the per-instance records by default; with
    ``evict_completed=True`` a finished instance is folded into streaming
    aggregates and its record dropped the moment every stage has fired —
    records then hold only in-flight instances and tracker memory is
    bounded by concurrency, not horizon (the fig9 long-horizon mode).
    """

    def __init__(self, graph: WorkflowGraph, evict_completed: bool = False):
        self.graph = graph
        self.evict_completed = evict_completed
        self.records: Dict[str, InstanceRecord] = {}
        self.stage_stats: Dict[str, StageStats] = defaultdict(StageStats)
        self._sinks = {s.name: s.firings for s in graph.sink_stages}
        self._expected_done = {s.name: s.firings for s in graph.stages}
        # streaming aggregates over completed instances (the only record
        # of evicted ones; maintained regardless so both modes agree)
        self.e2e = StageStats()
        # completion listeners (the autoscaler's pressure window): each
        # gets every end-to-end span as it completes, O(1) per completion
        self.e2e_sinks: List[Any] = []
        self.admitted = 0
        self.retired = 0
        self.completed_with_deadline = 0
        self.completed_deadline_misses = 0

    def admit(self, instance: str, t: float,
              deadline: Optional[float] = None) -> InstanceRecord:
        assert instance not in self.records, f"duplicate submit {instance!r}"
        rec = InstanceRecord(
            instance=instance, t_submit=t,
            deadline=(t + deadline) if deadline is not None else None)
        self.records[instance] = rec
        self.admitted += 1
        return rec

    def arrive(self, instance: str, stage: str, key: str,
               t: float) -> InstanceRecord:
        rec = self.records[instance]
        rec.arrivals[stage] += 1
        rec.inputs[stage].append(key)
        return rec

    def fire(self, instance: str, stage: str) -> int:
        """Record a body execution; returns the 0-based firing index."""
        rec = self.records[instance]
        seq = rec.fired[stage]
        rec.fired[stage] = seq + 1
        return seq

    def stage_done(self, instance: str, stage: str, t0: float,
                   t1: float) -> None:
        rec = self.records[instance]
        rec.done[stage] += 1
        self.stage_stats[stage].observe(t1 - t0)
        if rec.t_complete is None and all(
                rec.done.get(s, 0) >= n for s, n in self._sinks.items()):
            rec.t_complete = t1
            self.e2e.observe(t1 - rec.t_submit)
            for sink in self.e2e_sinks:
                sink(t1 - rec.t_submit)
            if rec.deadline is not None:
                self.completed_with_deadline += 1
                if t1 > rec.deadline:
                    self.completed_deadline_misses += 1
        # retire on the event that makes the record final — which may be
        # a side-branch firing AFTER the sinks completed, so re-check on
        # every stage_done once complete, not just at completion
        if self.evict_completed and rec.t_complete is not None and \
                self._fully_done(rec):
            self.records.pop(instance)
            self.retired += 1

    def _fully_done(self, rec: InstanceRecord) -> bool:
        """Every stage fired its expected per-instance count — no further
        event can touch this record, so it is safe to retire."""
        done = rec.done
        return all(done.get(s, 0) >= n
                   for s, n in self._expected_done.items())

    # -- results -----------------------------------------------------------

    def latencies(self) -> List[float]:
        """Latencies of completed instances still retained (all of them
        unless ``evict_completed`` retired some)."""
        return [r.latency for r in self.records.values()
                if r.latency is not None]

    def summary(self) -> Dict[str, Any]:
        import numpy as np
        out: Dict[str, Any] = {
            "n_submitted": self.admitted,
            "n": self.e2e.count,
        }
        if self.retired:
            # long-horizon mode: per-sample history is gone by design —
            # report the streaming aggregates (sketch-accurate)
            if self.e2e.count:
                out.update(median=self.e2e.quantile(0.5),
                           p75=self.e2e.quantile(0.75),
                           p95=self.e2e.quantile(0.95),
                           p99=self.e2e.quantile(0.99),
                           mean=self.e2e.mean)
        else:
            lats = self.latencies()
            if lats:
                arr = np.array(lats)
                out.update(median=float(np.median(arr)),
                           p75=float(np.percentile(arr, 75)),
                           p95=float(np.percentile(arr, 95)),
                           p99=float(np.percentile(arr, 99)),
                           mean=float(arr.mean()))
        # deadline accounting: completed misses are streamed; instances
        # admitted with a deadline but never completed count as misses
        open_deadline = sum(1 for r in self.records.values()
                            if r.deadline is not None
                            and r.t_complete is None)
        with_deadline = self.completed_with_deadline + open_deadline
        if with_deadline:
            misses = self.completed_deadline_misses + open_deadline
            out["slo_misses"] = misses
            out["slo_miss_rate"] = misses / with_deadline
        out["stages"] = {
            s: {"n": st.count,
                "median": st.quantile(0.5),
                "p99": st.quantile(0.99)}
            for s, st in self.stage_stats.items() if st.count}
        return out


# ---------------------------------------------------------------------------
# The compiler / driver
# ---------------------------------------------------------------------------

class WorkflowRuntime:
    """Compile ``graph`` and drive event-triggered instances through it.

    Placement knobs mirror the RCP app so every workflow can run the same
    sweeps: ``grouped=False`` drops affinity functions (raw key-hash
    baseline), ``placement`` picks the per-pool policy, ``read_replicas``
    wraps it in ``ReplicatedPlacement``, ``migrate_every`` enables the
    migration driver on pools marked migratable, and ``gang_pin`` turns on
    workflow-atomic admission.  ``hedge_after`` arms batch-level hedged
    execution (see ``repro.workflows.batching``) and
    :meth:`enable_faults` wires node-death repair — gang re-pinning,
    stranded-object migration, fault-aware admission — to a
    :class:`repro.runtime.FaultInjector`.
    """

    def __init__(self, graph: WorkflowGraph, *, grouped: bool = True,
                 placement: str = "hash", read_replicas: int = 1,
                 caching: bool = True, net: NetProfile = CLUSTER_NET,
                 scheduler: Optional[Scheduler] = None, seed: int = 0,
                 migrate_every: Optional[float] = None,
                 gang_pin: bool = False,
                 anchor_pool: Optional[str] = None,
                 unpin_on_complete: bool = False,
                 batching: bool = False,
                 batch_policy: Optional[BatchPolicy] = None,
                 cost_model: Optional[BatchCostModel] = None,
                 adaptive_batching: bool = False,
                 adaptive_policy: Optional[AdaptiveBatchPolicy] = None,
                 hedge_after: Optional[float] = None,
                 evict_completed: bool = False,
                 log_tasks: bool = True,
                 admission: Optional[str] = None,
                 admission_margin: float = 0.0,
                 admission_defer: float = 0.02,
                 admission_max_defer: float = 0.2,
                 exactly_once: bool = False,
                 brownout: Optional[float] = None,
                 prefetch: bool = False,
                 speculative: bool = False,
                 speculative_budget: int = 64 << 20,
                 prefetch_budget: int = 1 << 30,
                 tracing: Any = False):
        if not graph._validated:
            graph.validate()
        batching = batching or adaptive_batching
        assert not (gang_pin and not grouped), \
            "gang_pin needs instance affinity (grouped=True)"
        assert not (batching and not graph.instance_tracking), \
            "batching needs synthesized (instance-tracked) stages"
        assert admission in (None, "reject", "defer"), admission
        assert not (admission and not graph.instance_tracking), \
            "admission control needs an instance-tracked graph"
        assert hedge_after is None or batching, \
            "hedged execution rides the StageBatcher (batching=True)"
        assert not (exactly_once and not graph.instance_tracking), \
            "exactly_once needs an instance-tracked graph"
        prefetch = prefetch or speculative
        assert not (prefetch and not graph.instance_tracking), \
            "prefetch needs an instance-tracked graph (key enumeration)"
        self.graph = graph
        self.grouped = grouped
        self.placement = placement
        self.read_replicas = read_replicas
        self.gang_pin = gang_pin
        self.unpin_on_complete = unpin_on_complete
        self.tracker = InstanceTracker(graph,
                                       evict_completed=evict_completed)
        # exactly-once ordered delivery (paper §3.4 wired into recovery):
        # a GroupSequencer gates stage bodies per instance label so
        # failover / retry / hedge replays cannot reorder one group's
        # stage executions, and duplicated trigger deliveries dedupe on
        # the idempotence key (pool/instance/stage/seq is the object key)
        self.exactly_once = exactly_once
        self.sequencer: Optional[GroupSequencer] = \
            GroupSequencer() if exactly_once else None
        self.dup_triggers_dropped = 0
        self.on_sequenced: Optional[Any] = None  # hook(label, stage, key, t)

        nodes: List[str] = []
        resources: Dict[str, Dict[str, int]] = {}
        profiles: Dict[str, Any] = {}
        for tier in graph.tiers.values():
            # spares exist in the cluster (idle, outside every pool) so
            # the autoscaler can grow onto them without rebuilding state
            for n in tier.nodes + tier.spare_nodes:
                nodes.append(n)
                resources[n] = dict(tier.resources)
                profiles[n] = tier.profile
        store = CascadeStore(nodes)
        store.cache_enabled = caching

        instance_pools: List[str] = []
        for pool in graph.pools:
            pool_nodes = graph.nodes_of(pool)
            regex = None
            fn = None
            if grouped and pool.affinity == INSTANCE:
                fn = InstanceAffinity()
                instance_pools.append(pool.prefix)
            elif grouped and pool.affinity is not None:
                regex = pool.affinity
            p = store.create_object_pool(pool.prefix, pool_nodes,
                                         pool.shards,
                                         replication=pool.replication,
                                         affinity_set_regex=regex,
                                         policy=self._make_policy(
                                             pool.shards),
                                         affinity_fn=fn)
            # tier-aware placement: weight each slot by its members'
            # throughput FOR THE WORK THIS POOL TRIGGERS (a CPU tier's
            # gpu-speed 0.2 must not hide behind its cpu-speed 1.0 when
            # the pool's stages are gpu-bound); uniform tiers leave the
            # default 1.0 weights untouched — byte-stable
            stage_res = {s.resource for s in graph.stages_on(pool.prefix)}
            for shard in p.shards.values():
                w = sum(max((profiles[n].speed_of(r) for r in stage_res),
                            default=profiles[n].nominal_speed)
                        for n in shard.nodes)
                if shard.nodes and w != float(len(shard.nodes)):
                    p.engine.set_capacity(shard.name,
                                          w / len(shard.nodes))
        self._instance_pools = instance_pools
        if anchor_pool is None and instance_pools:
            anchor_pool = instance_pools[0]
        self.anchor_pool = anchor_pool
        assert not gang_pin or anchor_pool is not None, \
            "gang_pin needs at least one instance-affinity pool"
        if gang_pin:
            # the slot chosen on the anchor must mean the same thing in
            # every pinned pool — unequal shard counts would leave the
            # higher slots of bigger pools permanently unused
            counts = {p.prefix: p.shards for p in graph.pools
                      if p.prefix in instance_pools}
            assert len(set(counts.values())) == 1, \
                f"gang_pin needs equal shard counts across " \
                f"instance-grouped pools, got {counts}"

        if scheduler is None:
            scheduler = (ReplicaScheduler(store) if read_replicas > 1
                         else ShardLocalScheduler())
        self.rt = Runtime(store, resources, net=net, scheduler=scheduler,
                          seed=seed, hedge_after=hedge_after,
                          log_tasks=log_tasks, node_profiles=profiles)
        self.store = store
        # affinity-driven prefetch (paper §3.4): at admission the whole
        # downstream graph and (under gang_pin) every future read's home
        # are known, so declared-read objects ship to their predicted
        # fire nodes as overlapped NIC transfers while upstream stages
        # compute.  ``speculative`` additionally begins a fan-in stage's
        # data staging at the FIRST barrier arrival, toward the predicted
        # fire node — mispredicted bytes land in
        # ``wasted_speculative_bytes``, and the staging gate keeps
        # pending + wasted under ``speculative_budget`` at all times.
        self.prefetcher: Optional[PrefetchEngine] = (
            PrefetchEngine(store, max_bytes_per_plan=prefetch_budget)
            if prefetch else None)
        self.speculative = speculative and prefetch
        self.speculative_budget = speculative_budget
        self.wasted_speculative_bytes = 0
        self._spec_pending: Dict[Tuple[str, str], List[Any]] = {}
        self._spec_pending_bytes = 0
        # causal tracing + blame aggregation (``tracing`` is False, True,
        # or a TraceConfig).  The recorder observes only: enabling it
        # reproduces every latency byte-for-byte (tested).
        self.tracer: Optional[TraceRecorder] = None
        self.blame: Optional[BlameTable] = None
        if tracing:
            cfg = tracing if isinstance(tracing, TraceConfig) else None
            self.tracer = TraceRecorder(cfg).attach(self.rt.sim)
            self.blame = BlameTable()
            self.tracer.on_complete.append(self.blame.add)
            self.rt.trace_of = self._trace_of
        self.fault_injector: Optional[FaultInjector] = None
        self.fault_repins = 0
        # split-brain fencing: every gang-repair claim advances the
        # label's epoch, and replace_gang_pins drops claims whose token
        # went stale — a partitioned minority (or a superseded repair)
        # can never double-pin.  Fault-free runs never advance an epoch.
        self.fence = EpochFence()
        self._deferred_labels: set = set()   # migrations blocked by a cut
        # brownout degraded mode: `brownout` is the down-fraction per
        # degradation level (e.g. 0.25 -> losing a quarter of the active
        # fleet engages level 1).  At level L every synthesized stage
        # with a degraded_cost and priority < L fires its cheap variant
        # instead of shedding work — capacity loss costs quality first,
        # completions last.  None (default) never touches the cost path.
        self.brownout = brownout
        self.brownout_level = 0
        self.brownout_engagements = 0
        self.degraded_firings = 0

        # failure-domain topology: stamp every node from its tier's
        # striping and thread each slot's (unanimous) member domain into
        # the pool engines so replication spreads anti-affinity and
        # repair can avoid the dead zone.  Unstriped graphs skip all of
        # it — no labels, byte-identical placement.
        if any(t.domains > 1 for t in graph.tiers.values()):
            for name, nd in self.rt.nodes.items():
                nd.domain = graph.domain_of(name)
            for p in store.pools.values():
                for shard in p.shards.values():
                    doms = {self.rt.nodes[n].domain for n in shard.nodes}
                    if len(doms) == 1 and "" not in doms:
                        p.engine.set_domain(shard.name, doms.pop())
        self.planner: Optional[BatchPlanner] = None
        self.batcher: Optional[StageBatcher] = None
        if batching:
            batch_policy = batch_policy or BatchPolicy()
            # one cost model instance prices planning AND execution
            cost_model = cost_model or BatchCostModel(
                max_batch=batch_policy.max_batch)
            if adaptive_batching:
                self.planner = BatchPlanner(graph, self.tracker,
                                            cost_model=cost_model,
                                            policy=adaptive_policy)
            self.batcher = StageBatcher(self.rt, policy=batch_policy,
                                        cost_model=cost_model,
                                        planner=self.planner)
        if migrate_every is not None:
            for pool in graph.pools:
                if pool.migratable:
                    self.rt.enable_migration(pool.prefix,
                                             interval=migrate_every)

        # admission control (SAGA-style workflow-level gate): a deadline
        # submission is admitted only if the planner's critical-path tail
        # estimate on the current tier mix fits its headroom at the
        # virtual admission instant; otherwise it is rejected outright
        # ("reject") or re-checked ("defer") until headroom or feasibility
        # runs out
        self.admission = admission
        self.admission_margin = admission_margin
        self.admission_defer = admission_defer
        self.admission_max_defer = admission_max_defer
        self.admission_rejects = 0
        self.admission_deferrals = 0
        if admission is not None:
            # the adaptive planner doubles as the estimator when present
            # (one set of span sketches, one tail memo); otherwise a
            # dedicated estimator-only planner reads the same tracker
            self.admission_planner = self.planner or BatchPlanner(
                graph, self.tracker,
                cost_model=cost_model or BatchCostModel())
        else:
            self.admission_planner = None
        self.autoscaler: Optional[AutoScaler] = None

        for stage in graph.stages:
            pool = graph.pool_of(stage.pool)
            task = (stage.body if not graph.instance_tracking
                    else self._make_task(stage))
            self.rt.register(stage.pool, task, order_of=stage.order_of,
                             resource=stage.resource,
                             pool_nodes=graph.nodes_of(pool),
                             name=stage.name)

    def _trace_of(self, key: str):
        """Executor hook: the live trace (if sampled) owning ``key`` —
        stage tasks it launches get their op intervals categorized."""
        return self.tracer.live.get(instance_of(key))

    def _make_policy(self, n_shards: int) -> PlacementPolicy:
        base = POLICIES[self.placement]()
        if self.read_replicas > 1:
            return ReplicatedPlacement(
                base, n_replicas=min(self.read_replicas, n_shards))
        return base

    # -- stage synthesis ---------------------------------------------------

    def _make_task(self, stage: Stage):
        def task(ctx, key, value):
            inst = instance_of(key)
            if self.exactly_once:
                # idempotence: the object key doubles as the delivery's
                # idempotence key (pool/instance/stage-seq/index), so a
                # replayed trigger re-delivers a key this stage already
                # saw — dropping it keeps arrival counts and join
                # barriers exact under duplicated puts
                rec0 = self.tracker.records.get(inst)
                if rec0 is not None and \
                        key in rec0.inputs.get(stage.name, ()):
                    self.dup_triggers_dropped += 1
                    return
            rec = self.tracker.arrive(inst, stage.name, key, ctx.now)
            tracer = self.tracer
            tr = tracer.live.get(inst) if tracer is not None else None
            if tr is not None:
                # ingress: submit -> first stage activation (the trigger
                # put's transfer + dispatch); remote-priced ⇒ network
                t_in = tr.marks.pop("ingress", None)
                if t_in is not None and ctx.now > t_in:
                    cat = ("network"
                           if ctx.now - t_in > tracer.local_cut
                           else "other")
                    tracer.span(tr, cat, "ingress", t_in, ctx.now)
            if stage.join and \
                    rec.arrivals[stage.name] < stage.expected_arrivals:
                if tr is not None:
                    # remember when the barrier opened (first arrival)
                    tr.marks.setdefault(("join", stage.name), ctx.now)
                if self.speculative:
                    # speculative fan-in staging: the barrier is still
                    # open, but this input exists NOW — start shipping it
                    # (and, on the first arrival, the stage's declared
                    # reads) to the predicted fire node
                    self._stage_speculative(inst, stage, key)
                return                              # barrier not ready
            if stage.join and self._spec_pending:
                self._settle_speculative(inst, stage.name, ctx.node)
            if tr is not None and stage.join:
                t_first = tr.marks.pop(("join", stage.name), None)
                if t_first is not None:
                    # barrier skew: first input ready -> last input here
                    tracer.span(tr, "barrier", f"join:{stage.name}",
                                t_first, ctx.now)
            lbl = None
            if self.sequencer is not None:
                # per-group FIFO: park this firing until every earlier
                # firing of the same instance label released the gate —
                # ordered replay across failover / retry / hedge
                # duplicates.  The gate sits AFTER the join barrier so a
                # parked firing never withholds a barrier arrival.
                lbl = instance_label(inst)
                gate = SimFuture()
                self.sequencer.admit(lbl, gate)
                head = self.sequencer.ready(lbl)
                if head is not None:       # uncontended: our own gate
                    ctx.runtime.sim.resolve(head)
                yield WaitFor(gate)
                if self.on_sequenced is not None:
                    self.on_sequenced(lbl, stage.name, key, ctx.now)
            t0 = ctx.now
            seq = self.tracker.fire(inst, stage.name)
            try:
                if stage.body is not None:
                    yield from stage.body(ctx, key, value)
                else:
                    if stage.join:
                        # fan-in: fetch every input that arrived before us
                        for k in rec.inputs[stage.name]:
                            if k != key:
                                yield Get(k, required=False)
                    for r in stage.reads:
                        for k in r.keys(inst):
                            yield Get(k, required=r.required, wait=r.wait)
                    if stage.cost > 0:
                        if self.brownout_level > 0 and \
                                stage.degraded_cost is not None and \
                                stage.priority < self.brownout_level:
                            # brownout: fire the cheap variant — same
                            # events, same emits, same accounting, less
                            # service demand.  Bypasses the batcher (the
                            # degraded variant is priced standalone)
                            self.degraded_firings += 1
                            if stage.degraded_cost > 0:
                                yield Compute(stage.resource,
                                              stage.degraded_cost)
                        elif self.batcher is not None and stage.batchable:
                            yield from self.batcher.compute(
                                ctx, stage, deadline=rec.deadline)
                        else:
                            yield Compute(stage.resource, stage.cost)
                    for e in stage.emits:
                        for i in range(e.fanout):
                            yield Put(workflow_key(e.pool, inst,
                                                   f"{stage.name}{seq}", i),
                                      ("wf", inst, stage.name, seq, i),
                                      size=e.size)
                self.tracker.stage_done(inst, stage.name, t0, ctx.now)
                if rec.t_complete is not None and rec.t_complete == ctx.now:
                    self._on_complete(inst)
            finally:
                if lbl is not None:
                    # release the gate even if the body died mid-flight
                    # (a failed task must not wedge its group forever)
                    self.sequencer.complete(lbl)
                    nxt = self.sequencer.ready(lbl)
                    if nxt is not None:
                        ctx.runtime.sim.resolve(nxt)
        return task

    def _on_complete(self, instance: str) -> None:
        if self.tracer is not None:
            tr = self.tracer.live.get(instance)
            if tr is not None:
                self.tracer.complete(tr, self.rt.sim.now)
        if self.gang_pin and self.unpin_on_complete:
            label = instance_label(instance)
            for prefix in self._instance_pools:
                self.store.pools[prefix].engine.unpin(label)

    # -- driving -----------------------------------------------------------

    def preload(self, key: str, value: Any = None, size: int = 0,
                at: float = 0.0) -> None:
        """Store a shared object (e.g. an index slab) without triggering."""
        self.rt.client_put(at, key, value, size=size, fire_udls=False)

    def submit(self, instance: str, at: float, value: Any = None,
               size: int = 0, deadline: Optional[float] = None) -> None:
        """Admit one workflow instance at virtual time ``at``.

        Under ``gang_pin`` the admission event (scheduled just before the
        triggering put) picks one shard slot through the anchor pool's
        policy and pins the instance's label there in every
        instance-grouped pool — workflow-atomic placement.

        With ``admission`` enabled and a deadline given, the submission
        first passes the feasibility gate at its virtual arrival time:
        if ``now + slot backlog + service critical path`` (priced on the
        live tier mix) cannot fit the deadline, the instance is rejected
        (or deferred and re-checked) instead of being admitted to miss.
        """
        assert self.graph.instance_tracking, \
            "submit() needs an instance-tracked graph"
        assert "_" not in instance and "/" not in instance, instance
        if self.tracer is not None:
            # the blame window opens at the ORIGINAL submit time, so an
            # admission defer shows up inside it (trace e2e may exceed
            # tracker latency, which restarts at the admission instant)
            tr = self.tracer.begin(instance, at)
            if tr is not None:
                tr.marks["ingress"] = at
        if self.admission is not None and deadline is not None:
            self.rt.sim.at(at, self._admission_check,
                           (instance, at, value, size, at + deadline))
            return
        if self.gang_pin:
            self.rt.sim.at(at, lambda: self._admit_pins(instance))
        self.tracker.admit(instance, at, deadline=deadline)
        key = workflow_key(self.graph.source_pool, instance, "event", 0)
        self.rt.client_put(at, key, value, size=size)
        if self.prefetcher is not None:
            self.rt.sim.at(at, self._queue_prefetch, instance)

    def _admission_backlog(self) -> float:
        """Queue delay ahead of a fresh admission: the source pool's MEAN
        per-lane admitted-but-unfinished compute seconds.  The span
        sketches lag a *building* queue — they only see completions — so
        this live term is what lets the gate say no while the ramp is
        still steepening.  The mean (not the emptiest node) is
        deliberate: admissions spread over every slot, and right after a
        scale-out one fresh empty node would otherwise collapse the
        estimate and admit a doomed wave before its queue materializes."""
        names = self._active_source_nodes()
        if not names:
            return 0.0
        return sum(self._node_backlog(self.rt.nodes[n])
                   for n in names) / len(names)

    def _active_source_nodes(self) -> List[str]:
        """Member nodes of the source pool's ACTIVE slots.  The engine's
        shard list is authoritative — ``pool.shards`` additionally
        retains retired (drained) slots for straggler resolution, and
        counting those would dilute the backlog mean with empty nodes
        and price the service path at hardware that no longer serves."""
        pool = self.store.pools[self.graph.source_pool]
        return [n for s in pool.engine.shards
                for n in pool.shards[s].nodes]

    def _pinned_nodes(self, instance: str) -> List[str]:
        """Member nodes of the slot ``instance`` is gang-pinned to."""
        anchor = self.store.pools[self.anchor_pool]
        return anchor.shards[
            anchor.engine.home_of(instance_label(instance))].nodes

    def _node_backlog(self, node) -> float:
        worst = 0.0
        for r, cap in node.capacity.items():
            if cap and r != "nic":
                pend = node.pending[r]
                if self.batcher is not None:
                    # work enrolled in still-forming batches is committed
                    # but not yet in Node.pending — price it at this
                    # node's rate so the gate can't be gamed by windows
                    pend += self.batcher.forming_seconds(node.name, r) \
                        / max(node.rate(r), 1e-9)
                worst = max(worst, pend / cap)
        return worst

    def _nodes_backlog(self, names: List[str]) -> float:
        """Per-lane committed compute seconds on a slot (least-loaded
        member serves the gang, so take the min across members)."""
        return min((self._node_backlog(self.rt.nodes[n]) for n in names),
                   default=0.0)

    def _min_active_speed(self, resource: str) -> float:
        """Slowest service rate for ``resource`` among the source pool's
        CURRENT member nodes — the conservative "current tier mix" speed
        the admission estimate prices stage costs at (a scale-out onto a
        slower tier immediately makes the gate more cautious)."""
        speeds = [self.rt.nodes[n].rate(resource)
                  for n in self._active_source_nodes()]
        return min(speeds) if speeds else 1.0

    def _admission_check(self, arg: Tuple) -> None:
        instance, t_submit, value, size, deadline_abs = arg
        now = self.rt.sim.now
        # Feasibility on the live cluster: queue delay already committed
        # plus the pure-service critical path at the current tier mix's
        # speed.  Deliberately NOT the realized-span sketches: those lag
        # a building ramp and stay sticky-high long after one drains.
        # Under gang placement the
        # check is per-slot — pin first, price the exact slot this
        # workflow would join (its backlog, its hardware speed), and
        # unpin if the answer is no — so a deep slow-tier slot rejects
        # while a drained fast slot still admits.
        if self.gang_pin:
            self._admit_pins(instance)
            nodes = self._pinned_nodes(instance)
            est = (self._nodes_backlog(nodes)
                   + self.admission_planner.service_path(
                       lambda r: min(self.rt.nodes[n].rate(r)
                                     for n in nodes)))
        else:
            est = (self._admission_backlog()
                   + self.admission_planner.service_path(
                       self._min_active_speed))
        if now + est + self.admission_margin <= deadline_abs:
            if self.tracer is not None:
                tr = self.tracer.live.get(instance)
                if tr is not None and now > t_submit:
                    self.tracer.span(tr, "admission_defer", "admission",
                                     t_submit, now)
                    tr.marks["ingress"] = now
            self.tracker.admit(instance, now,
                               deadline=deadline_abs - now)
            key = workflow_key(self.graph.source_pool, instance,
                               "event", 0)
            self.rt.client_put(now, key, value, size=size)
            if self.prefetcher is not None:
                self.rt.sim.at(now, self._queue_prefetch, instance)
            return
        if self.gang_pin:
            # roll the trial placement back completely (forget, not just
            # unpin): a deferral retry must re-rank slots from scratch so
            # it can see capacity the autoscaler added in the meantime
            label = instance_label(instance)
            for prefix in self._instance_pools:
                self.store.pools[prefix].engine.forget(label)
        retry_at = now + self.admission_defer
        if self.admission == "defer" and \
                retry_at <= t_submit + self.admission_max_defer and \
                retry_at < deadline_abs:
            self.admission_deferrals += 1
            self.rt.sim.at(retry_at, self._admission_check, arg)
            return
        self.admission_rejects += 1
        if self.tracer is not None:
            self.tracer.instant(None, "admission_reject", now,
                                {"instance": instance})
            self.tracer.drop(instance)     # never ran: no blame record
        if self.autoscaler is not None:
            self.autoscaler.observe_reject()   # shed demand = pressure

    def enable_autoscale(self, slo: float,
                         policy: Optional[AutoscalePolicy] = None,
                         pools: Optional[List[str]] = None,
                         spares: Optional[List[str]] = None) -> AutoScaler:
        """Attach an SLO-pressure :class:`repro.runtime.AutoScaler` to the
        workflow's instance pools and start it ticking inside the DES.

        The scaler reshards every instance pool in lockstep (preserving
        the gang-pin equal-slot invariant), consumes spare nodes declared
        on the pools' tiers (``Tier.spares``), and reads its latency
        pressure from this runtime's completion stream.  With no explicit
        ``policy`` the pool's current slot count becomes the scale-in
        floor.
        """
        pools = pools or list(self._instance_pools)
        assert pools, "autoscaling needs at least one instance pool"
        if spares is None:
            spares, seen = [], set()
            for prefix in pools:
                for t in self.graph.pool_of(prefix).tiers:
                    if t not in seen:
                        seen.add(t)
                        spares.extend(self.graph.tiers[t].spare_nodes)
        if policy is None:
            policy = AutoscalePolicy(
                min_shards=len(self.store.pools[pools[0]].engine.shards))
        scaler = AutoScaler(self.rt, pools, spares, slo, policy=policy)
        self.tracker.e2e_sinks.append(scaler.observe_latency)
        self.autoscaler = scaler
        return scaler.start()

    # -- fault tolerance ----------------------------------------------------

    def enable_faults(self,
                      retry: Optional[RetryPolicy] = None) -> FaultInjector:
        """Create (once) a :class:`repro.runtime.FaultInjector` against
        this runtime and wire workflow-atomic repair to it: on a node
        death that leaves a slot with no live member, every gang pinned
        there is re-pinned to a surviving slot and its objects follow as
        charged migrations (:meth:`_on_node_down`), and fresh admissions
        stop landing on dead slots (:meth:`_admit_pins`).  An attached
        autoscaler needs no extra wiring — its pressure reads ``Node.up``
        directly — and hedged batching reacts through the batch future,
        so the three repair layers compose without ordering constraints.

        ``retry`` arms bounded retry probes on stalled tasks: instead of
        sleeping until the dead node recovers, a stranded compute is
        re-dispatched to a surviving replica shard after an exponential
        backoff, up to ``retry.max_attempts`` within ``retry.timeout``
        (exhaustion degrades to the stall-until-recovery baseline).
        """
        if self.fault_injector is None:
            inj = FaultInjector(self.rt, retry=retry)
            inj.on_down.append(self._on_node_down)
            # a heal finishes what a cut deferred: re-pin gangs still on
            # dead slots and move the object copies that could not cross
            inj.on_heal.append(self._on_heal)
            if self.brownout is not None:
                inj.on_down.append(self._brownout_eval)
                inj.on_up.append(self._brownout_eval)
            self.fault_injector = inj
        return self.fault_injector

    def _brownout_eval(self, ev: Optional[FailureEvent] = None) -> None:
        """Recompute the degradation level from the live down-fraction of
        the active (pool-member) fleet.  Engagements count level raises;
        recovery lowers the level back toward 0 and restores full-cost
        firings automatically (the cost pick reads the level per firing).
        """
        names = {n for p in self.graph.pools
                 for n in self.graph.nodes_of(p)}
        down = sum(1 for n in names if not self.rt.nodes[n].up)
        level = int(down / max(len(names), 1) / self.brownout + 1e-9)
        if level > self.brownout_level:
            self.brownout_engagements += 1
        self.brownout_level = level

    def _gang_pools(self) -> List[str]:
        """Instance pools with the anchor first (the order
        ``replace_gang_pins`` expects: pools[0] places, the rest follow)."""
        return [self.anchor_pool] + [p for p in self._instance_pools
                                     if p != self.anchor_pool]

    def _slot_dead(self, pool, sname: str) -> bool:
        nodes = pool.shards[sname].nodes
        rt_nodes = self.rt.nodes
        return bool(nodes) and all(not rt_nodes[n].up for n in nodes)

    def _on_node_down(self, ev: FailureEvent) -> None:
        """FaultInjector ``on_down`` listener: workflow-atomic gang repair.

        A slot with no live member can serve neither compute nor reads at
        replication 1, so every gang pinned to such a slot is re-pinned —
        same surviving slot INDEX in every instance pool, preserving the
        equal-slot invariant — and the stranded labels' objects move to
        their new homes as charged migrations (required Gets must keep
        resolving).  Replicated pools only top up a missing copy at the
        new primary home and keep the source replicas; a death that
        leaves the slot with a live member moves nothing (the replica
        scheduler and nearest-replica reads already route around it).
        """
        if not self.gang_pin:
            return
        self._repair_slots(avoid_domain=ev.domain
                           if ev.kind == "domain" else "")

    def _on_heal(self, ev: FailureEvent) -> None:
        """Partition heal: run the repair sweep the cut blocked (gangs
        still pinned to dead slots get majority-placed homes now that the
        whole fleet is a repair target again) and finish the deferred
        cross-cut object migrations."""
        if not self.gang_pin:
            return
        self._repair_slots()
        labels, self._deferred_labels = self._deferred_labels, set()
        if labels:
            for prefix in self._gang_pools():
                self._migrate_stranded(self.store.pools[prefix], labels)

    def _repair_slots(self, avoid_domain: str = "") -> None:
        anchor_pool = self.store.pools[self.anchor_pool]
        anchor = anchor_pool.engine
        dead = [s for s in anchor.shards
                if self._slot_dead(anchor_pool, s)]
        if not dead:
            return
        survivors = [s for s in anchor.shards if s not in dead]
        p = self.rt.sim.partition
        if p is not None:
            # split-brain safety: repair authority lives on the majority
            # side of the cut (group 0) — a slot across the partition is
            # alive but unpinnable, and if no majority-side slot
            # survives, repair waits for heal instead of letting the
            # minority elect itself (the fence would reject its pins
            # anyway; not attempting them keeps pin state clean)
            survivors = [s for s in survivors
                         if all(p.get(n, 0) == 0
                                for n in anchor_pool.shards[s].nodes)]
        stranded = anchor.pinned_labels(dead)
        if not survivors or not stranded:
            return          # total outage / cut-off, or nobody pinned
        # claim: one fence epoch per gang; replace_gang_pins re-checks at
        # commit so a stale claim (superseded mid-flight) pins nothing
        epochs = {lbl: self.fence.advance(lbl) for lbl in stranded}
        pools = self._gang_pools()
        placed = replace_gang_pins(self.store, pools, stranded, survivors,
                                   fence=self.fence, epochs=epochs,
                                   avoid_domain=avoid_domain)
        self.fault_repins += len(placed)
        labels = set(placed)
        for prefix in pools:
            self._migrate_stranded(self.store.pools[prefix], labels)

    def _migrate_stranded(self, pool, labels) -> None:
        """Make every object of ``labels`` reachable at its (re-pinned)
        primary home, charging the copy bytes like any migration.

        The relocation commits per group through
        :meth:`repro.core.AtomicGroupUpdate.move_group`: a stranded
        group's records move all-or-nothing, so a fault arriving during
        gang repair can never leave a group half-migrated (some keys at
        the new home, some marooned on the dead slot).  Replication 1
        moves (the dead copy is the only other one and keeping it would
        resurrect stale data if the label ever hashes back); replicated
        pools top up the missing copy and keep the source replicas.
        """
        replicated = isinstance(pool.engine.policy, ReplicatedPlacement)
        tracer = self.tracer
        tr_of: Dict[str, Any] = {}
        if tracer is not None:
            for inst, tr in tracer.live.items():
                lbl = instance_label(inst)
                if lbl in labels:
                    tr_of[lbl] = tr
        # stage: collect every stranded record per group, mutating nothing
        sim = self.rt.sim
        staged: Dict[str, List[Tuple[Any, str, Any]]] = {}
        placed = set()
        for shard in list(pool.shards.values()):
            for key, rec in list(shard.objects.items()):
                if key in placed or rec.affinity not in labels:
                    continue
                home = pool.home(key)
                if home.name == shard.name or key in home.objects:
                    placed.add(key)
                    continue
                if sim.partition is not None and not any(
                        sim.reachable(a, b)
                        for a in shard.nodes for b in home.nodes):
                    # the copy would cross the cut: defer to heal (the
                    # read side parks on the same condition, so nothing
                    # observes the stale location meanwhile)
                    self._deferred_labels.add(rec.affinity)
                    continue
                placed.add(key)
                staged.setdefault(rec.affinity, []).append(
                    (shard, key, rec))
        # commit: one atomic move per group, then charge the copies
        mover = AtomicGroupUpdate(self.store)
        for label, moves in staged.items():
            mover.move_group(pool, label, moves, keep_source=replicated)
            for _, key, rec in moves:
                home = pool.home(key)
                self.store.stats.bytes_migrated += rec.size
                if home.nodes:
                    self.rt.sim._charge_transfer(
                        self.rt.nodes[home.nodes[0]], rec.size)
                    tr = tr_of.get(label)
                    if tr is not None:
                        now = self.rt.sim.now
                        tracer.span(
                            tr, "migration", f"migrate:{pool.prefix}",
                            now,
                            now + self.rt.sim.net.transfer_time(rec.size),
                            node=home.nodes[0], args={"bytes": rec.size})
                self.store.invalidate_cached([key])
        self.store.stats.migrations += len(staged)

    # -- affinity-driven prefetch (paper §3.4) -------------------------------

    def _queue_prefetch(self, instance: str) -> None:
        """Defer issuance past every event already queued at this virtual
        instant: the trigger put installs its record, gang pins land, and
        same-time preloads (per-instance adapters stored right after
        ``submit``) become visible — so the planner sees the admission-
        time world, not a half-built one."""
        self.rt.sim.at(self.rt.sim.now, self._issue_prefetch, instance)

    def _stage_trigger_keys(self, stage: Stage, inst: str) -> List[str]:
        """Every key that will ever trigger ``stage`` for ``inst``, in
        emit order.  Fully enumerable at admission: upstream firing
        counts and fanouts are static (``validate()``), and the key
        schema is deterministic (``workflow_key``)."""
        out: List[str] = []
        for u in self.graph.stages:
            for e in u.emits:
                if e.pool == stage.pool:
                    for seq in range(u.firings):
                        for i in range(e.fanout):
                            out.append(workflow_key(
                                e.pool, inst, f"{u.name}{seq}", i))
        if not out and stage.pool == self.graph.source_pool:
            out.append(workflow_key(stage.pool, inst, "event", 0))
        return out

    def _home_node(self, key: str) -> Optional[str]:
        """The node a task triggered by ``key`` will run on: first up
        member of the key's home shard (exactly the
        ``ShardLocalScheduler`` pick at replication 1; an approximation
        — costing only warm-up precision, never correctness — beyond)."""
        shard = self.store.pool_for(key).home(key)
        rt_nodes = self.rt.nodes
        for n in shard.nodes:
            if rt_nodes[n].up:
                return n
        return shard.nodes[0] if shard.nodes else None

    def _predict_fire_node(self, stage: Stage, inst: str) -> Optional[str]:
        """Predicted fire node of a join stage: the barrier fires where
        its LAST arrival lands, and with same-sized emits delivered FIFO
        the last-enumerated trigger key is the one that arrives last.  A
        misprediction costs counted speculative bytes, never
        correctness."""
        keys = self._stage_trigger_keys(stage, inst)
        return self._home_node(keys[-1]) if keys else None

    def _issue_prefetch(self, instance: str) -> None:
        """Admission-time plan flow (the tentpole): for every downstream
        synthesized stage, ship its declared-read objects to the node(s)
        the stage will fire on, as overlapped NIC transfers issued while
        the upstream stages compute.  Join INPUTS do not exist yet and
        are skipped by the planner; the speculative path handles them as
        they materialize."""
        if instance not in self.tracker.records:
            return                       # rejected / already retired
        per_node: Dict[str, List[str]] = {}
        for stage in self.graph.stages:
            if stage.body is not None or not stage.reads:
                continue
            read_keys = [k for r in stage.reads for k in r.keys(instance)]
            if not read_keys:
                continue
            if stage.join:
                nodes = [self._predict_fire_node(stage, instance)]
            else:
                seen: set = set()
                nodes = []
                for k in self._stage_trigger_keys(stage, instance):
                    n = self._home_node(k)
                    if n is not None and n not in seen:
                        seen.add(n)
                        nodes.append(n)
            for n in nodes:
                if n is not None:
                    per_node.setdefault(n, []).extend(read_keys)
        sim = self.rt.sim
        for node_name, keys in per_node.items():
            fresh = [k for k in keys
                     if (node_name, k) not in sim.prefetch_futures]
            if not fresh:
                continue
            plan = self.prefetcher.plan_for_keys(fresh, node_name)
            if plan is not None:
                self._issue_plan(instance, plan)

    def _stage_speculative(self, inst: str, stage: Stage,
                           key: str) -> None:
        """Ship one early barrier arrival (plus, on the first call, the
        stage's declared reads) toward the predicted fire node, within
        the wasted-bytes budget."""
        pend = self._spec_pending.get((inst, stage.name))
        if pend is None:
            node = self._predict_fire_node(stage, inst)
            if node is None:
                return
            pend = self._spec_pending[(inst, stage.name)] = [node, 0]
            keys = [k for r in stage.reads for k in r.keys(inst)]
            keys.append(key)
        else:
            node = pend[0]
            keys = [key]
        sim = self.rt.sim
        keys = [k for k in keys
                if (node, k) not in sim.prefetch_futures]
        if not keys:
            return
        # hard bound: pending + wasted never exceed the budget, so the
        # bytes that can ever turn out wasted are bounded by construction
        remaining = (self.speculative_budget
                     - self.wasted_speculative_bytes
                     - self._spec_pending_bytes)
        if remaining <= 0:
            return
        eng = self.prefetcher
        cap, eng.max_bytes = eng.max_bytes, min(eng.max_bytes, remaining)
        try:
            plan = eng.plan_for_keys(keys, node, speculative=True)
        finally:
            eng.max_bytes = cap
        if plan is None:
            return
        pend[1] += plan.total_bytes
        self._spec_pending_bytes += plan.total_bytes
        self._issue_plan(inst, plan)

    def _settle_speculative(self, inst: str, stage_name: str,
                            fire_node: str) -> None:
        """The barrier fired: bytes staged to the right node were useful
        (the fire path's gets hit them); bytes staged anywhere else are
        charged to ``wasted_speculative_bytes``."""
        pend = self._spec_pending.pop((inst, stage_name), None)
        if pend is None:
            return
        self._spec_pending_bytes -= pend[1]
        if pend[0] != fire_node:
            self.wasted_speculative_bytes += pend[1]

    def _issue_plan(self, instance: str, plan) -> None:
        """Hand a plan's keys to the DES prefetch channel, version-
        stamped so a racing write/migration voids the install instead of
        caching stale data.  Traced instances get one ``prefetch`` span
        per landed transfer ([issue, install] — the overlapped window)."""
        sim = self.rt.sim
        node = self.rt.nodes[plan.node]
        tr = (self.tracer.live.get(instance)
              if self.tracer is not None else None)
        t0 = sim.now
        for k, ver, sz in zip(plan.keys, plan.versions, plan.sizes):
            def install(nn=plan.node, key=k, ver=ver, t0=t0, tr=tr):
                n = self.store.prefetch_install(nn, key, ver)
                if tr is not None and n and sim.now > t0:
                    self.tracer.span(tr, "prefetch", f"prefetch:{key}",
                                     t0, sim.now, nn)
                return n
            sim.prefetch(node, k, sz, install)

    # -- gang placement -----------------------------------------------------

    def _slot_unadmittable(self, pool, sname: str) -> bool:
        """A fresh gang must not pin here: every member down, or the slot
        sits across an active partition (the client lives on the majority
        side — its trigger put could not even reach the pin)."""
        if self._slot_dead(pool, sname):
            return True
        p = self.rt.sim.partition
        return p is not None and any(p.get(n, 0) != 0
                                     for n in pool.shards[sname].nodes)

    def _admit_pins(self, instance: str) -> None:
        label = instance_label(instance)
        anchor_pool = self.store.pools[self.anchor_pool]
        anchor = anchor_pool.engine
        home = anchor.home_of(label)
        if self.fault_injector is not None and \
                self._slot_unadmittable(anchor_pool, home):
            # fault-aware admission: policy placement is blind to Node.up,
            # so re-place over live slots (same mechanism as gang repair)
            # instead of pinning a fresh gang to a slot that cannot serve
            survivors = [s for s in anchor.shards
                         if not self._slot_unadmittable(anchor_pool, s)]
            if survivors:
                replace_gang_pins(self.store, self._gang_pools(),
                                  [label], survivors)
                return
        slot = anchor.shards.index(home)
        for prefix in self._instance_pools:
            eng = self.store.pools[prefix].engine
            eng.pin(label, eng.shards[slot])

    def pinned_slot(self, instance: str) -> Optional[int]:
        """Shard slot an instance is gang-pinned to (None if unpinned)."""
        label = instance_label(instance)
        anchor = self.store.pools[self.anchor_pool].engine
        shard = anchor.pins.get(label)
        return None if shard is None else anchor.shards.index(shard)

    def run(self, until: float = float("inf")) -> None:
        self.rt.run(until)

    def summary(self) -> Dict[str, Any]:
        out = self.tracker.summary()
        out.update(
            remote_gets=self.store.stats.remote_gets,
            local_gets=self.store.stats.local_gets,
            bytes_remote=self.store.stats.bytes_remote,
            bytes_replica_sync=self.store.stats.bytes_replica_sync,
            migrations=self.store.stats.migrations,
            bytes_migrated=self.store.stats.bytes_migrated,
        )
        if self.batcher is not None:
            out.update(self.batcher.summary())
        if self.rt.hedge_after is not None:
            out.setdefault("hedges", self.rt.hedges)
        if self.fault_injector is not None:
            rep = self.fault_injector.report()
            out["fault_downtime_s"] = round(rep.downtime, 4)
            out["fault_failovers"] = rep.tasks_failed_over
            out["fault_stalled"] = rep.tasks_stalled
            out["fault_repins"] = self.fault_repins
            if self.fault_injector.retry is not None:
                out["fault_retries"] = rep.tasks_retried
            if rep.domain_downtime:
                out["fault_domain_downtime_s"] = {
                    d: round(v, 4)
                    for d, v in sorted(rep.domain_downtime.items())}
            if rep.partition_time:
                out["fault_partition_s"] = round(rep.partition_time, 4)
                out["partition_blocked_gets"] = \
                    self.store.stats.partition_blocked
                out["partition_parked_dispatches"] = \
                    self.rt.sim.partition_parked_dispatches
            out["fence_rejected"] = self.fence.rejected
        if self.prefetcher is not None:
            st = self.store.stats
            out["prefetch_issued"] = self.prefetcher.issued
            out["prefetch_bytes_issued"] = self.prefetcher.bytes_issued
            out["prefetch_installs"] = st.prefetch_installs
            out["prefetch_stale"] = st.prefetch_stale
            out["prefetch_hits"] = st.prefetch_hits
            out["bytes_prefetched"] = st.bytes_prefetched
            out["prefetch_promotions"] = self.rt.sim.prefetch_promotions
            out["prefetch_skipped_over_budget"] = \
                self.prefetcher.skipped_over_budget
            if self.speculative:
                out["wasted_speculative_bytes"] = \
                    self.wasted_speculative_bytes
        if self.brownout is not None:
            out["brownout_engagements"] = self.brownout_engagements
            out["degraded_firings"] = self.degraded_firings
            out["brownout_level"] = self.brownout_level
        if self.exactly_once:
            out["dup_triggers_dropped"] = self.dup_triggers_dropped
            out["seq_max_queue"] = self.sequencer.max_queue_len
        if self.admission is not None:
            out["admission_rejects"] = self.admission_rejects
            out["admission_deferrals"] = self.admission_deferrals
        if self.autoscaler is not None:
            out["scale_events"] = len(self.autoscaler.decisions)
            out["node_seconds"] = round(self.autoscaler.node_seconds(), 4)
        if self.blame is not None:
            out.update(self.blame.flat())
            out.update(self.tracer.summary())
        return out
