"""Heterogeneous backend tiers, SLO-driven elastic scaling, and workflow
admission control (the fig10 subsystem)."""
import pytest

from repro.core import CascadeStore, LoadAwarePlacement
from repro.runtime import (CPU_POOL, GPU_A100, GPU_H100, UNIFORM,
                           AutoScaler, AutoscalePolicy, Compute,
                           HardwareProfile, Node, Runtime,
                           ShardLocalScheduler, node_load)
from repro.workflows import (Emit, WorkflowGraph, WorkflowRuntime,
                             mode_kwargs)

RES = {"gpu": 1, "cpu": 2, "nic": 2}


def _graph(fast=2, spares=2, cost=0.01, fast_profile=GPU_H100,
           spare_profile=GPU_A100):
    g = WorkflowGraph("elastic")
    g.add_tier("fast", fast, RES, profile=fast_profile)
    g.add_tier("slow", 0, RES, profile=spare_profile, spares=spares)
    for p in ("/in", "/out"):
        g.add_pool(p, tier=("fast", "slow"), shards=fast)
    g.add_stage("work", pool="/in", resource="gpu", cost=cost,
                emits=[Emit("/out", fanout=1, size=1024)], sink=True)
    return g.validate()


# -- hardware profiles --------------------------------------------------------

def test_profile_scales_compute_per_resource():
    store = CascadeStore(["f0", "c0"])
    store.create_object_pool("/x", store.nodes, 2,
                             affinity_set_regex=r"/[a-z0-9]+_")
    rt = Runtime(store, node_profiles={"f0": GPU_H100, "c0": CPU_POOL})
    done = {}

    def task(ctx, key, value):
        t0 = ctx.now
        yield Compute("gpu", 0.010)
        done[ctx.node] = ctx.now - t0

    rt.register("/x", task)
    picked = {}
    for g in range(32):                     # one key homed on each node
        picked.setdefault(store.shard_of(f"/x/g{g}_0").nodes[0],
                          f"/x/g{g}_0")
        if len(picked) == 2:
            break
    for key in picked.values():
        rt.client_put(0.0, key, size=0)
    rt.run()
    assert done["f0"] == pytest.approx(0.010 / 2.0)   # H100: gpu 2x
    assert done["c0"] == pytest.approx(0.010 / 0.2)   # CPU pool: gpu 0.2x


def test_uniform_profile_is_the_identity():
    n = Node("n", dict(RES))
    assert n.profile is UNIFORM
    assert n.rate("gpu") == 1.0 and n.rate("cpu") == 1.0
    assert UNIFORM.cost_model() is None
    assert GPU_H100.cost_model().max_batch == 32


def test_node_load_normalizes_by_tier_throughput():
    """Satellite case: fast tier busy -> spill prefers the idle slow tier
    over the queued fast tier; but a fast node's QUEUE still beats a slow
    node's equally deep one (it drains sooner)."""
    fast = Node("f", {"gpu": 1}, profile=GPU_H100)       # gpu speed 2.0
    slow = Node("s", {"gpu": 1}, profile=GPU_A100)       # gpu speed 1.0
    # both idle: dead heat at 0 — occupancy 0 is free at any speed
    assert node_load(fast, "gpu") == node_load(slow, "gpu") == 0.0
    # fast busy (no queue) vs idle slow: idle slow wins
    fast.in_use["gpu"] = 1
    assert node_load(slow, "gpu") < node_load(fast, "gpu")
    # fast busy+queued vs idle slow: idle slow still wins
    fast.queues["gpu"].append((0.0, lambda: None))
    assert node_load(slow, "gpu") < node_load(fast, "gpu")
    # equally queued: the fast tier drains its backlog in half the time
    slow.in_use["gpu"] = 1
    slow.queues["gpu"].append((0.0, lambda: None))
    assert node_load(fast, "gpu") < node_load(slow, "gpu")
    # homogeneous special case: exactly the raw fractional occupancy
    plain = Node("p", {"gpu": 2})
    plain.in_use["gpu"] = 1
    assert node_load(plain, "gpu") == 0.5


def test_pick_batch_spills_to_idle_slow_tier():
    """Satellite case at the scheduler level: a shard spanning tiers
    dispatches a batch to the idle slow member, not the queued fast one."""
    nodes = {"f0": Node("f0", dict(RES), profile=GPU_H100),
             "s0": Node("s0", dict(RES), profile=GPU_A100)}
    nodes["f0"].in_use["gpu"] = 1
    nodes["f0"].queues["gpu"].append((0.0, lambda: None))

    class TwoTierShard:
        name = "/x#s0"
        nodes = ["f0", "s0"]

    sched = ShardLocalScheduler()
    pick = sched.pick_batch(TwoTierShard(), ["/x/a_0"], nodes,
                            ["f0", "s0"], resource="gpu")
    assert pick == "s0"


def test_load_aware_capacity_weights_fill_fast_shards_more():
    pol = LoadAwarePlacement()
    pol.set_capacity("fast", 2.0)
    shards = ["fast", "slow"]
    counts = {"fast": 0, "slow": 0}
    for i in range(30):
        counts[pol.place(f"g{i}", shards)] += 1
    # 2x the weight -> ~2x the groups before looking equally full
    assert counts["fast"] == pytest.approx(20, abs=1)


# -- the scaler ---------------------------------------------------------------

def _scaled_runtime(n=3, spares=2):
    store = CascadeStore([f"n{i}" for i in range(n)]
                         + [f"sp{i}" for i in range(spares)])
    store.create_object_pool("/x", [f"n{i}" for i in range(n)], n,
                             affinity_set_regex=r"/[a-z0-9]+_")
    rt = Runtime(store)
    for g in range(30):
        store.put(f"/x/g{g}_0", b"d" * 100, fire=False)
    return rt, store


def test_scale_in_returns_node_to_spare_no_leak():
    """Regression for the pre-rewrite leak: out -> in -> out must work
    forever because scale-in RETURNS the slot's node to the spare list."""
    rt, store = _scaled_runtime(n=3, spares=1)
    sc = AutoScaler(rt, ["/x"], spare_nodes=["sp0"], slo=0.1,
                    policy=AutoscalePolicy(min_shards=1))
    sc._observed = 1
    for _ in range(3):                       # out -> in cycles
        sc.force(4)
        assert sc.spare == []
        sc.force(3)
        assert len(sc.spare) == 1
    # every object still reachable after all that churn
    for g in range(30):
        assert store.get(f"/x/g{g}_0")[0] is not None


def test_scaler_migration_charges_bytes():
    rt, store = _scaled_runtime()
    sc = AutoScaler(rt, ["/x"], spare_nodes=["sp0", "sp1"], slo=0.1)
    d = sc.force(4)
    assert d.bytes_moved > 0 and d.groups_moved > 0
    assert store.stats.bytes_migrated == d.bytes_moved
    rt.run()                                 # drain the charged transfers
    assert rt.sim.metrics["background_xfer_s"]


def test_pressure_prefers_worst_signal():
    rt, _ = _scaled_runtime()
    sc = AutoScaler(rt, ["/x"], spare_nodes=["sp0"], slo=0.1,
                    policy=AutoscalePolicy(min_samples=2))
    for _ in range(4):
        sc.observe_latency(0.25)             # 2.5x the SLO
    p, signal = sc.pressure()
    assert p == pytest.approx(2.5, rel=0.05) and signal == "p95"
    rt.nodes["n0"].pending["gpu"] = 0.5      # 5x the SLO in backlog
    p, signal = sc.pressure()
    assert p == pytest.approx(5.0, rel=0.05) and signal == "backlog"
    sc.observe_reject()
    p, signal = sc.pressure()                # backlog still dominates
    assert signal == "backlog"


def test_rejects_alone_raise_pressure():
    rt, _ = _scaled_runtime()
    sc = AutoScaler(rt, ["/x"], spare_nodes=["sp0"], slo=0.1)
    assert sc.pressure()[0] == 0.0
    sc.observe_reject()
    p, signal = sc.pressure()
    assert p >= sc.policy.high_pressure and signal == "rejects"


def test_workflow_autoscale_end_to_end_slo_pressure():
    """Overload an elastic workflow: the in-sim controller must scale out
    onto the spare tier, keep every pool's slot count in lockstep, and
    scale back in by the end of the drain."""
    wrt = WorkflowRuntime(_graph(fast=2, spares=2, cost=0.02),
                          **mode_kwargs("atomic+abatch"))
    sc = wrt.enable_autoscale(
        slo=0.08, policy=AutoscalePolicy(interval=0.02, min_samples=4,
                                         min_shards=2))
    # a burst well past the 2-slot capacity, then a light steady tail
    # whose in-SLO completions let the controller settle back down
    for i in range(400):
        wrt.submit(f"i{i}", at=0.01 + i / 1600.0, deadline=0.08)
    for i in range(100):
        wrt.submit(f"t{i}", at=2.0 + i / 100.0, deadline=0.08)
    wrt.run()
    assert any(d.new_shards > d.old_shards for d in sc.decisions)
    assert any(d.new_shards < d.old_shards for d in sc.decisions)
    counts = {p: len(wrt.store.pools[p].engine.shards)
              for p in ("/in", "/out")}
    assert len(set(counts.values())) == 1          # lockstep pools
    assert sc._n_active() + len(sc.spare) == 4     # capacity conserved
    assert wrt.summary()["n"] == 500               # nothing lost


def test_down_member_saturates_pressure():
    """A dead node in the active set is SLO pressure in itself — the
    controller must not wait for the latency echo."""
    rt, _ = _scaled_runtime()
    sc = AutoScaler(rt, ["/x"], spare_nodes=["sp0"], slo=0.1)
    assert sc.pressure()[0] == 0.0
    rt.nodes["n0"].up = False
    p, signal = sc.pressure()
    assert p >= sc.policy.high_pressure and signal == "down"
    rt.nodes["n0"].up = True
    assert sc.pressure()[0] == 0.0


def test_node_outage_provokes_scale_out_and_recovery():
    """Failure-induced pressure reaches the controller: a sustained
    outage at valley load (no latency signal yet) provokes a scale-out
    within one evaluation period of the death, and after recovery the
    fleet settles back with no capacity leak."""
    wrt = WorkflowRuntime(_graph(fast=2, spares=2, cost=0.01),
                          **mode_kwargs("atomic+abatch"))
    sc = wrt.enable_autoscale(
        slo=0.1, policy=AutoscalePolicy(interval=0.02, min_samples=4,
                                        min_shards=2))
    inj = wrt.enable_faults()
    inj.fail_node("fast0", at=0.2, duration=0.4)
    for i in range(120):
        wrt.submit(f"i{i}", at=0.01 + i / 100.0)      # valley load
    for i in range(60):                               # post-recovery tail
        wrt.submit(f"t{i}", at=1.3 + i / 50.0)
    wrt.run()
    outs = [d for d in sc.decisions if d.new_shards > d.old_shards]
    assert outs and outs[0].t <= 0.2 + 2 * 0.02 + 1e-9
    assert "down" in outs[0].reason
    assert any(d.new_shards < d.old_shards for d in sc.decisions)
    assert sc._n_active() + len(sc.spare) == 4        # no capacity leak
    assert wrt.summary()["n"] == 180                  # nothing lost


# -- admission control --------------------------------------------------------

def test_admission_rejects_infeasible_deadline():
    wrt = WorkflowRuntime(_graph(cost=0.02), admission="reject",
                          **mode_kwargs("atomic"))
    wrt.submit("ok", at=0.0, deadline=1.0)       # plenty of headroom
    wrt.submit("doomed", at=0.0, deadline=0.001)  # < service path
    wrt.run()
    s = wrt.summary()
    assert s["admission_rejects"] == 1
    assert s["n"] == 1 and s.get("slo_misses", 0) == 0
    assert "doomed" not in wrt.tracker.records


def test_admission_gate_bounds_queue_misses():
    """Saturate a tiny cluster: without the gate late completions pile
    up; with it, every admitted instance still meets its deadline and the
    overflow is rejected instead of served late."""
    def drive(**kw):
        wrt = WorkflowRuntime(_graph(fast=2, spares=0, cost=0.02),
                              **dict(mode_kwargs("atomic+abatch"), **kw))
        for i in range(150):
            wrt.submit(f"i{i}", at=0.01 + i / 2000.0, deadline=0.10)
        wrt.run()
        return wrt.summary()

    ungated = drive()
    gated = drive(admission="reject", admission_margin=0.03)
    assert ungated.get("slo_misses", 0) > 10
    assert gated.get("slo_misses", 0) == 0
    assert gated["admission_rejects"] > 0
    assert gated["n"] + gated["admission_rejects"] == 150


def test_admission_defer_admits_when_scaler_adds_capacity():
    """Deferral pays off exactly when the cluster can CHANGE under the
    waiting request: with fixed capacity, clock time and queue drain
    cancel out (est + now is invariant), but a scale-out adds an empty
    slot the retry re-places onto — converting a would-be reject into a
    served request (the forget-on-rollback path)."""
    wrt = WorkflowRuntime(_graph(fast=1, spares=1, cost=0.02),
                          admission="defer", admission_defer=0.02,
                          admission_max_defer=0.5,
                          **mode_kwargs("atomic"))
    sc = wrt.enable_autoscale(
        slo=0.2, policy=AutoscalePolicy(interval=0.02, min_samples=2,
                                        min_shards=1))
    for i in range(30):
        wrt.submit(f"w{i}", at=0.0)                   # no deadline: admit
    wrt.submit("d", at=0.001, deadline=0.3)
    wrt.run()
    s = wrt.summary()
    assert any(d.new_shards > d.old_shards for d in sc.decisions)
    assert s["admission_deferrals"] > 0
    assert s["admission_rejects"] == 0
    assert wrt.tracker.records["d"].t_complete is not None
    assert not wrt.tracker.records["d"].missed_deadline


def test_hardware_profile_cost_model_prices_tiers_differently():
    h, c = GPU_H100.cost_model(), CPU_POOL.cost_model()
    # H100 amortizes deeply; the CPU pool barely at all
    assert h.batch_seconds(1.0, 8) < c.batch_seconds(1.0, 8)
    assert h.speedup(8) > 2.5 > c.speedup(8)
    # drain_rate is the planner's capacity side: items/s at depth n
    assert h.drain_rate(0.01, 8) > h.drain_rate(0.01, 1)
