"""Architecture registry: the 10 assigned archs + the paper's RCP pipeline.

``get_config(name)`` returns the full published config; ``get_smoke(name)``
returns the reduced same-family config used by CPU smoke tests.  Shapes live
in ``shapes.py``; ``cells()`` enumerates the (arch x shape) dry-run grid with
skip annotations.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.common import ModelConfig
from .shapes import SHAPES, ShapeConfig

_MODULES = {
    "granite-3-2b": "granite_3_2b",
    "deepseek-7b": "deepseek_7b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen2.5-32b": "qwen2_5_32b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "hubert-xlarge": "hubert_xlarge",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "mamba2-780m": "mamba2_780m",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
}

ARCH_NAMES = list(_MODULES)


def _module(name: str):
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


# ---------------------------------------------------------------------------
# (arch x shape) grid with skip rules
# ---------------------------------------------------------------------------

SUBQUADRATIC = {"recurrentgemma-9b", "mamba2-780m"}
ENCODER_ONLY = {"hubert-xlarge"}


def skip_reason(arch: str, shape: str) -> Optional[str]:
    if arch in ENCODER_ONLY and shape in ("decode_32k", "long_500k"):
        return "encoder-only: no autoregressive decode step"
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return "pure full-attention arch: 500k decode requires sub-quadratic attention (see DESIGN.md)"
    return None


def cells() -> List[Tuple[str, str, Optional[str]]]:
    """All 40 (arch, shape, skip_reason) cells."""
    out = []
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            out.append((arch, shape, skip_reason(arch, shape)))
    return out


def runnable_cells() -> List[Tuple[str, str]]:
    return [(a, s) for a, s, skip in cells() if skip is None]
