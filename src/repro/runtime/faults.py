"""Fault injection + tolerance: node failures, shard failover, stragglers.

Failure semantics mirror a replicated Cascade deployment:
  * when a node dies, its queued tasks are re-dispatched to surviving shard
    members (replication >= 2) or stall until recovery (replication == 1 —
    objects are memory-resident, so an unreplicated shard is unavailable);
  * stragglers are modeled as per-node service-speed multipliers; hedged
    execution re-issues a task to a second shard member when it has waited
    in queue beyond `hedge_after` seconds, first completion wins.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .executor import Runtime
from .simulation import Node


@dataclasses.dataclass
class FailureEvent:
    node: str
    t_down: float
    t_up: float


class FaultInjector:
    def __init__(self, runtime: Runtime):
        self.rt = runtime
        self.events: List[FailureEvent] = []

    def fail_node(self, node: str, at: float, duration: float) -> None:
        ev = FailureEvent(node=node, t_down=at, t_up=at + duration)
        self.events.append(ev)
        self.rt.sim.at(at, lambda: self._down(ev))
        self.rt.sim.at(ev.t_up, lambda: self._up(ev))

    def _down(self, ev: FailureEvent) -> None:
        node = self.rt.nodes[ev.node]
        node.up = False
        # re-dispatch queued work to surviving shard members
        for resource, q in list(node.queues.items()):
            stranded = list(q)
            q.clear()
            for enq, fn in stranded:
                target = self._failover_target(ev.node)
                if target is None:
                    # no replica: stall until recovery
                    node.queues[resource].append((enq, fn))
                else:
                    self.rt.sim.acquire(self.rt.nodes[target], resource, fn,
                                        enq_time=enq)

    def _up(self, ev: FailureEvent) -> None:
        node = self.rt.nodes[ev.node]
        node.up = True
        # drain anything that stalled while down
        for resource in list(node.queues):
            while (node.queues[resource]
                   and node.in_use[resource] < node.capacity.get(resource, 1)):
                enq, fn = node.queues[resource].popleft()
                node.in_use[resource] += 1
                node.queue_wait += self.rt.sim.now - enq
                fn()

    def _failover_target(self, failed: str) -> Optional[str]:
        # a surviving member of any shard containing the failed node
        for pool in self.rt.store.pools.values():
            for shard in pool.shards.values():
                if failed in shard.nodes:
                    for n in shard.nodes:
                        if n != failed and self.rt.nodes[n].up:
                            return n
        return None


def set_straggler(runtime: Runtime, node: str, speed: float) -> None:
    """speed < 1.0 slows the node's compute (e.g. 0.5 = 2x slower)."""
    runtime.nodes[node].speed = speed


@dataclasses.dataclass
class AvailabilityReport:
    downtime: float
    tasks_failed_over: int
    tasks_stalled: int
