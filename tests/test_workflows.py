"""Workflow-graph subsystem: validation, fan-in/fan-out accounting,
affinity propagation, gang pinning, SLO tracking, and the fig7 claim
(workflow-atomic placement beats key-hash scatter at the tail)."""
import pytest

from repro.core import instance_label, instance_of, workflow_key
from repro.pipelines.rcp.app import Layout, RCPApp
from repro.pipelines.rcp.data import make_scene
from repro.workflows import (Emit, WorkflowGraph, WorkflowGraphError,
                             WorkflowRuntime, mode_kwargs, preload_index,
                             rag_workflow, speech_workflow)

RES = {"gpu": 1, "cpu": 2, "nic": 2}


# -- key helpers --------------------------------------------------------------

def test_workflow_key_roundtrip():
    k = workflow_key("/cands", "req7", "retrieve0", 3)
    assert k == "/cands/req7_retrieve0_3"
    assert instance_of(k) == "req7"
    assert instance_label("req7") == "/req7_"


def test_workflow_key_rejects_reserved_chars():
    with pytest.raises(AssertionError):
        workflow_key("/p", "a_b", "s", 0)


# -- graph validation ---------------------------------------------------------

def test_graph_rejects_unknown_pool():
    g = WorkflowGraph("bad")
    g.add_tier("t", 2, RES)
    g.add_pool("/a", tier="t", shards=2)
    g.add_stage("s", pool="/missing")
    with pytest.raises(WorkflowGraphError, match="unknown trigger pool"):
        g.validate()


def test_graph_rejects_cycle():
    g = WorkflowGraph("loop")
    g.add_tier("t", 2, RES)
    g.add_pool("/a", tier="t", shards=2)
    g.add_pool("/b", tier="t", shards=2)
    g.add_stage("s1", pool="/a", emits=[Emit("/b")])
    g.add_stage("s2", pool="/b", emits=[Emit("/a")])
    with pytest.raises(WorkflowGraphError, match="cycle"):
        g.validate()


def test_graph_rejects_undersized_tier():
    g = WorkflowGraph("tiny")
    g.add_tier("t", 2, RES)
    with pytest.raises(WorkflowGraphError, match="nodes"):
        g.add_pool("/a", tier="t", shards=2, replication=2)


def test_fan_in_accounting():
    rag = rag_workflow(shards=2, n_docs=5)
    by = {s.name: s for s in rag.stages}
    assert by["retrieve"].expected_arrivals == 1
    assert by["rerank"].expected_arrivals == 5      # join over the fan-out
    assert by["rerank"].firings == 1
    assert by["generate"].expected_arrivals == 1
    assert rag.source_pool == "/queries"
    assert [s.name for s in rag.sink_stages] == ["generate"]

    sp = speech_workflow(shards=2)
    by = {s.name: s for s in sp.stages}
    assert by["intent"].expected_arrivals == 1
    assert by["diarize"].expected_arrivals == 1
    assert by["action"].expected_arrivals == 2      # joins both branches
    assert by["action"].firings == 1


# -- end-to-end ---------------------------------------------------------------

def run_shape(make, mode, n=24, shards=3, **kw):
    g = make(shards=shards)
    wrt = WorkflowRuntime(g, **mode_kwargs(mode), **kw)
    if make is rag_workflow:
        preload_index(wrt)
    for i in range(n):
        wrt.submit(f"req{i}", at=0.05 + i * 0.02, deadline=0.5)
    wrt.run()
    return wrt


@pytest.mark.parametrize("make", [rag_workflow, speech_workflow],
                         ids=["rag", "speech"])
def test_all_instances_complete(make):
    wrt = run_shape(make, "atomic")
    s = wrt.summary()
    assert s["n"] == s["n_submitted"] == 24
    assert s["median"] > 0
    assert set(s["stages"]) == {st.name for st in wrt.graph.stages}


def test_join_barrier_fires_once_per_instance():
    wrt = run_shape(speech_workflow, "affinity", n=10)
    per_inst = [r for r in wrt.tracker.records.values()]
    for rec in per_inst:
        assert rec.arrivals["action"] == 2
        assert rec.fired["action"] == 1
        assert rec.done["action"] == 1


def test_affinity_propagation_all_stages_one_group():
    """Every object a workflow instance touches shares one affinity label."""
    wrt = run_shape(rag_workflow, "affinity", n=12)
    seen = 0
    for pool in wrt.store.pools.values():
        for shard in pool.shards.values():
            for key, rec in shard.objects.items():
                inst = instance_of(key)
                if inst and inst.startswith("req"):
                    assert rec.affinity == instance_label(inst), key
                    seen += 1
    assert seen > 12 * 3      # several objects per instance landed


def test_gang_pin_places_whole_instance_on_one_slot():
    wrt = run_shape(rag_workflow, "atomic", n=12)
    for i in range(12):
        slot = wrt.pinned_slot(f"req{i}")
        assert slot is not None
        label = instance_label(f"req{i}")
        for prefix in wrt._instance_pools:
            pool = wrt.store.pools[prefix]
            home = pool.engine.home_of(label)
            assert list(pool.shards).index(home) == slot, (prefix, i)


def test_unpin_on_complete_releases_pins():
    g = speech_workflow(shards=2)
    wrt = WorkflowRuntime(g, gang_pin=True, placement="load_aware",
                          unpin_on_complete=True)
    for i in range(6):
        wrt.submit(f"req{i}", at=0.01 + i * 0.05)
    wrt.run()
    assert wrt.summary()["n"] == 6
    for prefix in wrt._instance_pools:
        assert not wrt.store.pools[prefix].engine.pins


def test_deadline_slo_tracking():
    g = speech_workflow(shards=2)
    wrt = WorkflowRuntime(g, gang_pin=True, placement="load_aware")
    wrt.submit("fast", at=0.0, deadline=10.0)
    wrt.submit("tight", at=0.0, deadline=1e-6)
    wrt.run()
    s = wrt.summary()
    assert s["slo_misses"] == 1
    assert s["slo_miss_rate"] == 0.5
    assert wrt.tracker.records["tight"].missed_deadline
    assert not wrt.tracker.records["fast"].missed_deadline


def test_shared_index_is_one_hot_group():
    wrt = run_shape(rag_workflow, "affinity", n=8)
    homes = {wrt.store.shard_of(k).name
             for k in wrt.store.group_members("/index", "/corpus_")}
    assert len(homes) == 1      # all slabs collocate: one (hot) group


def test_atomic_beats_keyhash_p99():
    """The fig7 claim at test scale: gang placement <= key-hash scatter."""
    atomic = run_shape(rag_workflow, "atomic", n=30, shards=4).summary()
    scatter = run_shape(rag_workflow, "keyhash", n=30, shards=4).summary()
    assert atomic["p99"] <= scatter["p99"]
    assert atomic["remote_gets"] < scatter["remote_gets"]


def test_submit_requires_tracked_graph():
    app = RCPApp([make_scene("little3", 40)], Layout(1, 1, 1))
    with pytest.raises(AssertionError):
        app.wrt.submit("x", at=0.0)


# -- the RCP port -------------------------------------------------------------

def test_rcp_graph_shape():
    app = RCPApp([make_scene("little3", 40)], Layout(2, 3, 3))
    g = app.graph
    assert [s.name for s in g.stages] == ["MOT", "PRED", "CD"]
    assert g.source_pool == "/frames"
    assert [s.name for s in g.sink_stages] == ["CD"]
    assert [p.prefix for p in g.pools] == \
        ["/frames", "/states", "/positions", "/predictions", "/cd"]
    assert [p.prefix for p in g.pools if p.migratable] == \
        ["/positions", "/predictions"]
    assert app.mot_nodes == ["mot0", "mot1"]
    assert len(app.pred_nodes) == 3


def test_rcp_still_runs_on_workflow_runtime():
    app = RCPApp([make_scene("little3", 40)], Layout(2, 2, 2), grouped=True)
    app.stream()
    app.run()
    s = app.summary(warmup=10)
    assert s["n"] > 0
    assert s["remote_gets"] == 0      # collocation preserved by the port
