"""Jit'd public wrappers for the kernel package with backend dispatch.

Backends:
  * ``jnp``        — the pure-jnp oracle in ``ref.py`` (CPU, dry-run, GSPMD).
  * ``pallas``     — the TPU Pallas kernels (compiled, TPU target).
  * ``interpret``  — Pallas kernels executed with ``interpret=True`` (CPU
                     correctness validation of the kernel bodies).

The model zoo always calls these wrappers; the dry-run keeps the default
``jnp`` backend so XLA:CPU can lower the graph for the 512-device mesh, while
tests flip to ``interpret`` to exercise the Pallas bodies.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional

import jax

from . import ref

_BACKEND = "jnp"


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("jnp", "pallas", "interpret"), name
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


@contextlib.contextmanager
def backend(name: str):
    prev = get_backend()
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


def _pallas_mod():
    from . import flash_attention, decode_attention, ssd_scan, rglru_scan
    return flash_attention, decode_attention, ssd_scan, rglru_scan


# ---------------------------------------------------------------------------


def mha(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
        q_offset=0, q_chunk=0, unroll=False):
    if _BACKEND == "jnp":
        return ref.mha(q, k, v, causal=causal, window=window, softcap=softcap,
                       scale=scale, q_offset=q_offset, q_chunk=q_chunk,
                       unroll=unroll)
    fa, *_ = _pallas_mod()
    return fa.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        q_offset=q_offset, interpret=(_BACKEND == "interpret"))


def decode_attention(q, k_cache, v_cache, lengths, *, softcap=0.0, scale=None,
                     window=0):
    if _BACKEND == "jnp":
        return ref.decode_attention(q, k_cache, v_cache, lengths,
                                    softcap=softcap, scale=scale,
                                    window=window)
    _, da, *_ = _pallas_mod()
    return da.decode_attention(
        q, k_cache, v_cache, lengths, softcap=softcap, scale=scale,
        window=window, interpret=(_BACKEND == "interpret"))


def ssd(x, dt, A, Bm, Cm, D=None, *, chunk=256, init_state=None,
        unroll=False):
    if _BACKEND == "jnp":
        return ref.ssd(x, dt, A, Bm, Cm, D, chunk=chunk,
                       init_state=init_state, unroll=unroll)
    *_, ssd_k, _ = _pallas_mod()
    return ssd_k.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk,
                          init_state=init_state,
                          interpret=(_BACKEND == "interpret"))


def ssd_decode(x, dt, A, Bm, Cm, D, state):
    # Single recurrent step: einsum-bound, no kernel needed.
    return ref.ssd_decode(x, dt, A, Bm, Cm, D, state)


def rglru(a, b, h0=None):
    if _BACKEND == "jnp":
        return ref.rglru(a, b, h0)
    *_, rk = _pallas_mod()
    return rk.rglru_scan(a, b, h0, interpret=(_BACKEND == "interpret"))


def rglru_decode(a, b, h):
    return ref.rglru_decode(a, b, h)
