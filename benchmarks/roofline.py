"""§Roofline: per-(arch x shape) three-term roofline from dry-run artifacts.

compute  = HLO_FLOPs_per_device / 197 TFLOP/s
memory   = kernel-fused HBM model / 819 GB/s   (XLA-unfused shown alongside)
collective = HLO collective bytes per device / (4 x 50 GB/s ICI links)

Reads benchmarks/artifacts/dryrun/*.json produced by repro.launch.dryrun.
"""
import json
from pathlib import Path

from .common import ARTIFACTS, emit

DRYRUN = ARTIFACTS / "dryrun"


def load_cells(mesh="single", rules="baseline"):
    cells = []
    for f in sorted(DRYRUN.glob(f"*__{mesh}__{rules}.json")):
        d = json.loads(f.read_text())
        if not d.get("skip"):
            cells.append(d)
    return cells


def recompute(d):
    """Roofline with the kernel-fused memory model (see roofline_model)."""
    from repro import configs
    from repro.configs.shapes import SHAPES
    from repro.launch import mesh as meshlib
    from repro.launch.roofline_model import tpu_memory_model

    cfg = configs.get_config(d["arch"])
    shape = SHAPES[d["shape"]]
    mem = tpu_memory_model(cfg, shape)
    t_comp = d["flops_per_device"] / meshlib.PEAK_FLOPS_BF16
    t_mem = mem["total"] / meshlib.HBM_BW
    t_coll = d["collective_bytes_per_device"] / (
        4 * meshlib.ICI_BW_PER_LINK)
    peak = max(t_comp, t_mem, t_coll)
    return {
        "compute_s": t_comp,
        "memory_s": t_mem,
        "collective_s": t_coll,
        "memory_s_xla_unfused": d["roofline"]["memory_s"],
        "dominant": max((t_comp, "compute"), (t_mem, "memory"),
                        (t_coll, "collective"))[1],
        "roofline_fraction": (t_comp / peak) if peak > 0 else None,
        "mem_terms": mem,
    }


def run(quick=True, rules="baseline"):
    rows = []
    for d in load_cells(rules=rules):
        r = recompute(d)
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append((
            f"roofline/{d['arch']}/{d['shape']}/{rules}",
            step_s * 1e6,
            {
                "compute_s": round(r["compute_s"], 4),
                "memory_s": round(r["memory_s"], 4),
                "collective_s": round(r["collective_s"], 4),
                "dominant": r["dominant"],
                "frac": round(r["roofline_fraction"], 4),
                "useful_flops": round(d.get("useful_flop_ratio") or 0, 3),
                "mem_xla_s": round(r["memory_s_xla_unfused"], 2),
            }))
    return rows


if __name__ == "__main__":
    emit(run())
