"""hubert-xlarge [audio] — encoder-only, w2v2 arch. [arXiv:2106.07447]

Modality frontend is a STUB: ``input_specs()`` supplies precomputed conv
frame features (B, S, 512) which the model projects into d_model.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    head_dim=80,
    mlp_variant="gelu",
    is_causal=False,
    frontend="audio",
    frontend_dim=512,
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke",
    family="encoder",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=32,
    head_dim=16,
    mlp_variant="gelu",
    is_causal=False,
    frontend="audio",
    frontend_dim=24,
)
