"""Serving-plane recovery correctness (paper §7.2 under chaos): row
failover with KV-priced session recovery, bounded turn retries, graceful
shed, and exactly-once commit accounting."""
import jax
import pytest

from repro import configs
from repro.models import build_model
from repro.runtime import FaultInjector, RetryPolicy
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def model_and_params():
    cfg = configs.get_smoke("granite-3-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, svc=None, checkpoint_every=None,
            retry=None, n_rows=3):
    eng = ServingEngine(model, params, n_rows=n_rows, max_slots=8,
                        max_seq=128, policy="affinity",
                        checkpoint_every=checkpoint_every)
    if svc is not None:
        eng._svc = dict(svc)     # pin calibration: identical virtual cost
    if retry is not None:
        eng.retry = retry
    return eng


def _drive(eng, kills=(), n_sessions=8, turns=6, gen=4):
    """Chat turns spaced 2 decode-steps apart; kills are scheduled in
    decode-step units so outages land mid-conversation regardless of the
    host's calibrated step time."""
    dt = eng._svc["decode_step"]
    inj = FaultInjector(serving=eng)
    events = [inj.fail_row(row, at=t0 * dt, duration=dur * dt)
              for row, t0, dur in kills]
    for i in range(n_sessions):
        eng.open_session(f"s{i}")
    t, outs = 0.0, {}
    for _ in range(turns):
        for i in range(n_sessions):
            out, _ = eng.turn(f"s{i}", [1 + i, 2, 3], gen_tokens=gen,
                              now=t)
            outs.setdefault(f"s{i}", []).extend(out)
            t += dt * 2.0
    return outs, events


KILLS = ((0, 40, 30), (1, 55, 30))       # two rows die mid-conversation


def test_row_failover_recovers_every_session_exactly(model_and_params):
    """Both recovery modes reproduce the healthy run's greedy outputs
    token-for-token (zero lost sessions), commit every turn exactly once,
    and the checkpointed engine's p99 is strictly below re-prefill's —
    restoring a snapshot + replaying the suffix beats replaying the full
    transcript."""
    cfg, model, params = model_and_params
    healthy = _engine(model, params)
    svc = healthy._svc
    ours, _ = _drive(healthy)

    ck = _engine(model, params, svc=svc, checkpoint_every=2)
    re = _engine(model, params, svc=svc, checkpoint_every=None)
    outs_ck, ev_ck = _drive(ck, kills=KILLS)
    outs_re, ev_re = _drive(re, kills=KILLS)

    # recovery correctness: chaos is latency, never tokens
    assert outs_ck == ours
    assert outs_re == ours
    for eng, evs in ((ck, ev_ck), (re, ev_re)):
        s = eng.summary()
        assert s["turns_ok"] == 8 * 6          # zero lost turns
        assert s["shed_turns"] == 0
        assert s["dup_effects"] == 0           # exactly-once commits
        assert s["order_violations"] == 0      # per-group FIFO held
        assert s["sessions_displaced"] > 0     # the outages really bit
        assert s["groups_rerouted"] > 0
        assert sum(e.sessions_displaced for e in evs) == \
            s["sessions_displaced"]
    # the engines chose the mode they were configured for
    assert ck.summary()["recoveries_ckpt"] > 0
    assert ck.summary()["recovery_bytes"] > 0
    assert ck.summary()["checkpoint_bytes"] > 0
    assert re.summary()["recoveries_reprefill"] > 0
    assert re.summary()["recoveries_ckpt"] == 0
    # KV-priced recovery: checkpoint restore + suffix replay is strictly
    # cheaper at the tail than re-prefilling the whole transcript
    assert ck.summary()["turn_p99"] < re.summary()["turn_p99"]


def test_inflight_conflict_retries_within_budget(model_and_params):
    """A turn whose row dies inside its service window fails at the death
    instant, backs off, and succeeds on a surviving row — attempts stay
    within the budget and the output still matches the healthy run."""
    cfg, model, params = model_and_params
    healthy = _engine(model, params)
    svc = healthy._svc
    dt = svc["decode_step"]
    healthy.open_session("a")
    h1, _ = healthy.turn("a", [5, 2, 3], gen_tokens=4, now=0.0)
    h2, _ = healthy.turn("a", [7, 8], gen_tokens=32, now=10 * dt)

    eng = _engine(model, params, svc=svc,
                  retry=RetryPolicy(max_attempts=4, backoff=2 * dt))
    inj = FaultInjector(serving=eng)
    eng.open_session("a")
    o1, m1 = eng.turn("a", [5, 2, 3], gen_tokens=4, now=0.0)
    assert o1 == h1 and m1.attempts == 1
    # kill the session's own row three steps into its decode window
    ev = inj.fail_row(m1.row, at=13 * dt, duration=3 * dt)
    o2, m2 = eng.turn("a", [7, 8], gen_tokens=32, now=10 * dt)
    assert o2 == h2                           # retry re-ran it exactly
    assert m2.attempts == 2
    assert m2.attempts <= eng.retry.max_attempts
    assert m2.retry_wait > 0.0
    assert m2.recovered == "reprefill"        # state died with the row
    assert ev.turns_failed == 1
    assert ev.sessions_displaced == 1
    assert eng.summary()["dup_effects"] == 0


def test_exhausted_budget_sheds_turn_and_session_survives(model_and_params):
    """Retry budget exhaustion sheds the turn (no commit, session state
    untouched) instead of spinning; the session keeps working once a row
    is back."""
    cfg, model, params = model_and_params
    healthy = _engine(model, params, n_rows=1)
    svc = healthy._svc
    dt = svc["decode_step"]
    healthy.open_session("a")
    h1, _ = healthy.turn("a", [5, 2, 3], gen_tokens=4, now=0.0)
    h3, _ = healthy.turn("a", [9], gen_tokens=4, now=200 * dt)

    # one row only: when it dies there is nowhere to fail over to
    eng = _engine(model, params, svc=svc, n_rows=1,
                  retry=RetryPolicy(max_attempts=2, backoff=2 * dt))
    inj = FaultInjector(serving=eng)
    eng.open_session("a")
    o1, m1 = eng.turn("a", [5, 2, 3], gen_tokens=4, now=0.0)
    assert o1 == h1
    ev = inj.fail_row(0, at=12 * dt, duration=100 * dt)
    turns_before = eng.sessions["a"].turns
    o2, m2 = eng.turn("a", [7, 8], gen_tokens=32, now=10 * dt)
    assert m2.shed and o2 == []
    assert eng.sessions["a"].turns == turns_before   # nothing committed
    assert eng.summary()["shed_turns"] == 1
    assert ev.turns_failed >= 1
    # after recovery the session still answers (recovering its state),
    # and greedily matches a healthy session with the same committed
    # history — the shed turn left no partial effects behind
    o3, m3 = eng.turn("a", [9], gen_tokens=4, now=200 * dt)
    assert not m3.shed and len(o3) == 4
    assert o3 == h3
    assert eng.summary()["dup_effects"] == 0
