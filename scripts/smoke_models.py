"""Quick dev check: every smoke arch does fwd/loss/prefill/decode on CPU."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import build_model
from repro.models.common import count_params


def batch_for(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    batch = {}
    if cfg.frontend == "audio":
        batch["features"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.frontend_dim)), jnp.float32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        if cfg.frontend == "vision":
            batch["patches"] = jnp.asarray(
                rng.normal(size=(B, cfg.n_patches, cfg.frontend_dim)),
                jnp.float32)
    return batch


def main():
    names = sys.argv[1:] or configs.ARCH_NAMES
    for name in names:
        cfg = configs.get_smoke(name)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        n = count_params(params)
        batch = batch_for(cfg, B=2, S=16)
        loss, _ = jax.jit(model.loss)(params, batch)
        assert jnp.isfinite(loss), (name, loss)
        line = f"{name:30s} params={n:9d} loss={float(loss):8.4f}"
        if cfg.family != "encoder":
            logits, cache = jax.jit(model.prefill)(params, batch)
            assert jnp.all(jnp.isfinite(logits)), name
            lengths = jnp.full((2,), 16, jnp.int32)
            # grow cache to seq 16+4 for decode steps
            cache = jax.tree_util.tree_map(jnp.asarray, cache)
            full = model.init_cache(2, 32)
            def merge(z, c):
                upd = c.astype(z.dtype)
                sl = tuple(slice(0, d) for d in upd.shape)
                return z.at[sl].set(upd)
            cache = jax.tree_util.tree_map(merge, full, cache)
            step = jax.jit(model.decode_step)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            for i in range(3):
                logits2, cache = step(params, cache, tok, lengths + i)
                assert jnp.all(jnp.isfinite(logits2)), (name, i)
                tok = jnp.argmax(logits2, -1).astype(jnp.int32)
            line += " decode=ok"
        print(line)


if __name__ == "__main__":
    main()
