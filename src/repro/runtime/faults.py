"""Fault injection + tolerance: node failures, shard failover, stragglers.

Failure semantics mirror a replicated Cascade deployment:
  * when a node dies, compute admissions still queued on it are
    re-dispatched to a surviving shard member (replication >= 2) or stall
    until recovery (replication == 1 — objects are memory-resident, so an
    unreplicated shard is unavailable);
  * work already in service when the node dies drains in place: the paper's
    deployments fail nodes out of *scheduling*, they do not model losing
    in-flight kernels, and this keeps lane accounting exact;
  * recovery re-admits the stalled queue through the normal release
    accounting (``Simulator.kick``) and then notifies listeners;
  * stragglers are modeled as per-node service-speed multipliers.

The injector is deliberately layer-blind: it only flips ``Node.up`` and
moves typed queue entries.  Higher layers subscribe via ``on_down`` /
``on_up`` to react in their own vocabulary — the workflow runtime re-pins
stranded gangs and migrates their objects, the autoscaler reads the down
fraction as SLO pressure, the stage batcher hedges batches stuck behind a
dead or straggling slot.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from .executor import Runtime
from .simulation import _ComputeStart


@dataclasses.dataclass
class FailureEvent:
    """One scheduled down/up cycle, with per-event outcome counters.

    ``failed_over`` counts queued compute admissions re-dispatched to a
    surviving replica at down time; ``stalled`` counts entries that had no
    replica to go to and waited out the outage in place.
    """
    node: str
    t_down: float
    t_up: float
    failed_over: int = 0
    stalled: int = 0


@dataclasses.dataclass
class AvailabilityReport:
    """Aggregate over every ``FailureEvent`` an injector has fired."""
    downtime: float
    tasks_failed_over: int
    tasks_stalled: int


class FaultInjector:
    """Schedules node outages against a :class:`Runtime`'s simulator.

    ``on_down`` / ``on_up`` listeners are called as ``fn(event)`` after the
    injector has finished its own queue surgery, so listeners observe a
    consistent node state (``up`` flag set, queues settled).
    """

    def __init__(self, runtime: Runtime):
        self.rt = runtime
        self.events: List[FailureEvent] = []
        self.on_down: List[Callable[[FailureEvent], None]] = []
        self.on_up: List[Callable[[FailureEvent], None]] = []

    def fail_node(self, node: str, at: float, duration: float) -> FailureEvent:
        if node not in self.rt.nodes:
            raise KeyError(f"unknown node {node!r}")
        ev = FailureEvent(node=node, t_down=at, t_up=at + duration)
        self.events.append(ev)
        self.rt.sim.at(at, self._down, ev)
        self.rt.sim.at(ev.t_up, self._up, ev)
        return ev

    def report(self) -> AvailabilityReport:
        return AvailabilityReport(
            downtime=sum(ev.t_up - ev.t_down for ev in self.events),
            tasks_failed_over=sum(ev.failed_over for ev in self.events),
            tasks_stalled=sum(ev.stalled for ev in self.events))

    # -- event bodies -------------------------------------------------------

    def _down(self, ev: FailureEvent) -> None:
        sim = self.rt.sim
        node = self.rt.nodes[ev.node]
        node.up = False
        if sim.tracer is not None:
            # the recorder keeps per-node down intervals so lane waits
            # overlapping an outage are blamed fault_stall, not queueing
            sim.tracer.note_down(ev.node, sim.now)
        # Re-dispatch queued compute admissions to a surviving shard
        # member.  Only _ComputeStart entries move: they carry their op and
        # re-price at the target (requeue_compute keeps the pending-seconds
        # signal exact on both nodes).  Anything else queued (hedge lanes,
        # custom callbacks) stays put — its owner holds a reference and
        # decides for itself.
        for resource, q in list(node.queues.items()):
            stranded = list(q)
            q.clear()
            for enq, fn in stranded:
                target = None
                if isinstance(fn, _ComputeStart):
                    target = self._failover_target(ev.node)
                if target is None:
                    # no replica (or unmovable entry): stall until recovery
                    q.append((enq, fn))
                    ev.stalled += 1
                else:
                    ev.failed_over += 1
                    sim.requeue_compute(fn, self.rt.nodes[target],
                                        enq_time=enq)
        for fn in self.on_down:
            fn(ev)

    def _up(self, ev: FailureEvent) -> None:
        node = self.rt.nodes[ev.node]
        node.up = True
        if self.rt.sim.tracer is not None:
            self.rt.sim.tracer.note_up(ev.node, self.rt.sim.now)
        for resource in list(node.queues):
            self.rt.sim.kick(node, resource)
        for fn in self.on_up:
            fn(ev)

    def _failover_target(self, failed: str) -> Optional[str]:
        # a surviving up member of any shard containing the failed node
        for pool in self.rt.store.pools.values():
            for shard in pool.shards.values():
                if failed in shard.nodes:
                    for n in shard.nodes:
                        if n != failed and self.rt.nodes[n].up:
                            return n
        return None


def set_straggler(runtime: Runtime, node: str, speed: float) -> None:
    """speed < 1.0 slows the node's compute (e.g. 0.5 = 2x slower)."""
    runtime.nodes[node].speed = speed
