"""Fig. 10 (ours): SLO-driven elasticity + admission control on a
heterogeneous cluster under a ramping load.

The scenario the tier/elasticity layers exist for: a base tier of fast
GPUs (``GPU_H100``) sized for the valley load, a standby pool of slower
spares (``GPU_A100``) the autoscaler can grow onto, and an arrival ramp
(valley -> peak -> valley) that overruns the base tier at its peak.
Configurations compared on the SAME arrival schedule:

  * ``static{k}``  — fixed provisioning at ``k`` slots for the whole run
    (the InferLine-style planner output, pinned): the small cluster
    melts at the peak, the big one burns node-seconds in the valleys;
  * ``auto``       — base slots plus the SLO-pressure ``AutoScaler``
    growing/shrinking the tier in-sim (group-granular, charged moves);
  * ``auto+admit`` — autoscaling plus the admission gate: submissions
    whose deadline cannot fit the live critical-path estimate are
    rejected at arrival instead of being admitted to miss.

Recorded acceptance (all deterministic):

  1. ``auto+admit`` p99 and SLO-hit-rate beat the static provisioning
     with >= its node-seconds (equal-capacity fairness: elasticity wins
     by *placing* capacity in time, not by using more of it);
  2. admission yields ZERO hopeless-deadline completions — every
     admitted instance that completes meets its deadline (the gate's
     contract), while the no-admission runs complete late instances;
  3. the scaler actually moves: scale-out at the ramp, scale-in after,
     and capacity is conserved (spares return; a second ramp could
     rescale).
"""
import time

from .common import emit

BASE_SLOTS = 4               # fast tier (H100) — the valley provisioning
SPARE_SLOTS = 4              # slow standby tier (A100) the scaler grows onto
SLO = 0.120                  # end-to-end deadline/objective, seconds
# arrival ramp: (duration_s, instances_per_second) phases — the peak is
# ~1.7x what even the fully scaled-out cluster drains, so every
# configuration faces real overload and the difference is HOW it fails
PHASES = ((0.5, 300.0), (1.0, 2400.0), (1.0, 300.0))
# admission margin: covers what the live estimate cannot see — service
# growth from members that join a batch after this instance enrolls,
# plus formation-window slack (~ the stage unit cost + half max_window)
ADMISSION_MARGIN = 0.050
# static comparison points: valley-sized, equal-node-seconds (vs the
# autoscaler's realized usage), and peak-sized
STATIC_SLOTS = (BASE_SLOTS, BASE_SLOTS + 3, BASE_SLOTS + SPARE_SLOTS)


def build_graph(quick=True):
    """prep (cpu) -> infer (gpu) on a heterogeneous fast+spares cluster.

    Costs are A100-reference seconds: infer runs 2x faster on the H100
    base tier, 1x on scaled-out spares — per-stage hardware pricing is
    what makes static-vs-elastic node-seconds comparable.
    """
    from repro.runtime import GPU_A100, GPU_H100
    from repro.workflows import Emit, WorkflowGraph
    g = WorkflowGraph("elastic")
    g.add_tier("fast", BASE_SLOTS, {"gpu": 1, "cpu": 2, "nic": 2},
               profile=GPU_H100)
    g.add_tier("slow", 0, {"gpu": 1, "cpu": 2, "nic": 2},
               profile=GPU_A100, spares=SPARE_SLOTS)
    pool_kw = dict(tier=("fast", "slow"), shards=BASE_SLOTS)
    g.add_pool("/req", **pool_kw)
    g.add_pool("/feat", **pool_kw)
    g.add_pool("/out", **pool_kw)
    g.add_stage("prep", pool="/req", resource="cpu", cost=0.002,
                emits=[Emit("/feat", fanout=1, size=256 * 1024)])
    g.add_stage("infer", pool="/feat", resource="gpu", cost=0.016,
                emits=[Emit("/out", fanout=1, size=16 * 1024)], sink=True)
    return g.validate()


def submit_ramp(wrt):
    """Deterministic arrival schedule from PHASES; returns total count."""
    t, i = 0.05, 0
    for dur, rate in PHASES:
        n = int(dur * rate)
        for k in range(n):
            wrt.submit(f"r{i}", at=t + k / rate, deadline=SLO)
            i += 1
        t += dur
    return i


def run_static(slots, quick=True, seed=0):
    """Fixed provisioning: ``slots`` slots for the whole run (the first
    BASE_SLOTS fast, the rest slow) — built by pre-scaling the elastic
    cluster so placement/scheduling are identical apparatus."""
    from repro.workflows import WorkflowRuntime, mode_kwargs
    wrt = WorkflowRuntime(build_graph(quick), seed=seed,
                          **mode_kwargs("atomic+abatch"))
    if slots > BASE_SLOTS:
        scaler = wrt.enable_autoscale(slo=SLO)
        scaler.force(slots, reason="static pre-provisioning")
        # static run: controller must never act again
        scaler._cooldown = 10 ** 9
    n = submit_ramp(wrt)
    wrt.run()
    return wrt, n


def run_elastic(admission, quick=True, seed=0):
    from repro.workflows import WorkflowRuntime, mode_kwargs
    kw = mode_kwargs("atomic+abatch")
    if admission:
        kw.update(admission="reject", admission_margin=ADMISSION_MARGIN)
    wrt = WorkflowRuntime(build_graph(quick), seed=seed, **kw)
    wrt.enable_autoscale(slo=SLO)
    n = submit_ramp(wrt)
    wrt.run()
    return wrt, n


def _row(tag, wrt, n_submitted, node_seconds, t0):
    s = wrt.summary()
    completed = s["n"]
    misses = s.get("slo_misses", 0)
    hit = (completed - misses) / n_submitted
    d = {
        "p50_ms": round(s["median"] * 1e3, 2),
        "p99_ms": round(s["p99"] * 1e3, 2),
        "slo_hit_rate": round(hit, 4),
        "late_completions": misses,
        "completed": completed,
        "submitted": n_submitted,
        "node_seconds": round(node_seconds, 2),
        "wall_s": round(time.perf_counter() - t0, 3),
    }
    if "admission_rejects" in s:
        d["rejected"] = s["admission_rejects"]
    if "scale_events" in s:
        d["scale_events"] = s["scale_events"]
    return (f"fig10/{tag}", s["median"] * 1e6, d)


def run(quick=True):
    horizon = sum(d for d, _ in PHASES) + 0.05
    rows = []
    results = {}
    for slots in STATIC_SLOTS:
        t0 = time.perf_counter()
        wrt, n = run_static(slots, quick)
        end = max(wrt.rt.sim.now, horizon)
        results[f"static{slots}"] = (wrt, n, slots * end)
        rows.append(_row(f"static{slots}", wrt, n, slots * end, t0))
    for tag, admission in (("auto", False), ("auto+admit", True)):
        t0 = time.perf_counter()
        wrt, n = run_elastic(admission, quick)
        ns = wrt.autoscaler.node_seconds()
        results[tag] = (wrt, n, ns)
        rows.append(_row(tag, wrt, n, ns, t0))

    # -- acceptance ---------------------------------------------------------
    def hit(summary, n):
        return (summary["n"] - summary.get("slo_misses", 0)) / n

    aw, an, ans = results["auto+admit"]
    asum = aw.summary()
    # 1) dominate every static sizing that spends at least our
    #    node-seconds (the equal-capacity and the peak-provisioned
    #    clusters) on BOTH axes: tail latency and SLO-hit rate
    beats = True
    for slots in STATIC_SLOTS[1:]:
        sw, sn, sns = results[f"static{slots}"]
        ssum = sw.summary()
        beats &= (ans <= sns + 1e-6
                  and asum["p99"] <= ssum["p99"] + 1e-12
                  and hit(asum, an) >= hit(ssum, sn) - 1e-12)
    # 2) the admission contract: no admitted instance completed late —
    #    a deadline the gate could not protect was rejected, not served
    zero_hopeless = asum.get("slo_misses", 0) == 0
    # 3) elasticity actually happened, both directions, conserving spares
    scaler = aw.autoscaler
    grew = any(d.new_shards > d.old_shards for d in scaler.decisions)
    shrank = any(d.new_shards < d.old_shards for d in scaler.decisions)
    conserved = len(scaler.spare) + scaler._n_active() == \
        BASE_SLOTS + SPARE_SLOTS
    rows.append(("fig10/acceptance", 0.0, {
        "auto_admit_dominates_equal_or_bigger_static": beats,
        "auto_node_seconds": round(ans, 2),
        "zero_hopeless_completions": zero_hopeless,
        "scaled_out": grew, "scaled_in": shrank,
        "capacity_conserved": conserved,
    }))
    assert beats and zero_hopeless and grew and shrank and conserved, \
        rows[-1][2]
    return rows


if __name__ == "__main__":
    emit(run())
