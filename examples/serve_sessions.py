"""Serve a small model with batched multi-turn sessions: the paper-§7.2
pattern — session KV state + LoRA adapters as affinity groups.

Run:  PYTHONPATH=src python examples/serve_sessions.py [--policy random]
"""
import argparse
import sys
from pathlib import Path
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro import configs
from repro.models import build_model
from repro.runtime.simulation import NetProfile
from repro.serving import ServingEngine, make_adapter


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--policies", default="affinity,random,least_loaded")
    ap.add_argument("--sessions", type=int, default=12)
    ap.add_argument("--turns", type=int, default=3)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    net = NetProfile(bandwidth=2e6, rtt=0.05)   # state-migration-costly

    print(f"{'policy':14s} {'ttft_ms':>8s} {'p95_ms':>8s} "
          f"{'migrations':>10s} {'moved_KB':>9s}")
    for policy in args.policies.split(","):
        eng = ServingEngine(model, params, n_rows=4, max_slots=8,
                            max_seq=128, policy=policy, net=net)
        eng.adapters.register(make_adapter(
            jax.random.PRNGKey(1), "assistant-v2", cfg.d_model,
            cfg.vocab_size))
        for i in range(args.sessions):
            eng.open_session(f"user{i}",
                             adapter="assistant-v2" if i % 2 else None)
        t = 0.0
        for turn in range(args.turns):
            for i in range(args.sessions):
                toks, _ = eng.turn(f"user{i}", [1 + i % 17, 2, 3],
                                   gen_tokens=6, now=t)
                t += 0.002
        s = eng.summary()
        print(f"{policy:14s} {s['ttft_mean']*1e3:8.2f} "
              f"{s['ttft_p95']*1e3:8.2f} {s['migrations']:10d} "
              f"{s['migration_bytes']/1e3:9.1f}")


if __name__ == "__main__":
    main()
