"""KV-cache utilities: slot packing, prefill->slot merge, byte accounting.

A serving *row* (one data-parallel replica group) owns a slotted decode
cache: every leaf has layout (layers, slots, ...).  Prefill produces a
single-sequence cache (layers, 1, S, ...) that is written into a slot; when
a session migrates between rows (the baseline policies do this; affinity
routing avoids it) the slot state is extracted and shipped.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def tree_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def session_cache_bytes(model, max_seq: int) -> int:
    """Bytes of one session's decode state (the migration payload)."""
    spec = model.cache_spec(1, max_seq)
    return sum(
        int(jnp.prod(jnp.array(x.shape))) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(spec))


@functools.partial(jax.jit, static_argnames=("slot",), donate_argnums=(0,))
def write_slot(row_cache: Any, prefill_cache: Any, slot: int) -> Any:
    """Write a (L,1,...) prefill cache into slot `slot` of (L,B,...)."""
    def merge(dst, src):
        src = src.astype(dst.dtype)
        # align trailing dims: src may be shorter in the seq dim
        idx = [slice(None), slice(slot, slot + 1)]
        idx += [slice(0, s) for s in src.shape[2:]]
        return dst.at[tuple(idx)].set(src)
    return jax.tree_util.tree_map(merge, row_cache, prefill_cache)


@functools.partial(jax.jit, static_argnames=("slot",))
def read_slot(row_cache: Any, slot: int) -> Any:
    """Extract one slot's state (L,1,...) — the migration payload."""
    return jax.tree_util.tree_map(
        lambda x: x[:, slot:slot + 1], row_cache)


@functools.partial(jax.jit, static_argnames=("slot",), donate_argnums=(0,))
def clear_slot(row_cache: Any, slot: int) -> Any:
    def z(dst):
        return dst.at[:, slot].set(jnp.zeros_like(dst[:, slot]))
    return jax.tree_util.tree_map(z, row_cache)


@functools.partial(jax.jit, donate_argnums=(0,))
def reset_cache(row_cache: Any) -> Any:
    """Zero every slot — a dead row's memory is gone, so recovery starts
    from a blank cache (cheaper than re-allocating via ``init_cache``)."""
    return jax.tree_util.tree_map(jnp.zeros_like, row_cache)
