"""Common model-building utilities: configs, initializers, logical axes.

Every parameter tensor in the zoo is annotated with *logical axis names*
(e.g. ``("vocab", "embed")``).  ``repro.distributed.sharding_rules`` maps
logical names onto physical mesh axes per (arch, shape, mesh) — this is the
single knob the perf hillclimb turns.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

Family = str  # "dense" | "moe" | "hybrid" | "ssm" | "encoder" | "vlm"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    # --- MLP ---
    mlp_variant: str = "swiglu"        # swiglu | relu2 | gelu | geglu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    # --- rope / norm ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                  # per-expert hidden dim
    moe_capacity_factor: float = 1.25
    moe_chunk: int = 4096              # token-chunk for dispatch memory bound
    # --- MLA (deepseek-v2) ---
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- hybrid (recurrentgemma) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rglru","rglru","attn")
    lru_width: int = 0
    conv_width: int = 4
    attn_window: int = 0               # 0 -> global attention
    # --- ssm (mamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    # --- modality frontend stubs ---
    frontend: str = "none"             # none | audio | vision
    frontend_dim: int = 0              # feature dim supplied by the stub
    n_patches: int = 0                 # vlm: patches per request
    # --- dtypes ---
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    # --- training ---
    remat: str = "layer"               # none | layer | dots
    opt_state_dtype: Any = jnp.float32  # bf16 for >=100B archs (fits HBM)
    opt_factored: bool = False         # Adafactor-style 2nd moment (llama4)
    # --- lowering controls (dry-run cost extraction; see launch.dryrun) ---
    scan_layers: bool = True           # False: python-unrolled layer stack
    unroll_inner: bool = False         # True: unroll inner chunk loops
    attn_chunk: int = 0                # >0: q-block-chunked attention
    # --- beyond-paper perf knobs (EXPERIMENTS.md §Perf; default = the
    #     paper-faithful baseline the dry-run sweep recorded) ---
    attn_seq_shard: bool = False       # shard q-seq over 'model' when heads
    #                                    don't divide TP (qwen/llama4 40H)
    onehot_loss: bool = False          # einsum-onehot CE (vocab-sharded
    #                                    friendly; avoids logits all-reduce)
    moe_hoist_gather: bool = True      # force expert FSDP gather pre-loop
    #                                    (False: keep weights sharded;
    #                                    right for tiny decode batches)
    seq_parallel_residual: bool = False  # Megatron-SP: residual stream
    #                                    sharded over 'model' between blocks
    #                                    (AG+RS instead of all-reduce)
    # --- misc ---
    logit_softcap: float = 0.0
    is_causal: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model flops)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        nh, nkv = self.n_heads, self.n_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        for i in range(self.n_layers):
            kind = self.block_kind(i)
            if kind == "attn":
                if self.mla:
                    qdim = nh * (self.qk_nope_dim + self.qk_rope_dim)
                    attn = d * (self.q_lora_rank or qdim)
                    if self.q_lora_rank:
                        attn += self.q_lora_rank * qdim
                    attn += d * (self.kv_lora_rank + self.qk_rope_dim)
                    attn += self.kv_lora_rank * nh * (self.qk_nope_dim + self.v_head_dim)
                    attn += nh * self.v_head_dim * d
                else:
                    attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
                total += attn
            elif kind == "rglru":
                w = self.lru_width
                total += 2 * d * w + w * self.conv_width + 3 * w + w * d
            elif kind == "ssd":
                di = self.ssm_expand * d
                nheads = di // self.ssm_head_dim
                total += d * (2 * di + 2 * self.ssm_ngroups * self.ssm_state + nheads)
                total += self.conv_width * (di + 2 * self.ssm_ngroups * self.ssm_state)
                total += 2 * nheads + di * d
            if kind in ("attn", "rglru"):   # blocks followed by an MLP
                if self.n_experts and kind == "attn" and self.family == "moe":
                    pass  # handled below
                else:
                    total += self.mlp_params(f)
            if self.family == "moe" and kind == "attn":
                total += self.n_experts * self.mlp_params(self.moe_d_ff)
                total += self.n_shared_experts * self.mlp_params(self.moe_d_ff if self.name.startswith("deepseek") else self.d_ff)
                total += d * self.n_experts  # router
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed-to experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dense = self.param_count()
        all_expert = self.n_layers * self.n_experts * self.mlp_params(self.moe_d_ff)
        active_expert = self.n_layers * self.moe_top_k * self.mlp_params(self.moe_d_ff)
        return dense - all_expert + active_expert

    def mlp_params(self, f: int) -> int:
        d = self.d_model
        if self.mlp_variant in ("swiglu", "geglu"):
            return 3 * d * f
        return 2 * d * f

    def block_kind(self, layer_idx: int) -> str:
        if self.block_pattern:
            return self.block_pattern[layer_idx % len(self.block_pattern)]
        if self.family == "ssm":
            return "ssd"
        return "attn"

    def kv_cache_spec(self, batch: int, max_seq: int) -> Dict[str, Any]:
        """Shapes of the per-request decode state (see models.cache)."""
        raise NotImplementedError  # provided by models.cache


# ---------------------------------------------------------------------------
# Initializers (all take (key, shape, dtype))
# ---------------------------------------------------------------------------

def normal_init(key, shape, dtype, stddev=0.02):
    return (jax.random.normal(key, shape) * stddev).astype(dtype)


def scaled_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def zeros_init(key, shape, dtype):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    del key
    return jnp.ones(shape, dtype)


class ParamFactory:
    """Collects params + logical axes while a model's ``init`` runs.

    Usage::
        pf = ParamFactory(rng, dtype)
        w = pf.param("wq", (d, h, hd), ("embed", "heads", "head_dim"))
    """

    def __init__(self, key: jax.Array, dtype):
        self._key = key
        self.dtype = dtype
        self.params: Dict[str, Any] = {}
        self.axes: Dict[str, Any] = {}

    def _next(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, name, shape, logical_axes, init=scaled_init, **kw):
        assert len(shape) == len(logical_axes), (name, shape, logical_axes)
        self.params[name] = init(self._next(), shape, self.dtype, **kw)
        self.axes[name] = logical_axes
        return self.params[name]

    def subtree(self, name: str) -> "ParamFactory":
        sub = ParamFactory(self._next(), self.dtype)
        self.params[name] = sub.params
        self.axes[name] = sub.axes
        return sub


def stack_params(trees: Sequence[Any]) -> Any:
    """Stack a list of identical param pytrees along a new leading axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def stack_axes(axes_tree: Any) -> Any:
    """Prefix every logical-axes tuple with 'layers' (for stacked scans)."""
    return jax.tree_util.tree_map(
        lambda a: ("layers",) + tuple(a),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, str) for e in x),
    )


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))
