"""Discrete-event cluster simulation that executes real stage logic.

The paper's evaluation (17-server RDMA cluster / Azure) is reproduced with a
DES whose primitives are the ones that determine placement behavior:

  * nodes with FIFO *resources* (gpu, cpu, nic) and service queues,
  * links with bandwidth + RTT (cluster and cloud profiles),
  * the affinity-grouped CascadeStore for placement/caching,
  * UDL tasks written as python *generators* yielding ops
    (Get / Put / Trigger / Compute / BatchCompute / Sleep / WaitFor) — the
    sim advances virtual time around them, so the RCP application code reads
    like the paper's pseudo-code while queueing/transfer effects are modeled
    faithfully.

The event loop is built for scale: a stable heap whose entries carry a
bound handler + argument tuple (one tuple per event instead of a chain of
closures), ops dispatched through a per-type handler table, and node /
resource state touched through locals inside the handlers.  ``BatchCompute``
is the batched counterpart of ``Compute``: one resource occupancy that
covers ``n`` coalesced stage firings (see ``repro.workflows.batching``),
with the batch size recorded in ``metrics["batch_sizes"]``.

Node failures, stragglers (per-node slowdown factors) and hedged retries are
injectable (see repro.runtime.faults).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import random
from collections import defaultdict, deque
from typing import (Any, Callable, Dict, Generator, List, Mapping, Optional,
                    Tuple)

from repro.core import CascadeStore
from .batching import BatchCostModel


# ---------------------------------------------------------------------------
# Network / hardware profiles
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NetProfile:
    bandwidth: float          # bytes/s
    rtt: float                # seconds per transfer
    store_latency: float = 0.0   # extra per remote storage op (cloud)

    def transfer_time(self, nbytes: int) -> float:
        return self.rtt + self.store_latency + nbytes / self.bandwidth


# paper §4.4: 100 Gbps RDMA backbone, PTP-synced cluster
CLUSTER_NET = NetProfile(bandwidth=12.5e9, rtt=10e-6)
# paper §5: Azure — EH/blob/cosmos hops, ~10 Gbps effective, ms-scale RTTs
AZURE_NET = NetProfile(bandwidth=1.25e9, rtt=1e-3, store_latency=4e-3)


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """A backend tier's hardware shape: per-resource service rates, lane
    counts, and the tier's own batch-amortization curve.

    Stage ``cost`` is declared in *reference-hardware* seconds; a node with
    profile speed ``s`` for the stage's resource services it in ``cost/s``
    seconds.  ``batch_fixed``/``batch_marginal``/``max_batch`` describe how
    the tier amortizes batched invocations (weight-streaming share vs
    per-item share, and the largest batch its memory/lane shape admits);
    when left ``None`` the layer-shared :class:`BatchCostModel` prices the
    tier, which keeps the homogeneous single-profile case byte-identical
    to the pre-tier behavior.
    """
    name: str = "uniform"
    speed: Mapping[str, float] = dataclasses.field(default_factory=dict)
    resources: Mapping[str, int] = dataclasses.field(
        default_factory=lambda: {"gpu": 1, "cpu": 2, "nic": 2})
    batch_fixed: Optional[float] = None      # None -> shared cost model
    batch_marginal: Optional[float] = None
    max_batch: Optional[int] = None

    def speed_of(self, resource: str) -> float:
        return self.speed.get(resource, 1.0)

    @property
    def nominal_speed(self) -> float:
        """Scalar throughput weight (capacity-aware placement ranking)."""
        return max(self.speed.values(), default=1.0)

    def cost_model(self) -> Optional[BatchCostModel]:
        """The tier's own batching economics, or None for the shared one."""
        if self.batch_fixed is None:
            return None
        return BatchCostModel(fixed=self.batch_fixed,
                              marginal=self.batch_marginal
                              if self.batch_marginal is not None else
                              1.0 - self.batch_fixed,
                              max_batch=self.max_batch or 16)


#: The homogeneous default: every pre-tier construction maps onto it.
UNIFORM = HardwareProfile()

# Named tiers (benchmarks/fig10, docs/elasticity.md).  Speeds are relative
# to the A100 reference (stage costs are calibrated in A100-seconds);
# batch curves: newer parts stream weights relatively faster (higher fixed
# share -> deeper amortization) and admit bigger batches, CPU pools
# amortize almost nothing.
GPU_H100 = HardwareProfile(
    name="H100", speed={"gpu": 2.0, "cpu": 1.2},
    resources={"gpu": 1, "cpu": 2, "nic": 2},
    batch_fixed=0.75, batch_marginal=0.25, max_batch=32)
GPU_A100 = HardwareProfile(
    name="A100", speed={"gpu": 1.0, "cpu": 1.0},
    resources={"gpu": 1, "cpu": 2, "nic": 2},
    batch_fixed=0.65, batch_marginal=0.35, max_batch=16)
CPU_POOL = HardwareProfile(
    name="CPU", speed={"gpu": 0.2, "cpu": 1.0},
    resources={"gpu": 1, "cpu": 4, "nic": 2},
    batch_fixed=0.25, batch_marginal=0.75, max_batch=4)


# ---------------------------------------------------------------------------
# Ops yielded by task generators
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Get:
    key: str
    required: bool = True
    wait: bool = False        # True: block until the key is put


@dataclasses.dataclass
class Put:
    key: str
    value: Any = None
    size: int = 0
    fire: bool = True         # trigger downstream UDLs


@dataclasses.dataclass
class Trigger:
    key: str
    value: Any = None
    size: int = 0


@dataclasses.dataclass
class Compute:
    resource: str             # "gpu" | "cpu"
    seconds: float


@dataclasses.dataclass
class BatchCompute:
    """One resource occupancy covering ``n`` coalesced task firings.

    ``seconds`` is the total (already amortized) service time of the batch —
    the op is accounted exactly like a ``Compute`` of that duration, and the
    batch size lands in ``Simulator.metrics["batch_sizes"]`` so sweeps can
    report realized coalescing.
    """
    resource: str
    seconds: float
    n: int = 1


@dataclasses.dataclass
class Sleep:
    seconds: float


class SimFuture:
    """A one-shot virtual-time synchronization point.

    Tasks block on it with ``yield WaitFor(future)``; anyone (another task,
    a scheduled callback) completes it with ``Simulator.resolve``, which
    resumes every waiter at the current virtual time.  This is the
    primitive cross-task barriers (e.g. batched stage execution) build on
    without round-tripping through the object store.
    """
    __slots__ = ("done", "value", "_waiting", "blame")

    def __init__(self):
        self.done = False
        self.value: Any = None
        self._waiting: List[Callable[[Any], None]] = []
        # True when the future's owner (e.g. the StageBatcher) records
        # its own exact blame spans for waiters — the tracer then skips
        # the generic WaitFor barrier span to avoid double coverage
        self.blame = False


@dataclasses.dataclass
class WaitFor:
    future: SimFuture


TaskGen = Generator[Any, Any, None]


# ---------------------------------------------------------------------------
# Node model
# ---------------------------------------------------------------------------

class Node:
    def __init__(self, name: str, resources: Dict[str, int],
                 speed: float = 1.0, profile: HardwareProfile = UNIFORM,
                 domain: str = ""):
        self.name = name
        self.capacity = dict(resources)           # resource -> lanes
        self.in_use: Dict[str, int] = defaultdict(int)
        self.queues: Dict[str, deque] = defaultdict(deque)
        self.speed = speed                        # <1.0 => straggler
        self.profile = profile                    # backend tier hardware
        self.up = True
        # failure-domain label (rack/zone): correlated fault injection
        # kills whole domains and anti-affinity replication spreads over
        # them; "" = topology-blind (the pre-domain default everywhere)
        self.domain = domain
        # admitted-but-unfinished compute seconds per resource: the
        # "queue depth in seconds" load signal (maintained O(1) by the
        # compute handlers) that dispatch and the batch planner read
        self.pending: Dict[str, float] = defaultdict(float)
        # metrics
        self.busy_time: Dict[str, float] = defaultdict(float)
        self.n_tasks = 0
        self.queue_wait: float = 0.0

    def rate(self, resource: str) -> float:
        """Effective service rate for ``resource``: the tier's speed times
        the node's straggler dial.  1.0 on the uniform default profile."""
        return self.speed * self.profile.speed_of(resource)

    def __repr__(self):
        return f"Node({self.name})"


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------

_NO_ARG = object()          # sentinel: event handler takes no argument


class _ComputeStart:
    """A queued compute admission, as a typed entry instead of a closure.

    While a compute op waits for a lane its queue entry carries (node, op,
    cont, dur) in inspectable slots, which is what lets failover move the
    entry to another node with correct accounting (see
    :meth:`Simulator.requeue_compute`) — a closure would keep the dead
    node baked into its cell and corrupt in_use/pending on completion.
    """
    __slots__ = ("sim", "node", "op", "cont", "dur")

    def __init__(self, sim: "Simulator", node: "Node", op, cont,
                 dur: float):
        self.sim = sim
        self.node = node
        self.op = op
        self.cont = cont
        self.dur = dur

    def __call__(self) -> None:
        sim = self.sim
        sim.at(sim.now + self.dur, sim._compute_done,
               (self.node, self.op, self.cont, self.dur))


class Simulator:
    def __init__(self, store: CascadeStore, nodes: Dict[str, Node],
                 net: NetProfile = CLUSTER_NET, seed: int = 0,
                 local_get_cost: float = 2e-6):
        self.store = store
        self.nodes = nodes
        self.net = net
        self.now = 0.0
        self.rng = random.Random(seed)
        self._heap: List[Tuple[float, int, Callable, Any]] = []
        self._seq = itertools.count()
        self.local_get_cost = local_get_cost
        # task bookkeeping
        self.completed_tasks = 0
        self.events_fired = 0
        self.metrics: Dict[str, Any] = defaultdict(list)
        self.udl_dispatch: Optional[Callable] = None  # set by Runtime
        # optional span sink (repro.runtime.tracing.TraceRecorder),
        # attached externally; None keeps every traced path to a single
        # predicate check
        self.tracer: Optional[Any] = None
        # called as on_release(node, resource) when a lane frees with an
        # empty queue (the work-conserving flush hook the adaptive
        # batcher uses); None costs one branch on the release hot path
        self.on_release: Optional[Callable[[Node, str], None]] = None
        self._waiters: Dict[str, List[Tuple[Node, Any, Callable]]] = \
            defaultdict(list)
        # active network partition: node name -> group id, or None (the
        # fault-free fast path is one `is None` check).  Two nodes are
        # mutually reachable iff they map to the same group; unlisted
        # nodes belong to group 0 (the majority/client side).  Reads
        # whose every replica is across the cut park here until heal.
        self.partition: Optional[Dict[str, int]] = None
        self._partition_parked: List[Tuple[Node, Any, Callable]] = []
        # dispatches whose only viable lanes sit across the cut park as
        # bare callbacks (the dispatcher re-picks a node at heal)
        self._partition_parked_calls: List[Callable] = []
        self.partition_parked_dispatches = 0
        # overlapped prefetch channel (paper §3.4): warm-up transfers
        # share each node's NIC lanes with demand fetches / migration
        # (so prefetch is never free), but the bytes a node may have
        # in flight for prefetch alone are capped — excess plans queue
        # and drain as transfers land.  (node, key) -> SimFuture lets a
        # demand Get racing its own warm-up join the in-flight transfer
        # instead of paying a second full fetch.
        self.prefetch_futures: Dict[Tuple[str, str], SimFuture] = {}
        self.prefetch_inflight_cap: int = 64 << 20
        self._prefetch_inflight: Dict[str, int] = defaultdict(int)
        self._prefetch_queue: Dict[str, deque] = defaultdict(deque)
        self.prefetch_promotions = 0
        # per-op-type handler table (replaces an isinstance chain in the
        # hot path); exact-type keyed — subclassed ops resolve through
        # _handler_for, which memoizes the subclass into the table
        self._handlers: Dict[type, Callable] = {
            Compute: self._op_compute,
            BatchCompute: self._op_compute,
            Sleep: self._op_sleep,
            Get: self._op_get,
            Put: self._op_put,
            Trigger: self._op_put,
            WaitFor: self._op_wait,
        }

    # -- event loop ---------------------------------------------------------

    def at(self, t: float, fn: Callable, arg: Any = _NO_ARG) -> None:
        """Schedule ``fn`` (optionally ``fn(arg)``) at virtual time ``t``.

        Carrying the argument in the heap entry lets hot-path handlers be
        bound methods + a tuple instead of a freshly allocated closure per
        op; same-time events keep FIFO order through the sequence column.
        """
        if t < self.now:
            t = self.now
        heapq.heappush(self._heap, (t, next(self._seq), fn, arg))

    def after(self, dt: float, fn: Callable, arg: Any = _NO_ARG) -> None:
        self.at(self.now + dt, fn, arg)

    def run(self, until: float = float("inf")) -> None:
        heap = self._heap
        pop = heapq.heappop
        no_arg = _NO_ARG
        fired = 0
        try:
            while heap:
                item = pop(heap)
                t = item[0]
                if t > until:
                    heapq.heappush(heap, item)   # keep it for a later run()
                    self.now = until
                    return
                self.now = t
                fired += 1
                if item[3] is no_arg:
                    item[2]()
                else:
                    item[2](item[3])
        finally:
            self.events_fired += fired

    # -- futures ------------------------------------------------------------

    def resolve(self, future: SimFuture, value: Any = None) -> None:
        """Complete a ``SimFuture``, resuming every waiter at time ``now``."""
        if future.done:
            return
        future.done = True
        future.value = value
        waiting, future._waiting = future._waiting, []
        for cont in waiting:
            self.at(self.now, cont, value)

    # -- partitions ---------------------------------------------------------

    def reachable(self, a: str, b: str) -> bool:
        """True iff node ``a`` can talk to node ``b`` right now.

        Without an active partition every pair is reachable ("up" is the
        only failure axis); under one, reachability is same-group
        membership, with unlisted nodes on the majority side (group 0).
        """
        p = self.partition
        if p is None:
            return True
        return p.get(a, 0) == p.get(b, 0)

    def heal_partition(self) -> None:
        """Clear the active partition and re-drive every read it parked.

        Each parked op is stamped with the heal instant (``op._pstall``)
        so tracing can split its interval into a ``partition_stall`` span
        followed by the normal network transfer."""
        self.partition = None
        self.store.partition = None
        parked, self._partition_parked = self._partition_parked, []
        for node, op, cont in parked:
            try:
                op._pstall = self.now
            except AttributeError:
                pass                     # slotted op types: skip the stamp
            self._execute(node, op, cont)
        calls, self._partition_parked_calls = \
            self._partition_parked_calls, []
        for fn in calls:
            fn()

    # -- resources ------------------------------------------------------------

    def acquire(self, node: Node, resource: str, fn: Callable[[], None],
                enq_time: Optional[float] = None) -> None:
        enq = self.now if enq_time is None else enq_time
        if not node.up:
            # node down: park in queue; failover logic re-dispatches
            node.queues[resource].append((enq, fn))
            return
        if node.in_use[resource] < node.capacity.get(resource, 1):
            node.in_use[resource] += 1
            node.queue_wait += self.now - enq
            fn()
        else:
            node.queues[resource].append((enq, fn))

    def release(self, node: Node, resource: str) -> None:
        node.in_use[resource] -= 1
        q = node.queues[resource]
        while q and node.up:
            enq, fn = q.popleft()
            node.in_use[resource] += 1
            node.queue_wait += self.now - enq
            fn()
            return
        if self.on_release is not None and node.up:
            self.on_release(node, resource)

    def kick(self, node: Node, resource: str) -> None:
        """Start queued work on ``resource`` up to capacity.

        The recovery path: a node coming back up re-admits entries that
        parked while it was down through the same accounting as
        ``release`` (in_use, queue_wait, on_release), instead of any
        caller hand-rolling the drain."""
        q = node.queues[resource]
        cap = node.capacity.get(resource, 1)
        while q and node.up and node.in_use[resource] < cap:
            enq, fn = q.popleft()
            node.in_use[resource] += 1
            node.queue_wait += self.now - enq
            fn()
        if self.on_release is not None and node.up and not q and \
                node.in_use[resource] < cap:
            self.on_release(node, resource)

    # -- task execution ---------------------------------------------------------

    def spawn(self, node_name: str, gen: TaskGen, done: Optional[Callable] = None,
              label: str = "", trace: Any = None) -> None:
        """Run a generator task on a node, advancing sim time per op.

        With ``trace`` (an ``InstanceTrace``) and a tracer attached, the
        step loop records every op's elapsed interval the moment the
        generator resumes (``TraceRecorder.record_op`` appends one
        primitive tuple; categorization into spans is deferred to trace
        completion) — the untraced loop below stays byte-identical when
        either is absent.
        """
        node = self.nodes[node_name]
        node.n_tasks += 1
        send = gen.send
        handlers = self._handlers

        if trace is not None and self.tracer is not None:
            cut = self.tracer.local_cut
            record = self.tracer.record_op
            pending_op: Any = None
            pending_t = 0.0

            def step(send_value=None):
                nonlocal pending_op, pending_t, step
                now = self.now
                if pending_op is not None:
                    # one compare + one flat-record append per op is
                    # the whole hot-path cost: categorization is
                    # deferred to trace materialization.  Sub-cut ops
                    # (local puts/gets, instantly-satisfied waits) are
                    # noise the blame sweep charges to "other" as
                    # uncovered time anyway
                    if now - pending_t > cut:
                        record(trace, pending_op, pending_t, now, node)
                    pending_op = None
                try:
                    op = send(send_value)
                except StopIteration:
                    self.completed_tasks += 1
                    if done is not None:
                        done()
                    # step references itself (it hands itself to the op
                    # handler as the continuation), so the closure is a
                    # reference cycle refcounting can never free; clear
                    # the cell and the whole task's closure graph dies
                    # here instead of piling up for the collector
                    step = None
                    return
                pending_op = op
                pending_t = now
                handler = handlers.get(type(op)) or self._handler_for(op)
                handler(node, op, step)

            step(None)
            return

        def step(send_value=None):
            nonlocal step
            try:
                op = send(send_value)
            except StopIteration:
                self.completed_tasks += 1
                if done is not None:
                    done()
                step = None     # break the step->closure->step cycle
                return
            handler = handlers.get(type(op)) or self._handler_for(op)
            handler(node, op, step)

        step(None)

    def _handler_for(self, op: Any) -> Callable:
        """Slow-path lookup for subclassed ops: resolve by isinstance and
        memoize the concrete type into the handler table."""
        for cls in (Compute, BatchCompute, Sleep, Get, Trigger, Put,
                    WaitFor):
            if isinstance(op, cls):
                handler = self._handlers[cls]
                self._handlers[type(op)] = handler
                return handler
        raise TypeError(f"unknown op {op!r}")

    def _execute(self, node: Node, op: Any, cont: Callable[[Any], None]):
        """Execute one op for ``cont`` — the re-dispatch entry point used by
        waiter wake-ups (``Get(wait=True)`` satisfied by a later put)."""
        handler = self._handlers.get(type(op)) or self._handler_for(op)
        handler(node, op, cont)

    # -- op handlers --------------------------------------------------------

    def _op_compute(self, node: Node, op, cont) -> None:
        dur = op.seconds / max(node.rate(op.resource), 1e-9)
        node.pending[op.resource] += dur
        self.acquire(node, op.resource,
                     _ComputeStart(self, node, op, cont, dur))

    def requeue_compute(self, start: _ComputeStart, dst: Node,
                        enq_time: Optional[float] = None) -> None:
        """Move a still-queued compute admission to another node.

        Transfers the pending-seconds load signal and re-prices the op at
        the destination's rate, so a failed-over op is indistinguishable
        from one issued to ``dst`` directly.  Only valid for entries that
        have not started (i.e. popped straight out of a node queue)."""
        op = start.op
        start.node.pending[op.resource] -= start.dur
        dur = op.seconds / max(dst.rate(op.resource), 1e-9)
        dst.pending[op.resource] += dur
        start.node = dst
        start.dur = dur
        self.acquire(dst, op.resource, start, enq_time=enq_time)

    def _compute_done(self, arg) -> None:
        node, op, cont, dur = arg
        node.pending[op.resource] -= dur
        node.busy_time[op.resource] += dur
        if isinstance(op, BatchCompute):
            self.metrics["batch_sizes"].append(op.n)
        self.release(node, op.resource)
        cont(None)

    def _op_sleep(self, node: Node, op, cont) -> None:
        self.at(self.now + op.seconds, cont, None)

    def _op_wait(self, node: Node, op, cont) -> None:
        future = op.future
        if future.done:
            self.at(self.now, cont, future.value)
        else:
            future._waiting.append(cont)

    def _op_get(self, node: Node, op, cont) -> None:
        if self.prefetch_futures:
            fut = self.prefetch_futures.get((node.name, op.key))
            if fut is not None and not fut.done:
                # a warm-up transfer for exactly this key is in flight
                # (or queued — promote it): join it rather than issuing
                # a duplicate fetch, then re-drive the get, which will
                # find the installed cache entry.  The resume instant is
                # stamped so tracing bills [yield, resume] as `prefetch`.
                self.promote_prefetch(node.name, op.key)

                def rejoin(_value, node=node, op=op, cont=cont):
                    try:
                        op._pwait = self.now
                    except AttributeError:
                        pass
                    self._op_get(node, op, cont)
                fut._waiting.append(rejoin)
                return
        rec, local = self.store.get(op.key, node=node.name)
        if rec is None:
            if self.partition is not None and self.store.last_get_blocked:
                # the object exists, but every replica is across the
                # partition: park until heal (liveness over availability
                # — the minority side must not invent data)
                self._partition_parked.append((node, op, cont))
                return
            if op.wait:
                self._waiters[op.key].append((node, op, cont))
                return
            if op.required:
                raise KeyError(f"missing object {op.key} at t={self.now}")
            self.at(self.now + self.local_get_cost, cont, None)
            return
        if local:
            self.at(self.now + self.local_get_cost, cont, rec.value)
        else:
            dt = self.net.transfer_time(rec.size)

            def start_xfer():
                self.at(self.now + dt, self._xfer_done,
                        (node, cont, rec.value))
            self.acquire(node, "nic", start_xfer)

    def _xfer_done(self, arg) -> None:
        node, cont, value = arg
        self.release(node, "nic")
        cont(value)

    def _op_put(self, node: Node, op, cont) -> None:
        is_put = not isinstance(op, Trigger)
        fire = (not is_put) or op.fire
        if is_put:
            sync0 = self.store.stats.bytes_replica_sync
            shard, udls = self.store.put(op.key, op.value, size=op.size,
                                         fire=fire)
            # replication cost: object ships to every member not local
            remote = [n for n in shard.nodes if n != node.name]
            dt = self.net.transfer_time(op.size) if remote else \
                self.local_get_cost
            # cross-shard replica fan-out (ReplicatedPlacement): async
            # sync that still occupies the writer's NIC
            sync_bytes = self.store.stats.bytes_replica_sync - sync0
            if sync_bytes:
                self._charge_transfer(node, sync_bytes)
        else:
            shard, udls = self.store.trigger(op.key, op.value,
                                             size=op.size)
            remote = [n for n in shard.nodes if n != node.name]
            dt = self.net.transfer_time(op.size) if remote else \
                self.local_get_cost
        self.at(self.now + dt, self._put_delivered,
                (op, is_put, fire, shard, udls, cont))

    def _put_delivered(self, arg) -> None:
        op, is_put, fire, shard, udls, cont = arg
        if is_put and op.key in self._waiters:
            for wnode, wop, wcont in self._waiters.pop(op.key):
                self._execute(wnode, wop, wcont)
        if fire and udls and self.udl_dispatch is not None:
            for u in udls:
                self.udl_dispatch(u, shard, op.key, op.value)
        cont(None)

    # -- background transfers ------------------------------------------------

    def _charge_transfer(self, node: Node, nbytes: int,
                         done: Optional[Callable[[], None]] = None) -> None:
        """Occupy `node`'s NIC for a background transfer (replica sync,
        group migration).  Does not block the initiating task."""
        dt = self.net.transfer_time(nbytes)

        def start():
            self.at(self.now + dt, self._bg_xfer_done, (node, dt, done))
        self.acquire(node, "nic", start)

    def _bg_xfer_done(self, arg) -> None:
        node, dt, done = arg
        self.release(node, "nic")
        self.metrics["background_xfer_s"].append(dt)
        if done is not None:
            done()

    # -- overlapped prefetch channel -----------------------------------------

    def prefetch(self, node: Node, key: str, nbytes: int,
                 install: Callable[[], int]) -> SimFuture:
        """Ship ``key`` to ``node``'s cache as an overlapped NIC transfer.

        ``install`` runs when the bytes land (typically
        ``store.prefetch_install`` with the plan-time version, so stale
        transfers become counted no-ops).  The returned future resolves
        to the installed byte count; it carries ``blame=True`` because
        the prefetch span is recorded explicitly by the issuer.  Bytes
        in flight per node are capped at ``prefetch_inflight_cap`` —
        excess entries queue FIFO and drain as transfers complete, and
        a demand read for a queued key promotes it to the front.
        """
        fut = SimFuture()
        fut.blame = True
        self.prefetch_futures[(node.name, key)] = fut
        entry = (node, key, nbytes, install, fut)
        inflight = self._prefetch_inflight[node.name]
        if inflight == 0 or inflight + nbytes <= self.prefetch_inflight_cap:
            self._prefetch_start(entry)
        else:
            self._prefetch_queue[node.name].append(entry)
        return fut

    def promote_prefetch(self, node_name: str, key: str) -> None:
        """Start a still-queued prefetch immediately (demand arrived)."""
        q = self._prefetch_queue.get(node_name)
        if not q:
            return
        for i, entry in enumerate(q):
            if entry[1] == key:
                del q[i]
                self.prefetch_promotions += 1
                self._prefetch_start(entry)
                return

    def _prefetch_start(self, entry) -> None:
        node, key, nbytes, install, fut = entry
        self._prefetch_inflight[node.name] += nbytes
        dt = self.net.transfer_time(nbytes)

        def start():
            self.at(self.now + dt, self._prefetch_done, entry)
        self.acquire(node, "nic", start)

    def _prefetch_done(self, entry) -> None:
        node, key, nbytes, install, fut = entry
        self.release(node, "nic")
        self._prefetch_inflight[node.name] -= nbytes
        # drop the join point BEFORE installing/resolving: a waiter's
        # re-driven get must see the cache entry, not re-join a done
        # future
        self.prefetch_futures.pop((node.name, key), None)
        installed = install()
        self.resolve(fut, installed)
        self._prefetch_pump(node.name)

    def _prefetch_pump(self, node_name: str) -> None:
        q = self._prefetch_queue.get(node_name)
        if not q:
            return
        inflight = self._prefetch_inflight
        cap = self.prefetch_inflight_cap
        while q and (inflight[node_name] == 0
                     or inflight[node_name] + q[0][2] <= cap):
            self._prefetch_start(q.popleft())
