import os
import sys
from pathlib import Path

# Tests run on the single host CPU device (the 512-device override lives
# ONLY in repro.launch.dryrun).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
