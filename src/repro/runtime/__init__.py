from .simulation import (AZURE_NET, CLUSTER_NET, CPU_POOL, GPU_A100,
                         GPU_H100, UNIFORM, BatchCompute, Compute, Get,
                         HardwareProfile, NetProfile, Node, Put, SimFuture,
                         Simulator, Sleep, Trigger, WaitFor)
from .batching import BatchCostModel
from .stats import P2Quantile, StageStats
from .scheduler import (LeastLoadedScheduler, RandomScheduler,
                        ReplicaScheduler, Scheduler, ShardLocalScheduler,
                        dispatchable, node_load)
from .executor import Runtime, TaskContext
from .faults import (AvailabilityReport, FailureEvent, FaultInjector,
                     RetryPolicy, set_straggler)
from .autoscale import (AutoScaler, AutoscalePolicy, ScaleDecision,
                        replace_gang_pins)
from .tracing import (CATEGORIES, InstanceTrace, Span, TraceConfig,
                      TraceRecorder)

__all__ = [
    "AZURE_NET", "CLUSTER_NET", "BatchCompute", "Compute", "Get",
    "NetProfile", "Node", "Put", "SimFuture", "Simulator", "Sleep",
    "Trigger", "WaitFor",
    "CPU_POOL", "GPU_A100", "GPU_H100", "UNIFORM", "HardwareProfile",
    "BatchCostModel",
    "P2Quantile", "StageStats",
    "LeastLoadedScheduler", "RandomScheduler", "ReplicaScheduler",
    "Scheduler", "ShardLocalScheduler", "dispatchable", "node_load",
    "Runtime", "TaskContext",
    "AvailabilityReport", "FailureEvent", "FaultInjector", "RetryPolicy",
    "set_straggler",
    "AutoScaler", "AutoscalePolicy", "ScaleDecision", "replace_gang_pins",
    "CATEGORIES", "InstanceTrace", "Span", "TraceConfig", "TraceRecorder",
]
