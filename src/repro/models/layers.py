"""Shared building blocks: norms, rope, embeddings, GQA attention, MLPs.

Every block exposes three entry points used by ``models.model``:
  * ``*_train``   — full-sequence forward, no cache.
  * ``*_prefill`` — full-sequence forward that also emits the decode cache.
  * ``*_decode``  — single-token forward against a cache (serve_step).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.distributed import constraints as cst
from .common import ModelConfig, ParamFactory, scaled_init, zeros_init, ones_init

Params = Dict[str, Any]


def shard_attn_q(cfg: ModelConfig, q: jax.Array) -> jax.Array:
    """Context parallelism for archs whose head count doesn't divide TP
    (qwen/llama4: 40 heads, TP 16): shard the q-sequence over 'model'
    instead of replicating the whole attention across it (16x flop waste
    observed in the baseline sweep — EXPERIMENTS.md §Perf)."""
    if not cfg.attn_seq_shard:
        return q
    mesh = cst.get_mesh()
    if mesh is None or q.ndim != 4:
        return q
    tp = mesh.shape.get("model", 1)
    if q.shape[2] % tp == 0:            # heads shard fine; nothing to do
        return cst.constrain(q, "dp", None, "tp", None)
    return cst.constrain(q, "dp", "tp", None, None)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(pf: ParamFactory, name: str, dim: int):
    pf.param(name, (dim,), ("norm",), init=ones_init)


def rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) or (..., H, D) with positions (..., S) or (...,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def init_embedding(pf: ParamFactory, cfg: ModelConfig):
    pf.param("tok_embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
             init=scaled_init, fan_in=cfg.d_model)
    if not cfg.tie_embeddings:
        pf.param("unembed", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                 init=scaled_init, fan_in=cfg.d_model)
    init_rmsnorm(pf, "final_norm", cfg.d_model)


def embed(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    emb = jnp.take(params["tok_embed"], tokens, axis=0)
    return emb.astype(cfg.compute_dtype)


def unembed(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["tok_embed"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["unembed"])
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits.astype(jnp.float32)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def init_attention(pf: ParamFactory, cfg: ModelConfig, window: int = 0):
    d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    init_rmsnorm(pf, "ln", d)
    pf.param("wq", (d, H, Dh), ("embed", "heads", "head_dim"), fan_in=d)
    pf.param("wk", (d, K, Dh), ("embed", "kv_heads", "head_dim"), fan_in=d)
    pf.param("wv", (d, K, Dh), ("embed", "kv_heads", "head_dim"), fan_in=d)
    pf.param("wo", (H, Dh, d), ("heads", "head_dim", "embed"), fan_in=H * Dh)
    if cfg.qkv_bias:
        pf.param("bq", (H, Dh), ("heads", "head_dim"), init=zeros_init)
        pf.param("bk", (K, Dh), ("kv_heads", "head_dim"), init=zeros_init)
        pf.param("bv", (K, Dh), ("kv_heads", "head_dim"), init=zeros_init)


def _qkv(p: Params, cfg: ModelConfig, x: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cfg.compute_dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cfg.compute_dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cfg.compute_dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cfg.compute_dtype)
        k = k + p["bk"].astype(cfg.compute_dtype)
        v = v + p["bv"].astype(cfg.compute_dtype)
    return q, k, v


def attention_train(p: Params, cfg: ModelConfig, x: jax.Array,
                    window: int = 0, causal: Optional[bool] = None) -> jax.Array:
    B, S, _ = x.shape
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    q, k, v = _qkv(p, cfg, h)
    pos = jnp.arange(S)[None]
    q = rope(q, jnp.broadcast_to(pos, (B, S)), cfg.rope_theta)
    k = rope(k, jnp.broadcast_to(pos, (B, S)), cfg.rope_theta)
    q = shard_attn_q(cfg, q)
    causal = cfg.is_causal if causal is None else causal
    o = ops.mha(q, k, v, causal=causal, window=window,
                q_chunk=cfg.attn_chunk, unroll=cfg.unroll_inner)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cfg.compute_dtype))
    return x + out


def attention_prefill(p: Params, cfg: ModelConfig, x: jax.Array,
                      window: int = 0) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, _ = x.shape
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    q, k, v = _qkv(p, cfg, h)
    pos = jnp.arange(S)[None]
    q = rope(q, jnp.broadcast_to(pos, (B, S)), cfg.rope_theta)
    k = rope(k, jnp.broadcast_to(pos, (B, S)), cfg.rope_theta)
    q = shard_attn_q(cfg, q)
    o = ops.mha(q, k, v, causal=True, window=window,
                q_chunk=cfg.attn_chunk, unroll=cfg.unroll_inner)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cfg.compute_dtype))
    cache = {"k": k, "v": v}
    return x + out, cache


def attention_decode(p: Params, cfg: ModelConfig, x: jax.Array,
                     cache: Dict[str, jax.Array], lengths: jax.Array,
                     window: int = 0) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, d) one token per row; cache k/v: (B, Smax, K, Dh)."""
    B, _ = x.shape
    h = rmsnorm(p["ln"], x[:, None, :], cfg.norm_eps)
    q, k, v = _qkv(p, cfg, h)                       # (B,1,H,Dh)/(B,1,K,Dh)
    q = rope(q, lengths[:, None], cfg.rope_theta)[:, 0]      # (B,H,Dh)
    k = rope(k, lengths[:, None], cfg.rope_theta)[:, 0]      # (B,K,Dh)
    v = v[:, 0]
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, lengths].set(k.astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, lengths].set(v.astype(cache["v"].dtype))
    o = ops.decode_attention(q, k_cache, v_cache, lengths + 1, window=window)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"].astype(cfg.compute_dtype))
    return x + out, {"k": k_cache, "v": v_cache}


def attention_cache_spec(cfg: ModelConfig, batch: int, max_seq: int,
                         window: int = 0) -> Dict[str, jax.ShapeDtypeStruct]:
    K, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    seq = min(max_seq, window) if window else max_seq
    shp = (batch, seq, K, Dh)
    return {"k": jax.ShapeDtypeStruct(shp, cfg.compute_dtype),
            "v": jax.ShapeDtypeStruct(shp, cfg.compute_dtype)}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(pf: ParamFactory, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    init_rmsnorm(pf, "ln", d)
    if cfg.mlp_variant in ("swiglu", "geglu"):
        pf.param("wi_gate", (d, f), ("embed", "mlp"), fan_in=d)
        pf.param("wi_up", (d, f), ("embed", "mlp"), fan_in=d)
    else:
        pf.param("wi", (d, f), ("embed", "mlp"), fan_in=d)
    pf.param("wo_mlp", (f, d), ("mlp", "embed"), fan_in=f)


def mlp_core(p: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    """MLP without the residual/norm (shared by dense + MoE experts)."""
    cd = cfg.compute_dtype
    if cfg.mlp_variant == "swiglu":
        g = jax.nn.silu(h @ p["wi_gate"].astype(cd)) * (h @ p["wi_up"].astype(cd))
    elif cfg.mlp_variant == "geglu":
        g = jax.nn.gelu(h @ p["wi_gate"].astype(cd)) * (h @ p["wi_up"].astype(cd))
    elif cfg.mlp_variant == "relu2":
        g = jnp.square(jax.nn.relu(h @ p["wi"].astype(cd)))
    else:  # gelu
        g = jax.nn.gelu(h @ p["wi"].astype(cd))
    return g @ p["wo_mlp"].astype(cd)


def mlp_block(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    return x + mlp_core(p, cfg, h)
