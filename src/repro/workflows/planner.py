"""Adaptive batch planning: self-tuning windows from streaming load signals.

PR 3's ``StageBatcher`` made cross-instance batching *possible*; its
window and size cap were still static per run, and fig8 showed the best
window differs per workflow shape and arrival rate — exactly the tuning
burden InferLine (1812.01776) argues a planner should absorb, and that
Vortex (2511.02062) absorbs by adapting batch formation to queue
pressure.  ``BatchPlanner`` closes it with three streaming signals, all
O(1) to read:

  * **arrival rate** — an EWMA of per-(stage, slot) inter-arrival gaps,
    fed by every enrollment (how long does one more member cost?);
  * **service percentiles** — the tracker's per-stage
    :class:`repro.runtime.stats.StageStats` sketches (what does the rest
    of the workflow still cost after this stage?);
  * **backlog** — the slot nodes' admitted-but-unfinished compute
    seconds per lane (``Node.pending``, maintained O(1) by the compute
    handlers): is there anything to amortize against at all, and for how
    long is waiting free?

On every batch open it picks the largest batch whose expected formation
wait plus amortized service (``BatchCostModel.largest_within``) fits the
enrolling member's deadline headroom net of the downstream critical path,
then sizes the window to the backlog: holding a batch open only costs
latency once a lane could actually have run it, so the window tracks the
slot's pending compute seconds (scaled by ``pending_gain``) — near zero
on an unloaded slot (the idle rule flushes anyway), growing exactly when
contention makes formation free.  No per-rate knobs: the same policy
instance matches or beats the best hand-picked static window at every
arrival rate of the fig8 sweep (``benchmarks/fig9_adaptive.py`` records
that).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.runtime.batching import BatchCostModel
from repro.runtime.stats import StageStats

from .graph import Stage, WorkflowGraph


@dataclasses.dataclass(frozen=True)
class AdaptiveBatchPolicy:
    """Bounds and gains for the planner (NOT per-rate tuning knobs —
    one instance is meant to serve every load level).

    ``min_window``/``max_window`` clamp the planned formation window;
    ``gap_alpha`` is the EWMA weight on new inter-arrival gaps;
    ``headroom_safety`` is the fraction of remaining deadline headroom the
    planner lets formation + service spend (the rest absorbs estimation
    error); ``window_slack`` over-provisions the window past the expected
    fill time so the size cap — not the timer — usually flushes;
    ``min_samples`` gates trusting a stage's span sketch over the static
    fallback; ``tail_quantile`` is the percentile used for the downstream
    critical path.
    """
    min_window: float = 0.0005
    max_window: float = 0.064
    slo_margin: float = 0.0
    gap_alpha: float = 0.25
    headroom_safety: float = 0.85
    gap_gain: float = 0.0          # window per observed arrival gap
    pending_gain: float = 0.75     # window per second of backlogged compute
    min_samples: int = 8
    tail_quantile: float = 0.95
    refresh_every: int = 64        # plans between tail-estimate refreshes
    # saturation (queue-drain) controller: the slot is saturated when
    # per-lane backlog already exceeds this many unit service times — in
    # that regime individual deadlines are not the binding constraint,
    # drain rate is, so the formation window is sized to FILL the cap
    # instead of being clamped by (exhausted) deadline headroom
    saturate_backlog: float = 4.0  # unit-costs of backlog => saturated
    # utilization-controller window floors (the sustained-overload fix):
    # under backlog the window never shrinks below ``unit_window`` service
    # times of the stage itself, and — whenever an arrival rate has been
    # observed — below ``gap_window`` arrival gaps, so a batch always
    # stays open long enough to catch the next upstream burst instead of
    # flushing into a queue that cannot drain it any sooner
    unit_window: float = 0.4       # window floor in stage unit costs
    gap_window: float = 1.5        # window floor in observed arrival gaps
    # economic idle rule: holding a batch open on an idle lane is worth
    # one expected arrival gap of dead time when the NEXT member's
    # amortization saving (unit x the cost model's fixed share) exceeds
    # it — cheap stages still flush at once, expensive weight-streaming
    # stages wait for their burst.  0 disables holding (always flush on
    # idle, the pre-planner behavior).
    hold_gain: float = 1.3


class BatchPlanner:
    """Per-(stage, slot) controller retuning window/max_batch continuously.

    The :class:`~repro.workflows.batching.StageBatcher` calls
    :meth:`note_arrival` on every enrollment and :meth:`plan` on every
    batch open; both are O(1) (the downstream-tail estimate is memoized
    and refreshed every ``refresh_every`` plans).
    """

    def __init__(self, graph: WorkflowGraph, tracker,
                 cost_model: Optional[BatchCostModel] = None,
                 policy: Optional[AdaptiveBatchPolicy] = None):
        self.graph = graph
        self.tracker = tracker                 # InstanceTracker
        self.cost_model = cost_model or BatchCostModel()
        self.policy = policy or AdaptiveBatchPolicy()
        self._stages: Dict[str, Stage] = {s.name: s for s in graph.stages}
        # emit->trigger successor map (for the downstream critical path)
        self._succ: Dict[str, Tuple[str, ...]] = {
            s.name: tuple(sorted({d.name for e in s.emits
                                  for d in graph.stages_on(e.pool)}))
            for s in graph.stages}
        self._gap: Dict[Tuple[str, str], float] = {}     # EWMA arrival gap
        self._last: Dict[Tuple[str, str], float] = {}
        self._tail: Dict[str, float] = {}                # memoized tails
        self._plans_since_refresh = 0
        # realized-planning stats (summary() reports them)
        self.plans = 0
        self.throughput_mode = 0      # budget exhausted -> max batch
        self.saturated_plans = 0      # queue-drain term engaged
        self.windows = StageStats()   # distribution of planned windows
        self.caps = StageStats()      # distribution of planned size caps

    # -- signal feeds --------------------------------------------------------

    def note_arrival(self, stage_name: str, slot: str, now: float) -> None:
        """EWMA the inter-arrival gap of (stage, slot) — every enrollment."""
        key = (stage_name, slot)
        last = self._last.get(key)
        self._last[key] = now
        if last is None:
            return
        gap = now - last
        prev = self._gap.get(key)
        a = self.policy.gap_alpha
        self._gap[key] = gap if prev is None else (1 - a) * prev + a * gap

    # -- estimates -----------------------------------------------------------

    def span_tail(self, stage_name: str) -> float:
        """Tail (``tail_quantile``) span of one stage — sketch if warm,
        static fallback (2x declared cost covers transfer/queue slack)."""
        st: Optional[StageStats] = \
            self.tracker.stage_stats.get(stage_name)
        if st is not None and st.count >= self.policy.min_samples:
            return st.quantile(self.policy.tail_quantile)
        return 2.0 * self._stages[stage_name].cost

    def tail_after(self, stage_name: str) -> float:
        """Critical-path tail span strictly downstream of ``stage_name``
        (what the instance still pays after this stage completes)."""
        cached = self._tail.get(stage_name)
        if cached is not None:
            return cached
        tail = max((self.span_tail(d) + self.tail_after(d)
                    for d in self._succ[stage_name]), default=0.0)
        self._tail[stage_name] = tail
        return tail

    def service_path(self, speed_of=None) -> float:
        """Pure-service end-to-end critical path: the max-cost stage
        chain with every cost divided by ``speed_of(resource)`` — the
        *current tier mix* half of the admission estimate (the other
        half, live queue backlog, comes from the runtime).  Unlike the
        realized span sketches this carries no queueing, so it neither
        lags a building ramp nor stays sticky-high after one.
        """
        if speed_of is None:
            speed_of = lambda resource: 1.0          # noqa: E731
        memo: Dict[str, float] = {}                  # shared sub-chains

        def chain(name: str) -> float:
            v = memo.get(name)
            if v is None:
                s = self._stages[name]
                v = s.cost / max(speed_of(s.resource), 1e-9) + \
                    max((chain(d) for d in self._succ[name]), default=0.0)
                memo[name] = v
            return v
        return chain(self.graph.source_stages[0].name)

    def hold_when_idle(self, stage_name: str, slot: str,
                       unit: float) -> bool:
        """Economic idle rule: should a fresh batch stay open even though
        a lane is free right now?

        Flushing buys an immediate start; holding one expected arrival
        gap buys the next member's amortization saving, ``unit x
        fixed/(fixed+marginal)`` (the weight-streaming share a deeper
        batch does not pay again).  Hold exactly when the saving (scaled
        by ``hold_gain``) exceeds the expected wait — so cheap stages
        still flush instantly on idle lanes while expensive
        weight-streaming stages wait for their burst.  Without an
        observed arrival rate there is nothing to wait for.
        """
        pol = self.policy
        if pol.hold_gain <= 0.0 or unit <= 0.0:
            return False
        gap = self._gap.get((stage_name, slot))
        if gap is None or gap <= 0.0:
            return False
        cm = self.cost_model
        saving = unit * cm.fixed / (cm.fixed + cm.marginal)
        return gap < pol.hold_gain * saving

    # -- the decision --------------------------------------------------------

    def plan(self, stage: Stage, slot: str, now: float,
             deadline: Optional[float],
             pending: float = 0.0) -> Tuple[float, int]:
        """(window_seconds, max_batch) for a batch opening now.

        ``deadline`` is the enrolling member's absolute deadline (None =
        unconstrained); ``pending`` the seconds of admitted-but-unfinished
        compute per lane on the slot's least-backed-up member — how long
        the fresh batch would wait for a lane even if it flushed right
        now.
        """
        pol = self.policy
        self.plans += 1
        self._plans_since_refresh += 1
        if self._plans_since_refresh >= pol.refresh_every:
            self._tail.clear()                 # re-read the span sketches
            self._plans_since_refresh = 0
        cm = self.cost_model
        unit = stage.cost
        gap = self._gap.get((stage.name, slot))

        budget = float("inf")
        if deadline is not None:
            budget = (deadline - now - self.tail_after(stage.name)
                      - pol.slo_margin) * pol.headroom_safety
        # queue-drain saturation check: per-lane backlog already holds
        # several unit services, i.e. the queue has not been draining —
        # the long-plateau regime where the deadline-headroom clamp below
        # used to collapse the window to its minimum and strand the cap
        # unfilled (the fig8 full-scale under-batching gap)
        saturated = unit > 0.0 and pending >= pol.saturate_backlog * unit
        if budget <= cm.batch_seconds(unit, 1):
            # Deadline headroom is already gone (overload ate it upstream):
            # protecting this member is impossible, so maximize throughput
            # for everyone behind it — the regime where batching pays most.
            self.throughput_mode += 1
            cap = cm.max_batch
            saturated = True
        elif gap is None or gap <= 0.0:
            # No arrival-rate signal yet: admit the full cap and let the
            # SLO/size/idle rules govern (first batches of a run).
            cap = cm.max_batch
        elif saturated:
            # Still some headroom, but the queue can only grow: per-member
            # latency is set by drain rate, not by this batch's formation
            # wait, so run at the deepest amortization the tier admits.
            cap = cm.max_batch
        else:
            cap = cm.largest_within(unit, budget, wait_per_member=gap)
        # The window is NOT "time to fill the cap": holding a batch open
        # costs its members latency, and that wait is only free while the
        # slot's lanes are busy with earlier work.  Two signals size it:
        # the observed arrival gap (long enough to catch the next firing)
        # and the backlogged compute seconds per lane (formation time the
        # batch could not have started in anyway).  Never longer than the
        # headroom left after the planned batch's own service time —
        # EXCEPT under saturation, where that headroom is already spent
        # and clamping by it would under-batch exactly when amortization
        # pays most: there the window follows the backlog/fill signals
        # alone (the size cap, not the timer, flushes in practice).
        if cap <= 1 or gap is None or gap <= 0.0:
            window = pol.min_window
        else:
            window = max(pol.gap_gain * gap, pol.pending_gain * pending)
            if saturated:
                window = max(window, gap * (cap - 1))
            elif budget != float("inf"):
                window = min(window, max(
                    budget - cm.batch_seconds(unit, cap), pol.min_window))
        # utilization floors: under backlog, flushing faster than the
        # stage's own service time just lengthens the queue at a
        # shallower batch depth; and a window shorter than the observed
        # arrival cadence can never coalesce at all
        if cap > 1 and unit > 0.0:
            if pending > 0.0:
                window = max(window, pol.unit_window * unit)
            if gap is not None and gap > 0.0:
                window = max(window, pol.gap_window * gap)
        window = min(max(window, pol.min_window), pol.max_window)
        if saturated:
            self.saturated_plans += 1
        self.windows.observe(window)
        self.caps.observe(float(cap))
        return window, cap

    # -- reporting -----------------------------------------------------------

    def summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {
            "plans": self.plans,
            "throughput_mode_plans": self.throughput_mode,
            "saturated_plans": self.saturated_plans,
        }
        if self.plans:
            out["planned_window_p50"] = self.windows.quantile(0.5)
            out["planned_cap_p50"] = self.caps.quantile(0.5)
        return out
