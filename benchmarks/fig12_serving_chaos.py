"""Fig. 12 (ours): serving rows under chaos — KV-priced session recovery.

The serving engine (real JAX decode over the granite smoke model) drives
multi-turn chat sessions while rows die mid-conversation.  At each chaos
intensity (number of rows killed) the SAME turn schedule runs under two
recovery configurations:

  * ``reprefill`` — a displaced session rebuilds its decode cache by
    re-prefilling its full transcript on the surviving row (priced at
    ``prefill_per_tok * len(transcript)``);
  * ``ckpt``      — periodic KV snapshots (every ``CKPT_EVERY`` turns,
    off the critical path): recovery ships the checkpoint
    (``net.transfer_time(session_cache_bytes)``) and replays only the
    transcript suffix past it.  The engine picks the cheaper plan per
    session — KV-priced recovery, paper §7.2's state objects under §3.4's
    group semantics.

Virtual service costs are PINNED (``SVC``) so the latency rows are
deterministic across hosts; the model still executes every real token —
output equivalence against the healthy run is checked bit-for-bit.

Recorded acceptance (all deterministic):

  1. ZERO lost sessions and ZERO shed turns at every intensity — chaos
     costs latency, never tokens: every chaos run's greedy outputs equal
     the healthy run's token-for-token;
  2. ZERO duplicate group effects and ZERO order violations everywhere —
     the per-group sequencer keeps replays exactly-once and in order;
  3. recovery engages at every intensity >= 1 (sessions displaced, the
     configured recovery mode fires), and the checkpointed engine's p99
     is STRICTLY below re-prefill's at every intensity >= 1;
  4. the traced run reproduces the untraced latencies byte-for-byte and
     its blame decomposition carries the recovery category
     (``blame_recovery_ms`` > 0 — the ``bench_explain`` vocabulary).
"""
import time

import numpy as np

from .common import emit, write_chrome_trace

N_ROWS = 3
MAX_SLOTS = 8
MAX_SEQ = 128
N_SESSIONS = 8
TURNS = 6
GEN = 4
CKPT_EVERY = 2
# pinned virtual service costs (seconds): decode step + per-token prefill
SVC = {"decode_step": 1e-3, "prefill_per_tok": 1.25e-4}
DT = SVC["decode_step"]
# kill schedules by intensity: (row, t_down, duration) in decode steps —
# mid-conversation, after sessions hold state, before the drive ends
CHAOS = {
    1: ((0, 40, 30),),
    2: ((0, 40, 30), (1, 55, 30)),
}

_CACHE = {}


def _model():
    if "mp" not in _CACHE:
        import jax
        from repro import configs
        from repro.models import build_model
        cfg = configs.get_smoke("granite-3-2b")
        model = build_model(cfg)
        _CACHE["mp"] = (model, model.init(jax.random.PRNGKey(0)))
    return _CACHE["mp"]


def run_serving(intensity, checkpoint_every, tracer=None):
    """One configuration over the shared turn schedule + chaos."""
    from repro.runtime import FaultInjector, RetryPolicy
    from repro.serving import ServingEngine
    model, params = _model()
    eng = ServingEngine(model, params, n_rows=N_ROWS,
                        max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
                        policy="affinity", tracer=tracer,
                        checkpoint_every=checkpoint_every)
    eng._svc = dict(SVC)
    eng.retry = RetryPolicy(max_attempts=4, backoff=2 * DT)
    inj = FaultInjector(serving=eng)
    for row, t_down, dur in CHAOS.get(intensity, ()):
        inj.fail_row(row, at=t_down * DT, duration=dur * DT)
    for i in range(N_SESSIONS):
        eng.open_session(f"s{i}")
    t, outs = 0.0, {}
    for _ in range(TURNS):
        for i in range(N_SESSIONS):
            out, _ = eng.turn(f"s{i}", [1 + i, 2, 3], gen_tokens=GEN,
                              now=t)
            outs.setdefault(f"s{i}", []).extend(out)
            t += 2 * DT
    return eng, inj, outs


def _lost_sessions(eng):
    return sum(1 for s in eng.sessions.values() if s.turns != TURNS)


def _e2e(eng):
    return np.array([m.e2e for m in eng.metrics if not m.shed])


def _row(tag, eng, inj, t0):
    e2e = _e2e(eng)
    s = eng.summary()
    d = {
        "p50_ms": round(float(np.percentile(e2e, 50)) * 1e3, 4),
        "p99_ms": round(float(np.percentile(e2e, 99)) * 1e3, 4),
        "turns": len(eng.metrics),
        "turns_ok": int(len(e2e)),
        "turns_failed": eng.turns_failed,
        "shed_turns": eng.shed_turns,
        "lost_sessions": _lost_sessions(eng),
        "dup_effects": eng.dup_effects,
        "order_violations": eng.order_violations,
        "sessions_displaced": sum(ev.sessions_displaced
                                  for ev in inj.events),
        "groups_rerouted": sum(ev.groups_rerouted for ev in inj.events),
        "recoveries_ckpt": eng.recoveries_ckpt,
        "recoveries_reprefill": eng.recoveries_reprefill,
        "recovery_kb": round(eng.recovery_bytes / 1024, 1),
        "checkpoint_kb": round(eng.checkpoint_bytes / 1024, 1),
        "migrations": s["migrations"],
        "wall_s": round(time.perf_counter() - t0, 3),
    }
    return (f"fig12/{tag}", float(np.mean(e2e)) * 1e6, d)


def run(quick=True):
    rows = []
    p99 = {}
    outputs_ok = {}
    clean = {}          # zero dup effects / order violations / shed
    recovered = {}

    t0 = time.perf_counter()
    healthy, inj0, base_outs = run_serving(0, checkpoint_every=None)
    rows.append(_row("healthy", healthy, inj0, t0))

    configs_ = (("reprefill", None), ("ckpt", CKPT_EVERY))
    for k in sorted(CHAOS):
        for tag, every in configs_:
            t0 = time.perf_counter()
            eng, inj, outs = run_serving(k, checkpoint_every=every)
            name = f"{tag}{k}"
            rows.append(_row(name, eng, inj, t0))
            p99[name] = float(np.percentile(_e2e(eng), 99))
            outputs_ok[name] = outs == base_outs
            clean[name] = (eng.dup_effects == 0
                           and eng.order_violations == 0
                           and eng.shed_turns == 0
                           and _lost_sessions(eng) == 0)
            recovered[name] = (eng.recoveries_ckpt if every
                               else eng.recoveries_reprefill)

    # one traced run (max intensity, checkpointed): the blame table shows
    # where the outage's latency went — recovery/retry land in the
    # bench_explain vocabulary — and tracing must reproduce the untraced
    # latencies byte-for-byte
    from repro.runtime import TraceRecorder
    from repro.workflows import BlameTable
    t0 = time.perf_counter()
    rec = TraceRecorder()
    blame = BlameTable()
    rec.on_complete.append(blame.add)
    eng, inj, outs = run_serving(max(CHAOS), checkpoint_every=CKPT_EVERY,
                                 tracer=rec)
    path, payload = write_chrome_trace(rec, "fig12")
    traced_p99 = float(np.percentile(_e2e(eng), 99))
    flat = blame.flat()
    rows.append((f"fig12/trace/ckpt{max(CHAOS)}",
                 float(np.mean(_e2e(eng))) * 1e6,
                 {"p99_ms": round(traced_p99 * 1e3, 4),
                  **flat,
                  "trace_events": len(payload["traceEvents"]),
                  "artifact": path.name,
                  "wall_s": round(time.perf_counter() - t0, 3)}))

    # -- acceptance ---------------------------------------------------------
    zero_lost = (_lost_sessions(healthy) == 0
                 and all(clean.values()))
    outputs_exact = all(outputs_ok.values()) and outs == base_outs
    recovery_engaged = all(recovered[f"{tag}{k}"] > 0
                           for tag, _ in configs_ for k in CHAOS)
    ckpt_beats_reprefill = all(p99[f"ckpt{k}"] < p99[f"reprefill{k}"]
                               for k in CHAOS)
    traced_matches = abs(traced_p99 - p99[f"ckpt{max(CHAOS)}"]) < 1e-12
    recovery_blamed = flat["blame_recovery_ms"] > 0.0
    rows.append(("fig12/acceptance", 0.0, {
        "zero_lost_sessions": zero_lost,
        "zero_duplicate_group_effects": all(clean.values()),
        "chaos_outputs_equal_healthy": outputs_exact,
        "recovery_engaged": recovery_engaged,
        "ckpt_p99_beats_reprefill": ckpt_beats_reprefill,
        "traced_run_latency_identical": traced_matches,
        "recovery_blame_emitted": recovery_blamed,
    }))
    assert zero_lost and outputs_exact and recovery_engaged \
        and ckpt_beats_reprefill and traced_matches \
        and recovery_blamed, rows[-1][2]
    return rows


if __name__ == "__main__":
    emit(run())
