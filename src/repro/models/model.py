"""Model assembly: config -> init / train / prefill / decode entry points.

All families share one ``Model`` facade:

  * params are a pytree with a stacked ``layers`` subtree (scan-over-layers;
    the hybrid family scans pattern *groups* + an unrolled tail),
  * every leaf has a logical-axes annotation (``param_axes``) consumed by
    ``repro.distributed.sharding_rules``,
  * ``decode_step`` implements serve_step: one token per sequence against the
    family-specific cache (KV / latent-KV / SSM state / LRU state).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import constraints as cst
from .common import ModelConfig, ParamFactory, count_params, scaled_init
from . import layers, moe, mla, rglru, ssd

Params = Dict[str, Any]


def _sp(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Sequence-parallel residual: pin the (B,S,d) stream's S over 'model'
    between blocks, so norms/elementwise run sharded and GSPMD lowers the
    per-block boundary to all-gather + reduce-scatter (half the bytes of
    the default per-sublayer all-reduce pair)."""
    if cfg.seq_parallel_residual and x.ndim == 3:
        return cst.constrain(x, "dp", "tp", None)
    return x


# ---------------------------------------------------------------------------
# Per-kind block init/apply
# ---------------------------------------------------------------------------

def _init_block(pf: ParamFactory, cfg: ModelConfig, kind: str):
    if kind in ("attn", "wattn"):
        sub = pf.subtree("mixer")
        if cfg.mla and kind == "attn":
            mla.init_mla(sub, cfg)
        else:
            layers.init_attention(sub, cfg)
        if cfg.family == "moe" and kind == "attn":
            moe.init_moe_mlp(pf.subtree("mlp"), cfg)
        else:
            layers.init_mlp(pf.subtree("mlp"), cfg)
    elif kind == "rglru":
        rglru.init_rglru_block(pf.subtree("mixer"), cfg)
        layers.init_mlp(pf.subtree("mlp"), cfg)
    elif kind == "ssd":
        ssd.init_ssd_block(pf.subtree("mixer"), cfg)
    else:
        raise ValueError(kind)


def _block_train(bp: Params, cfg: ModelConfig, x: jax.Array, kind: str):
    if kind == "attn":
        if cfg.mla:
            x = mla.mla_train(bp["mixer"], cfg, x)
        else:
            x = layers.attention_train(bp["mixer"], cfg, x, window=0)
        if cfg.family == "moe":
            x = moe.moe_block(bp["mlp"], cfg, x)
        else:
            x = layers.mlp_block(bp["mlp"], cfg, x)
    elif kind == "rglru":
        x = rglru.rglru_train(bp["mixer"], cfg, x)
        x = layers.mlp_block(bp["mlp"], cfg, x)
    elif kind == "ssd":
        x = ssd.ssd_train(bp["mixer"], cfg, x)
    elif kind == "wattn":   # hybrid local-window attention
        x = layers.attention_train(bp["mixer"], cfg, x, window=cfg.attn_window)
        x = layers.mlp_block(bp["mlp"], cfg, x)
    return x


def _block_prefill(bp, cfg, x, kind):
    if kind == "attn":
        if cfg.mla:
            x, cache = mla.mla_prefill(bp["mixer"], cfg, x)
        else:
            x, cache = layers.attention_prefill(bp["mixer"], cfg, x)
        if cfg.family == "moe":
            x = moe.moe_block(bp["mlp"], cfg, x)
        else:
            x = layers.mlp_block(bp["mlp"], cfg, x)
    elif kind == "rglru":
        x, cache = rglru.rglru_prefill(bp["mixer"], cfg, x)
        x = layers.mlp_block(bp["mlp"], cfg, x)
    elif kind == "ssd":
        x, cache = ssd.ssd_prefill(bp["mixer"], cfg, x)
    elif kind == "wattn":
        x, cache = layers.attention_prefill(bp["mixer"], cfg, x)
        w = cfg.attn_window
        cache = {"k": cache["k"][:, -w:], "v": cache["v"][:, -w:]}
        x = layers.mlp_block(bp["mlp"], cfg, x)
    return x, cache


def _block_decode(bp, cfg, x, cache, lengths, kind):
    if kind == "attn":
        if cfg.mla:
            x, cache = mla.mla_decode(bp["mixer"], cfg, x, cache, lengths)
        else:
            x, cache = layers.attention_decode(bp["mixer"], cfg, x, cache,
                                               lengths)
        if cfg.family == "moe":
            x = moe.moe_block(bp["mlp"], cfg, x[:, None, :])[:, 0]
        else:
            x = layers.mlp_block(bp["mlp"], cfg, x[:, None, :])[:, 0]
    elif kind == "rglru":
        x, cache = rglru.rglru_decode(bp["mixer"], cfg, x, cache, lengths)
        x = layers.mlp_block(bp["mlp"], cfg, x[:, None, :])[:, 0]
    elif kind == "ssd":
        x, cache = ssd.ssd_decode(bp["mixer"], cfg, x, cache, lengths)
    elif kind == "wattn":
        w = cfg.attn_window
        ring_len = cache["k"].shape[1]
        slot = lengths % ring_len
        valid = jnp.minimum(lengths + 1, ring_len)
        x, cache = _ring_attention_decode(bp["mixer"], cfg, x, cache, lengths,
                                          slot, valid)
        x = layers.mlp_block(bp["mlp"], cfg, x[:, None, :])[:, 0]
    return x, cache


def _ring_attention_decode(p, cfg, x, cache, lengths, slot, valid):
    """Window attention against a ring-buffer cache (slot = pos % window)."""
    from repro.kernels import ops
    B, _ = x.shape
    h = layers.rmsnorm(p["ln"], x[:, None, :], cfg.norm_eps)
    q, k, v = layers._qkv(p, cfg, h)
    q = layers.rope(q, lengths[:, None], cfg.rope_theta)[:, 0]
    k = layers.rope(k, lengths[:, None], cfg.rope_theta)[:, 0]
    v = v[:, 0]
    bidx = jnp.arange(B)
    k_c = cache["k"].at[bidx, slot].set(k.astype(cache["k"].dtype))
    v_c = cache["v"].at[bidx, slot].set(v.astype(cache["v"].dtype))
    o = ops.decode_attention(q, k_c, v_c, valid)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"].astype(cfg.compute_dtype))
    return x + out, {"k": k_c, "v": v_c}


def _block_cache_spec(cfg, kind, batch, max_seq):
    if kind == "attn":
        if cfg.mla:
            return mla.mla_cache_spec(cfg, batch, max_seq)
        return layers.attention_cache_spec(cfg, batch, max_seq)
    if kind == "rglru":
        return rglru.rglru_cache_spec(cfg, batch, max_seq)
    if kind == "ssd":
        return ssd.ssd_cache_spec(cfg, batch, max_seq)
    if kind == "wattn":
        return layers.attention_cache_spec(cfg, batch, max_seq,
                                           window=cfg.attn_window)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Model facade
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._hybrid = bool(cfg.block_pattern) and len(set(cfg.block_pattern)) > 1
        if self._hybrid:
            period = len(cfg.block_pattern)
            self.n_groups = cfg.n_layers // period
            self.tail_kinds = tuple(
                self._kind(i) for i in range(self.n_groups * period,
                                             cfg.n_layers))
            self.group_kinds = tuple(self._kind(i) for i in range(period))
        self._axes: Optional[Any] = None

    def _kind(self, layer_idx: int) -> str:
        k = self.cfg.block_kind(layer_idx)
        if k == "attn" and self.cfg.attn_window:
            return "wattn"
        return k

    # -- init ---------------------------------------------------------------

    def _init_one_layer(self, rng, kind: str):
        pf = ParamFactory(rng, self.cfg.param_dtype)
        _init_block(pf, self.cfg, kind)
        return pf.params, pf.axes

    def init(self, rng: jax.Array) -> Params:
        cfg = self.cfg
        rngs = jax.random.split(rng, 4)
        pf = ParamFactory(rngs[0], cfg.param_dtype)
        layers.init_embedding(pf, cfg)
        params: Params = {"embed": pf.params}
        axes: Dict[str, Any] = {"embed": pf.axes}

        if cfg.frontend == "audio":
            fp = ParamFactory(rngs[2], cfg.param_dtype)
            fp.param("w_feat", (cfg.frontend_dim, cfg.d_model),
                     ("frontend", "embed"), fan_in=cfg.frontend_dim)
            params["frontend"] = fp.params
            axes["frontend"] = fp.axes
        elif cfg.frontend == "vision":
            fp = ParamFactory(rngs[2], cfg.param_dtype)
            fp.param("w_patch", (cfg.frontend_dim, cfg.d_model),
                     ("frontend", "embed"), fan_in=cfg.frontend_dim)
            params["frontend"] = fp.params
            axes["frontend"] = fp.axes

        if self._hybrid:
            def init_group(key):
                ps, axs = {}, {}
                keys = jax.random.split(key, len(self.group_kinds))
                for i, kind in enumerate(self.group_kinds):
                    ps[f"b{i}"], axs[f"b{i}"] = self._init_one_layer(keys[i],
                                                                     kind)
                return ps, axs
            gkeys = jax.random.split(rngs[1], self.n_groups)
            stacked, gaxes = jax.vmap(lambda k: init_group(k)[0])(gkeys), \
                init_group(gkeys[0])[1]
            params["groups"] = stacked
            axes["groups"] = jax.tree_util.tree_map(
                lambda a: ("layers",) + tuple(a), gaxes,
                is_leaf=_is_axes_leaf)
            tkeys = jax.random.split(rngs[3], max(len(self.tail_kinds), 1))
            params["tail"] = {}
            axes["tail"] = {}
            for i, kind in enumerate(self.tail_kinds):
                params["tail"][f"t{i}"], axes["tail"][f"t{i}"] = \
                    self._init_one_layer(tkeys[i], kind)
        else:
            kind = self._kind(0)
            lkeys = jax.random.split(rngs[1], cfg.n_layers)
            stacked = jax.vmap(lambda k: self._init_one_layer(k, kind)[0])(
                lkeys)
            _, laxes = self._init_one_layer(lkeys[0], kind)
            params["layers"] = stacked
            axes["layers"] = jax.tree_util.tree_map(
                lambda a: ("layers",) + tuple(a), laxes,
                is_leaf=_is_axes_leaf)
        self._axes = axes
        return params

    def param_axes(self) -> Any:
        if self._axes is None:
            jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return self._axes

    # -- embedding-side input handling ---------------------------------------

    def _embed_inputs(self, params: Params, batch: Dict[str, jax.Array]):
        cfg = self.cfg
        if cfg.frontend == "audio":
            x = (batch["features"].astype(cfg.compute_dtype)
                 @ params["frontend"]["w_feat"].astype(cfg.compute_dtype))
            return x
        x = layers.embed(params["embed"], cfg, batch["tokens"])
        if cfg.frontend == "vision" and "patches" in batch:
            proj = (batch["patches"].astype(cfg.compute_dtype)
                    @ params["frontend"]["w_patch"].astype(cfg.compute_dtype))
            x = x.at[:, :proj.shape[1]].set(proj)
        return x

    # -- layer-stack application ---------------------------------------------

    def _remat(self, fn):
        if self.cfg.remat == "none":
            return fn
        if self.cfg.remat == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            return jax.checkpoint(fn, policy=policy)
        return jax.checkpoint(fn)   # "layer": save nothing

    def _apply_stack_train(self, params: Params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        if self._hybrid:
            def group_fn(x, gp):
                for i, kind in enumerate(self.group_kinds):
                    x = _block_train(gp[f"b{i}"], cfg, x, kind)
                return x, None
            if cfg.scan_layers:
                x, _ = jax.lax.scan(self._remat(group_fn), x,
                                    params["groups"])
            else:
                for g in range(self.n_groups):
                    gp = _tree_index(params["groups"], g)
                    x, _ = self._remat(group_fn)(x, gp)
            for i, kind in enumerate(self.tail_kinds):
                x = _block_train(params["tail"][f"t{i}"], cfg, x, kind)
            return x
        kind = self._kind(0)
        def body(x, lp):
            return _sp(cfg, _block_train(lp, cfg, _sp(cfg, x), kind)), None
        if cfg.scan_layers:
            x, _ = jax.lax.scan(self._remat(body), x, params["layers"])
        else:
            for li in range(cfg.n_layers):
                x, _ = self._remat(body)(x, _tree_index(params["layers"], li))
        return x

    # -- public entry points --------------------------------------------------

    def forward_train(self, params: Params, batch: Dict[str, jax.Array]):
        x = self._embed_inputs(params, batch)
        x = self._apply_stack_train(params, x)
        return layers.unembed(params["embed"], self.cfg, x)

    def loss(self, params: Params, batch: Dict[str, jax.Array]):
        cfg = self.cfg
        logits = self.forward_train(params, batch)        # (B,S,V) fp32
        if cfg.family == "encoder" or not cfg.is_causal:
            targets = batch["labels"]
            valid = targets >= 0
            tgt = jnp.where(valid, targets, 0)
            nll = self._nll(logits, tgt)
            loss = jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)
        else:
            targets = batch["tokens"][:, 1:]
            nll = self._nll(logits[:, :-1], targets)
            loss = jnp.mean(nll)
        return loss, {"loss": loss}

    def _nll(self, logits: jax.Array, targets: jax.Array) -> jax.Array:
        lp = jax.nn.log_softmax(logits, axis=-1)
        if self.cfg.onehot_loss:
            # iota-compare one-hot + contraction: under a vocab-sharded
            # layout this lowers to a tiny (B,S) partial-sum all-reduce
            # instead of materializing/gathering the full logits.
            V = logits.shape[-1]
            onehot = (targets[..., None]
                      == jax.lax.broadcasted_iota(jnp.int32, (V,), 0)
                      ).astype(lp.dtype)
            return -jnp.einsum("bsv,bsv->bs", lp, onehot)
        return -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]

    def prefill(self, params: Params, batch: Dict[str, jax.Array]):
        """Returns (last-position logits, decode cache)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        if self._hybrid:
            caches: Dict[str, Any] = {}
            def group_fn(x, gp):
                cs = {}
                for i, kind in enumerate(self.group_kinds):
                    x, cs[f"b{i}"] = _block_prefill(gp[f"b{i}"], cfg, x, kind)
                return x, cs
            if cfg.scan_layers:
                x, gcaches = jax.lax.scan(group_fn, x, params["groups"])
            else:
                gc_list = []
                for g in range(self.n_groups):
                    x, gc = group_fn(x, _tree_index(params["groups"], g))
                    gc_list.append(gc)
                gcaches = _tree_stack(gc_list)
            caches["groups"] = gcaches
            caches["tail"] = {}
            for i, kind in enumerate(self.tail_kinds):
                x, caches["tail"][f"t{i}"] = _block_prefill(
                    params["tail"][f"t{i}"], cfg, x, kind)
        else:
            kind = self._kind(0)
            def body(x, lp):
                return _block_prefill(lp, cfg, x, kind)
            if cfg.scan_layers:
                x, caches = jax.lax.scan(body, x, params["layers"])
            else:
                c_list = []
                for li in range(cfg.n_layers):
                    x, c = body(x, _tree_index(params["layers"], li))
                    c_list.append(c)
                caches = _tree_stack(c_list)
        logits = layers.unembed(params["embed"], cfg, x[:, -1:])[:, 0]
        return logits, caches

    def decode_step(self, params: Params, cache: Any, tokens: jax.Array,
                    lengths: jax.Array, return_hidden: bool = False):
        """tokens (B,) int32, lengths (B,). Returns (logits (B,V), cache)."""
        cfg = self.cfg
        x = layers.embed(params["embed"], cfg, tokens)
        if self._hybrid:
            def group_fn(x, xs):
                gp, gc = xs
                ncs = {}
                for i, kind in enumerate(self.group_kinds):
                    x, ncs[f"b{i}"] = _block_decode(gp[f"b{i}"], cfg, x,
                                                    gc[f"b{i}"], lengths, kind)
                return x, ncs
            if cfg.scan_layers:
                x, gcaches = jax.lax.scan(group_fn, x,
                                          (params["groups"], cache["groups"]))
            else:
                gc_list = []
                for g in range(self.n_groups):
                    x, gc = group_fn(x, (_tree_index(params["groups"], g),
                                         _tree_index(cache["groups"], g)))
                    gc_list.append(gc)
                gcaches = _tree_stack(gc_list)
            new_cache = {"groups": gcaches, "tail": {}}
            for i, kind in enumerate(self.tail_kinds):
                x, new_cache["tail"][f"t{i}"] = _block_decode(
                    params["tail"][f"t{i}"], cfg, x, cache["tail"][f"t{i}"],
                    lengths, kind)
        else:
            kind = self._kind(0)
            def body(x, xs):
                lp, lc = xs
                return _block_decode(lp, cfg, x, lc, lengths, kind)
            if cfg.scan_layers:
                x, new_cache = jax.lax.scan(body, x,
                                            (params["layers"], cache))
            else:
                c_list = []
                for li in range(cfg.n_layers):
                    x, c = body(x, (_tree_index(params["layers"], li),
                                    _tree_index(cache, li)))
                    c_list.append(c)
                new_cache = _tree_stack(c_list)
        logits = layers.unembed(params["embed"], cfg, x[:, None])[:, 0]
        if return_hidden:
            return logits, new_cache, x
        return logits, new_cache

    # -- cache construction ----------------------------------------------------

    def cache_spec(self, batch: int, max_seq: int):
        cfg = self.cfg
        def stack(spec, n):
            return jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), spec)
        if self._hybrid:
            g = {f"b{i}": _block_cache_spec(cfg, kind, batch, max_seq)
                 for i, kind in enumerate(self.group_kinds)}
            return {"groups": stack(g, self.n_groups),
                    "tail": {f"t{i}": _block_cache_spec(cfg, kind, batch,
                                                        max_seq)
                             for i, kind in enumerate(self.tail_kinds)}}
        kind = self._kind(0)
        return stack(_block_cache_spec(cfg, kind, batch, max_seq),
                     cfg.n_layers)

    def init_cache(self, batch: int, max_seq: int):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_spec(batch, max_seq))


def _is_axes_leaf(x):
    return isinstance(x, tuple) and all(isinstance(e, str) for e in x)


def _tree_index(tree, i):
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def _tree_stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
