"""mamba2-780m [ssm] — SSD (state-space duality), attn-free. [arXiv:2405.21060]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,              # unused (attn-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    head_dim=1,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    ssm_ngroups=1,
)

SMOKE = ModelConfig(
    name="mamba2-780m-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=256,
    head_dim=1,
    ssm_state=16,
    ssm_head_dim=8,
    ssm_expand=2,
    ssm_chunk=8,
    ssm_ngroups=1,
)
