"""Fig. 7 (ours): workflow shape x placement policy x migration x scale.

Weak-scaling sweep over the example workflow graphs: the offered load
grows with the shard count (fixed per-shard arrival rate), so a placement
policy only holds its latency as the cluster scales out if it keeps each
workflow instance's edges local.  Modes:

  * ``keyhash``  — ungrouped raw key-hash placement (cloud baseline):
    every stage output hashes independently, so fan-in joins pay remote
    fetches for almost all inputs as the shard count grows;
  * ``affinity`` — instance-affinity groups, hash-of-label placement
    (the paper's static policy lifted to whole workflow instances);
  * ``atomic``   — workflow-atomic placement: instance affinity plus
    admission-time gang pinning through a load-aware anchor policy
    (SAGA-style whole-workflow scheduling);
  * ``atomic+mig`` — atomic plus the GroupMigrator ticking on the
    migratable pools.

Reported: median (us column), p95/p99 ms, remote gets, SLO miss rate.

Finding worth keeping: on this workload migrations stay ~0 even when
enabled.  Workflow-instance groups live for tens of milliseconds — far
shorter than any useful migration interval — so runtime migration is
structurally the wrong tool for them, and the migrator's leave-ideal-
placements-alone property means it correctly never moves anything once
gang admission has balanced the load.  Admission-time (workflow-atomic)
placement is where the p99 win comes from; migration earns its keep on
persistent hot groups (see fig6), not transient instances.
"""
from .common import emit

MODES = ("keyhash", "affinity", "atomic", "atomic+mig")
DEADLINES = {"rag": 0.30, "speech": 0.20}
PER_SHARD_RATE = 12.0          # instances/s per shard (below saturation)


def run_workflow(shape: str, mode: str, shards: int, n_instances: int,
                 seed: int = 0, tracing=False):
    from repro.workflows import (WORKFLOW_SHAPES, WorkflowRuntime,
                                 mode_kwargs, preload_index)
    graph = WORKFLOW_SHAPES[shape](shards=shards)
    wrt = WorkflowRuntime(graph, seed=seed, tracing=tracing,
                          **mode_kwargs(mode))
    if shape == "rag":
        preload_index(wrt)
    rate = PER_SHARD_RATE * shards
    for i in range(n_instances):
        wrt.submit(f"req{i}", at=0.05 + i / rate,
                   deadline=DEADLINES[shape])
    wrt.run()
    return wrt


def trace_row(per_shard: int):
    """One traced exemplar (rag/4sh/atomic+mig) exporting the Perfetto
    artifact CI uploads.  Tracing reproduces every latency byte-for-byte
    (tested), so this is the same run as the sweep's, plus spans."""
    from .common import write_chrome_trace
    wrt = run_workflow("rag", "atomic+mig", 4, n_instances=per_shard * 4,
                       tracing=True)
    s = wrt.summary()
    path, payload = write_chrome_trace(wrt.tracer, "fig7")
    return ("fig7/trace/rag/4sh/atomic+mig", s["median"] * 1e6,
            {"p50_ms": round(s["median"] * 1e3, 2),
             "p99_ms": round(s["p99"] * 1e3, 2),
             "spans": s["spans"],
             "traces_completed": s["traces_completed"],
             "trace_events": len(payload["traceEvents"]),
             "blame_top": s["blame_top"],
             "blame_compute_ms": s["blame_compute_ms"],
             "artifact": path.name})


def run(quick=True):
    import time
    scales = (2, 4, 8) if quick else (2, 4, 8, 16)
    per_shard = 30 if quick else 120
    rows = []
    for shape in ("rag", "speech"):
        for shards in scales:
            for mode in MODES:
                t0 = time.perf_counter()
                s = run_workflow(shape, mode, shards,
                                 n_instances=per_shard * shards).summary()
                name = f"fig7/{shape}/{shards}sh/{mode}"
                rows.append((name, s["median"] * 1e6,
                             {"p50_ms": round(s["median"] * 1e3, 2),
                              "p95_ms": round(s["p95"] * 1e3, 2),
                              "p99_ms": round(s["p99"] * 1e3, 2),
                              "remote_gets": s["remote_gets"],
                              "slo_miss": round(s["slo_miss_rate"], 3),
                              "migrations": s["migrations"],
                              "wall_s": round(time.perf_counter() - t0, 3),
                              "n": s["n"]}))
    rows.append(trace_row(per_shard))
    return rows


if __name__ == "__main__":
    emit(run())
