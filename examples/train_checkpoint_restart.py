"""Train a small LM with async checkpointing, kill it, restart, and verify
the loss trace continues bit-identically (fault-tolerance drill).

Run:  PYTHONPATH=src python examples/train_checkpoint_restart.py
      [--steps 60] [--arch granite-3-2b]
"""
import argparse
import sys
import tempfile
from pathlib import Path
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro import configs
from repro.configs.shapes import ShapeConfig
from repro.training import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    shape = ShapeConfig("ex", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    tc = TrainConfig(n_steps=args.steps, ckpt_every=args.steps // 4,
                     ckpt_dir=ckpt_dir, log_every=10)

    print(f"training {cfg.name} for {args.steps} steps, "
          f"checkpoints -> {ckpt_dir}")
    trainer = Trainer(cfg, shape, tc)
    crash_at = args.steps // 2
    try:
        trainer.run(crash_at=crash_at)
    except RuntimeError as e:
        print(f"!! simulated node failure at step {crash_at}: {e}")
    trainer.ckpt.wait()

    print("restarting from the latest checkpoint ...")
    trainer2 = Trainer(cfg, shape, tc)
    print(f"resumed at step {trainer2.step}")
    hist = trainer2.run()
    print(f"finished at step {trainer2.step}; "
          f"final loss {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
