"""Model zoo: per-arch smoke (reduced config, fwd/train/decode on CPU) +
prefill/decode consistency.

Compiling every architecture takes minutes — the whole module is marked
``slow`` so the fast tier-1 CI job (``-m "not slow"``) skips it; the
dedicated slow job runs it.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow

from repro import configs
from repro.models import build_model, count_params


def batch_for(cfg, rng, B=2, S=16):
    batch = {}
    if cfg.frontend == "audio":
        batch["features"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.frontend_dim)), jnp.float32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        if cfg.frontend == "vision":
            batch["patches"] = jnp.asarray(
                rng.normal(size=(B, cfg.n_patches, cfg.frontend_dim)),
                jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_smoke_forward_and_train_step(arch, rng):
    """Reduced same-family config: one forward + loss + grad step, no NaNs."""
    cfg = configs.get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert count_params(params) > 0
    batch = batch_for(cfg, rng, B=2, S=16)
    logits = jax.jit(model.forward_train)(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    def loss_fn(p):
        return model.loss(p, batch)[0]
    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", [a for a in configs.ARCH_NAMES
                                  if a != "hubert-xlarge"])
def test_prefill_then_decode_runs(arch, rng):
    cfg = configs.get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = batch_for(cfg, rng, B=B, S=S)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    # grow cache and decode 3 tokens greedily
    full = model.init_cache(B, 32)

    def merge(z, c):
        sl = tuple(slice(0, d) for d in c.shape)
        return z.at[sl].set(c.astype(z.dtype))
    cache = jax.tree_util.tree_map(merge, full, cache)
    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    lengths = jnp.full((B,), S, jnp.int32)
    for i in range(3):
        logits, cache = step(params, cache, tok, lengths + i)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def _fp32(cfg):
    import dataclasses as dc
    return dc.replace(cfg, param_dtype=jnp.float32,
                      compute_dtype=jnp.float32)


@pytest.mark.parametrize("arch", ["granite-3-2b", "deepseek-v2-236b",
                                  "mamba2-780m"])
def test_decode_matches_forward(arch, rng):
    """Teacher forcing: decode logits at position t == full-forward logits.

    The strongest correctness check for every cache implementation (KV,
    MLA latent, SSD state).  fp32 so any real divergence fails loudly."""
    cfg = _fp32(configs.get_smoke(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 12
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    full_logits = model.forward_train(params, {"tokens": toks})

    cache = model.init_cache(B, S)
    for t in range(S - 1):
        lengths = jnp.full((B,), t, jnp.int32)
        logits, cache = model.decode_step(params, cache, toks[:, t], lengths)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, t], np.float32),
            atol=2e-4, rtol=2e-4)


def test_hybrid_decode_matches_forward(rng):
    """recurrentgemma: ring-buffer window cache + LRU state consistency.

    S must be a multiple of the attention window for the ring layout."""
    cfg = _fp32(configs.get_smoke("recurrentgemma-9b"))  # window 8
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 16
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    full_logits = model.forward_train(params, {"tokens": toks})
    cache = model.init_cache(B, S)
    for t in range(S - 1):
        lengths = jnp.full((B,), t, jnp.int32)
        logits, cache = model.decode_step(params, cache, toks[:, t], lengths)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, t], np.float32),
            atol=2e-4, rtol=2e-4)


def test_vlm_patches_change_output(rng):
    cfg = configs.get_smoke("llava-next-mistral-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = batch_for(cfg, rng, B=1, S=16)
    l1 = model.forward_train(params, batch)
    batch2 = dict(batch, patches=batch["patches"] + 1.0)
    l2 = model.forward_train(params, batch2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-4


def test_param_count_analytic_close():
    """Analytic 6ND param count tracks the real tree within 20%."""
    for arch in ("granite-3-2b", "mamba2-780m"):
        cfg = configs.get_smoke(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        real = sum(int(np.prod(x.shape))
                   for x in jax.tree_util.tree_leaves(shapes))
        est = cfg.param_count()
        assert 0.6 < est / real < 1.6, (arch, est, real)


def test_scan_vs_unrolled_layers_identical(rng):
    import dataclasses as dc
    cfg = _fp32(configs.get_smoke("granite-3-2b"))
    model_s = build_model(cfg)
    model_u = build_model(dc.replace(cfg, scan_layers=False,
                                     unroll_inner=True))
    params = model_s.init(jax.random.PRNGKey(0))
    batch = batch_for(cfg, rng, B=2, S=16)
    ls = model_s.forward_train(params, batch)
    lu = model_u.forward_train(params, batch)
    np.testing.assert_allclose(np.asarray(ls), np.asarray(lu),
                               atol=1e-4, rtol=1e-4)
