"""Paper §7.2 applied: LLM serving with session/KV + adapter affinity.

TTFT + migration volume for affinity vs random vs least-loaded routing on
the continuous-batching engine (real JAX decode on a smoke model; network
costs virtual)."""
from .common import emit


def run(quick=True):
    import jax
    from repro import configs
    from repro.models import build_model
    from repro.runtime.simulation import NetProfile
    from repro.serving import ServingEngine, make_adapter

    cfg = configs.get_smoke("granite-3-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # interconnect scaled so state-migration cost/step matches the real
    # ratio (production KV ~GBs vs ms decode steps)
    net = NetProfile(bandwidth=2e6, rtt=0.05)
    sessions, turns, gen = (8, 3, 4) if quick else (16, 6, 8)

    rows = []
    for policy in ("affinity", "adapter_affinity", "random", "least_loaded"):
        eng = ServingEngine(model, params, n_rows=4, max_slots=8,
                            max_seq=128, policy=policy, net=net)
        eng.adapters.register(make_adapter(
            jax.random.PRNGKey(1), "a1", cfg.d_model, cfg.vocab_size))
        for i in range(sessions):
            eng.open_session(f"s{i}", adapter="a1" if i % 2 else None)
        t = 0.0
        for turn in range(turns):
            for i in range(sessions):
                eng.turn(f"s{i}", [1 + i % 13, 2, 3], gen_tokens=gen, now=t)
                t += 0.002
        s = eng.summary()
        rows.append((f"serving/{policy}", s["ttft_mean"] * 1e6,
                     {"ttft_p95_ms": round(s["ttft_p95"] * 1e3, 2),
                      "migrations": s["migrations"],
                      "migration_bytes": s["migration_bytes"],
                      "adapter_fetch_bytes": s["adapter_fetch_bytes"]}))
    return rows


if __name__ == "__main__":
    emit(run())
