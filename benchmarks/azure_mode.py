"""Paper §5 (Figs. 8-12): the cloud-baseline deployment profile.

Pools live on dedicated storage nodes (Blob/Cosmos stand-ins), compute runs
on endpoint-instance nodes behind a load balancer, and the network is the
AZURE profile (ms RTT + storage latency).  'grouped' reproduces the paper's
manual per-video endpoints + modulo routing (§5.3-5.4), i.e. affinity
grouping hand-rolled at the application layer.

``azure/wf/*`` runs the fig7 WORKFLOW shapes on ``AZURE_NET`` (the
ROADMAP's "Azure profile for workflows"): in the ms-RTT regime every
scattered edge costs ~5 ms before a byte moves, so workflow-atomic
placement's all-local edges dominate by an order of magnitude more margin
than on the RDMA cluster profile — the paper's cloud argument carried
over from the RCP app to the general workflow layer."""
import time

from .common import emit

SCENES = ("little3", "hyang5", "gates3")

# cloud-regime deadlines: the cluster-profile fig7 deadlines plus the
# ms-scale store/RTT budget every stage edge pays on Azure
WF_DEADLINES = {"rag": 0.60, "speech": 0.45}
WF_SHARDS = 4
WF_INSTANCES_PER_SHARD = 30
WF_PER_SHARD_RATE = 12.0


def run_workflow_azure(shape: str, mode: str, quick=True, seed: int = 0):
    from repro.runtime import AZURE_NET
    from repro.workflows import (WORKFLOW_SHAPES, WorkflowRuntime,
                                 mode_kwargs, preload_index)
    graph = WORKFLOW_SHAPES[shape](shards=WF_SHARDS)
    wrt = WorkflowRuntime(graph, seed=seed, net=AZURE_NET,
                          **mode_kwargs(mode))
    if shape == "rag":
        preload_index(wrt)
    n = WF_INSTANCES_PER_SHARD * WF_SHARDS * (1 if quick else 4)
    rate = WF_PER_SHARD_RATE * WF_SHARDS
    for i in range(n):
        wrt.submit(f"req{i}", at=0.05 + i / rate,
                   deadline=WF_DEADLINES[shape])
    wrt.run()
    return wrt.summary()


def _build(grouped, n_mot, n_pred, n_cd, frames, seed=0, net=None):
    from repro.core import CascadeStore, stable_hash
    from repro.pipelines.rcp.app import ACTOR_RE, FRAME_RE, Layout, RCPApp
    from repro.pipelines.rcp.data import make_scene
    from repro.runtime import AZURE_NET, RandomScheduler, Scheduler
    net = net or AZURE_NET

    class GroupHashScheduler(Scheduler):
        """The paper's SA-job modulo routing (actor_id % n_endpoints)."""
        def __init__(self, store):
            self.store = store

        def pick(self, shard, key, nodes, pool_nodes):
            label = self.store.affinity_of(key)
            return pool_nodes[stable_hash(label) % len(pool_nodes)]

        def name(self):
            return "group_hash"

    app = RCPApp([make_scene(s, frames) for s in SCENES],
                 Layout(n_mot, n_pred, n_cd), grouped=True,  # regexes on
                 net=net, seed=seed)
    # storage-separated: re-home every pool onto two storage nodes so all
    # gets are network hops (Blob/Cosmos), as in the Azure deployment
    store = app.store
    for n in ("blob0", "cosmos0"):
        store.nodes.append(n)
        store.caches[n] = {}
        from repro.runtime.simulation import Node
        app.rt.nodes[n] = Node(n, {"gpu": 0, "cpu": 4, "nic": 8})
    for pool in store.pools.values():
        for shard in pool.shards.values():
            shard.nodes = ["blob0" if "frame" in pool.prefix
                           or "state" in pool.prefix else "cosmos0"]
    app.rt.scheduler = (GroupHashScheduler(store) if grouped
                        else RandomScheduler(seed))
    return app


def run(quick=True):
    from repro.runtime.simulation import NetProfile
    frames = 120 if quick else 700
    # paper §5 regime: Cosmos/Blob per-op latencies (~8 ms) make ungrouped
    # PRED/CD fetch overhead exceed the 2.5 FPS budget -> queues explode,
    # while grouped endpoints stay cache-local (Figs 10-12).
    net = NetProfile(bandwidth=1.25e9, rtt=1e-3, store_latency=8e-3)
    rows = []
    for grouped in (False, True):
        app = _build(grouped, 3, 7, 7, frames, net=net)
        app.stream()
        app.run()
        s = app.summary(warmup=min(100, frames // 3))
        name = f"azure/{'grouped' if grouped else 'lb'}/3/7/7"
        rows.append((name, s["median"] * 1e6,
                     {"p95_ms": round(s["p95"] * 1e3, 1),
                      "remote_gets": s["remote_gets"],
                      "bytes_remote_MB": round(s["bytes_remote"] / 1e6, 1)}))
    # fig7 workflow shapes in the ms-RTT regime (see module docstring)
    for shape in ("rag", "speech"):
        p99 = {}
        for mode in ("keyhash", "atomic"):
            s = run_workflow_azure(shape, mode, quick=quick)
            p99[mode] = s["p99"]
            rows.append((f"azure/wf/{shape}/{mode}", s["median"] * 1e6,
                         {"p99_ms": round(s["p99"] * 1e3, 1),
                          "remote_gets": s["remote_gets"],
                          "slo_miss": round(s.get("slo_miss_rate", 0.0), 3),
                          "n": s["n"]}))
        assert p99["atomic"] <= p99["keyhash"], (shape, p99)
    return rows


if __name__ == "__main__":
    emit(run())
