from .optimizer import AdamWConfig, adamw_update, init_opt_state, lr_at
from .data import DataConfig, TokenPipeline
from . import checkpointing
from .train_loop import TrainConfig, Trainer
from . import compression

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "lr_at",
           "DataConfig", "TokenPipeline", "checkpointing", "TrainConfig",
           "Trainer", "compression"]
