"""Paper Fig. 5: caching disabled, 3 clients, 3/5/5 — random collapses,
affinity is unaffected (every get is shard-local)."""
from .common import emit, run_rcp

SCENES = ("little3", "hyang5", "gates3")


def run(quick=True):
    frames = 150 if quick else 700
    rows = []
    for grouped in (True, False):
        for caching in (True, False):
            s = run_rcp(grouped, (3, 5, 5), SCENES, frames, caching=caching)
            name = f"fig5/{'affinity' if grouped else 'random'}/" \
                   f"{'cache' if caching else 'nocache'}"
            rows.append((name, s["median"] * 1e6,
                         {"p95_ms": round(s["p95"] * 1e3, 1),
                          "remote_gets": s["remote_gets"],
                          "bytes_remote_MB":
                              round(s["bytes_remote"] / 1e6, 1)}))
    return rows


if __name__ == "__main__":
    emit(run())
