"""Quickstart: the affinity grouping mechanism in ~40 lines (paper §3).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import CascadeStore, ServiceClientAPI

# A 8-node cluster hosting a sharded K/V store (Cascade-like).
store = CascadeStore([f"node{i}" for i in range(8)])
capi = ServiceClientAPI(store)

# Paper Listing 1: pools with and without affinity grouping.
capi.create_object_pool("/no_grouping")
capi.create_object_pool("/grouping", affinity_set_regex="_[0-9]+")

capi.put("/no_grouping/example_1")
capi.put("/grouping/example_1")          # affinity key '_1'
print("affinity key of /grouping/example_1 :",
      capi.get_affinity_key("/grouping/example_1"))

# The paper's Table-1 pattern: all positions of actor 7 in video little3
# share the key '/little3_7_' and therefore one shard — while different
# actors spread across shards (load balance via hash-of-affinity-key).
capi.create_object_pool("/positions",
                        affinity_set_regex=r"/[a-zA-Z0-9]+_[0-9]+_")
for frame in range(20):
    capi.put(f"/positions/little3_7_{frame}", value=b"xy", size=64)

shards = {store.shard_of(f"/positions/little3_7_{f}").name
          for f in range(20)}
print("actor 7's 20 positions live in shards:", shards)

spread = {store.shard_of(f"/positions/little3_{a}_0").name
          for a in range(32)}
print(f"32 different actors spread over {len(spread)} shards")

# Unified placement: a *task* triggered with the same affinity key routes
# to the same shard that holds the data (compute follows data).
shard, _ = store.trigger("/positions/little3_7_99")
print("PRED task for actor 7 runs on shard:", shard.name,
      "nodes:", shard.nodes)
print("data home of actor 7:",
      store.shard_of("/positions/little3_7_0").name)

# --- dynamic placement (docs/affinity_api.md) ------------------------------
# Load-aware: whole groups bind to the least-loaded shard at creation.
from repro.core import GroupMigrator, LoadAwarePlacement

store2 = CascadeStore([f"srv{i}" for i in range(4)])
store2.create_object_pool("/tracks", store2.nodes, 4,
                          affinity_set_regex=r"/[a-zA-Z0-9]+_[0-9]+_",
                          policy=LoadAwarePlacement())
for a in range(8):
    for f in range((a + 1) * 4):          # skewed group sizes
        store2.put(f"/tracks/vid_{a}_{f}", b"x" * 100)
resident = {n: sum(r.size for r in s.objects.values())
            for n, s in store2.pools["/tracks"].shards.items()}
print("load-aware bytes per shard:", sorted(resident.values()))

# Migration: relocate a hot group — every member moves, caches invalidate,
# and future puts/tasks follow the pin.
home = store2.shard_of("/tracks/vid_7_0").name
target = next(n for n in store2.pools["/tracks"].shards if n != home)
move = GroupMigrator(store2).migrate("/tracks", "/vid_7_", to_shard=target)
print(f"migrated group /vid_7_: {move.n_objects} objects, "
      f"{move.bytes_moved}B  {home} -> {move.dst_shard}")
assert store2.shard_of("/tracks/vid_7_99").name == target
