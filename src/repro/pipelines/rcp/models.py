"""The three RCP stages as real JAX models (paper §4.1 equivalents).

  MOT  — small conv feature extractor + greedy nearest-neighbour matcher
         (stands in for YOLOv5 + StrongSORT/OSNet re-identification);
  PRED — MLP trajectory head over the last p=8 positions predicting q=12
         future waypoints (stands in for YNet);
  CD   — exact all-pairs segment-intersection collision test (the paper's
         own CD algorithm, which IS a linear interpolation crossing check).

These run on CPU for correctness tests and to calibrate DES service times;
the cluster benchmarks use paper-scale service-time profiles.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .data import P_HIST, Q_PRED


# ---------------------------------------------------------------------------
# MOT
# ---------------------------------------------------------------------------

def init_mot(rng: jax.Array, res: int = 64, feat: int = 32) -> Dict:
    k1, k2 = jax.random.split(rng)
    return {
        "conv1": jax.random.normal(k1, (3, 3, 3, 16)) * 0.1,
        "conv2": jax.random.normal(k2, (3, 3, 16, feat)) * 0.1,
    }


@functools.partial(jax.jit, static_argnames=("max_actors",))
def mot_detect(params: Dict, frame: jax.Array, prev_pos: jax.Array,
               prev_valid: jax.Array, det_pos: jax.Array,
               det_valid: jax.Array, max_actors: int = 64):
    """Detect + re-identify.

    frame: (R,R,3); prev_pos/det_pos: (A,2); *_valid: (A,) bool.
    Returns (matched_ids (A,) int32, features (A,F)) — detection i keeps the
    id of the nearest previous actor within radius, else a fresh id.
    """
    h = jax.nn.relu(jax.lax.conv_general_dilated(
        frame[None], params["conv1"], (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")))
    h = jax.nn.relu(jax.lax.conv_general_dilated(
        h, params["conv2"], (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")))
    feat_map = h[0]                                    # (R/4,R/4,F)
    R4 = feat_map.shape[0]
    idx = jnp.clip((det_pos * (R4 - 1)).astype(jnp.int32), 0, R4 - 1)
    feats = feat_map[idx[:, 1], idx[:, 0]]             # (A,F)

    d2 = jnp.sum((det_pos[:, None] - prev_pos[None]) ** 2, -1)
    d2 = jnp.where(prev_valid[None] & det_valid[:, None], d2, 1e9)
    nearest = jnp.argmin(d2, axis=1)
    dist = jnp.take_along_axis(d2, nearest[:, None], 1)[:, 0]
    matched = (dist < 0.01) & det_valid
    ids = jnp.where(matched, nearest, jnp.arange(max_actors) + max_actors)
    return ids.astype(jnp.int32), feats


# ---------------------------------------------------------------------------
# PRED (YNet stand-in)
# ---------------------------------------------------------------------------

def init_pred(rng: jax.Array, hidden: int = 128) -> Dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    din, dout = P_HIST * 2, Q_PRED * 2
    return {
        "w1": jax.random.normal(k1, (din, hidden)) * (din ** -0.5),
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, hidden)) * (hidden ** -0.5),
        "b2": jnp.zeros((hidden,)),
        "w3": jax.random.normal(k3, (hidden, dout)) * (hidden ** -0.5),
        "b3": jnp.zeros((dout,)),
    }


@jax.jit
def pred_trajectory(params: Dict, history: jax.Array) -> jax.Array:
    """history: (P_HIST, 2) -> (Q_PRED, 2).

    Predicts displacement deltas from the last observed position — a
    residual parameterization like trajectory-forecasting heads use.
    """
    x = history.reshape(-1)
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    h = jnp.tanh(h @ params["w2"] + params["b2"])
    d = (h @ params["w3"] + params["b3"]).reshape(Q_PRED, 2)
    return history[-1][None] + jnp.cumsum(d * 0.01, axis=0)


# ---------------------------------------------------------------------------
# CD — exact segment-intersection over predicted trajectories
# ---------------------------------------------------------------------------

@jax.jit
def cd_collisions(traj_a: jax.Array, trajs: jax.Array,
                  valid: jax.Array) -> jax.Array:
    """traj_a: (Q,2); trajs: (A,Q,2); valid: (A,).

    Returns (A,) bool — does any segment of traj_a properly intersect any
    time-aligned segment window of each other trajectory (paper: linear
    interpolation + crossing test).
    """
    a0, a1 = traj_a[:-1], traj_a[1:]                   # (Q-1,2)
    b0, b1 = trajs[:, :-1], trajs[:, 1:]               # (A,Q-1,2)

    def cross(o, p, q):
        return ((p[..., 0] - o[..., 0]) * (q[..., 1] - o[..., 1])
                - (p[..., 1] - o[..., 1]) * (q[..., 0] - o[..., 0]))

    # segment i of a vs segment i of each b (time-aligned collision)
    d1 = cross(a0[None], a1[None], b0)
    d2 = cross(a0[None], a1[None], b1)
    d3 = cross(b0, b1, a0[None])
    d4 = cross(b0, b1, a1[None])
    inter = (d1 * d2 < 0) & (d3 * d4 < 0)              # (A,Q-1)
    near = jnp.sum((b0 - a0[None]) ** 2, -1) < (0.02 ** 2)
    return (jnp.any(inter | near, axis=1)) & valid


# ---------------------------------------------------------------------------
# Calibration: measure real service times for the DES
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StageProfile:
    """Service times (seconds) used by the simulator.

    Defaults approximate the paper's T4-scale workloads: MOT inference
    ~180 ms/frame, PRED ~18 ms/actor, CD ~5 ms/trajectory.  PRED is
    calibrated so the paper's 3-client x 3/5/5 deployment runs below
    saturation (as it evidently did in §4.6) — above saturation, STATIC
    hash pinning develops hot shards and dynamic LB catches up, a
    trade-off the paper acknowledges by calling affinity complementary to
    scheduling (documented in EXPERIMENTS.md §1).
    """
    mot: float = 0.180
    pred: float = 0.018
    cd: float = 0.005


def calibrate(res: int = 64, iters: int = 5) -> StageProfile:
    """Measure the real JAX stand-ins on this host (relative scale only)."""
    rng = jax.random.PRNGKey(0)
    pm, pp = init_mot(rng, res), init_pred(rng)
    frame = jnp.zeros((res, res, 3))
    pos = jnp.zeros((64, 2))
    val = jnp.ones((64,), bool)
    hist = jnp.zeros((P_HIST, 2))
    trajs = jnp.zeros((64, Q_PRED, 2))

    def timeit(fn):
        fn()                                            # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn())
        return (time.perf_counter() - t0) / iters

    t_mot = timeit(lambda: mot_detect(pm, frame, pos, val, pos, val))
    t_pred = timeit(lambda: pred_trajectory(pp, hist))
    t_cd = timeit(lambda: cd_collisions(trajs[0], trajs, val))
    return StageProfile(mot=t_mot, pred=t_pred, cd=t_cd)
