"""Explain a benchmark delta: which blame category moved the latency.

Diffs two BENCH rows that carry blame decompositions (the ``blame_*_ms``
keys traced benchmark runs embed — per-instance mean milliseconds per
exclusive category, see ``repro.workflows.blame``) and names the
category that moved.  Two modes:

  * two record files — every row name present in both is diffed
    (``python scripts/bench_explain.py old/BENCH_fig9.json \
    benchmarks/artifacts/BENCH_fig9.json``): the cross-PR question
    "my p99 regressed; what kind of time did it gain?";
  * one record file and two row names (``--row A --row2 B``): the
    within-run question "config B beats config A; where does the
    residual live?" — e.g. the committed fig9 full-scale rag-8x
    adaptive-vs-static table (``BLAME_fig9_rag8x.md``):

      python scripts/bench_explain.py \
          benchmarks/artifacts/BENCH_fig9.json \
          --row  fig9/fullscale/rag/8x/static16ms \
          --row2 fig9/fullscale/rag/8x/adaptive \
          -o benchmarks/artifacts/BLAME_fig9_rag8x.md

Output is a markdown blame table (stdout, and ``-o`` to write a file):
one line per category with both sides' per-instance milliseconds and the
delta, the dominant mover called out, and the e2e/p99 movement it
explains.  Exits non-zero if neither side carries blame keys — an
untraced record cannot be explained, only re-measured.
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.runtime.tracing import CATEGORIES  # noqa: E402


def load_rows(path: str):
    payload = json.loads(Path(path).read_text())
    return {r["name"]: r for r in payload.get("rows", ())}


def blame_of(row):
    out = {}
    for cat in CATEGORIES:
        v = row.get(f"blame_{cat}_ms")
        if isinstance(v, (int, float)):
            out[cat] = float(v)
    return out


def explain(row_a, row_b, label_a, label_b):
    """Markdown lines diffing ``row_b`` against ``row_a``."""
    ba, bb = blame_of(row_a), blame_of(row_b)
    if not ba or not bb:
        missing = label_a if not ba else label_b
        raise SystemExit(f"no blame_*_ms keys in {missing!r} — "
                         f"re-run the suite with tracing enabled")
    lines = [f"### {label_b} vs {label_a}", ""]
    p99a, p99b = row_a.get("p99_ms"), row_b.get("p99_ms")
    if isinstance(p99a, (int, float)) and isinstance(p99b, (int, float)):
        lines.append(f"p99: {p99a} ms -> {p99b} ms "
                     f"({p99b - p99a:+.2f} ms)")
        lines.append("")
    lines.append(f"| category | {label_a} (ms/inst) | "
                 f"{label_b} (ms/inst) | delta (ms) |")
    lines.append("|---|---|---|---|")
    deltas = {}
    for cat in CATEGORIES:
        a, b = ba.get(cat, 0.0), bb.get(cat, 0.0)
        deltas[cat] = b - a
        lines.append(f"| {cat} | {a:.3f} | {b:.3f} | {b - a:+.3f} |")
    tot_a, tot_b = sum(ba.values()), sum(bb.values())
    lines.append(f"| **total (= mean e2e)** | {tot_a:.3f} | {tot_b:.3f} "
                 f"| {tot_b - tot_a:+.3f} |")
    mover = max(deltas, key=lambda c: abs(deltas[c]))
    lines.append("")
    lines.append(f"**Dominant mover: `{mover}` "
                 f"({deltas[mover]:+.3f} ms/instance)** — "
                 f"{abs(deltas[mover]) / max(abs(tot_b - tot_a), 1e-12):.0%}"
                 f" of the {tot_b - tot_a:+.3f} ms mean-latency move.")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser(
        description="name the blame category behind a benchmark delta")
    ap.add_argument("record_a", help="BENCH_*.json (baseline)")
    ap.add_argument("record_b", nargs="?", default=None,
                    help="second BENCH_*.json; omit to compare two rows "
                         "of record_a (--row/--row2)")
    ap.add_argument("--row", default=None,
                    help="row name on the baseline side")
    ap.add_argument("--row2", default=None,
                    help="row name on the comparison side")
    ap.add_argument("-o", "--out", default=None,
                    help="also write the markdown to this path")
    args = ap.parse_args()

    rows_a = load_rows(args.record_a)
    lines = []
    if args.record_b is not None:
        rows_b = load_rows(args.record_b)
        names = [n for n in rows_b if n in rows_a]
        if args.row:
            names = [n for n in names if n == args.row]
        explained = 0
        for n in names:
            if not (blame_of(rows_a[n]) and blame_of(rows_b[n])):
                continue
            lines.extend(explain(rows_a[n], rows_b[n],
                                 f"{n} (old)", f"{n} (new)"))
            lines.append("")
            explained += 1
        if not explained:
            raise SystemExit("no shared rows carry blame_*_ms keys")
    else:
        if not (args.row and args.row2):
            ap.error("single-record mode needs --row and --row2")
        for r in (args.row, args.row2):
            if r not in rows_a:
                raise SystemExit(f"row {r!r} not in {args.record_a}; "
                                 f"rows: {sorted(rows_a)[:8]}...")
        lines.extend(explain(rows_a[args.row], rows_a[args.row2],
                             args.row, args.row2))
    text = "\n".join(lines) + "\n"
    print(text, end="")
    if args.out:
        Path(args.out).write_text(text)
        print(f"# wrote {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
