"""Synthetic Stanford-Drone-like trajectory dataset.

Scenes named after the paper's videos (little3 / hyang5 / gates3) with
deterministic per-name actor trajectories: actors enter/leave, move with
smoothed random-waypoint dynamics inside a unit intersection.  Object sizes
follow the paper: ~8 MB uncompressed frames, state objects scaling with
actor count (up to ~10 MB), 10s-of-bytes positions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

FRAME_BYTES = 8 * 1024 * 1024          # paper §4.1
STATE_BYTES_PER_ACTOR = 200 * 1024     # features+positions; 49 actors ~ 10MB
POSITION_BYTES = 64                    # "10s of bytes"
PREDICTION_BYTES = 640                 # q=12 waypoints + metadata
P_HIST = 8                             # PRED needs p=8 past positions
Q_PRED = 12                            # predicts q=12 future positions


@dataclasses.dataclass
class Scene:
    name: str
    n_frames: int
    max_actors: int
    fps: float = 2.5                   # paper: clients stream at 2.5 FPS
    seed: int = 0

    def __post_init__(self):
        # stable_hash, not hash(): python's randomized string hashing made
        # scene content (and every figure derived from it) vary per
        # interpreter launch unless PYTHONHASHSEED was pinned
        from repro.core.placement import stable_hash
        rng = np.random.default_rng(
            stable_hash(f"{self.name}::{self.seed}") % (2 ** 31))
        A, F = self.max_actors, self.n_frames
        # actor lifetimes
        enter = rng.integers(0, max(F - 20, 1), A)
        leave = np.minimum(enter + rng.integers(30, F, A), F)
        # smoothed random-walk trajectories in [0,1]^2
        pos = np.zeros((A, F, 2), np.float32)
        vel = rng.normal(0, 0.004, (A, 2)).astype(np.float32)
        pos[:, 0] = rng.uniform(0.1, 0.9, (A, 2))
        for f in range(1, F):
            vel = 0.95 * vel + rng.normal(0, 0.002, (A, 2))
            pos[:, f] = np.clip(pos[:, f - 1] + vel, 0.0, 1.0)
        self.enter, self.leave, self.pos = enter, leave, pos

    def actors_in_frame(self, f: int) -> List[int]:
        return [a for a in range(self.max_actors)
                if self.enter[a] <= f < self.leave[a]]

    def position(self, actor: int, f: int) -> np.ndarray:
        return self.pos[actor, f]

    def history(self, actor: int, f: int) -> np.ndarray:
        """Last P_HIST positions ending at frame f (may be shorter)."""
        start = max(self.enter[actor], f - P_HIST + 1)
        return self.pos[actor, start:f + 1]

    def frame_tensor(self, f: int, res: int = 64) -> np.ndarray:
        """A small dense 'image' of the scene for the real-JAX MOT model."""
        img = np.zeros((res, res, 3), np.float32)
        for a in self.actors_in_frame(f):
            x, y = (self.pos[a, f] * (res - 1)).astype(int)
            img[y, x, a % 3] = 1.0
        return img

    def state_bytes(self, f: int) -> int:
        return max(len(self.actors_in_frame(f)), 1) * STATE_BYTES_PER_ACTOR


PAPER_SCENES = {
    "little3": dict(max_actors=14, seed=3),
    "hyang5": dict(max_actors=22, seed=5),
    "gates3": dict(max_actors=49, seed=8),   # paper: up to 49 actors
}


def make_scene(name: str, n_frames: int = 700) -> Scene:
    kw = PAPER_SCENES.get(name, dict(max_actors=20, seed=1))
    return Scene(name=name, n_frames=n_frames, **kw)
