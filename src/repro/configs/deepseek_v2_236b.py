"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]

Deviation from the HF config: every layer is MoE (the real model's first
layer is a dense MLP) so the layer stack stays homogeneous for
scan-over-layers; parameter/flop impact is <0.2%.
"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,
    vocab_size=102400,
    mlp_variant="swiglu",
    n_experts=160,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1536,
    moe_chunk=4096,
    mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    rope_theta=10000.0,
    opt_state_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab_size=256,
    mlp_variant="swiglu",
    n_experts=8,
    n_shared_experts=2,
    moe_top_k=2,
    moe_d_ff=64,
    moe_chunk=64,
    mla=True,
    kv_lora_rank=32,
    q_lora_rank=48,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
)
