"""Declarative workflow graphs (paper §2, §4.5: "a pipeline or graph of AI
programs triggered by events").

A :class:`WorkflowGraph` names the pieces an event-driven inference
application is made of, without wiring any of them by hand:

  * **tiers** — named groups of homogeneous nodes (``mot0..motN``) with a
    resource vector, the deployment units stages run on;
  * **pools** — pathname-prefixed object pools bound to a tier, each with a
    shard count/replication and an affinity mode (``INSTANCE`` groups every
    key of one workflow instance, a regex reproduces the paper's Table 1
    behavior, ``None`` leaves the pool ungrouped);
  * **stages** — event-triggered units of work.  A stage is fired by puts
    into its trigger pool; it either supplies a custom generator ``body``
    (arbitrary logic, like the RCP stages) or is synthesized from its
    declarative ``reads``/``cost``/``emits``.  ``join=True`` makes the
    stage a fan-in barrier: its body runs once per instance, after every
    expected upstream event has arrived.

Edges are implicit: stage A ``emits`` into pool P, stage B is triggered by
P.  :meth:`WorkflowGraph.validate` checks the induced stage graph is a DAG,
computes each stage's expected per-instance arrival count (fan-out
bookkeeping the RCP app previously hand-rolled in ``FrameTracker``), and
identifies sources and sinks.  The graph itself is timeless and
placement-agnostic — ``repro.workflows.runtime.WorkflowRuntime`` compiles
it onto the store/simulator and owns every placement decision.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.runtime.simulation import UNIFORM, HardwareProfile

# Affinity mode sentinel: group every key of a workflow instance together
# (see repro.core.affinity.InstanceAffinity).
INSTANCE = "instance"


@dataclasses.dataclass
class Tier:
    """A named group of same-hardware nodes (``<name>0 .. <name>{n-1}``).

    ``profile`` is the tier's :class:`repro.runtime.HardwareProfile` —
    per-resource service rates and batch economics; different tiers of one
    graph model a heterogeneous cluster (GPU generations, CPU pools).
    ``spares`` declares extra standby nodes (named after the active ones)
    that exist in the cluster but start outside every pool — the
    autoscaler's scale-out inventory.

    ``domains`` > 1 stripes the tier's nodes (spares included) over that
    many failure domains round-robin: node ``i`` lands in zone
    ``<name>-d{i % domains}``.  Placement replication spreads over the
    labels (anti-affinity) and ``FaultInjector.fail_domain`` kills whole
    zones; the default 1 keeps the tier topology-blind (no labels, no
    behavior change anywhere).
    """
    name: str
    n_nodes: int
    resources: Dict[str, int]
    profile: HardwareProfile = UNIFORM
    spares: int = 0
    domains: int = 1

    @property
    def nodes(self) -> List[str]:
        return [f"{self.name}{i}" for i in range(self.n_nodes)]

    @property
    def spare_nodes(self) -> List[str]:
        return [f"{self.name}{i}"
                for i in range(self.n_nodes, self.n_nodes + self.spares)]

    def domain_of(self, node: str) -> str:
        """Failure-domain label of one of this tier's nodes ("" when the
        tier is not striped)."""
        if self.domains <= 1:
            return ""
        i = int(node[len(self.name):])
        return f"{self.name}-d{i % self.domains}"


@dataclasses.dataclass
class Pool:
    """An object pool declaration (compiled to ``create_object_pool``).

    ``tier`` may name ONE tier or a tuple of tiers: a multi-tier pool's
    shard slots span the listed tiers in order, which is how a stage
    declares its set of acceptable backends — the stage runs wherever its
    trigger pool's slots live, so listing ``("h100", "cpu")`` means "this
    work may land on either hardware" and tier-aware placement/dispatch
    picks among them by normalized load.
    """
    prefix: str
    tier: Union[str, Tuple[str, ...]]
    shards: int
    replication: int = 1
    affinity: Optional[str] = INSTANCE   # INSTANCE | regex string | None
    migratable: bool = False             # opt into Runtime.enable_migration

    @property
    def tiers(self) -> Tuple[str, ...]:
        return (self.tier,) if isinstance(self.tier, str) else \
            tuple(self.tier)


@dataclasses.dataclass
class Read:
    """An extra per-firing read (e.g. a shared retrieval index).

    ``keys(instance)`` returns the full keys to fetch; misses are treated
    as optional unless ``required``.
    """
    pool: str
    keys: Callable[[str], Sequence[str]]
    required: bool = False
    wait: bool = False


@dataclasses.dataclass
class Emit:
    """A write edge: each firing puts ``fanout`` objects into ``pool``."""
    pool: str
    fanout: int = 1
    size: int = 0


@dataclasses.dataclass
class Stage:
    """An event-triggered stage.

    Synthesized stages (``body=None``) read their join inputs + declared
    ``reads``, spend ``cost`` seconds on ``resource``, then ``emit``.
    Custom-body stages run the supplied generator verbatim (yielding the
    runtime's Get/Put/Compute ops) — the graph still records their
    trigger pool, resource and ordering so compilation stays uniform.

    ``degraded_cost`` declares a cheaper brownout variant of a
    synthesized stage (a smaller model, coarser retrieval, sampled
    frames): when the runtime's brownout controller is engaged the stage
    fires with this cost instead of ``cost``, preserving every event,
    emit, and accounting invariant — degradation changes quality, never
    topology.  ``priority`` orders the sacrifice: class 0 degrades first,
    higher classes only under deeper capacity loss.
    """
    name: str
    pool: str                             # trigger pool prefix
    resource: str = "gpu"
    cost: float = 0.0
    degraded_cost: Optional[float] = None
    priority: int = 0
    reads: List[Read] = dataclasses.field(default_factory=list)
    emits: List[Emit] = dataclasses.field(default_factory=list)
    join: bool = False                    # fan-in barrier (fire once/instance)
    sink: bool = False                    # completing this completes the inst
    body: Optional[Callable[..., Any]] = None
    order_of: Optional[Callable[[str], str]] = None
    batchable: bool = True                # StageBatcher may coalesce firings

    # filled in by WorkflowGraph.validate()
    expected_arrivals: int = 1            # events/instance into this stage
    firings: int = 1                      # body executions/instance


class WorkflowGraphError(ValueError):
    pass


class WorkflowGraph:
    """Declarative container + validator for tiers/pools/stages."""

    def __init__(self, name: str, instance_tracking: bool = True):
        self.name = name
        # False: the application does its own accounting (the RCP port
        # keeps its FrameTracker and dynamic per-frame fan-out)
        self.instance_tracking = instance_tracking
        self.tiers: Dict[str, Tier] = {}
        self.pools: List[Pool] = []
        self.stages: List[Stage] = []
        self._validated = False

    # -- declaration -------------------------------------------------------

    def add_tier(self, name: str, n_nodes: int,
                 resources: Dict[str, int],
                 profile: HardwareProfile = UNIFORM,
                 spares: int = 0, domains: int = 1) -> Tier:
        if name in self.tiers:
            raise WorkflowGraphError(f"duplicate tier {name!r}")
        if domains < 1:
            raise WorkflowGraphError(
                f"tier {name!r}: domains must be >= 1, got {domains}")
        tier = Tier(name, n_nodes, dict(resources), profile=profile,
                    spares=spares, domains=domains)
        self.tiers[name] = tier
        return tier

    def add_pool(self, prefix: str,
                 tier: Union[str, Sequence[str]], shards: int,
                 replication: int = 1, affinity: Optional[str] = INSTANCE,
                 migratable: bool = False) -> Pool:
        tier = tier if isinstance(tier, str) else tuple(tier)
        pool = Pool(prefix, tier, shards, replication, affinity, migratable)
        for t in pool.tiers:
            if t not in self.tiers:
                raise WorkflowGraphError(
                    f"pool {prefix!r}: unknown tier {t!r}")
        if any(p.prefix == prefix for p in self.pools):
            raise WorkflowGraphError(f"duplicate pool {prefix!r}")
        n_nodes = len(self.nodes_of(pool))
        if n_nodes < shards * replication:
            raise WorkflowGraphError(
                f"pool {prefix!r}: tier(s) {pool.tiers} have {n_nodes} "
                f"nodes < {shards} shards x {replication} replication")
        self.pools.append(pool)
        self._validated = False
        return pool

    def nodes_of(self, pool: Pool) -> List[str]:
        """Active nodes backing ``pool``, in tier declaration order (slot
        ``i`` of every same-tier-tuple pool maps to the same node set)."""
        return [n for t in pool.tiers for n in self.tiers[t].nodes]

    def add_stage(self, name: str, pool: str, resource: str = "gpu",
                  cost: float = 0.0, reads: Sequence[Read] = (),
                  emits: Sequence[Emit] = (), join: bool = False,
                  sink: bool = False, body: Optional[Callable] = None,
                  order_of: Optional[Callable[[str], str]] = None,
                  batchable: bool = True,
                  degraded_cost: Optional[float] = None,
                  priority: int = 0) -> Stage:
        if any(s.name == name for s in self.stages):
            raise WorkflowGraphError(f"duplicate stage {name!r}")
        if degraded_cost is not None and (
                body is not None or degraded_cost > cost):
            raise WorkflowGraphError(
                f"stage {name!r}: degraded_cost needs a synthesized body "
                f"and must not exceed cost")
        stage = Stage(name=name, pool=pool, resource=resource, cost=cost,
                      degraded_cost=degraded_cost, priority=priority,
                      reads=list(reads), emits=list(emits), join=join,
                      sink=sink, body=body, order_of=order_of,
                      batchable=batchable)
        self.stages.append(stage)
        self._validated = False
        return stage

    def domain_of(self, node: str) -> str:
        """Failure-domain label of ``node`` over every tier ("" when its
        tier is unstriped)."""
        best = None
        for t in self.tiers.values():
            if node.startswith(t.name) and node[len(t.name):].isdigit():
                if best is None or len(t.name) > len(best.name):
                    best = t            # longest tier-name prefix wins
        return best.domain_of(node) if best is not None else ""

    # -- derived structure --------------------------------------------------

    def pool_of(self, prefix: str) -> Pool:
        for p in self.pools:
            if p.prefix == prefix:
                return p
        raise WorkflowGraphError(f"unknown pool {prefix!r}")

    def stages_on(self, pool: str) -> List[Stage]:
        return [s for s in self.stages if s.pool == pool]

    @property
    def source_stages(self) -> List[Stage]:
        """Stages triggered only by external (client) events."""
        emitted = {e.pool for s in self.stages for e in s.emits}
        return [s for s in self.stages if s.pool not in emitted]

    @property
    def sink_stages(self) -> List[Stage]:
        marked = [s for s in self.stages if s.sink]
        if marked:
            return marked
        triggers = {s.pool for s in self.stages}
        return [s for s in self.stages
                if not any(e.pool in triggers for e in s.emits)]

    @property
    def source_pool(self) -> str:
        """The pool external events are submitted to."""
        src = self.source_stages
        if len(src) != 1:
            raise WorkflowGraphError(
                f"workflow {self.name!r} needs exactly one source stage, "
                f"has {[s.name for s in src]}")
        return src[0].pool

    def validate(self) -> "WorkflowGraph":
        """Check the stage DAG and fill in fan-in/fan-out accounting."""
        pool_names = {p.prefix for p in self.pools}
        for s in self.stages:
            if s.pool not in pool_names:
                raise WorkflowGraphError(
                    f"stage {s.name!r}: unknown trigger pool {s.pool!r}")
            for e in s.emits:
                if e.pool not in pool_names:
                    raise WorkflowGraphError(
                        f"stage {s.name!r}: emits into unknown pool "
                        f"{e.pool!r}")
                if e.fanout < 1:
                    raise WorkflowGraphError(
                        f"stage {s.name!r}: fanout {e.fanout} < 1")
            if s.body is not None and (s.reads or s.emits) and \
                    self.instance_tracking:
                raise WorkflowGraphError(
                    f"stage {s.name!r}: custom body and declarative "
                    f"reads/emits are mutually exclusive under tracking")
        if not self.stages:
            raise WorkflowGraphError(f"workflow {self.name!r} has no stages")

        # topological order over the stage graph (emit -> trigger edges)
        downstream = {s.name: sorted({d.name for e in s.emits
                                      for d in self.stages_on(e.pool)})
                      for s in self.stages}
        indeg = {s.name: 0 for s in self.stages}
        for outs in downstream.values():
            for d in outs:
                indeg[d] += 1
        order = [n for n, d in indeg.items() if d == 0]
        topo: List[str] = []
        while order:
            n = order.pop(0)
            topo.append(n)
            for d in downstream[n]:
                indeg[d] -= 1
                if indeg[d] == 0:
                    order.append(d)
        if len(topo) != len(self.stages):
            cyc = sorted(set(s.name for s in self.stages) - set(topo))
            raise WorkflowGraphError(
                f"workflow {self.name!r} has a trigger cycle through {cyc}")
        src = self.source_stages
        if self.instance_tracking and len(src) != 1:
            raise WorkflowGraphError(
                f"workflow {self.name!r} needs exactly one source stage, "
                f"has {[s.name for s in src]}")

        # per-instance fan-in/fan-out accounting
        by_name = {s.name: s for s in self.stages}
        src_names = {s.name for s in src}
        for s in self.stages:
            s.expected_arrivals = 1 if s.name in src_names else 0
        for name in topo:
            s = by_name[name]
            s.firings = (1 if (s.join or s.name in src_names)
                         else s.expected_arrivals)
            for e in s.emits:
                for d in self.stages_on(e.pool):
                    d.expected_arrivals += s.firings * e.fanout
        for s in self.stages:
            if s.expected_arrivals < 1:
                raise WorkflowGraphError(
                    f"stage {s.name!r} is unreachable (no events arrive)")
        if self.instance_tracking and not self.sink_stages:
            raise WorkflowGraphError(
                f"workflow {self.name!r} has no sink stage")
        self._validated = True
        return self
