"""Fig. 9 (ours): the adaptive batch planner vs the fig8 static windows.

Three claims, one sweep:

  1. **No per-rate tuning.** ``atomic+abatch`` (one
     ``AdaptiveBatchPolicy`` instance, no window knob) is run at every
     arrival rate of the fig8 sweep next to BOTH static windows of each
     shape; the recorded acceptance flag is adaptive p99 <= the best
     static window at every (shape, rate) — the planner absorbs exactly
     the tuning burden fig8 exposed.  ``run()`` raises if the flag fails
     (the DES is deterministic, so this is a regression gate, not a
     flake).

  2. **Sketch accuracy.** The tracker now feeds the planner from bounded
     ``repro.runtime.StageStats`` sketches instead of per-sample lists;
     a seeded 50k-sample stream per distribution family records the
     worst-case relative error of the sketch p50/p95/p99 vs exact
     ``np.percentile`` (must stay inside 5%; the log-binned estimator
     guarantees ~2%).

  3. **Bounded memory, flat summary cost.** A long-horizon single-stage
     workflow (20k instances quick / 100k full) runs with
     ``evict_completed=True`` and ``log_tasks=False``: the recorded row
     shows retained records at 0 at the end of the run, the per-stage
     stat footprint constant, and ``summary()`` costing the same after
     100k instances as after 1k — the O(1) metrics hot path at
     million-event scale.
"""
import time

from .common import emit
from .fig8_batching import (DEADLINES, PER_SLOT_INSTANCES, PER_SLOT_RATE,
                            RATE_MULTS, SLOTS, WINDOWS_MS, run_config)

LONG_HORIZON_QUICK = 20_000
LONG_HORIZON_FULL = 100_000
SKETCH_SAMPLES = 50_000
# the sustained-overload acceptance point: fig8's FULL scale (4x the
# quick per-slot instance count) at the 8x overload rate, where the
# planner used to trail the best static window by ~13% before the
# drain-rate/economic-hold terms (see AdaptiveBatchPolicy.unit_window /
# gap_window / hold_gain)
FULLSCALE_PER_SLOT = 4 * PER_SLOT_INSTANCES
FULLSCALE_SHAPE, FULLSCALE_RATE = "rag", 8


def run_adaptive(shape: str, rate_x: int, slots: int = SLOTS,
                 n_instances: int = None, seed: int = 0, tracing=False):
    """One ``atomic+abatch`` run — same stream as ``fig8.run_config``."""
    from repro.workflows import (WORKFLOW_SHAPES, WorkflowRuntime,
                                 mode_kwargs, preload_index)
    graph = WORKFLOW_SHAPES[shape](shards=slots)
    wrt = WorkflowRuntime(graph, seed=seed, tracing=tracing,
                          **mode_kwargs("atomic+abatch"))
    if shape == "rag":
        preload_index(wrt)
    rate = PER_SLOT_RATE * rate_x * slots
    n = n_instances if n_instances is not None else \
        PER_SLOT_INSTANCES * slots
    for i in range(n):
        wrt.submit(f"req{i}", at=0.05 + i / rate,
                   deadline=DEADLINES[shape])
    wrt.run()
    return wrt.summary()


def sketch_accuracy_rows():
    """Worst-case StageStats quantile error vs exact np.percentile."""
    import numpy as np

    from repro.runtime import StageStats
    rng = np.random.default_rng(0)
    streams = {
        "uniform": rng.uniform(1e-3, 1.0, SKETCH_SAMPLES),
        "exponential": rng.exponential(0.02, SKETCH_SAMPLES),
        "lognormal": rng.lognormal(-3.0, 0.8, SKETCH_SAMPLES),
        "trending": (rng.exponential(0.02, SKETCH_SAMPLES)
                     * np.linspace(1.0, 5.0, SKETCH_SAMPLES)),
    }
    rows = []
    for name, xs in streams.items():
        st = StageStats()
        for x in xs:
            st.observe(float(x))
        errs = {}
        for q in (0.5, 0.95, 0.99):
            exact = float(np.percentile(xs, q * 100))
            errs[f"relerr_p{round(q * 100)}"] = round(
                abs(st.quantile(q) - exact) / exact, 4)
        worst = max(errs.values())
        rows.append((f"fig9/sketch/{name}", worst * 1e6,
                     {**errs, "n": SKETCH_SAMPLES,
                      "within_5pct": worst < 0.05,
                      "buffered_samples": st.footprint()[0],
                      "bins": st.footprint()[1]}))
        assert worst < 0.05, (name, errs)
    return rows


def long_horizon_row(n_instances: int):
    """Bounded tracker memory + flat summary cost over a long horizon."""
    from repro.workflows import (Emit, WorkflowGraph, WorkflowRuntime,
                                 mode_kwargs)
    g = WorkflowGraph("pipe")
    g.add_tier("t", 4, {"gpu": 1, "cpu": 2, "nic": 2})
    g.add_pool("/in", tier="t", shards=4)
    g.add_pool("/out", tier="t", shards=4)
    g.add_stage("work", pool="/in", resource="gpu", cost=0.002,
                emits=[Emit("/out", fanout=1, size=1024)], sink=True)
    g.validate()
    wrt = WorkflowRuntime(g, seed=0, evict_completed=True, log_tasks=False,
                          **mode_kwargs("atomic+abatch"))
    rate = 4 * 400.0                      # ~0.8 utilization per slot gpu
    t0 = time.perf_counter()
    # interleave submission so the event heap never holds the whole
    # horizon at once (drive in chunks, like an open-loop client)
    chunk = 5_000
    checkpoint_ms = []
    retained_peak = 0
    for start in range(0, n_instances, chunk):
        for i in range(start, min(start + chunk, n_instances)):
            wrt.submit(f"i{i}", at=0.01 + i / rate, deadline=0.5)
        wrt.run(until=0.01 + min(start + chunk, n_instances) / rate)
        ts = time.perf_counter()
        wrt.summary()                     # the planner-era hot read
        checkpoint_ms.append((time.perf_counter() - ts) * 1e3)
        retained_peak = max(retained_peak, len(wrt.tracker.records))
    wrt.run()
    wall = time.perf_counter() - t0
    s = wrt.summary()
    st = wrt.tracker.stage_stats["work"]
    # summary cost flat: the last checkpoint (full horizon) must not cost
    # more than 2x the median checkpoint (first call pays numpy warmup,
    # so compare against the median, not the first)
    mid = sorted(checkpoint_ms)[len(checkpoint_ms) // 2]
    flat = checkpoint_ms[-1] <= 2.0 * mid + 0.5
    row = {
        "n": s["n"], "p99_ms": round(s["p99"] * 1e3, 3),
        "slo_miss": round(s.get("slo_miss_rate", 0.0), 4),
        "wall_s": round(wall, 2),
        "events": wrt.rt.sim.events_fired,
        "retained_records": len(wrt.tracker.records),
        "retained_peak": retained_peak,
        "retired": wrt.tracker.retired,
        "stage_stat_bins": st.footprint()[1],
        "stage_stat_buffered": st.footprint()[0],
        "summary_ms_median": round(mid, 3),
        "summary_ms_final": round(checkpoint_ms[-1], 3),
        "summary_cost_flat": flat,
        "task_log_len": len(wrt.rt.task_log),
    }
    assert row["retained_records"] == 0, row
    assert row["stage_stat_buffered"] == 0, row       # sketch-only mode
    assert row["task_log_len"] == 0, row
    return (f"fig9/long_horizon/{n_instances}", s["p99"] * 1e6, row)


def _blame_keys(s):
    """The flattened blame table a traced summary carries."""
    return {k: v for k, v in s.items() if k.startswith("blame_")}


def fullscale_rows():
    """The sustained-overload plateau: full-scale rag at 8x.

    Runs both fig8 static windows and the adaptive planner at
    ``FULLSCALE_PER_SLOT`` instances/slot and asserts adaptive p99 <=
    the best static — the regression gate for the queue-drain /
    economic-hold terms (the pre-term planner lost this point by ~13%).

    These runs are TRACED (tracing reproduces every latency
    byte-for-byte, so the committed p99 numbers are unaffected): each
    row carries its blame decomposition, and
    ``scripts/bench_explain.py`` diffs the adaptive row against the best
    static one to name the category behind the residual — the committed
    ``BLAME_fig9_rag8x.md`` table.
    """
    n = FULLSCALE_PER_SLOT * SLOTS
    rows = []
    static_p99 = {}
    for w in WINDOWS_MS[FULLSCALE_SHAPE]:
        s = run_config(FULLSCALE_SHAPE, "atomic+batch", FULLSCALE_RATE,
                       float(w), n_instances=n, tracing=True)
        static_p99[w] = s["p99"]
        rows.append((f"fig9/fullscale/{FULLSCALE_SHAPE}/"
                     f"{FULLSCALE_RATE}x/static{w}ms",
                     s["median"] * 1e6,
                     {"p99_ms": round(s["p99"] * 1e3, 2),
                      "n": s["n"], **_blame_keys(s)}))
    sa = run_adaptive(FULLSCALE_SHAPE, FULLSCALE_RATE, n_instances=n,
                      tracing=True)
    best = min(static_p99.values())
    le_best = sa["p99"] <= best + 1e-12
    rows.append((f"fig9/fullscale/{FULLSCALE_SHAPE}/"
                 f"{FULLSCALE_RATE}x/adaptive",
                 sa["median"] * 1e6,
                 {"p99_ms": round(sa["p99"] * 1e3, 2),
                  "best_static_ms": round(best * 1e3, 2),
                  "le_best_static": le_best,
                  "mean_batch": round(sa.get("mean_batch", 1.0), 2),
                  "saturated_plans": sa.get("saturated_plans", 0),
                  "n": sa["n"], **_blame_keys(sa)}))
    assert le_best, (sa["p99"], static_p99)
    return rows


def run(quick=True):
    rows = []
    t_sweep = time.perf_counter()
    all_le_best = True
    for shape in ("rag", "speech"):
        for rate_x in RATE_MULTS:
            static_p99 = {}
            for w in WINDOWS_MS[shape]:
                s = run_config(shape, "atomic+batch", rate_x, float(w))
                static_p99[w] = s["p99"]
                rows.append((f"fig9/{shape}/{rate_x}x/static{w}ms",
                             s["median"] * 1e6,
                             {"p99_ms": round(s["p99"] * 1e3, 2),
                              "slo_miss": round(
                                  s.get("slo_miss_rate", 0.0), 3)}))
            sa = run_adaptive(shape, rate_x)
            best = min(static_p99.values())
            le_best = sa["p99"] <= best + 1e-12
            all_le_best &= le_best
            derived = {
                "p99_ms": round(sa["p99"] * 1e3, 2),
                "best_static_ms": round(best * 1e3, 2),
                "le_best_static": le_best,
                "slo_miss": round(sa.get("slo_miss_rate", 0.0), 3),
                "plans": sa.get("plans", 0),
            }
            if "mean_batch" in sa:
                derived["mean_batch"] = round(sa["mean_batch"], 2)
            rows.append((f"fig9/{shape}/{rate_x}x/adaptive",
                         sa["median"] * 1e6, derived))
    rows.extend(fullscale_rows())
    rows.extend(sketch_accuracy_rows())
    rows.append(long_horizon_row(
        LONG_HORIZON_QUICK if quick else LONG_HORIZON_FULL))
    total = round(time.perf_counter() - t_sweep, 2)
    rows.append(("fig9/sweep_wall", total * 1e6,
                 {"wall_s": total, "adaptive_le_best_static_everywhere":
                  all_le_best}))
    # deterministic acceptance gate: the planner must never lose to the
    # best hand-tuned static window at any rate
    assert all_le_best, [r for r in rows if r[2].get("le_best_static")
                         is False]
    return rows


if __name__ == "__main__":
    emit(run())
