"""Cascade-like sharded K/V object store with affinity-grouped placement.

Mirrors the subset of Cascade (paper §4.2) the evaluation needs:

  * server nodes logically grouped into disjoint *shards*;
  * *object pools* identified by pathname prefixes, each with its own shard
    count/replication and (our extension, §4.3) an optional
    ``affinity_set_regex``;
  * ``put`` stores + replicates an object in its home shard and fires any
    registered UDL (user-defined logic) whose key prefix matches — tasks are
    routed to the SAME home shard, which is the unified data+compute
    placement the paper argues for;
  * ``trigger`` fires the UDL without storing; ``get`` fetches by key.

The store is *timeless*: it records what moved where (hits, misses, bytes),
and the discrete-event runtime (repro.runtime) charges transfer/queue time
around it.  The serving engine reuses it with real JAX buffers as values.
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .affinity import (AffinityFunction, Descriptor, InstrumentedAffinity,
                       NoAffinity, RegexAffinity, affinity_key_for)
from .placement import HashPlacement, PlacementEngine, PlacementPolicy


@dataclasses.dataclass
class ObjectRecord:
    key: str
    value: Any
    size: int
    version: int
    affinity: str


@dataclasses.dataclass
class StoreStats:
    puts: int = 0
    gets: int = 0
    local_gets: int = 0
    remote_gets: int = 0
    bytes_put: int = 0
    bytes_remote: int = 0
    triggers: int = 0
    replica_syncs: int = 0        # extra-replica write fan-outs
    bytes_replica_sync: int = 0
    migrations: int = 0           # group relocations (GroupMigrator)
    bytes_migrated: int = 0
    partition_blocked: int = 0    # reads with no reachable replica
    prefetch_installs: int = 0    # warm-up transfers that landed valid
    prefetch_stale: int = 0       # dropped: version moved / unreachable
    prefetch_hits: int = 0        # gets served from a prefetched entry
    bytes_prefetched: int = 0

    def as_dict(self):
        return dataclasses.asdict(self)


@dataclasses.dataclass
class GroupCounters:
    """Per-affinity-group load counters (hot-group detection input)."""
    pool: str
    label: str
    puts: int = 0
    gets: int = 0
    remote_gets: int = 0
    bytes_put: int = 0
    bytes_remote: int = 0

    @property
    def heat(self) -> float:
        """Remote access pressure used to rank groups.

        Local gets/puts are zero-copy and free — only remote traffic
        counts, so a perfectly collocated group has exactly 0 heat and
        the migrator provably leaves already-ideal placements alone
        (migration can then only fire where placement is causing real
        network cost).
        """
        return self.bytes_remote + 64.0 * self.remote_gets


class Shard:
    def __init__(self, name: str, nodes: Sequence[str]):
        self.name = name
        self.nodes = list(nodes)
        self.objects: Dict[str, ObjectRecord] = {}

    def __repr__(self):
        return f"Shard({self.name}, nodes={self.nodes}, n={len(self.objects)})"


class ObjectPool:
    """A pathname-prefixed resource partition with its own placement."""

    def __init__(self, prefix: str, shards: List[Shard],
                 affinity_fn: Optional[AffinityFunction],
                 policy: Optional[PlacementPolicy] = None):
        self.prefix = prefix.rstrip("/")
        self.shards = {s.name: s for s in shards}
        self.affinity_fn = (InstrumentedAffinity(affinity_fn)
                            if affinity_fn else None)
        self.engine = PlacementEngine(
            [s.name for s in shards],
            affinity_fn=self.affinity_fn,
            policy=policy or HashPlacement())
        # key -> label memo: valid whenever the affinity function is
        # key-pure (labels depend only on the key, never size/meta), which
        # holds for regex / instance / no-affinity pools.  Labels never
        # change for a given key, so no invalidation is needed.  NOTE:
        # hits bypass the InstrumentedAffinity wrapper, so the pool's
        # AffinityStats counts cache MISSES only (distinct keys) — the
        # per-call overhead microbenchmarks call the function directly.
        self._label_memo: Optional[Dict[str, str]] = (
            {} if (self.affinity_fn is None or self.affinity_fn.key_pure)
            else None)

    def descriptor(self, key: str, size: int = 0, **meta) -> Descriptor:
        # the affinity regex is matched against the key *inside* the pool
        rel = key[len(self.prefix):]
        return Descriptor.of(rel, size=size, full_key=key, **meta)

    def label_of(self, key: str, size: int = 0, **meta) -> str:
        """The placement label of ``key`` (memoized for key-pure pools)."""
        memo = self._label_memo
        if memo is not None:
            label = memo.get(key)
            if label is None:
                label = affinity_key_for(self.affinity_fn,
                                         self.descriptor(key))
                memo[key] = label
            return label
        return affinity_key_for(self.affinity_fn,
                                self.descriptor(key, size, **meta))

    def home(self, key: str, size: int = 0, **meta) -> Shard:
        label = self.label_of(key, size, **meta)
        return self.shards[self.engine.home_of(label)]

    def replica_homes(self, key: str, size: int = 0, **meta) -> List[Shard]:
        """All shards holding the key's group, primary first."""
        label = self.label_of(key, size, **meta)
        return [self.shards[s] for s in self.engine.replica_homes(label)]

    def affinity_of(self, key: str) -> str:
        return self.label_of(key)


@dataclasses.dataclass
class UDL:
    """User-defined logic bound to a key prefix (Cascade UDL framework)."""
    prefix: str
    fn: Callable[..., Any]            # fn(store, node, key, value) -> None
    name: str = ""


class CascadeStore:
    """The full store: pools + UDL registry + node-local caches."""

    def __init__(self, nodes: Sequence[str]):
        self.nodes = list(nodes)
        self.pools: Dict[str, ObjectPool] = {}
        self.udls: List[UDL] = []
        self.caches: Dict[str, Dict[str, ObjectRecord]] = {
            n: {} for n in self.nodes}
        # node -> {key: version installed by prefetch}; entries are
        # dropped the moment anything else touches the cache line
        # (demand fill, invalidation), so `prefetch_hits` only counts
        # reads the warm-up genuinely made local.
        self.prefetch_marks: Dict[str, Dict[str, int]] = {
            n: {} for n in self.nodes}
        self.cache_enabled = True
        self.stats = StoreStats()
        self.group_counters: Dict[Tuple[str, str], GroupCounters] = {}
        self._version = 0
        # directory -> pool memo for the hot put/get/trigger path; keys in
        # one directory always resolve to the same pool unless pool
        # prefixes nest, in which case the memo is disabled (see pool_for)
        self._pool_memo: Dict[str, ObjectPool] = {}
        self._nested_prefixes = False
        # active network partition (node -> group id, unlisted = group 0)
        # mirrored from the simulator by FaultInjector.partition; None
        # keeps the read path to a single predicate check.
        self.partition: Optional[Dict[str, int]] = None
        # one-shot flag: the last get returned None because the record
        # exists but every replica holding it is across the partition
        self.last_get_blocked = False

    # -- pool management (paper Listing 1) -----------------------------------

    def create_object_pool(self, prefix: str, nodes: Sequence[str],
                           n_shards: int, replication: int = 1,
                           affinity_set_regex: Optional[str] = None,
                           policy: Optional[PlacementPolicy] = None,
                           affinity_fn: Optional[AffinityFunction] = None
                           ) -> ObjectPool:
        assert prefix not in self.pools, prefix
        assert len(nodes) >= n_shards * replication, \
            (prefix, len(nodes), n_shards, replication)
        assert not (affinity_set_regex and affinity_fn), \
            "pass either affinity_set_regex or affinity_fn, not both"
        shards = []
        for i in range(n_shards):
            members = nodes[i * replication:(i + 1) * replication]
            shards.append(Shard(f"{prefix}#s{i}", members))
        fn = (RegexAffinity(affinity_set_regex) if affinity_set_regex
              else affinity_fn)
        pool = ObjectPool(prefix, shards, fn, policy)
        self.pools[prefix] = pool
        self._pool_memo.clear()
        self._nested_prefixes = any(
            a != b and b.startswith(a + "/")
            for a in self.pools for b in self.pools)
        return pool

    def pool_for(self, key: str) -> ObjectPool:
        # fast path: all keys under one directory share a pool (checked:
        # a hit is verified, and nesting pool prefixes disables the memo,
        # so the longest-prefix-wins rule below stays authoritative)
        memo_key = key.rpartition("/")[0] or key
        if not self._nested_prefixes:
            pool = self._pool_memo.get(memo_key)
            if pool is not None and (
                    key.startswith(pool.prefix + "/") or key == pool.prefix):
                return pool
        best = None
        for prefix, pool in self.pools.items():
            if key.startswith(prefix + "/") or key == prefix:
                if best is None or len(prefix) > len(best.prefix):
                    best = pool
        if best is None:
            raise KeyError(f"no object pool matches key {key!r}")
        if not self._nested_prefixes:
            self._pool_memo[memo_key] = best
        return best

    # -- UDLs ------------------------------------------------------------------

    def register_udl(self, prefix: str, fn: Callable[..., Any],
                     name: str = "") -> None:
        self.udls.append(UDL(prefix=prefix, fn=fn, name=name or prefix))

    def _matching_udls(self, key: str) -> List[UDL]:
        return [u for u in self.udls if key.startswith(u.prefix)]

    # -- data plane --------------------------------------------------------------

    def put(self, key: str, value: Any, size: Optional[int] = None,
            fire: bool = True, **meta) -> Tuple[Shard, List[UDL]]:
        """Store (replicated in home shard) and return shard + fired UDLs.

        The caller (runtime / serving engine) executes the returned UDLs on a
        node of the home shard — task placement follows data placement.
        """
        pool = self.pool_for(key)
        sz = size if size is not None else _sizeof(value)
        homes = pool.replica_homes(key, sz, **meta)
        shard = homes[0]
        self._version += 1
        rec = ObjectRecord(key=key, value=value, size=sz,
                           version=self._version,
                           affinity=pool.affinity_of(key))
        shard.objects[key] = rec
        self.stats.puts += 1
        self.stats.bytes_put += sz * max(len(shard.nodes), 1)
        pool.engine.record_load(shard.name, sz)
        # replica fan-out: ship the object to every extra replica shard
        for extra in homes[1:]:
            extra.objects[key] = rec
            self.stats.replica_syncs += 1
            self.stats.bytes_replica_sync += sz * max(len(extra.nodes), 1)
        if pool.affinity_fn is not None:
            # ungrouped pools can never be migrated — tracking a counter
            # per raw key would only grow detection/decay scans unboundedly
            g = self._counters(pool.prefix, rec.affinity)
            g.puts += 1
            g.bytes_put += sz
        fired = self._matching_udls(key) if fire else []
        return shard, fired

    def trigger(self, key: str, value: Any = None, size: int = 0,
                **meta) -> Tuple[Shard, List[UDL]]:
        """Route a task to the key's home shard without storing data."""
        pool = self.pool_for(key)
        shard = pool.home(key, size, **meta)
        self.stats.triggers += 1
        return shard, self._matching_udls(key)

    def get(self, key: str, node: Optional[str] = None
            ) -> Tuple[Optional[ObjectRecord], bool]:
        """Fetch by key from `node`. Returns (record, was_local).

        was_local is True when the record lives in the node's shard or its
        cache (Cascade zero-copy local get).  Under ``ReplicatedPlacement``
        the read is served by the *nearest* replica: a replica shard the
        node belongs to wins; otherwise any replica serves it remotely.
        The runtime charges network time for remote gets.
        """
        pool = self.pool_for(key)
        homes = pool.replica_homes(key)
        p = self.partition
        if p is not None:
            # reachability filter: a replica only serves readers on its
            # side of the cut, so a reachable (possibly non-home) replica
            # beats an unreachable home.  A record whose every holder is
            # across the cut blocks (flagged for the simulator to park
            # the read) instead of being invented missing.
            self.last_get_blocked = False
            rg = p.get(node, 0) if node is not None else 0
            reach = [h for h in homes
                     if any(p.get(m, 0) == rg for m in h.nodes)]
            if len(reach) < len(homes):
                if not any(key in h.objects for h in reach) and \
                        any(key in h.objects for h in homes):
                    self.last_get_blocked = True
                    self.stats.partition_blocked += 1
                    self.stats.gets += 1
                    return None, False
                if reach:
                    homes = reach
        shard, rec = homes[0], None
        for h in homes:
            r = h.objects.get(key)
            if r is None:
                continue
            if rec is None or (node is not None and node in h.nodes):
                shard, rec = h, r
            if node is not None and node in h.nodes:
                break
        self.stats.gets += 1
        if rec is None:
            return None, False
        g = (self._counters(pool.prefix, rec.affinity)
             if pool.affinity_fn is not None else None)
        if g is not None:
            g.gets += 1
        local = node is not None and node in shard.nodes
        if not local and node is not None and self.cache_enabled:
            cached = self.caches[node].get(key)
            if cached is not None and cached.version == rec.version:
                self.stats.local_gets += 1
                if key in self.prefetch_marks[node]:
                    self.stats.prefetch_hits += 1
                return cached, True
        if local:
            self.stats.local_gets += 1
        else:
            self.stats.remote_gets += 1
            self.stats.bytes_remote += rec.size
            if g is not None:
                g.remote_gets += 1
                g.bytes_remote += rec.size
            pool.engine.record_load(shard.name, rec.size)
            if node is not None and self.cache_enabled:
                self.caches[node][key] = rec
                self.prefetch_marks[node].pop(key, None)
        return rec, local

    def prefetch_install(self, node: str, key: str,
                         version: Optional[int] = None) -> int:
        """Land a completed warm-up transfer in ``node``'s cache.

        Returns the bytes installed, or 0 when the transfer is a no-op:
        the record vanished, the node holds it natively, caching is off,
        every holder is across an active partition, or — the correctness
        case — ``version`` (stamped at plan time) no longer matches the
        live record because a write/migration raced the transfer.  The
        version mismatch and unreachable cases count ``prefetch_stale``;
        nothing stale is ever installed.
        """
        if not self.cache_enabled:
            return 0
        try:
            pool = self.pool_for(key)
        except KeyError:
            return 0
        rec = None
        p = self.partition
        rg = p.get(node, 0) if p is not None else 0
        reachable = p is None
        for shard in pool.replica_homes(key):
            r = shard.objects.get(key)
            if r is None:
                continue
            if node in shard.nodes:
                return 0
            rec = r
            if p is not None and any(p.get(m, 0) == rg
                                     for m in shard.nodes):
                reachable = True
        if rec is None:
            return 0
        if not reachable or (version is not None
                             and rec.version != version):
            self.stats.prefetch_stale += 1
            return 0
        self.caches[node][key] = rec
        self.prefetch_marks[node][key] = rec.version
        self.stats.prefetch_installs += 1
        self.stats.bytes_prefetched += rec.size
        return rec.size

    def delete_prefix(self, prefix: str) -> int:
        n = 0
        for pool in self.pools.values():
            seen = set()
            for shard in pool.shards.values():
                doomed = [k for k in shard.objects if k.startswith(prefix)]
                for k in doomed:
                    del shard.objects[k]
                    if k not in seen:      # replicas count once
                        seen.add(k)
                        n += 1
        return n

    def invalidate_cached(self, keys: Sequence[str]) -> int:
        """Drop node-cache entries for `keys` (migration barrier)."""
        n = 0
        for cache in self.caches.values():
            for k in keys:
                if cache.pop(k, None) is not None:
                    n += 1
        for marks in self.prefetch_marks.values():
            for k in keys:
                marks.pop(k, None)
        return n

    # -- introspection -------------------------------------------------------------

    def shard_of(self, key: str) -> Shard:
        return self.pool_for(key).home(key)

    def affinity_of(self, key: str) -> str:
        return self.pool_for(key).affinity_of(key)

    def group_members(self, prefix: str, label: str) -> List[str]:
        pool = self.pools[prefix]
        out: List[str] = []
        seen = set()
        for shard in pool.shards.values():
            for k, r in shard.objects.items():
                if r.affinity == label and k not in seen:
                    seen.add(k)
                    out.append(k)
        return out

    def _counters(self, pool_prefix: str, label: str) -> GroupCounters:
        gid = (pool_prefix, label)
        g = self.group_counters.get(gid)
        if g is None:
            g = self.group_counters[gid] = GroupCounters(pool=pool_prefix,
                                                         label=label)
        return g


def _sizeof(value: Any) -> int:
    if value is None:
        return 0
    if hasattr(value, "nbytes"):
        return int(value.nbytes)
    if isinstance(value, (bytes, bytearray, str)):
        return len(value)
    return 64
