"""Benchmark runner — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full] [--only fig3,...]``
prints ``name,us_per_call,derived`` CSV rows per the harness contract,
writes a machine-readable ``benchmarks/artifacts/BENCH_<suite>.json`` per
suite (p50/p99/SLO-hit/wall-clock per config — the cross-PR perf record,
uploaded as a CI artifact), and exits non-zero if any suite raised, so a
broken figure fails CI instead of scrolling past on stderr.
"""
import argparse
import sys
import time

from . import (azure_mode, fig3_single_client, fig4_three_clients,
               fig5_no_caching, fig6_replication, fig7_workflows,
               fig8_batching, fig9_adaptive, fig10_elastic, fig11_chaos,
               fig12_serving_chaos, fig13_domains, fig14_prefetch,
               micro_affinity, roofline, serving_affinity)
from .common import (bench_regressions, emit, load_bench_json,
                     write_bench_json)

SUITES = {
    "fig3": fig3_single_client,
    "fig4": fig4_three_clients,
    "fig5": fig5_no_caching,
    "fig6": fig6_replication,
    "fig7": fig7_workflows,
    "fig8": fig8_batching,
    "fig9": fig9_adaptive,
    "fig10": fig10_elastic,
    "fig11": fig11_chaos,
    "fig12": fig12_serving_chaos,
    "fig13": fig13_domains,
    "fig14": fig14_prefetch,
    "azure": azure_mode,
    "micro": micro_affinity,
    "serving": serving_affinity,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale workloads (700 frames etc.)")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--strict", action="store_true",
                    help="fail (exit 1) on perf regressions vs the "
                         "committed BENCH records, beyond each metric's "
                         "tolerance; host wall clocks stay advisory")
    args = ap.parse_args()
    names = (args.only.split(",") if args.only else list(SUITES))
    failures = []
    strict_regs = []
    print("name,us_per_call,derived")
    for name in names:
        mod = SUITES[name]
        prior = load_bench_json(name)    # committed/previous record, if any
        t0 = time.perf_counter()
        try:
            rows = mod.run(quick=not args.full)
        except Exception as e:   # noqa: BLE001 — keep the suite going
            print(f"{name}/ERROR,0,{e!r}", file=sys.stderr)
            failures.append(name)
            continue
        wall = time.perf_counter() - t0
        emit(rows)
        path = write_bench_json(name, rows, wall)
        print(f"# {name}: {wall:.1f}s -> {path.name}", file=sys.stderr)
        # perf trajectory: per-metric deltas vs the prior record.
        # Warn-only by default; --strict (CI on the committed suites)
        # escalates non-wall regressions to a failing exit.  The
        # committed BENCH files + these lines ARE the cross-PR record.
        regs, compared = bench_regressions(name, prior, rows)
        for r in regs:
            tag = "PERF(wall)" if r["wall"] else "PERF"
            print(f"# {tag} {r['suite']} {r['name']} {r['metric']} "
                  f"{r['old']} -> {r['new']} (+{r['pct']:.1f}%)",
                  file=sys.stderr)
        if compared:
            print(f"# {name}: {compared} metric(s) compared vs prior "
                  f"record, {len(regs)} regressed", file=sys.stderr)
        strict_regs.extend(r for r in regs if not r["wall"])
    if args.strict and strict_regs:
        print(f"# STRICT: {len(strict_regs)} non-wall regression(s) vs "
              f"committed records", file=sys.stderr)
        sys.exit(1)
    if failures:
        print(f"# FAILED suites: {','.join(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
