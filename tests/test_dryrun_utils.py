"""Dry-run/roofline machinery: HLO parsing, skip rules, knob equivalence."""
import dataclasses as dc

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.shapes import SHAPES
from repro.models import build_model


# -- HLO collective parsing (pure text, no compile needed) --------------------

HLO_SAMPLE = """
  %all-reduce.5 = f32[16,4096,128]{2,1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %all-gather.2 = bf16[1024,512]{1,0} all-gather(%y), replica_groups=[16,16]<=[256] , dimensions={0}
  %rs = (f32[64]{0}, f32[64]{0}) reduce-scatter(%a, %b), replica_groups={{0,1}}
  %fusion.1 = f32[8]{0} fusion(%z)
"""


def test_collective_stats_parses_ops():
    from repro.launch.dryrun import collective_stats
    stats, total = collective_stats(HLO_SAMPLE, 256)
    assert set(stats) == {"all-reduce", "all-gather", "reduce-scatter"}
    ar = 16 * 4096 * 128 * 4
    assert stats["all-reduce"]["bytes"] == pytest.approx(2 * 3 / 4 * ar)
    ag = 1024 * 512 * 2
    assert stats["all-gather"]["bytes"] == pytest.approx(15 / 16 * ag)
    rs = 2 * 64 * 4
    assert stats["reduce-scatter"]["bytes"] == pytest.approx(1 * rs)
    assert total == sum(v["bytes"] for v in stats.values())


def test_group_size_formats():
    from repro.launch.dryrun import _group_size
    assert _group_size("replica_groups={{0,1,2,3},{4,5,6,7}}", 512) == 4
    assert _group_size("replica_groups=[8,64]<=[512]", 512) == 64
    assert _group_size("no groups here", 512) == 512


# -- grid skip rules ----------------------------------------------------------

def test_cell_grid_counts():
    cells = configs.cells()
    assert len(cells) == 40
    skips = [c for c in cells if c[2]]
    assert len(skips) == 9          # 8 long_500k + 1 hubert decode_32k
    assert len(configs.runnable_cells()) == 31


def test_skip_rules():
    assert configs.skip_reason("hubert-xlarge", "decode_32k")
    assert configs.skip_reason("granite-3-2b", "long_500k")
    assert configs.skip_reason("mamba2-780m", "long_500k") is None
    assert configs.skip_reason("recurrentgemma-9b", "long_500k") is None


# -- analytic model flops -------------------------------------------------------

def test_analytic_flops_orders():
    from repro.launch.dryrun import analytic_model_flops
    cfg = configs.get_config("granite-3-2b")
    train = analytic_model_flops(cfg, SHAPES["train_4k"])
    prefill = analytic_model_flops(cfg, SHAPES["prefill_32k"])
    decode = analytic_model_flops(cfg, SHAPES["decode_32k"])
    assert train > prefill > decode > 0
    # 6ND dominates: train ~ 6 * 2.5e9 * 1.05e6
    assert 0.5e16 < train < 5e16


def test_memory_model_terms():
    from repro.launch.roofline_model import tpu_memory_model
    cfg = configs.get_config("llama4-maverick-400b-a17b")
    dec = tpu_memory_model(cfg, SHAPES["decode_32k"])
    # MoE decode wall: touched experts dominate the per-step traffic
    assert dec["weights"] > dec["kv_state"]
    tr = tpu_memory_model(cfg, SHAPES["train_4k"])
    assert tr["total"] > dec["total"]


# -- beyond-paper knobs keep the math identical --------------------------------

def _loss(cfg, tokens):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return float(model.loss(params, {"tokens": tokens})[0])


@pytest.mark.parametrize("arch", ["granite-3-2b", "deepseek-v2-236b"])
def test_perf_knobs_preserve_loss(arch, rng):
    base = configs.get_smoke(arch)
    toks = jnp.asarray(rng.integers(0, base.vocab_size, (2, 16)), jnp.int32)
    l0 = _loss(base, toks)
    for knobs in (
        {"onehot_loss": True},
        {"moe_hoist_gather": False},
        {"attn_seq_shard": True},
        {"seq_parallel_residual": True},
        {"onehot_loss": True, "attn_seq_shard": True,
         "seq_parallel_residual": True, "moe_hoist_gather": False},
    ):
        l1 = _loss(dc.replace(base, **knobs), toks)
        assert l1 == pytest.approx(l0, abs=1e-5), knobs


def test_rulesets_registered():
    from repro.launch.dryrun import RULESETS
    for name in ("baseline", "opt_attnseq", "opt_train", "opt_train2",
                 "opt_moedec", "opt_all"):
        assert name in RULESETS
